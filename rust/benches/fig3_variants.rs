//! Bench: regenerate Figure 3 (a–c) — the four HyTM variants
//! (RND / Fx / StAd / DyAd) on both kernels at the large scale.
//!
//! ```sh
//! cargo bench --bench fig3_variants
//! ```

use dyadhytm::coordinator::figures;

fn main() {
    let seed = 7;
    let t0 = std::time::Instant::now();
    for id in ["3a", "3b", "3c"] {
        let fig = figures::fig_by_name(id).expect("figure id");
        println!("{}", figures::render_figure(&fig, seed));
    }
    // The paper's §4 percentages at 28 threads.
    use dyadhytm::coordinator::figures::{sim_cell, Kernel};
    use dyadhytm::hytm::PolicySpec;
    let secs = |p, k| sim_cell(p, 28, 16, k, 1, seed).0;
    let dyad_b = secs(PolicySpec::DyAd { n: 43 }, Kernel::Both);
    let dyad_c = secs(PolicySpec::DyAd { n: 43 }, Kernel::Computation);
    println!("### Paper §4 deltas at 28 threads (paper -> ours)\n");
    println!("| vs | kernel | paper | ours |\n|---|---|---|---|");
    for (name, p) in [
        ("StAd", PolicySpec::StAd { n: 6 }),
        ("Fx", PolicySpec::Fx { n: 43 }),
        ("RND", PolicySpec::Rnd { lo: 1, hi: 50 }),
    ] {
        let both = (secs(p, Kernel::Both) / dyad_b - 1.0) * 100.0;
        let comp = (secs(p, Kernel::Computation) / dyad_c - 1.0) * 100.0;
        let paper = match name {
            "StAd" => ("1.4%", "4.2%"),
            "Fx" => ("3.81%", "21.8%"),
            _ => ("24.8%", "155.1%"),
        };
        println!("| DyAd vs {name} | both | {} | {both:.1}% |", paper.0);
        println!("| DyAd vs {name} | computation | {} | {comp:.1}% |", paper.1);
    }
    eprintln!("[fig3_variants: regenerated in {:?}]", t0.elapsed());
}
