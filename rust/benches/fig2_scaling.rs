//! Bench: regenerate Figure 2 (a–f) and the in-text T0 triple.
//!
//! For every Figure-2 policy × thread count × kernel × scale, run the
//! simulated 28-HT Broadwell and print the paper-shaped series, then the
//! headline speedup summary. (criterion is not in the offline registry;
//! this is a `harness = false` driver — wall time of the *simulation*
//! is incidental, the virtual seconds are the measurement.)
//!
//! ```sh
//! cargo bench --bench fig2_scaling
//! ```

use dyadhytm::coordinator::figures;

fn main() {
    let seed = 7;
    let t0 = std::time::Instant::now();
    for id in ["t0", "2a", "2b", "2c", "2d", "2e", "2f"] {
        let fig = figures::fig_by_name(id).expect("figure id");
        println!("{}", figures::render_figure(&fig, seed));
    }
    println!("{}", figures::render_headline(seed));
    eprintln!("[fig2_scaling: regenerated in {:?}]", t0.elapsed());
}
