//! Bench: regenerate Figure 4 (a–c) — per-thread HTM transactions,
//! retries, and STM fallbacks for the four HyTM variants.
//!
//! ```sh
//! cargo bench --bench fig4_stats
//! ```

use dyadhytm::coordinator::figures::{sim_cell, Kernel};
use dyadhytm::hytm::PolicySpec;

fn main() {
    let seed = 7;
    let scale = 16;
    let t0 = std::time::Instant::now();
    let variants = [
        ("rnd-hytm", PolicySpec::Rnd { lo: 1, hi: 50 }),
        ("fx-hytm", PolicySpec::Fx { n: 43 }),
        ("stad-hytm", PolicySpec::StAd { n: 6 }),
        ("dyad-hytm", PolicySpec::DyAd { n: 43 }),
    ];

    for (fig, title, metric) in [
        ("4a", "HTM transactions per thread", 0usize),
        ("4b", "HTM retries per thread", 1),
        ("4c", "STM transactions per thread", 2),
    ] {
        println!("### Figure {fig} — {title} (simulated, scale {scale}, both kernels)\n");
        print!("| policy \\ threads |");
        let threads = [4usize, 8, 12, 14, 16, 20, 24, 28];
        for t in threads {
            print!(" {t} |");
        }
        println!("\n|---|---|---|---|---|---|---|---|---|");
        for (name, p) in variants {
            print!("| {name} |");
            for t in threads {
                let (_, stats) = sim_cell(p, t, scale, Kernel::Both, 1, seed);
                let v = match metric {
                    0 => stats.hw_attempts_per_thread(),
                    1 => stats.hw_retries_per_thread(),
                    _ => stats.sw_commits_per_thread(),
                };
                print!(" {v:.0} |");
            }
            println!();
        }
        println!();
    }

    // The paper's scale-27 anchor: total retries at 28 threads
    // (161.4M / 171M / 6.95M / 6.78M for RND/Fx/StAd/DyAd).
    println!("### Total retries at 28 threads (paper scale 27: 161.4M / 171M / 6.95M / 6.78M)\n");
    println!("| policy | total retries (scale {scale}) |\n|---|---|");
    for (name, p) in variants {
        let (_, stats) = sim_cell(p, 28, scale, Kernel::Both, 1, seed);
        println!("| {name} | {} |", stats.total().hw_retries);
    }
    eprintln!("[fig4_stats: regenerated in {:?}]", t0.elapsed());
}
