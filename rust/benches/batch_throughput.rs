//! Bench: the speculative batch backend vs DyAdHyTM vs the coarse lock
//! on the SSCA-2 edge-insertion (generation) workload, plus a
//! block-size × conflict-rate sweep on the descriptor substrate.
//!
//! Prints markdown tables plus one machine-readable `BENCH_JSON` line
//! per cell (the same flat-JSON record shape the other `BENCH_*`
//! outputs use), so sweeps can be scraped with `grep '^BENCH_JSON'`.
//! Record kinds: `"bench":"batch_throughput"` (generation head-to-head)
//! and `"bench":"batch_block_sweep"` (block vs conflict rate).
//!
//! ```sh
//! cargo bench --bench batch_throughput
//! ```

use std::sync::Arc;
use std::time::Instant;

use dyadhytm::batch::{BatchReport, BatchSystem, BatchTxn};
use dyadhytm::graph::{generation, rmat, verify, Graph, Ssca2Config};
use dyadhytm::htm::HtmConfig;
use dyadhytm::hytm::{PolicySpec, TmSystem};
use dyadhytm::mem::{TxHeap, WORDS_PER_LINE};
use dyadhytm::tm::access::TxAccess;
use dyadhytm::util::rng::Rng;
use dyadhytm::util::zipf::Zipf;

/// Sweep the admission block size against the workload's conflict
/// skew: Zipf-s 0 spreads RMWs uniformly over the lines, s = 1.5
/// concentrates them on a few hubs. Emits one `batch_block_sweep`
/// BENCH_JSON record per cell so the perf trajectory accumulates
/// comparable points across PRs.
fn block_conflict_sweep() {
    const SWEEP_TXNS: usize = 4096;
    const LINES: usize = 64;
    const WORKERS: usize = 4;

    println!("\n### batch_throughput — block size vs conflict rate (Zipf RMW substrate, {WORKERS} workers)\n");
    println!("| block | zipf_s | txns | elapsed ms | txns/s | executions | validation_aborts | dependencies | conflict_rate |");
    println!("|---|---|---|---|---|---|---|---|---|");

    for &block in &[256usize, 1024, 4096] {
        for &zipf_s in &[0.0f64, 0.8, 1.5] {
            let mut rng = Rng::new(0xB10C ^ block as u64 ^ (zipf_s * 8.0) as u64);
            let zipf = Zipf::new(LINES - 1, zipf_s);
            // Two Zipf-drawn RMW lines + one read line per txn: the
            // hub-counter shape of the generation kernel, skew-tunable.
            let txns: Vec<BatchTxn> = (0..SWEEP_TXNS)
                .map(|_| {
                    let w1 = (1 + zipf.sample(&mut rng)) * WORDS_PER_LINE;
                    let w2 = (1 + zipf.sample(&mut rng)) * WORDS_PER_LINE;
                    let r = (1 + zipf.sample(&mut rng)) * WORDS_PER_LINE;
                    let salt = rng.next_u64();
                    BatchTxn::new(move |t: &mut dyn TxAccess| {
                        let mut acc = salt ^ t.read(r)?;
                        let v = t.read(w1)?;
                        acc = acc.rotate_left(13).wrapping_add(v);
                        t.write(w1, acc)?;
                        let v2 = t.read(w2)?;
                        t.write(w2, acc ^ v2)
                    })
                })
                .collect();

            let heap = TxHeap::new(LINES * WORDS_PER_LINE);
            let t0 = Instant::now();
            let mut report = BatchReport::default();
            let mut j0 = 0;
            while j0 < txns.len() {
                let j1 = (j0 + block).min(txns.len());
                report.merge(&BatchSystem::run(&heap, &txns[j0..j1], WORKERS));
                j0 = j1;
            }
            let elapsed = t0.elapsed();
            let tps = SWEEP_TXNS as f64 / elapsed.as_secs_f64().max(1e-9);
            let conflict_rate =
                report.validation_aborts as f64 / report.executions.max(1) as f64;
            println!(
                "| {block} | {zipf_s} | {SWEEP_TXNS} | {:.1} | {:.0} | {} | {} | {} | {:.4} |",
                elapsed.as_secs_f64() * 1e3,
                tps,
                report.executions,
                report.validation_aborts,
                report.dependencies,
                conflict_rate,
            );
            println!(
                "BENCH_JSON {{\"bench\":\"batch_block_sweep\",\"block\":{block},\
                 \"zipf_s\":{zipf_s},\"workers\":{WORKERS},\"txns\":{SWEEP_TXNS},\
                 \"elapsed_ns\":{},\"txns_per_sec\":{:.0},\"executions\":{},\
                 \"validations\":{},\"validation_aborts\":{},\"dependencies\":{},\
                 \"conflict_rate\":{:.4}}}",
                elapsed.as_nanos(),
                tps,
                report.executions,
                report.validations,
                report.validation_aborts,
                report.dependencies,
                conflict_rate,
            );
        }
    }
}

fn main() {
    let scale = 12u32;
    let seed = 0x55CA_2017u64;
    let t0 = std::time::Instant::now();
    let variants = [
        PolicySpec::Batch { block: 2048 },
        PolicySpec::DyAd { n: 43 },
        PolicySpec::CoarseLock,
    ];

    println!(
        "### batch_throughput — SSCA-2 generation kernel, live (scale {scale}, edge factor 8)\n"
    );
    println!("| policy | threads | edges | elapsed ms | edges/s | commits | sw_aborts |");
    println!("|---|---|---|---|---|---|---|");

    for &threads in &[1usize, 2, 4, 8] {
        for policy in variants {
            let cfg = Ssca2Config::new(scale).with_seed(seed);
            let g = Graph::alloc(cfg);
            let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
            let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
            let (elapsed, stats) = generation::run(&sys, &g, &tuples, policy, threads, seed);
            verify::check_graph(&g, &tuples)
                .unwrap_or_else(|e| panic!("{} corrupted the graph: {e}", policy.name()));
            let total = stats.total();
            let eps = tuples.len() as f64 / elapsed.as_secs_f64().max(1e-9);
            println!(
                "| {} | {threads} | {} | {:.1} | {:.0} | {} | {} |",
                policy.name(),
                tuples.len(),
                elapsed.as_secs_f64() * 1e3,
                eps,
                total.total_commits(),
                total.sw_aborts,
            );
            println!(
                "BENCH_JSON {{\"bench\":\"batch_throughput\",\"kernel\":\"generation\",\
                 \"policy\":\"{}\",\"scale\":{scale},\"threads\":{threads},\"edges\":{},\
                 \"elapsed_ns\":{},\"edges_per_sec\":{:.0},\"commits\":{},\"sw_aborts\":{}}}",
                policy.name(),
                tuples.len(),
                elapsed.as_nanos(),
                eps,
                total.total_commits(),
                total.sw_aborts,
            );
        }
    }
    block_conflict_sweep();
    eprintln!("[batch_throughput: finished in {:?}]", t0.elapsed());
}
