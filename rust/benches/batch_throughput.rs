//! Bench: the speculative batch backend vs DyAdHyTM vs the coarse lock
//! on the SSCA-2 edge-insertion (generation) workload, plus a
//! **window × block × skew** sweep on the descriptor substrate that
//! A/Bs the **lock-free multi-version store against the sharded-mutex
//! baseline**, the **admission barrier against the W-deep pipelined
//! session** (per cell: `steal_rate`, `overlap_ratio`,
//! `locality_steal_ratio`, `window_occupancy`), and measures where the
//! **adaptive block controller** (block size co-tuned with window
//! depth) converges relative to the best fixed cell.
//!
//! Prints markdown tables plus one machine-readable `BENCH_JSON` line
//! per cell (the same flat-JSON record shape the other `BENCH_*`
//! outputs use), so sweeps can be scraped with `grep '^BENCH_JSON'`.
//! Record kinds: `"bench":"batch_throughput"` (generation head-to-head)
//! and `"bench":"batch_block_sweep"` (window × block vs conflict rate,
//! one record per (store, window, block, skew) cell plus one per
//! adaptive run).
//!
//! An `obs A/B` cell runs the same workload with telemetry fully off
//! vs tracing + latency timing on, exercising the telemetry plane's
//! overhead contract (`dyadhytm::obs`) end to end.
//!
//! The sweep additionally writes the stable perf-trajectory file
//! **`BENCH_batch.json`** at the repository root: a JSON array of
//! `{policy, window, block, conflict, txns_per_sec, steal_rate,
//! overlap_ratio, locality_steal_ratio, window_occupancy,
//! lat_p50_ns, lat_p90_ns, lat_p99_ns, ...}`
//! records (`policy` is `batch` for the barrier lock-free store,
//! `batch-mutex` for the sharded-mutex baseline, `batch-pipelined` for
//! the cross-block-overlapping session at each window depth,
//! `batch-adaptive` for the controller run, whose `block`/`window` are
//! the converged values, and `serve-ingest` / `serve-mixed` for the
//! continuous-serving session cells — the mixed cell's `lat_*` columns
//! hold the abort-free snapshot-read serving percentiles). CI runs the
//! bench in smoke mode
//! (`BENCH_SMOKE=1`, smaller sizes), **fails the run if the sweep
//! produced no records** (an empty `[]` would otherwise upload as a
//! "successful" artifact), and uploads the file.
//!
//! ```sh
//! cargo bench --bench batch_throughput          # full sizes
//! BENCH_SMOKE=1 cargo bench --bench batch_throughput
//! ```

use std::sync::Arc;
use std::time::Instant;

use dyadhytm::batch::adaptive::BlockSizeController;
use dyadhytm::batch::workload::run_txns_pipelined;
use dyadhytm::batch::{set_reclaim, BatchReport, BatchSystem, BatchTxn};
use dyadhytm::graph::{generation, rmat, verify, Graph, Ssca2Config};
use dyadhytm::htm::HtmConfig;
use dyadhytm::hytm::{PolicySpec, TmSystem};
use dyadhytm::mem::{TxHeap, WORDS_PER_LINE};
use dyadhytm::tm::access::TxAccess;
use dyadhytm::util::rng::Rng;
use dyadhytm::util::zipf::Zipf;

fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

/// One sweep cell's outcome, destined for `BENCH_batch.json`.
struct SweepRec {
    policy: &'static str,
    /// Pipelining window depth (1 for the barrier cells).
    window: usize,
    block: usize,
    zipf_s: f64,
    workers: usize,
    conflict: f64,
    txns_per_sec: f64,
    /// Deque steals per execution (worker-runtime load balance).
    steal_rate: f64,
    /// Overlapped executions per execution (cross-block pipelining;
    /// 0 for barrier cells by construction).
    overlap_ratio: f64,
    /// Fraction of steals served by a same-locality-group victim
    /// (1.0 on flat topologies / when nothing was stolen).
    locality_steal_ratio: f64,
    /// Mean blocks in flight at admission (the W-deep window's
    /// utilization; 0 for barrier cells, which admit no window).
    window_occupancy: f64,
    /// Winning execution-attempt latency percentiles (log2-bucket
    /// upper bounds, ns) — the sweep runs with `obs::set_timing(true)`
    /// so the per-worker histograms fill.
    lat_p50_ns: u64,
    lat_p90_ns: u64,
    lat_p99_ns: u64,
    /// Peak live recorded-set cells in the session's reclamation
    /// domain (0 for barrier cells, which have no domain).
    mv_live_cells: u64,
    /// Peak bump-arena footprint of the version store, bytes.
    arena_bytes: u64,
    /// Recorded-set cells freed per admitted block — the reclamation
    /// keep-up rate (0 when reclamation is off or barrier-only).
    reclaimed_per_block: f64,
}

impl SweepRec {
    fn from_report(
        policy: &'static str,
        window: usize,
        block: usize,
        zipf_s: f64,
        workers: usize,
        report: &BatchReport,
        txns_per_sec: f64,
    ) -> Self {
        let execs = report.executions.max(1) as f64;
        Self {
            policy,
            window,
            block,
            zipf_s,
            workers,
            conflict: report.validation_aborts as f64 / execs,
            txns_per_sec,
            steal_rate: report.steals as f64 / execs,
            overlap_ratio: report.overlapped_txns as f64 / execs,
            locality_steal_ratio: report.locality_steal_ratio(),
            window_occupancy: report.window_occupancy(),
            lat_p50_ns: report.txn_lat.p50(),
            lat_p90_ns: report.txn_lat.p90(),
            lat_p99_ns: report.txn_lat.p99(),
            mv_live_cells: report.mv_live_cells,
            arena_bytes: report.arena_bytes,
            reclaimed_per_block: report.mv_reclaimed as f64
                / report.window_admissions.max(1) as f64,
        }
    }

    fn to_json(&self) -> String {
        format!(
            "{{\"policy\":\"{}\",\"window\":{},\"block\":{},\"conflict\":{:.4},\
             \"txns_per_sec\":{:.0},\"zipf_s\":{},\"workers\":{},\
             \"steal_rate\":{:.4},\"overlap_ratio\":{:.4},\
             \"locality_steal_ratio\":{:.4},\"window_occupancy\":{:.4},\
             \"lat_p50_ns\":{},\"lat_p90_ns\":{},\"lat_p99_ns\":{},\
             \"mv_live_cells\":{},\"arena_bytes\":{},\"reclaimed_per_block\":{:.1}}}",
            self.policy,
            self.window,
            self.block,
            self.conflict,
            self.txns_per_sec,
            self.zipf_s,
            self.workers,
            self.steal_rate,
            self.overlap_ratio,
            self.locality_steal_ratio,
            self.window_occupancy,
            self.lat_p50_ns,
            self.lat_p90_ns,
            self.lat_p99_ns,
            self.mv_live_cells,
            self.arena_bytes,
            self.reclaimed_per_block,
        )
    }
}

/// Two Zipf-drawn RMW lines + one read line per txn: the hub-counter
/// shape of the generation kernel, skew-tunable. Deterministic per
/// (skew, count): identical bodies for every store/controller variant.
fn sweep_txns(zipf_s: f64, n: usize, lines: usize) -> Vec<BatchTxn<'static>> {
    let mut rng = Rng::new(0xB10C ^ (zipf_s * 8.0) as u64);
    let zipf = Zipf::new(lines - 1, zipf_s);
    (0..n)
        .map(|_| {
            let w1 = (1 + zipf.sample(&mut rng)) * WORDS_PER_LINE;
            let w2 = (1 + zipf.sample(&mut rng)) * WORDS_PER_LINE;
            let r = (1 + zipf.sample(&mut rng)) * WORDS_PER_LINE;
            let salt = rng.next_u64();
            BatchTxn::new(move |t: &mut dyn TxAccess| {
                let mut acc = salt ^ t.read(r)?;
                let v = t.read(w1)?;
                acc = acc.rotate_left(13).wrapping_add(v);
                t.write(w1, acc)?;
                let v2 = t.read(w2)?;
                t.write(w2, acc ^ v2)
            })
        })
        .collect()
}

fn run_fixed(
    txns: &[BatchTxn<'_>],
    heap_words: usize,
    block: usize,
    workers: usize,
    mutex_baseline: bool,
) -> (BatchReport, f64) {
    let heap = TxHeap::new(heap_words);
    let t0 = Instant::now();
    let mut report = BatchReport::default();
    let mut j0 = 0;
    while j0 < txns.len() {
        let j1 = (j0 + block).min(txns.len());
        let r = if mutex_baseline {
            BatchSystem::run_baseline_mutex(&heap, &txns[j0..j1], workers)
        } else {
            BatchSystem::run(&heap, &txns[j0..j1], workers)
        };
        report.merge(&r);
        j0 = j1;
    }
    let tps = txns.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    (report, tps)
}

/// Sweep the pipelining window and admission block size against the
/// workload's conflict skew: Zipf-s 0 spreads RMWs uniformly over the
/// lines, s = 1.5 concentrates them on a few hubs. Each (block, skew)
/// cell runs the barrier executor on both stores **and** the
/// cross-block pipelined session at window depths {2, 3, 4} (the
/// barrier-vs-W-deep A/B), emitting `steal_rate`, `overlap_ratio`,
/// `locality_steal_ratio`, and `window_occupancy` per cell; each skew
/// additionally runs the adaptive controller (block co-tuned with
/// window). Returns the records for `BENCH_batch.json`.
fn block_conflict_sweep() -> Vec<SweepRec> {
    let sweep_txn_count: usize = if smoke() { 4096 } else { 16384 };
    const LINES: usize = 64;
    const WORKERS: usize = 4;
    let heap_words = LINES * WORDS_PER_LINE;
    let blocks = [256usize, 1024, 4096];
    let skews = [0.0f64, 0.8, 1.5];
    let windows = [2usize, 3, 4];

    println!(
        "\n### batch_throughput — window x block vs conflict rate, barrier vs pipelined \
         (Zipf RMW substrate, {WORKERS} workers, {sweep_txn_count} txns)\n"
    );
    println!("| store | window | block | zipf_s | txns/s | executions | validation_aborts | dependencies | conflict_rate | steal_rate | overlap_ratio | locality_steal_ratio | window_occupancy |");
    println!("|---|---|---|---|---|---|---|---|---|---|---|---|---|");

    let emit = |policy: &'static str,
                    window: usize,
                    block: usize,
                    zipf_s: f64,
                    report: &BatchReport,
                    tps: f64,
                    records: &mut Vec<SweepRec>| {
        let rec = SweepRec::from_report(policy, window, block, zipf_s, WORKERS, report, tps);
        println!(
            "| {policy} | {window} | {block} | {zipf_s} | {tps:.0} | {} | {} | {} | {:.4} | {:.4} | {:.4} | {:.4} | {:.4} |",
            report.executions,
            report.validation_aborts,
            report.dependencies,
            rec.conflict,
            rec.steal_rate,
            rec.overlap_ratio,
            rec.locality_steal_ratio,
            rec.window_occupancy,
        );
        println!(
            "BENCH_JSON {{\"bench\":\"batch_block_sweep\",\"store\":\"{policy}\",\
             \"window\":{window},\"block\":{block},\"zipf_s\":{zipf_s},\
             \"workers\":{WORKERS},\"txns\":{sweep_txn_count},\
             \"txns_per_sec\":{tps:.0},\
             \"executions\":{},\"validations\":{},\"validation_aborts\":{},\
             \"dependencies\":{},\"conflict_rate\":{:.4},\"steal_rate\":{:.4},\
             \"overlap_ratio\":{:.4},\"locality_steal_ratio\":{:.4},\
             \"window_occupancy\":{:.4}}}",
            report.executions,
            report.validations,
            report.validation_aborts,
            report.dependencies,
            rec.conflict,
            rec.steal_rate,
            rec.overlap_ratio,
            rec.locality_steal_ratio,
            rec.window_occupancy,
        );
        records.push(rec);
    };

    let mut records = Vec::new();
    for &zipf_s in &skews {
        let txns = sweep_txns(zipf_s, sweep_txn_count, LINES);
        let mut best_fixed: Option<(usize, f64)> = None;
        for &block in &blocks {
            for (policy, mutex_baseline) in [("batch", false), ("batch-mutex", true)] {
                let (report, tps) =
                    run_fixed(&txns, heap_words, block, WORKERS, mutex_baseline);
                if !mutex_baseline
                    && best_fixed.map_or(true, |(_, best_tps)| tps > best_tps)
                {
                    best_fixed = Some((block, tps));
                }
                emit(policy, 1, block, zipf_s, &report, tps, &mut records);
            }

            // The pipelined A/B on the same substrate and block grid,
            // one cell per window depth: W-deep cross-block overlap
            // replaces the admission barrier. Transaction construction
            // happens before the clock starts, exactly as run_fixed's
            // prebuilt slice does.
            for &window in &windows {
                let pipe_txns = sweep_txns(zipf_s, sweep_txn_count, LINES);
                let heap = TxHeap::new(heap_words);
                let mut ctl = BlockSizeController::fixed(block).with_window(window);
                let t0 = Instant::now();
                let report = run_txns_pipelined(&heap, pipe_txns, WORKERS, &mut ctl);
                let tps = sweep_txn_count as f64 / t0.elapsed().as_secs_f64().max(1e-9);
                emit("batch-pipelined", window, block, zipf_s, &report, tps, &mut records);
            }
        }

        // The adaptive controller on the same substrate (pipelined at
        // the deepest window ceiling — the shipped configuration for
        // `--policy batch=adaptive:window=4`), bounded by the sweep's
        // own grid so "converged" is comparable to "best fixed".
        // Construction again stays outside the timed region.
        let adaptive_txns = sweep_txns(zipf_s, sweep_txn_count, LINES);
        let heap = TxHeap::new(heap_words);
        let mut ctl = BlockSizeController::with_bounds(
            blocks[1],
            blocks[0],
            blocks[blocks.len() - 1],
            BlockSizeController::GROW_STEP,
        )
        .with_window(windows[windows.len() - 1]);
        let t0 = Instant::now();
        let report = run_txns_pipelined(&heap, adaptive_txns, WORKERS, &mut ctl);
        let tps = sweep_txn_count as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        let converged = ctl.current();
        emit(
            "batch-adaptive",
            ctl.current_window(),
            converged,
            zipf_s,
            &report,
            tps,
            &mut records,
        );
        println!(
            "> zipf {zipf_s}: adaptive converged to block {converged}, window {} \
             ({} grows, {} shrinks; {} window grows, {} window shrinks{})",
            ctl.current_window(),
            ctl.grows,
            ctl.shrinks,
            ctl.window_grows,
            ctl.window_shrinks,
            best_fixed
                .map(|(b, _)| format!("; best fixed lock-free block: {b}"))
                .unwrap_or_default()
        );
    }

    // Headlines of the sweep: what the lock-free hot path buys over the
    // mutex store, and what cross-block pipelining buys over the
    // admission barrier, per conflict regime.
    for &zipf_s in &skews {
        let speedup = |policy: &str| {
            records
                .iter()
                .filter(|r| r.policy == policy && r.zipf_s == zipf_s)
                .map(|r| r.txns_per_sec)
                .fold(0.0f64, f64::max)
        };
        let lockfree = speedup("batch");
        let mutex = speedup("batch-mutex");
        let pipelined = speedup("batch-pipelined");
        if mutex > 0.0 {
            println!(
                "> zipf {zipf_s}: lock-free store {:.2}x vs mutex baseline \
                 (best-block txns/s {lockfree:.0} vs {mutex:.0})",
                lockfree / mutex
            );
        }
        if lockfree > 0.0 {
            let max_overlap = records
                .iter()
                .filter(|r| r.policy == "batch-pipelined" && r.zipf_s == zipf_s)
                .map(|r| r.overlap_ratio)
                .fold(0.0f64, f64::max);
            println!(
                "> zipf {zipf_s}: pipelined {:.2}x vs barrier \
                 (best-cell txns/s {pipelined:.0} vs {lockfree:.0}, \
                 max overlap_ratio {max_overlap:.4})",
                pipelined / lockfree
            );
            // Which window depth won this skew, and how utilized it was.
            if let Some(best) = records
                .iter()
                .filter(|r| r.policy == "batch-pipelined" && r.zipf_s == zipf_s)
                .max_by(|a, b| a.txns_per_sec.total_cmp(&b.txns_per_sec))
            {
                println!(
                    "> zipf {zipf_s}: best pipelined cell window={} block={} \
                     (occupancy {:.2}, locality_steal_ratio {:.2})",
                    best.window, best.block, best.window_occupancy,
                    best.locality_steal_ratio
                );
            }
        }
    }
    records
}

/// A/B the reclamation overhead contract: the same pipelined cell
/// (zipf 0, block 1024, window 3 — the uncontended regime where any
/// reclamation cost would show as pure overhead) with epoch
/// reclamation on vs off. The contract (ISSUE 9): the on cell's
/// throughput must not trail the off cell's — retire + epoch advance
/// + limbo frees ride the promotion path, off the per-transaction hot
/// path — while its live-cell peak stays bounded and the off cell's
/// grows with the stream. Both cells land in `BENCH_batch.json` under
/// their own policy names so the CI throughput-delta gate tracks them.
fn reclaim_overhead_ab(records: &mut Vec<SweepRec>) {
    let n: usize = if smoke() { 4096 } else { 16384 };
    const LINES: usize = 64;
    const WORKERS: usize = 4;
    let heap_words = LINES * WORDS_PER_LINE;
    let (block, window, zipf_s) = (1024usize, 3usize, 0.0f64);

    let mut cell = |policy: &'static str, reclaim: bool| -> SweepRec {
        set_reclaim(reclaim);
        let txns = sweep_txns(zipf_s, n, LINES);
        let heap = TxHeap::new(heap_words);
        let mut ctl = BlockSizeController::fixed(block).with_window(window);
        let t0 = Instant::now();
        let report = run_txns_pipelined(&heap, txns, WORKERS, &mut ctl);
        let tps = n as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        SweepRec::from_report(policy, window, block, zipf_s, WORKERS, &report, tps)
    };
    let on = cell("batch-reclaim-on", true);
    let off = cell("batch-reclaim-off", false);
    set_reclaim(true);

    println!(
        "\n> reclaim A/B (block {block}, window {window}, zipf {zipf_s}, {WORKERS} workers, \
         {n} txns): on {:.0} txns/s (live peak {} cells, {:.1} reclaimed/block) vs \
         off {:.0} txns/s (live peak {} cells, arena {} B)",
        on.txns_per_sec,
        on.mv_live_cells,
        on.reclaimed_per_block,
        off.txns_per_sec,
        off.mv_live_cells,
        off.arena_bytes,
    );
    println!("BENCH_JSON {}", on.to_json());
    println!("BENCH_JSON {}", off.to_json());
    records.push(on);
    records.push(off);
}

/// Continuous-serving cells: one long-lived `ServeSession` per cell,
/// four producers streaming tenant-partitioned edge/bridge mutations
/// through the bounded ingress into the pipelined window.
/// `serve-ingest` is the write-only baseline; `serve-mixed` overlays a
/// concurrent snapshot reader querying every tenant (degree +
/// neighborhood off one pinned horizon per pass). Both cells land in
/// `BENCH_batch.json` under their own policy names — the CI
/// throughput-delta gate tracks serving regressions like any other
/// cell — with the **mixed cell's `lat_*` columns carrying the
/// snapshot-read serving percentiles** (p50/p90/p99 of the abort-free
/// read path) rather than write-path execution latency.
fn serve_cells(records: &mut Vec<SweepRec>) {
    use dyadhytm::serve::{Op, ServeConfig, ServeSession, TenantLayout};

    const WORKERS: usize = 4;
    const PRODUCERS: usize = 4;
    const TENANTS: usize = 4;
    const VERTS: usize = 64;
    let per_producer: usize = if smoke() { 2048 } else { 8192 };
    let (window, block) = (3usize, 1024usize);
    let lay = TenantLayout::new(TENANTS, VERTS, 8);
    let total = (PRODUCERS * per_producer) as u64;

    let mut cell = |policy: &'static str, with_reads: bool| -> SweepRec {
        let heap = lay.make_heap();
        let cfg = ServeConfig {
            producers: PRODUCERS,
            workers: WORKERS,
            window,
            block,
            queue_cap: 1024,
            ..ServeConfig::default()
        };
        let t0 = Instant::now();
        let (rep, _) = ServeSession::run(&heap, lay, &cfg, |h| {
            std::thread::scope(|s| {
                for p in 0..PRODUCERS {
                    s.spawn(move || {
                        let mut rng = Rng::new(0x5E12_0000 + p as u64);
                        for _ in 0..per_producer {
                            let t = rng.below(TENANTS as u64) as usize;
                            let u = rng.below(VERTS as u64) as usize;
                            let v = rng.below(VERTS as u64) as usize;
                            let op = if rng.below(8) == 0 {
                                Op::Bridge { from: t, to: (t + 1) % TENANTS, u, v }
                            } else {
                                Op::Edge { tenant: t, u, v }
                            };
                            h.submit(p, op).expect("producer closed early");
                        }
                        h.close_producer(p);
                    });
                }
                if with_reads {
                    // Concurrent reader on the session thread: one
                    // pinned snapshot per pass, every tenant queried,
                    // until the ingress has drained the full stream.
                    let mut rng = Rng::new(0x5EAD);
                    loop {
                        let snap = h.snapshot();
                        for t in 0..TENANTS {
                            let v = rng.below(VERTS as u64) as usize;
                            let _ = snap.degree(t, v);
                            let _ = snap.neighbors(t, v);
                        }
                        if h.status().drained >= total {
                            break;
                        }
                    }
                }
            });
        });
        let tps = total as f64 / t0.elapsed().as_secs_f64().max(1e-9);
        assert_eq!(
            rep.promoted_txns, total,
            "{policy}: exactly-once ingestion violated"
        );
        let mut rec =
            SweepRec::from_report(policy, window, block, 0.0, WORKERS, &rep.batch, tps);
        if with_reads {
            rec.lat_p50_ns = rep.read_lat.p50();
            rec.lat_p90_ns = rep.read_lat.p90();
            rec.lat_p99_ns = rep.read_lat.p99();
        }
        println!(
            "> {policy} (window {window}, block {block}, {PRODUCERS} producers, \
             {TENANTS} tenants, {total} ops): {tps:.0} ops/s, {} blocks, \
             reads {} (p99 {} ns), queue peak {}, snapshot age {} ns, \
             log live peak {} cells ({} reclaimed)",
            rep.promoted_blocks,
            rep.served_reads,
            rep.read_lat.p99(),
            rep.queue_depth_peak,
            rep.snapshot_age_ns,
            rep.log_live_peak_cells,
            rep.log_reclaimed_cells,
        );
        println!("BENCH_JSON {}", rec.to_json());
        rec
    };

    println!("\n### batch_throughput — continuous-serving session cells\n");
    let ingest = cell("serve-ingest", false);
    let mixed = cell("serve-mixed", true);
    let slowdown = ingest.txns_per_sec / mixed.txns_per_sec.max(1e-9);
    println!(
        "> serve read overlay cost: {slowdown:.3}x ingest slowdown with a \
         full-time snapshot reader (reads are abort-free: conflict rate \
         {:.4} mixed vs {:.4} ingest-only)",
        mixed.conflict, ingest.conflict,
    );
    records.push(ingest);
    records.push(mixed);
}

/// A/B the telemetry overhead contract end to end: the same Zipf-RMW
/// cell with telemetry fully off (no timestamps, trace sites reduce to
/// one relaxed load + branch) and with tracing + latency timing on.
/// Emits one `BENCH_JSON` record with both throughputs and their ratio;
/// the contract (documented in `dyadhytm::obs`) is that the "off" cell
/// pays no locks and no clock reads.
fn obs_overhead_ab() {
    let n: usize = if smoke() { 4096 } else { 16384 };
    const LINES: usize = 64;
    const WORKERS: usize = 4;
    let heap_words = LINES * WORDS_PER_LINE;

    dyadhytm::obs::set_timing(false);
    let txns_off = sweep_txns(0.8, n, LINES);
    let (_, tps_off) = run_fixed(&txns_off, heap_words, 1024, WORKERS, false);

    dyadhytm::obs::trace::enable(); // also turns latency timing on
    let txns_on = sweep_txns(0.8, n, LINES);
    let (report_on, tps_on) = run_fixed(&txns_on, heap_words, 1024, WORKERS, false);
    let traced = dyadhytm::obs::trace::drain().len();
    dyadhytm::obs::trace::disable();

    println!(
        "\n> obs A/B (block 1024, zipf 0.8, {WORKERS} workers, {n} txns): \
         off {tps_off:.0} txns/s vs on {tps_on:.0} txns/s \
         ({:.3}x, {traced} events traced, txn p50/p99 {} / {} ns)",
        tps_on / tps_off.max(1e-9),
        report_on.txn_lat.p50(),
        report_on.txn_lat.p99(),
    );
    println!(
        "BENCH_JSON {{\"bench\":\"batch_obs_ab\",\"block\":1024,\"zipf_s\":0.8,\
         \"workers\":{WORKERS},\"txns\":{n},\"txns_per_sec_off\":{tps_off:.0},\
         \"txns_per_sec_on\":{tps_on:.0},\"on_off_ratio\":{:.4},\
         \"events_traced\":{traced},\"lat_p50_ns\":{},\"lat_p99_ns\":{}}}",
        tps_on / tps_off.max(1e-9),
        report_on.txn_lat.p50(),
        report_on.txn_lat.p99(),
    );
}

/// Write the perf-trajectory file at the repo root (next to
/// `Cargo.toml`): a stable JSON array, one object per sweep cell.
/// An empty sweep is a bench bug, not a result — fail loudly instead
/// of writing the `[]` CI would silently upload as a "successful"
/// artifact.
fn write_bench_json(records: &[SweepRec]) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_batch.json");
    if records.is_empty() {
        eprintln!(
            "batch_throughput: sweep produced ZERO records — refusing to write an \
             empty {path}"
        );
        std::process::exit(1);
    }
    let body: Vec<String> = records.iter().map(|r| format!("  {}", r.to_json())).collect();
    let json = format!("[\n{}\n]\n", body.join(",\n"));
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nwrote {} records to {path}", records.len()),
        Err(e) => {
            eprintln!("failed to write {path}: {e}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let scale = if smoke() { 10u32 } else { 12 };
    let seed = 0x55CA_2017u64;
    let t0 = std::time::Instant::now();
    let variants = [
        PolicySpec::Batch { block: 2048 },
        PolicySpec::batch_adaptive(),
        PolicySpec::DyAd { n: 43 },
        PolicySpec::CoarseLock,
    ];

    println!(
        "### batch_throughput — SSCA-2 generation kernel, live (scale {scale}, edge factor 8)\n"
    );
    println!("| policy | threads | edges | elapsed ms | edges/s | commits | sw_aborts | final_block |");
    println!("|---|---|---|---|---|---|---|---|");

    for &threads in &[1usize, 2, 4, 8] {
        for policy in variants {
            let cfg = Ssca2Config::new(scale).with_seed(seed);
            let g = Graph::alloc(cfg);
            let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
            let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
            let (elapsed, stats) = generation::run(&sys, &g, &tuples, policy, threads, seed);
            verify::check_graph(&g, &tuples)
                .unwrap_or_else(|e| panic!("{} corrupted the graph: {e}", policy.name()));
            let total = stats.total();
            let eps = tuples.len() as f64 / elapsed.as_secs_f64().max(1e-9);
            println!(
                "| {} | {threads} | {} | {:.1} | {:.0} | {} | {} | {} |",
                policy.name(),
                tuples.len(),
                elapsed.as_secs_f64() * 1e3,
                eps,
                total.total_commits(),
                total.sw_aborts,
                total.final_block,
            );
            println!(
                "BENCH_JSON {{\"bench\":\"batch_throughput\",\"kernel\":\"generation\",\
                 \"policy\":\"{}\",\"scale\":{scale},\"threads\":{threads},\"edges\":{},\
                 \"elapsed_ns\":{},\"edges_per_sec\":{:.0},\"commits\":{},\"sw_aborts\":{},\
                 \"final_block\":{}}}",
                policy.name(),
                tuples.len(),
                elapsed.as_nanos(),
                eps,
                total.total_commits(),
                total.sw_aborts,
                total.final_block,
            );
        }
    }
    obs_overhead_ab();
    // The sweep itself runs with latency timing on so every record
    // carries real lat_p50/p90/p99 fields (tracing stays off: the
    // histograms live in BatchCounters, no rings needed).
    dyadhytm::obs::set_timing(true);
    let mut records = block_conflict_sweep();
    reclaim_overhead_ab(&mut records);
    serve_cells(&mut records);
    dyadhytm::obs::set_timing(false);
    write_bench_json(&records);
    eprintln!("[batch_throughput: finished in {:?}]", t0.elapsed());
}
