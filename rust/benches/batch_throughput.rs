//! Bench: the speculative batch backend vs DyAdHyTM vs the coarse lock
//! on the SSCA-2 edge-insertion (generation) workload.
//!
//! Prints a markdown table plus one machine-readable `BENCH_JSON` line
//! per cell (the same flat-JSON record shape the other `BENCH_*`
//! outputs use), so sweeps can be scraped with `grep '^BENCH_JSON'`.
//!
//! ```sh
//! cargo bench --bench batch_throughput
//! ```

use std::sync::Arc;

use dyadhytm::graph::{generation, rmat, verify, Graph, Ssca2Config};
use dyadhytm::htm::HtmConfig;
use dyadhytm::hytm::{PolicySpec, TmSystem};

fn main() {
    let scale = 12u32;
    let seed = 0x55CA_2017u64;
    let t0 = std::time::Instant::now();
    let variants = [
        PolicySpec::Batch { block: 2048 },
        PolicySpec::DyAd { n: 43 },
        PolicySpec::CoarseLock,
    ];

    println!(
        "### batch_throughput — SSCA-2 generation kernel, live (scale {scale}, edge factor 8)\n"
    );
    println!("| policy | threads | edges | elapsed ms | edges/s | commits | sw_aborts |");
    println!("|---|---|---|---|---|---|---|");

    for &threads in &[1usize, 2, 4, 8] {
        for policy in variants {
            let cfg = Ssca2Config::new(scale).with_seed(seed);
            let g = Graph::alloc(cfg);
            let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
            let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
            let (elapsed, stats) = generation::run(&sys, &g, &tuples, policy, threads, seed);
            verify::check_graph(&g, &tuples)
                .unwrap_or_else(|e| panic!("{} corrupted the graph: {e}", policy.name()));
            let total = stats.total();
            let eps = tuples.len() as f64 / elapsed.as_secs_f64().max(1e-9);
            println!(
                "| {} | {threads} | {} | {:.1} | {:.0} | {} | {} |",
                policy.name(),
                tuples.len(),
                elapsed.as_secs_f64() * 1e3,
                eps,
                total.total_commits(),
                total.sw_aborts,
            );
            println!(
                "BENCH_JSON {{\"bench\":\"batch_throughput\",\"kernel\":\"generation\",\
                 \"policy\":\"{}\",\"scale\":{scale},\"threads\":{threads},\"edges\":{},\
                 \"elapsed_ns\":{},\"edges_per_sec\":{:.0},\"commits\":{},\"sw_aborts\":{}}}",
                policy.name(),
                tuples.len(),
                elapsed.as_nanos(),
                eps,
                total.total_commits(),
                total.sw_aborts,
            );
        }
    }
    eprintln!("[batch_throughput: finished in {:?}]", t0.elapsed());
}
