//! Bench: live hot-path microbenchmarks (the §Perf measurement tool).
//!
//! Per-transaction wall costs of every engine on this machine, single
//! thread (the only configuration a 1-core box can measure honestly),
//! plus the policy-bookkeeping overheads the paper argues about:
//! RND's RNG draw vs DyAd's flag check vs Fx's nothing.
//!
//! ```sh
//! cargo bench --bench hotpath
//! ```

use std::sync::Arc;

use dyadhytm::htm::{HtmConfig, HtmEngine, HtmScratch};
use dyadhytm::hytm::{PolicySpec, ThreadExecutor, TmSystem};
use dyadhytm::mem::TxHeap;
use dyadhytm::stm::{NorecEngine, Tl2Engine};
use dyadhytm::tm::access::{TxAccess, TxResult};
use dyadhytm::util::rng::Rng;
use dyadhytm::util::timer::bench_ns;

const ITERS: usize = 30_000;
const WARMUP: usize = 3_000;

fn body(base: usize) -> impl FnMut(&mut dyn TxAccess) -> TxResult<()> {
    // The generation kernel's 2-read/6-write shape.
    move |t: &mut dyn TxAccess| {
        let a = t.read(base)?;
        let b = t.read(base + 8)?;
        t.write(base + 16, a)?;
        t.write(base + 17, b)?;
        t.write(base + 18, 1)?;
        t.write(base + 19, 2)?;
        t.write(base, a + 1)?;
        t.write(base + 8, b + 1)?;
        Ok(())
    }
}

fn main() {
    let heap = Arc::new(TxHeap::new(1 << 14));
    let base = heap.alloc_lines(4);

    println!("### Hot path: ns per 2r/6w transaction, single thread (live)\n");
    println!("| engine | median ns | p95 ns |");
    println!("|---|---|---|");

    // Raw engines.
    let htm = HtmEngine::new(Arc::clone(&heap), HtmConfig::broadwell());
    let mut rng = Rng::new(1);
    let mut b = body(base);
    let mut scratch = HtmScratch::new(htm.config());
    let s = bench_ns(WARMUP, ITERS, || {
        htm.attempt_with(&mut scratch, 0, &mut rng, None, &mut b)
            .unwrap();
    });
    println!("| software HTM attempt | {} | {} |", s.median, s.p95);

    let norec = NorecEngine::new(Arc::clone(&heap));
    let mut b = body(base);
    let s = bench_ns(WARMUP, ITERS, || {
        norec.attempt(&mut b).unwrap();
    });
    println!("| NOrec STM attempt | {} | {} |", s.median, s.p95);

    let tl2 = Tl2Engine::new(Arc::clone(&heap));
    let mut b = body(base);
    let s = bench_ns(WARMUP, ITERS, || {
        tl2.attempt(0, &mut b).unwrap();
    });
    println!("| TL2 STM attempt | {} | {} |", s.median, s.p95);

    // Full policy executors (uncontended): measures executor overhead.
    println!("\n### Full policy executors, uncontended (live)\n");
    println!("| policy | median ns | p95 ns | vs fx |");
    println!("|---|---|---|---|");
    // Measure the fx baseline first (the "vs fx" column's denominator).
    let fx_median = {
        let sys = TmSystem::new(Arc::clone(&heap), HtmConfig::broadwell());
        let mut ex = ThreadExecutor::new(&sys, PolicySpec::Fx { n: 43 }, 0, 9);
        let mut b = body(base);
        bench_ns(WARMUP, ITERS, || {
            ex.execute(&mut b);
        })
        .median
        .max(1)
    };
    for spec in [
        PolicySpec::CoarseLock,
        PolicySpec::StmNorec,
        PolicySpec::StmTl2,
        PolicySpec::HtmSpin { retries: 8 },
        PolicySpec::Hle,
        PolicySpec::Fx { n: 43 },
        PolicySpec::Rnd { lo: 1, hi: 50 },
        PolicySpec::StAd { n: 6 },
        PolicySpec::DyAd { n: 43 },
    ] {
        let sys = TmSystem::new(Arc::clone(&heap), HtmConfig::broadwell());
        let mut ex = ThreadExecutor::new(&sys, spec, 0, 9);
        let mut b = body(base);
        let s = bench_ns(WARMUP, ITERS, || {
            ex.execute(&mut b);
        });
        println!(
            "| {} | {} | {} | {:+.1}% |",
            spec.name(),
            s.median,
            s.p95,
            (s.median as f64 / fx_median as f64 - 1.0) * 100.0
        );
    }
    println!(
        "\n(\"low overhead\" claim: dyad-hytm vs fx-hytm should be within a few percent —\n\
         the only extra work is reading the abort cause.)"
    );
}
