//! Bench: the ablations DESIGN.md §5 calls out (A1–A3) plus the batch /
//! task-size sensitivity study.
//!
//! * A1 — DyAd's capacity-flag short-circuit ON (DyAd) vs OFF (Fx with
//!   the same quota): isolates the paper's actual mechanism.
//! * A2 — NOrec vs TL2 as the HyTM fallback STM.
//! * A3 — RND quota ranges (the paper's 1-20 / 20-50 / 50-100 DSE).
//! * A4 — task size (batch) sweep: when do capacity aborts start to
//!   dominate, and how does each policy cope?
//! * A5 — DyAdHyTM (per-transaction fallback) vs PhTM (phase-global
//!   switching), the paper's taxonomy class 2.
//! * A6 — SSCA-2 kernel 3 (multi-source BFS): policy sensitivity of a
//!   claim-heavy graph-traversal kernel.
//!
//! ```sh
//! cargo bench --bench ablation
//! ```

use dyadhytm::coordinator::figures::{sim_cell, Kernel};
use dyadhytm::hytm::PolicySpec;
use dyadhytm::sim::workload::TxnDesc;
use dyadhytm::sim::{CostModel, SimWorkload, Simulator};

const SEED: u64 = 7;
const SCALE: u32 = 16;

fn main() {
    let t0 = std::time::Instant::now();

    // -- A1: the capacity short-circuit ---------------------------------
    println!("### A1 — DyAd's flag adaptation on/off (same quota n=43, both kernels)\n");
    println!("| threads | Fx (flag OFF) s | DyAd (flag ON) s | saved |");
    println!("|---|---|---|---|");
    for t in [4usize, 14, 28] {
        let fx = sim_cell(PolicySpec::Fx { n: 43 }, t, SCALE, Kernel::Both, 1, SEED).0;
        let dy = sim_cell(PolicySpec::DyAd { n: 43 }, t, SCALE, Kernel::Both, 1, SEED).0;
        println!("| {t} | {fx:.3} | {dy:.3} | {:.1}% |", (fx / dy - 1.0) * 100.0);
    }

    // -- A2: fallback STM flavour ----------------------------------------
    println!("\n### A2 — HyTM fallback STM: NOrec vs TL2 (live, scale 10, 4 threads)\n");
    println!("| fallback | generation | computation |");
    println!("|---|---|---|");
    {
        use dyadhytm::graph::{computation, generation, rmat, Graph, Ssca2Config};
        use dyadhytm::htm::HtmConfig;
        use dyadhytm::hytm::TmSystem;
        use std::sync::Arc;
        for (name, spec) in [
            ("norec", PolicySpec::DyAd { n: 43 }),
            ("tl2", PolicySpec::DyAdTl2 { n: 43 }),
        ] {
            let cfg = Ssca2Config::new(10);
            let g = Graph::alloc(cfg);
            let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::tiny());
            let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
            let (gen_t, _) = generation::run(&sys, &g, &tuples, spec, 4, SEED);
            let comp = computation::run(&sys, &g, spec, 4, SEED);
            println!("| {name} | {gen_t:?} | {:?} |", comp.elapsed);
        }
    }

    // -- A3: RND ranges ----------------------------------------------------
    println!("\n### A3 — RNDHyTM quota ranges (sim, 28 threads, both kernels)\n");
    println!("| range | seconds | retries/thread |");
    println!("|---|---|---|");
    for (lo, hi) in [(1u32, 20u32), (20, 50), (50, 100)] {
        let (s, stats) = sim_cell(PolicySpec::Rnd { lo, hi }, 28, SCALE, Kernel::Both, 1, SEED);
        println!("| {lo}-{hi} | {s:.3} | {:.0} |", stats.hw_retries_per_thread());
    }

    // -- A4: task-size sweep -------------------------------------------------
    println!("\n### A4 — task size (batch) sweep, generation kernel, 14 threads (sim)\n");
    println!("| batch | policy | seconds | capacity aborts | stm fallbacks |");
    println!("|---|---|---|---|---|");
    let cost = CostModel::for_scale(SCALE);
    for batch in [1usize, 8, 32] {
        for spec in [PolicySpec::Fx { n: 43 }, PolicySpec::DyAd { n: 43 }] {
            let mut w = SimWorkload::new(SCALE);
            w.batch = batch;
            let sim = Simulator::new(cost.clone());
            let streams: Vec<Box<dyn Iterator<Item = TxnDesc>>> = (0..14)
                .map(|tid| Box::new(w.generation_stream(&cost, 14, tid)) as _)
                .collect();
            let out = sim.run(spec, 14, streams, SEED);
            let t = out.stats.total();
            println!(
                "| {batch} | {} | {:.3} | {} | {} |",
                spec.name(),
                out.seconds,
                t.aborts_of(dyadhytm::tm::AbortCause::Capacity),
                t.sw_commits
            );
        }
    }
    // -- A5: per-txn fallback (DyAd) vs phase-global (PhTM) ----------------
    println!("\n### A5 — DyAdHyTM vs PhTM (sim, both kernels)\n");
    println!("| threads | DyAd s | PhTM s | PhTM penalty |");
    println!("|---|---|---|---|");
    for t in [4usize, 14, 28] {
        let dy = sim_cell(PolicySpec::DyAd { n: 43 }, t, SCALE, Kernel::Both, 1, SEED).0;
        let ph = sim_cell(
            PolicySpec::PhTm { retries: 8, sw_quantum: 64 },
            t,
            SCALE,
            Kernel::Both,
            1,
            SEED,
        )
        .0;
        println!("| {t} | {dy:.3} | {ph:.3} | {:+.1}% |", (ph / dy - 1.0) * 100.0);
    }

    // -- A6: kernel 3 policy sensitivity (live) ----------------------------
    println!("\n### A6 — SSCA-2 kernel 3 (multi-source BFS, live, scale 10, 4 threads)\n");
    println!("| policy | time | marked | hw commits | sw commits |");
    println!("|---|---|---|---|---|");
    {
        use dyadhytm::graph::{computation, generation, rmat, subgraph, Graph, Ssca2Config};
        use dyadhytm::htm::HtmConfig;
        use dyadhytm::hytm::TmSystem;
        use std::sync::Arc;
        for spec in [
            PolicySpec::CoarseLock,
            PolicySpec::StmNorec,
            PolicySpec::HtmSpin { retries: 8 },
            PolicySpec::DyAd { n: 43 },
            PolicySpec::PhTm { retries: 8, sw_quantum: 64 },
        ] {
            let cfg = Ssca2Config::new(10);
            let g = Graph::alloc(cfg);
            let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
            let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
            generation::build_serial(&sys, &g, &tuples);
            let _ = computation::run(&sys, &g, spec, 4, SEED);
            let roots = subgraph::roots_from_results(&g);
            let r = subgraph::run(&sys, &g, &roots, 3, spec, 4, SEED);
            subgraph::verify_subgraph(&g, &roots, 3, &r).unwrap();
            let t = r.stats.total();
            println!(
                "| {} | {:?} | {} | {} | {} |",
                spec.name(),
                r.elapsed,
                r.total_marked,
                t.hw_commits,
                t.sw_commits
            );
        }
    }
    eprintln!("[ablation: {:?}]", t0.elapsed());
}
