//! The fault-injection plane: deterministic, seeded fault injection
//! for every layer of the retry/fallback ladder, plus the progress
//! watchdog ([`watchdog`]) that heals what the faults break.
//!
//! # Fault spec grammar (`--faults SPEC`)
//!
//! A spec is a comma-separated `key=value` list:
//!
//! ```text
//! seed=7,htm_abort=0.05,validation_fail=0.02,wakeup_drop=0.01,\
//! worker_stall=0.005:2ms,panic=0.001
//! ```
//!
//! * `seed=N` — the injection RNG seed (default 1). Same seed + same
//!   spec ⇒ the same set of injection decisions per site.
//! * `htm_abort=P` — probability a hardware attempt is killed at
//!   `HW_BEGIN` with a forced abort (alternating conflict/capacity
//!   causes, so both ladder rungs are exercised).
//! * `validation_fail=P` — probability a passing batch read-set
//!   validation is forced to fail (the transaction re-incarnates
//!   exactly as on a genuine conflict).
//! * `wakeup_drop=P` — probability a scheduler dependency wakeup is
//!   dropped (the classic lost-wakeup bug, induced on demand; the
//!   scheduler records the victim so the watchdog can re-ready it).
//! * `worker_stall=P[:DUR]` — probability a worker pauses for `DUR`
//!   (default 1ms; suffixes `ns`/`us`/`ms`/`s`) before its next task.
//! * `panic=P` — probability a transaction body panics mid-flight
//!   (quarantined by the executor's `catch_unwind`, never published).
//!
//! Probabilities parse in `[0, 1]` and are clamped to
//! [`MAX_RATE`] = 0.95 so every fault regime stays live: a rate of 1.0
//! on a retried site (validation, panic) would otherwise loop forever.
//! Unknown keys and malformed values are parse *errors* (the CLI turns
//! them into usage errors, never panics).
//!
//! # Determinism
//!
//! Each site keeps a monotone ticket counter; a draw hashes
//! `seed ⊕ site-salt ⊕ ticket` through SplitMix64. The *set* of
//! injected tickets per site is therefore a pure function of
//! (seed, spec), independent of thread interleaving — which dynamic
//! operation claims which ticket still races, but every injection is
//! recoverable by construction, so kernel output stays bitwise equal
//! to the fault-free run regardless (the `tests/fault_injection.rs`
//! invariant).
//!
//! # Overhead contract
//!
//! Matching [`crate::obs`]: with no plane installed every injection
//! site is one relaxed load and one branch ([`active`]); the hashing,
//! counters, and trace emission live in `#[cold]` slow paths.
//!
//! # The degradation ladder
//!
//! Injected faults exercise, in escalation order:
//!
//! 1. HTM abort → the policy's own retry/STM/lock fallback;
//! 2. validation failure → batch re-incarnation (ESTIMATE + re-run);
//! 3. task panic → quarantine + re-dispatch with a bumped incarnation
//!    ([`crate::batch`]'s executor, bounded by [`MAX_REQUEUE`]);
//! 4. lost wakeup / stall → the [`watchdog`] re-readies recorded
//!    victims and forces a revalidation pass;
//! 5. repeated watchdog kicks → [`crate::engine::degraded`] escalates
//!    the engine to the global-lock serial backend, recovering with
//!    hysteresis once progress resumes.

pub mod watchdog;

use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, Ordering};
use std::time::Duration;

use crate::util::rng::SplitMix64;

/// Injection rates clamp here so retried sites always terminate.
pub const MAX_RATE: f64 = 0.95;

/// Injected-panic requeue budget per transaction: past this many
/// quarantines the executor stops injecting at that transaction, and a
/// *genuine* (non-injected) persistent panic is re-raised — a real bug
/// must still surface.
pub const MAX_REQUEUE: u32 = 12;

/// Quarantines after which injection is suppressed for a transaction
/// (strictly below [`MAX_REQUEUE`], so injected panics can never
/// exhaust the requeue budget).
pub const MAX_INJECT_PER_TXN: u32 = 8;

/// The injection sites, indexable for counters and salts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// `htm/engine.rs`: forced abort at `HW_BEGIN`.
    HtmAbort = 0,
    /// `batch/executor.rs`: forced read-set validation failure.
    ValidationFail = 1,
    /// `batch/scheduler.rs`: dropped dependency wakeup.
    WakeupDrop = 2,
    /// Worker loops: a bounded stall before the next task.
    WorkerStall = 3,
    /// `batch/executor.rs`: a panic inside the transaction body.
    Panic = 4,
}

/// Number of distinct sites.
pub const SITES: usize = 5;

/// Per-site draw salts (arbitrary odd constants so sites decorrelate
/// under one seed).
const SALTS: [u64; SITES] = [
    0x9E37_79B9_7F4A_7C15,
    0xBF58_476D_1CE4_E5B9,
    0x94D0_49BB_1331_11EB,
    0xD6E8_FEB8_6659_FD93,
    0xA076_1D64_78BD_642F,
];

impl Site {
    pub fn name(self) -> &'static str {
        match self {
            Site::HtmAbort => "htm-abort",
            Site::ValidationFail => "validation-fail",
            Site::WakeupDrop => "wakeup-drop",
            Site::WorkerStall => "worker-stall",
            Site::Panic => "panic",
        }
    }

    pub const ALL: [Site; SITES] = [
        Site::HtmAbort,
        Site::ValidationFail,
        Site::WakeupDrop,
        Site::WorkerStall,
        Site::Panic,
    ];
}

/// A parsed `--faults` spec. See the module docs for the grammar.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    pub seed: u64,
    pub htm_abort: f64,
    pub validation_fail: f64,
    pub wakeup_drop: f64,
    pub worker_stall: f64,
    /// Duration of one injected worker stall.
    pub stall: Duration,
    pub panic: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: 1,
            htm_abort: 0.0,
            validation_fail: 0.0,
            wakeup_drop: 0.0,
            worker_stall: 0.0,
            stall: Duration::from_millis(1),
            panic: 0.0,
        }
    }
}

impl FaultSpec {
    /// Parse a comma-separated `key=value` spec. Every malformed key or
    /// value is an `Err` with a human-readable reason — the CLI maps
    /// that to a usage error, never a panic.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::default();
        if s.trim().is_empty() {
            return Err("empty fault spec".into());
        }
        for part in s.split(',') {
            let part = part.trim();
            let Some((key, value)) = part.split_once('=') else {
                return Err(format!("fault spec entry '{part}' is not key=value"));
            };
            let rate = |v: &str| -> Result<f64, String> {
                let p: f64 = v
                    .parse()
                    .map_err(|_| format!("bad probability for {key}: '{v}'"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("probability for {key} out of [0,1]: {p}"));
                }
                Ok(p.min(MAX_RATE))
            };
            match key {
                "seed" => {
                    spec.seed = value
                        .parse()
                        .map_err(|_| format!("bad seed: '{value}'"))?;
                }
                "htm_abort" => spec.htm_abort = rate(value)?,
                "validation_fail" => spec.validation_fail = rate(value)?,
                "wakeup_drop" => spec.wakeup_drop = rate(value)?,
                "panic" => spec.panic = rate(value)?,
                "worker_stall" => match value.split_once(':') {
                    Some((p, dur)) => {
                        spec.worker_stall = rate(p)?;
                        spec.stall = parse_duration(dur)
                            .ok_or_else(|| format!("bad stall duration: '{dur}'"))?;
                    }
                    None => spec.worker_stall = rate(value)?,
                },
                _ => return Err(format!("unknown fault key '{key}'")),
            }
        }
        Ok(spec)
    }

    /// The injection probability of a site.
    pub fn rate_of(&self, site: Site) -> f64 {
        match site {
            Site::HtmAbort => self.htm_abort,
            Site::ValidationFail => self.validation_fail,
            Site::WakeupDrop => self.wakeup_drop,
            Site::WorkerStall => self.worker_stall,
            Site::Panic => self.panic,
        }
    }

    /// The deterministic draw: does ticket number `ticket` at `site`
    /// inject under this spec? Pure — the whole plane's decision
    /// function, unit-testable without installing anything.
    pub fn draw(&self, site: Site, ticket: u64) -> bool {
        let rate = self.rate_of(site);
        if rate <= 0.0 {
            return false;
        }
        let mut mix = SplitMix64::new(
            self.seed ^ SALTS[site as usize] ^ ticket.wrapping_mul(0x2545_F491_4F6C_DD1D),
        );
        let unit = (mix.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        unit < rate
    }
}

/// `"2ms"` / `"150us"` / `"3s"` / `"500ns"` → a `Duration`.
fn parse_duration(s: &str) -> Option<Duration> {
    let (digits, unit): (String, String) = {
        let split = s.find(|c: char| !c.is_ascii_digit())?;
        (s[..split].to_string(), s[split..].to_string())
    };
    let n: u64 = digits.parse().ok()?;
    Some(match unit.as_str() {
        "ns" => Duration::from_nanos(n),
        "us" => Duration::from_micros(n),
        "ms" => Duration::from_millis(n),
        "s" => Duration::from_secs(n),
        _ => return None,
    })
}

// ----------------------------------------------------------------
// The installed plane
// ----------------------------------------------------------------

struct Plane {
    spec: FaultSpec,
    /// Per-site ticket counters (draws taken).
    tickets: [AtomicU64; SITES],
    /// Per-site injections fired.
    injected: [AtomicU64; SITES],
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static PLANE: AtomicPtr<Plane> = AtomicPtr::new(std::ptr::null_mut());

/// Install a fault plane process-wide. Re-installing swaps the plane
/// (the old one is intentionally leaked — installs happen O(1) times
/// per process: once from `--faults`, a handful from the fault test
/// binary — so the leak is bounded and keeps every reader lock-free).
pub fn install(spec: FaultSpec) {
    let plane = Box::leak(Box::new(Plane {
        spec,
        tickets: std::array::from_fn(|_| AtomicU64::new(0)),
        injected: std::array::from_fn(|_| AtomicU64::new(0)),
    }));
    PLANE.store(plane, Ordering::Release);
    ACTIVE.store(true, Ordering::SeqCst);
    crate::obs::diag(1, "fault plane installed");
}

/// Disable injection (the plane stays readable for counter queries).
pub fn clear() {
    ACTIVE.store(false, Ordering::SeqCst);
}

/// Is a fault plane installed and enabled? One relaxed load — the
/// whole cost of every injection site on a fault-free run.
#[inline]
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

#[inline]
fn plane() -> Option<&'static Plane> {
    let p = PLANE.load(Ordering::Acquire);
    if p.is_null() {
        None
    } else {
        Some(unsafe { &*p })
    }
}

/// The installed spec, if any (regardless of [`active`]).
pub fn current() -> Option<FaultSpec> {
    if !active() {
        return None;
    }
    plane().map(|p| p.spec.clone())
}

/// Should this dynamic occurrence of `site` inject? Returns the
/// claimed ticket on injection (callers that shape the fault by ticket
/// parity — the HTM abort-cause alternation — read it).
#[inline]
pub fn inject_ticket(site: Site) -> Option<u64> {
    if !active() {
        return None;
    }
    inject_slow(site)
}

/// [`inject_ticket`] without the ticket.
#[inline]
pub fn inject(site: Site) -> bool {
    inject_ticket(site).is_some()
}

#[cold]
fn inject_slow(site: Site) -> Option<u64> {
    let plane = plane()?;
    if plane.spec.rate_of(site) <= 0.0 {
        return None;
    }
    let ticket = plane.tickets[site as usize].fetch_add(1, Ordering::Relaxed);
    if !plane.spec.draw(site, ticket) {
        return None;
    }
    plane.injected[site as usize].fetch_add(1, Ordering::Relaxed);
    crate::obs::trace::fault_injected(site as u64, ticket);
    Some(ticket)
}

/// Stall the calling worker if the `worker_stall` site fires. One
/// relaxed load + branch when the plane is off.
#[inline]
pub fn maybe_stall() {
    if !active() {
        return;
    }
    stall_slow();
}

#[cold]
fn stall_slow() {
    if inject(Site::WorkerStall) {
        if let Some(plane) = plane() {
            std::thread::sleep(plane.spec.stall);
        }
    }
}

/// Injections fired at one site since install.
pub fn injected(site: Site) -> u64 {
    plane().map_or(0, |p| p.injected[site as usize].load(Ordering::Relaxed))
}

/// Total injections fired across all sites since install.
pub fn injected_total() -> u64 {
    plane().map_or(0, |p| {
        p.injected
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: these tests never call `install` — the plane is
    // process-global, and this binary's other tests (batch
    // determinism, kernel runs) must not race an injected fault. All
    // installed-plane behaviour is covered by the serialized
    // `tests/fault_injection.rs` binary; here only the pure pieces.

    #[test]
    fn parse_full_spec_round_trips() {
        let s = FaultSpec::parse(
            "seed=7,htm_abort=0.05,validation_fail=0.02,wakeup_drop=0.01,\
             worker_stall=0.005:2ms,panic=0.001",
        )
        .unwrap();
        assert_eq!(s.seed, 7);
        assert!((s.htm_abort - 0.05).abs() < 1e-12);
        assert!((s.validation_fail - 0.02).abs() < 1e-12);
        assert!((s.wakeup_drop - 0.01).abs() < 1e-12);
        assert!((s.worker_stall - 0.005).abs() < 1e-12);
        assert_eq!(s.stall, Duration::from_millis(2));
        assert!((s.panic - 0.001).abs() < 1e-12);
    }

    #[test]
    fn parse_defaults_and_partial_specs() {
        let s = FaultSpec::parse("seed=3").unwrap();
        assert_eq!(s.seed, 3);
        assert_eq!(s.rate_of(Site::Panic), 0.0);
        assert_eq!(s.stall, Duration::from_millis(1));
        let s = FaultSpec::parse("worker_stall=0.5").unwrap();
        assert!((s.worker_stall - 0.5).abs() < 1e-12);
        // Duration suffixes.
        for (txt, want) in [
            ("worker_stall=0.1:500ns", Duration::from_nanos(500)),
            ("worker_stall=0.1:150us", Duration::from_micros(150)),
            ("worker_stall=0.1:3s", Duration::from_secs(3)),
        ] {
            assert_eq!(FaultSpec::parse(txt).unwrap().stall, want, "{txt}");
        }
    }

    #[test]
    fn parse_clamps_saturating_rates() {
        let s = FaultSpec::parse("panic=1.0,validation_fail=0.99").unwrap();
        assert!((s.panic - MAX_RATE).abs() < 1e-12, "1.0 clamps to MAX_RATE");
        assert!((s.validation_fail - 0.95).abs() < 1e-12);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "   ",
            "htm_abort",               // not key=value
            "htm_abort=",              // empty value
            "htm_abort=x",             // not a number
            "htm_abort=1.5",           // out of range
            "htm_abort=-0.1",          // negative
            "seed=abc",                // bad seed
            "worker_stall=0.1:2",      // missing duration unit
            "worker_stall=0.1:2min",   // unknown unit
            "worker_stall=0.1:ms",     // missing digits
            "worker_stall=x:2ms",      // bad probability
            "unknown_key=0.1",         // unknown key
            "panic=0.1,,seed=2",       // empty entry
            "panic=0.1;seed=2",        // wrong separator
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn draw_is_deterministic_and_seed_sensitive() {
        let mut spec = FaultSpec::default();
        spec.seed = 7;
        spec.validation_fail = 0.25;
        let hits: Vec<u64> = (0..4096)
            .filter(|&t| spec.draw(Site::ValidationFail, t))
            .collect();
        let again: Vec<u64> = (0..4096)
            .filter(|&t| spec.draw(Site::ValidationFail, t))
            .collect();
        assert_eq!(hits, again, "same seed ⇒ same injected ticket set");
        // The empirical rate tracks the requested one.
        let rate = hits.len() as f64 / 4096.0;
        assert!((rate - 0.25).abs() < 0.05, "empirical rate {rate}");
        // A different seed moves the set; a different site decorrelates.
        let mut other = spec.clone();
        other.seed = 8;
        let moved: Vec<u64> = (0..4096)
            .filter(|&t| other.draw(Site::ValidationFail, t))
            .collect();
        assert_ne!(hits, moved, "seed must matter");
        let mut wider = spec.clone();
        wider.wakeup_drop = 0.25;
        let cross: Vec<u64> = (0..4096)
            .filter(|&t| wider.draw(Site::WakeupDrop, t))
            .collect();
        assert_ne!(hits, cross, "sites must decorrelate under one seed");
    }

    #[test]
    fn zero_rate_never_draws() {
        let spec = FaultSpec::default();
        for site in Site::ALL {
            assert!((0..1000).all(|t| !spec.draw(site, t)), "{}", site.name());
        }
    }

    #[test]
    fn inactive_plane_is_inert() {
        // No install in this binary: every query path returns the
        // fault-free answer.
        if active() {
            return; // another harness installed a plane; skip
        }
        assert!(inject_ticket(Site::Panic).is_none());
        assert!(!inject(Site::HtmAbort));
        maybe_stall(); // must not sleep or panic
        assert_eq!(current(), None);
    }

    #[test]
    fn site_names_are_stable() {
        let names: Vec<&str> = Site::ALL.iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            vec![
                "htm-abort",
                "validation-fail",
                "wakeup-drop",
                "worker-stall",
                "panic"
            ]
        );
        for (i, s) in Site::ALL.iter().enumerate() {
            assert_eq!(*s as usize, i);
        }
    }
}
