//! Progress watchdog: detects a stalled run (the global commit/
//! execution counter stops advancing past a deadline), elects one
//! kicker to run recovery, and escalates to the serial backend after
//! repeated fruitless kicks.
//!
//! # Contract
//!
//! * The watchdog never fires while progress advances: every observed
//!   change of the progress counter resets the deadline clock.
//! * The deadline **scales with measured commit latency** so
//!   single-threaded, `NO_PIN=1`, or debug-slow runs do not trip it:
//!   `deadline = max(base, SLACK_FACTOR × ewma_commit_latency)` where
//!   the EWMA is fed from the same nanosecond samples the
//!   [`crate::obs::hist`] latency histograms record (the batch driver
//!   folds the live transaction-latency histogram into
//!   [`Watchdog::observe_commit_latency`]). The law is pinned by a
//!   unit test below.
//! * [`Watchdog::poll`] is safe to call from many workers; exactly one
//!   caller wins each kick (CAS election), so recovery never runs
//!   twice for one stall.
//! * Recovery is the *caller's* job (re-ready recorded lost wakeups,
//!   force a revalidation pass via `reopen_validation`); the watchdog
//!   supplies the trigger, the kick accounting, and the escalation /
//!   recovery hysteresis ([`Watchdog::should_escalate`],
//!   [`Watchdog::ready_to_recover`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Deadline slack over the commit-latency EWMA: a run is only
/// "stalled" once nothing commits for this many typical commit
/// latencies.
pub const SLACK_FACTOR: u64 = 1024;

/// Deadline floor before any latency has been observed.
pub const DEFAULT_BASE_DEADLINE: Duration = Duration::from_millis(250);

/// EWMA decay: `e' = e + (sample - e) / 2^EWMA_SHIFT` (α = 1/8).
pub const EWMA_SHIFT: u32 = 3;

/// Kicks with zero intervening progress before the watchdog asks for
/// escalation to the serial backend.
pub const ESCALATE_AFTER_KICKS: u64 = 3;

/// Consecutive progress observations required after an escalation
/// before the degraded state may lift (recovery hysteresis).
pub const RECOVERY_HYSTERESIS: u64 = 2;

/// The pinned deadline scaling law (pure; see module docs).
pub fn deadline_law_ns(base_ns: u64, ewma_ns: u64) -> u64 {
    base_ns.max(SLACK_FACTOR.saturating_mul(ewma_ns))
}

/// What a kick found. Carried in the watchdog-kick trace event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Diagnosis {
    /// Dropped dependency wakeups were recorded and re-readied.
    LostWakeup = 0,
    /// No recorded drops — a parked ESTIMATE chain or stuck
    /// validation frontier; recovery forces a revalidation pass.
    ParkedChain = 1,
    /// Structural recovery found nothing and tasks remain unclaimed:
    /// a livelocked retry storm. Only escalation helps.
    Livelock = 2,
    /// Structural recovery found nothing and *every* task stream is
    /// drained: all remaining work is claimed by workers whose
    /// progress counters are flat — a dead or stalled worker holding
    /// tickets (the `worker_stall` fault signature). In a serving
    /// session this is the stall that freezes the snapshot horizon:
    /// the head block cannot promote until the holder resumes.
    WorkerStall = 3,
}

impl Diagnosis {
    pub fn name(self) -> &'static str {
        match self {
            Diagnosis::LostWakeup => "lost-wakeup",
            Diagnosis::ParkedChain => "parked-chain",
            Diagnosis::Livelock => "livelock",
            Diagnosis::WorkerStall => "worker-stall",
        }
    }
}

/// Shared stall detector. All methods take `&self`; the struct is
/// designed to sit in an `Arc` or on the driver's stack, polled by
/// the driver thread and/or idle workers.
pub struct Watchdog {
    epoch: Instant,
    base_ns: u64,
    ewma_ns: AtomicU64,
    last_progress: AtomicU64,
    last_change_ns: AtomicU64,
    kicks: AtomicU64,
    kicks_since_progress: AtomicU64,
    healthy_streak: AtomicU64,
}

impl Watchdog {
    pub fn new(base: Duration) -> Watchdog {
        Watchdog {
            epoch: Instant::now(),
            base_ns: base.as_nanos() as u64,
            ewma_ns: AtomicU64::new(0),
            last_progress: AtomicU64::new(0),
            last_change_ns: AtomicU64::new(0),
            kicks: AtomicU64::new(0),
            kicks_since_progress: AtomicU64::new(0),
            healthy_streak: AtomicU64::new(0),
        }
    }

    pub fn with_default_deadline() -> Watchdog {
        Watchdog::new(DEFAULT_BASE_DEADLINE)
    }

    /// Feed one commit-latency sample (nanoseconds) into the EWMA.
    /// Racy updates may drop a sample; the estimate only steers the
    /// deadline, so that is harmless.
    pub fn observe_commit_latency(&self, ns: u64) {
        let prev = self.ewma_ns.load(Ordering::Relaxed);
        let next = if prev == 0 {
            ns
        } else {
            // e + (sample - e)/8, in integer arithmetic without
            // underflow on sample < e.
            let shifted = prev - (prev >> EWMA_SHIFT) + (ns >> EWMA_SHIFT);
            shifted.max(1)
        };
        self.ewma_ns.store(next, Ordering::Relaxed);
    }

    /// Current commit-latency EWMA in nanoseconds (0 until fed).
    pub fn ewma_ns(&self) -> u64 {
        self.ewma_ns.load(Ordering::Relaxed)
    }

    /// The live deadline under the pinned scaling law.
    pub fn deadline_ns(&self) -> u64 {
        deadline_law_ns(self.base_ns, self.ewma_ns())
    }

    /// Report the current progress counter. Returns `true` exactly
    /// once per stall interval — the caller that receives `true` owns
    /// the recovery for this kick.
    pub fn poll(&self, progress: u64) -> bool {
        let now = self.epoch.elapsed().as_nanos() as u64;
        let last = self.last_progress.load(Ordering::Relaxed);
        if progress != last {
            self.last_progress.store(progress, Ordering::Relaxed);
            self.last_change_ns.store(now, Ordering::Relaxed);
            self.kicks_since_progress.store(0, Ordering::Relaxed);
            self.healthy_streak.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let seen = self.last_change_ns.load(Ordering::Relaxed);
        if now.saturating_sub(seen) < self.deadline_ns() {
            return false;
        }
        // Elect one kicker; the CAS also restarts the deadline clock
        // so recovery gets a full fresh interval before the next kick.
        if self
            .last_change_ns
            .compare_exchange(seen, now, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            self.kicks.fetch_add(1, Ordering::Relaxed);
            self.kicks_since_progress.fetch_add(1, Ordering::Relaxed);
            self.healthy_streak.store(0, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Heartbeat from a pool that is *legitimately* idle (empty
    /// pipelined window while a serving stream is paused): refresh
    /// the deadline clock without claiming progress. A long-lived
    /// session can idle arbitrarily long between bursts; without
    /// this, the first flat-progress poll after a pause would compare
    /// against a timestamp from before the pause, kick immediately,
    /// and — repeated across a few pauses — spuriously escalate a
    /// healthy session to the degraded backend.
    pub fn note_idle(&self) {
        let now = self.epoch.elapsed().as_nanos() as u64;
        self.last_change_ns.store(now, Ordering::Relaxed);
    }

    /// Total kicks fired.
    pub fn kicks(&self) -> u64 {
        self.kicks.load(Ordering::Relaxed)
    }

    /// Consecutive kicks with no intervening progress — past
    /// [`ESCALATE_AFTER_KICKS`], structural recovery is not working
    /// and the caller should escalate to the serial backend.
    pub fn should_escalate(&self) -> bool {
        self.kicks_since_progress.load(Ordering::Relaxed) >= ESCALATE_AFTER_KICKS
    }

    /// Recovery hysteresis: after an escalation, has progress resumed
    /// for long enough that the degraded state may lift?
    pub fn ready_to_recover(&self) -> bool {
        self.healthy_streak.load(Ordering::Relaxed) >= RECOVERY_HYSTERESIS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_scaling_law_is_pinned() {
        // The exact law — changing it is a deliberate act.
        let base = DEFAULT_BASE_DEADLINE.as_nanos() as u64;
        assert_eq!(deadline_law_ns(base, 0), base, "floor before any sample");
        // Fast commits (1µs): the floor dominates.
        assert_eq!(deadline_law_ns(base, 1_000), base);
        // Slow commits (1ms, a debug/single-thread regime): the EWMA
        // term dominates and the deadline stretches to SLACK×EWMA.
        assert_eq!(
            deadline_law_ns(base, 1_000_000),
            SLACK_FACTOR * 1_000_000,
            "deadline must scale with measured commit latency"
        );
        // Monotone in the EWMA.
        let mut prev = 0;
        for e in [0u64, 10, 1_000, 100_000, 10_000_000] {
            let d = deadline_law_ns(base, e);
            assert!(d >= prev);
            prev = d;
        }
        // Crossover point: base / SLACK_FACTOR.
        let cross = base / SLACK_FACTOR;
        assert_eq!(deadline_law_ns(base, cross.saturating_sub(1)), base);
        assert!(deadline_law_ns(base, cross + 1) > base);
    }

    #[test]
    fn ewma_converges_and_tracks_regime_changes() {
        let wd = Watchdog::new(Duration::from_millis(1));
        for _ in 0..64 {
            wd.observe_commit_latency(1_000);
        }
        let settled = wd.ewma_ns();
        assert!(
            (900..=1_100).contains(&settled),
            "EWMA should settle near the constant sample, got {settled}"
        );
        // A 100× slower regime pulls the estimate (and the deadline) up.
        for _ in 0..64 {
            wd.observe_commit_latency(100_000);
        }
        let slow = wd.ewma_ns();
        assert!(slow > 50_000, "EWMA must track the slow regime, got {slow}");
        assert_eq!(wd.deadline_ns(), deadline_law_ns(1_000_000, slow));
    }

    #[test]
    fn slow_commit_latency_suppresses_false_positives() {
        // A single-threaded / debug-slow run: commits take ~5ms each.
        // With a 1ms base deadline the naive watchdog would kick
        // between every two commits; the scaled deadline must not.
        let wd = Watchdog::new(Duration::from_millis(1));
        wd.observe_commit_latency(5_000_000);
        assert!(!wd.poll(1), "first observation only records progress");
        std::thread::sleep(Duration::from_millis(5));
        assert!(
            !wd.poll(1),
            "5ms of no progress is within one commit latency — no kick"
        );
        assert_eq!(wd.kicks(), 0);
    }

    #[test]
    fn kicks_after_deadline_then_resets_and_escalates() {
        let wd = Watchdog::new(Duration::from_millis(2));
        assert!(!wd.poll(7), "progress registration is not a kick");
        std::thread::sleep(Duration::from_millis(5));
        assert!(wd.poll(7), "deadline passed with no progress");
        assert_eq!(wd.kicks(), 1);
        assert!(!wd.poll(7), "kick restarts the deadline clock");
        assert!(!wd.should_escalate());
        for _ in 0..(ESCALATE_AFTER_KICKS - 1) {
            std::thread::sleep(Duration::from_millis(5));
            assert!(wd.poll(7));
        }
        assert!(wd.should_escalate(), "repeated fruitless kicks escalate");
        // Progress clears the escalation pressure and, sustained,
        // satisfies the recovery hysteresis.
        assert!(!wd.poll(8));
        assert!(!wd.should_escalate());
        assert!(!wd.ready_to_recover(), "one healthy poll is not enough");
        assert!(!wd.poll(9));
        assert!(wd.ready_to_recover(), "hysteresis satisfied after {RECOVERY_HYSTERESIS} healthy polls");
    }

    #[test]
    fn diagnosis_names_are_stable() {
        assert_eq!(Diagnosis::LostWakeup.name(), "lost-wakeup");
        assert_eq!(Diagnosis::ParkedChain.name(), "parked-chain");
        assert_eq!(Diagnosis::Livelock.name(), "livelock");
        assert_eq!(Diagnosis::WorkerStall.name(), "worker-stall");
        assert_eq!(Diagnosis::LostWakeup as u64, 0);
        assert_eq!(Diagnosis::ParkedChain as u64, 1);
        assert_eq!(Diagnosis::Livelock as u64, 2);
        assert_eq!(Diagnosis::WorkerStall as u64, 3);
    }

    #[test]
    fn idle_heartbeat_holds_the_kicker_off_across_a_pause() {
        let wd = Watchdog::new(Duration::from_millis(2));
        assert!(!wd.poll(1), "first observation only records progress");
        // A paused serving stream: progress is flat, but the pool is
        // idle (empty window), not stalled — heartbeats every lap.
        for _ in 0..3 {
            std::thread::sleep(Duration::from_millis(2));
            wd.note_idle();
        }
        assert!(
            !wd.poll(1),
            "flat progress right after an idle pause must not kick"
        );
        assert_eq!(wd.kicks(), 0);
        // A genuine stall after the pause still fires.
        std::thread::sleep(Duration::from_millis(5));
        assert!(wd.poll(1), "real stalls still kick after a pause");
        assert_eq!(wd.kicks(), 1);
    }
}
