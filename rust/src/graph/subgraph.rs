//! SSCA-2 kernel 3: subgraph extraction around the heavy edges.
//!
//! The benchmark's third kernel grows subgraphs outward from the
//! kernel-2 edge set. We implement it as a **multi-source
//! level-synchronous parallel BFS**: the frontier starts at the heavy
//! edges' endpoints and expands `depth` levels; claiming a vertex
//! (`read mark; if unmarked, write level`) is the critical section.
//! Power-law hubs appear in many adjacency lists, so early levels are
//! conflict-dense — the same dynamics Kang & Bader's TM-MSF paper (the
//! paper's reference [21]) reports for graph TM workloads.
//!
//! Level-synchronous multi-source BFS visits a *deterministic vertex
//! set* (the distance-≤depth ball of the root set) regardless of thread
//! interleaving — which is what [`verify_subgraph`] checks against a
//! serial oracle, making this kernel a strong end-to-end serializability
//! probe for every policy. Under `PolicySpec::Batch` the kernel
//! dispatches to [`crate::batch::workload::run_subgraph`], which admits
//! each level's claims as deterministic blocks through `BatchSystem` —
//! no per-transaction NOrec fallback.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::engine::Engine;
use crate::hytm::{PolicySpec, ThreadExecutor, TmSystem};
use crate::mem::{Addr, WORDS_PER_LINE};
use crate::runtime::workers::{run_sharded, PoolConfig};
use crate::stats::StatsTable;
use crate::tm::access::{TxAccess, TxResult};

use super::generation::kernel_grain;
use super::layout::Graph;

/// Kernel-3 outcome.
#[derive(Clone, Debug)]
pub struct SubgraphResult {
    /// Vertices claimed per BFS level (level 0 = the roots).
    pub level_sizes: Vec<usize>,
    pub total_marked: usize,
    pub elapsed: Duration,
    pub stats: StatsTable,
    /// Base of the mark region (for verification).
    pub marks_base: Addr,
}

/// Root set: the destination endpoints of the kernel-2 result edges.
pub fn roots_from_results(g: &Graph) -> Vec<u32> {
    let mut roots: Vec<u32> = g
        .results()
        .iter()
        .map(|&cell| g.heap.load(cell as usize + Graph::CELL_DST) as u32)
        .collect();
    roots.sort_unstable();
    roots.dedup();
    roots
}

/// Run kernel 3 from `roots`, expanding `depth` levels under `spec`.
/// Thin wrapper over [`run_with`] with a run-local [`Engine`].
pub fn run(
    sys: &TmSystem,
    g: &Graph,
    roots: &[u32],
    depth: usize,
    spec: PolicySpec,
    threads: usize,
    seed: u64,
) -> SubgraphResult {
    let mut engine = Engine::new(spec);
    run_with(sys, g, roots, depth, &mut engine, threads, seed)
}

/// Run kernel 3 through an [`Engine`] handle: dispatch is decided at
/// kernel entry, every level's interval is fed back via
/// [`Engine::observe`], and each level boundary is a re-dispatch point
/// for per-transaction backends (a switch *into* the batch backend
/// waits for the next kernel boundary — the level-synchronous claims
/// make any per-level backend sequence visit the same deterministic
/// vertex set, which [`verify_subgraph`] checks).
pub fn run_with(
    sys: &TmSystem,
    g: &Graph,
    roots: &[u32],
    depth: usize,
    engine: &mut Engine,
    threads: usize,
    seed: u64,
) -> SubgraphResult {
    assert!(threads >= 1);
    let (sizing, exec_spec) = {
        let be = engine.backend("extraction", "level-0");
        (be.sizing(), be.spec())
    };
    if let Some(ctl) = sizing {
        // The batch backend owns its worker pool and serialization
        // order; `threads` becomes its concurrency level. No silent
        // NOrec fallback: the claims run through `BatchSystem`.
        let r = crate::batch::workload::run_subgraph(g, roots, depth, threads, ctl);
        let mut interval = r.stats.total();
        interval.time_ns = r.elapsed.as_nanos() as u64;
        crate::obs::snapshot::record(
            "extraction",
            "kernel",
            &interval,
            &[
                ("threads", threads.to_string()),
                ("marked", r.total_marked.to_string()),
            ],
        );
        engine.observe(&interval);
        return r;
    }
    let n = g.cfg.vertices();
    // Mark region: one word per vertex, level+1 when claimed.
    let marks_base = g.heap.alloc_lines(n.div_ceil(WORDS_PER_LINE));
    let t0 = Instant::now();
    let mut table = StatsTable::new();
    for tid in 0..threads {
        table.push(tid, crate::stats::TxStats::new());
    }

    // Level 0: claim the roots (serial claim is fine — roots are few —
    // but run it through the TM path anyway for uniformity).
    let mut frontier: Vec<u32> = Vec::new();
    {
        let mut ex = ThreadExecutor::new(sys, exec_spec, 0, seed);
        for &r in roots {
            let claimed = ex.execute(&mut |t: &mut dyn TxAccess| -> TxResult<bool> {
                let m = t.read(marks_base + r as usize)?;
                if m == 0 {
                    t.write(marks_base + r as usize, 1)?;
                    Ok(true)
                } else {
                    Ok(false)
                }
            });
            if claimed {
                frontier.push(r);
            }
        }
        crate::obs::snapshot::record(
            "extraction",
            "level-0",
            &ex.stats,
            &[("frontier", frontier.len().to_string())],
        );
        engine.observe(&ex.stats);
        table.rows[0].stats.merge(&ex.stats);
    }

    let mut level_sizes = vec![frontier.len()];

    for level in 1..=depth {
        if frontier.is_empty() {
            break;
        }
        let next = Mutex::new(Vec::<u32>::new());
        let mark_val = (level + 1) as u64;
        // Level boundary: per-transaction re-dispatch under auto.
        let level_spec = engine.threaded_spec(exec_spec);
        // Frontier ranges on the shared worker runtime: hub-heavy
        // frontier entries make shares wildly uneven, which is exactly
        // what the stealing deques absorb.
        let grain = kernel_grain(frontier.len(), threads, 1).min(frontier.len().max(1));
        let level_t0 = Instant::now();
        let (rows, pool) = run_sharded(
            &PoolConfig::pinned(threads),
            frontier.len(),
            grain,
            |tid, feed, _| {
                let mut ex = ThreadExecutor::new(sys, level_spec, tid as u32, seed ^ level as u64);
                let t = Instant::now();
                let mut local_next = Vec::new();
                while let Some((lo, hi)) = feed.next() {
                    for &v in &frontier[lo..hi] {
                        // Non-transactional adjacency walk (the graph is
                        // frozen after kernel 1); claiming is the
                        // critical section.
                        for (dst, _, _) in g.adjacency(v) {
                            let addr = marks_base + dst as usize;
                            let claimed =
                                ex.execute(&mut |t: &mut dyn TxAccess| -> TxResult<bool> {
                                    let m = t.read(addr)?;
                                    if m == 0 {
                                        t.write(addr, mark_val)?;
                                        Ok(true)
                                    } else {
                                        Ok(false)
                                    }
                                });
                            if claimed {
                                local_next.push(dst);
                            }
                        }
                    }
                }
                ex.stats.time_ns = t.elapsed().as_nanos() as u64;
                next.lock().unwrap().extend_from_slice(&local_next);
                ex.stats
            },
        );
        {
            let mut interval = crate::stats::TxStats::new();
            for s in &rows {
                interval.merge(s);
            }
            interval.time_ns = level_t0.elapsed().as_nanos() as u64;
            let phase = format!("level-{level}");
            crate::obs::snapshot::record(
                "extraction",
                &phase,
                &interval,
                &[("frontier", frontier.len().to_string())],
            );
            engine.observe(&interval);
        }
        for (tid, mut s2) in rows.into_iter().enumerate() {
            if tid == 0 {
                s2.steals += pool.steals;
                s2.local_steals += pool.local_steals;
                s2.pinned_workers = pool.pinned_workers;
            }
            let keep = table.rows[tid].stats.time_ns + s2.time_ns;
            table.rows[tid].stats.merge(&s2);
            table.rows[tid].stats.time_ns = keep;
        }
        frontier = next.into_inner().unwrap();
        level_sizes.push(frontier.len());
    }

    let total_marked = level_sizes.iter().sum();
    SubgraphResult {
        level_sizes,
        total_marked,
        elapsed: t0.elapsed(),
        stats: table,
        marks_base,
    }
}

/// Serial BFS oracle: the exact distance-≤depth ball and each vertex's
/// BFS level, compared against the parallel marks.
pub fn verify_subgraph(
    g: &Graph,
    roots: &[u32],
    depth: usize,
    result: &SubgraphResult,
) -> Result<(), String> {
    let n = g.cfg.vertices();
    let mut dist = vec![u64::MAX; n];
    let mut frontier: Vec<u32> = Vec::new();
    for &r in roots {
        if dist[r as usize] == u64::MAX {
            dist[r as usize] = 0;
            frontier.push(r);
        }
    }
    for level in 1..=depth as u64 {
        let mut next = Vec::new();
        for &v in &frontier {
            for (dst, _, _) in g.adjacency(v) {
                if dist[dst as usize] == u64::MAX {
                    dist[dst as usize] = level;
                    next.push(dst);
                }
            }
        }
        frontier = next;
    }

    let mut expected_total = 0usize;
    for v in 0..n {
        let mark = g.heap.load(result.marks_base + v);
        match (dist[v], mark) {
            (u64::MAX, 0) => {}
            (u64::MAX, m) => return Err(format!("vertex {v}: marked {m} but unreachable")),
            (d, 0) => return Err(format!("vertex {v}: reachable at {d} but unmarked")),
            (d, m) => {
                expected_total += 1;
                if m != d + 1 {
                    return Err(format!(
                        "vertex {v}: BFS level {d} but marked {}",
                        m - 1
                    ));
                }
            }
        }
    }
    if expected_total != result.total_marked {
        return Err(format!(
            "marked {} vertices, oracle says {expected_total}",
            result.total_marked
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layout::Ssca2Config;
    use crate::graph::{computation, generation, rmat};
    use crate::htm::HtmConfig;
    use std::sync::Arc;

    fn built(scale: u32) -> (TmSystem, Graph) {
        let cfg = Ssca2Config::new(scale);
        let g = Graph::alloc(cfg);
        let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        generation::build_serial(&sys, &g, &tuples);
        let _ = computation::run(&sys, &g, PolicySpec::CoarseLock, 2, 5);
        (sys, g)
    }

    #[test]
    fn bfs_ball_matches_serial_oracle() {
        let (sys, g) = built(8);
        let roots = roots_from_results(&g);
        assert!(!roots.is_empty());
        let r = run(&sys, &g, &roots, 3, PolicySpec::DyAd { n: 43 }, 4, 7);
        verify_subgraph(&g, &roots, 3, &r).unwrap();
        assert!(r.total_marked >= roots.len());
    }

    #[test]
    fn every_policy_visits_identical_set() {
        let mut totals = Vec::new();
        for spec in [
            PolicySpec::CoarseLock,
            PolicySpec::StmNorec,
            PolicySpec::HtmSpin { retries: 6 },
            PolicySpec::DyAd { n: 43 },
            PolicySpec::PhTm {
                retries: 4,
                sw_quantum: 32,
            },
            PolicySpec::Batch { block: 64 },
            // Auto on a fresh engine resolves to the batch backend; the
            // visited set must still match every fixed policy.
            PolicySpec::Auto { hysteresis: 2 },
        ] {
            let (sys, g) = built(7);
            let roots = roots_from_results(&g);
            let r = run(&sys, &g, &roots, 2, spec, 4, 11);
            verify_subgraph(&g, &roots, 2, &r)
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name()));
            totals.push(r.total_marked);
        }
        assert!(
            totals.windows(2).all(|w| w[0] == w[1]),
            "visited set must be schedule-independent: {totals:?}"
        );
    }

    #[test]
    fn depth_zero_marks_only_roots() {
        let (sys, g) = built(6);
        let roots = roots_from_results(&g);
        let r = run(&sys, &g, &roots, 0, PolicySpec::CoarseLock, 2, 3);
        assert_eq!(r.total_marked, roots.len());
        verify_subgraph(&g, &roots, 0, &r).unwrap();
    }

    #[test]
    fn deeper_balls_are_supersets() {
        let (sys, g) = built(7);
        let roots = roots_from_results(&g);
        let r1 = run(&sys, &g, &roots, 1, PolicySpec::DyAd { n: 43 }, 3, 9);
        // Fresh graph for the deeper run (marks are write-once).
        let (sys2, g2) = built(7);
        let r2 = run(&sys2, &g2, &roots, 3, PolicySpec::DyAd { n: 43 }, 3, 9);
        assert!(r2.total_marked >= r1.total_marked);
    }

    #[test]
    fn claim_txns_race_on_hubs_without_losing_vertices() {
        // High thread count on a small graph: the hub claims all race.
        let (sys, g) = built(6);
        let roots = roots_from_results(&g);
        let r = run(&sys, &g, &roots, 4, PolicySpec::DyAd { n: 43 }, 8, 13);
        verify_subgraph(&g, &roots, 4, &r).unwrap();
    }
}
