//! The SSCA-2 computation kernel: heavy-edge extraction.
//!
//! Two phases over the built multigraph:
//!
//! 1. **max probe** — each thread scans its share of the edge-cell
//!    region, and for *every* edge runs the critical section
//!    `read gmax; if w > gmax write gmax`. This is the paper's
//!    "dynamic conflict scenario where threads compete to update a
//!    critical section": early in the scan writes are common and
//!    conflict; quickly the probe becomes read-only — a coarse lock
//!    still serializes every probe while TM lets them run concurrently
//!    (Fig 2(c/f)'s 8x). The runtime path accelerates the *scan* side
//!    with the AOT `classify` artifact.
//! 2. **collect** — each thread re-scans its share and appends every
//!    edge in the top weight band (`weight > cutoff`, band = 1/2^shift)
//!    to the shared result list, buffered in flushes of
//!    [`COLLECT_FLUSH`] so the shared counter doesn't serialize the
//!    whole phase.

use std::time::{Duration, Instant};

use crate::engine::Engine;
use crate::hytm::{PolicySpec, ThreadExecutor, TmSystem};
use crate::runtime::workers::{run_sharded, PoolConfig};
use crate::stats::{StatsTable, TxStats};
use crate::tm::access::{TxAccess, TxResult};

use super::generation::kernel_grain;
use super::layout::Graph;

/// Outcome of the computation kernel.
#[derive(Clone, Debug)]
pub struct ComputationResult {
    pub max_weight: u32,
    pub cutoff: u32,
    pub selected: usize,
    pub elapsed: Duration,
    pub stats: StatsTable,
}

/// How many band hits the collect phase buffers before one append
/// transaction (mirrored by the simulator's `COLLECT_FLUSH`).
pub const COLLECT_FLUSH: usize = 8;

/// Phase 1 worker: the per-edge transactional max probe.
fn scan_and_merge_max(g: &Graph, ex: &mut ThreadExecutor<'_>, lo: usize, hi: usize) {
    for i in lo..hi {
        let w = g.heap.load(g.cell(i) + Graph::CELL_WEIGHT);
        // The critical section, once per scanned edge.
        ex.execute(&mut |t: &mut dyn TxAccess| -> TxResult<()> {
            let cur = t.read(g.gmax)?;
            if w > cur {
                t.write(g.gmax, w)?;
            }
            Ok(())
        });
    }
}

/// The collect-phase append critical section, shared with the batch
/// backend (`crate::batch::workload`): push `cells` onto the shared
/// result list and bump its count. One definition keeps every backend's
/// result-list protocol in lockstep.
pub fn append_results(t: &mut dyn TxAccess, g: &Graph, cells: &[u64]) -> TxResult<()> {
    let count = t.read(g.result_count)?;
    for (k, &cell) in cells.iter().enumerate() {
        t.write(g.results_base + count as usize + k, cell)?;
    }
    t.write(g.result_count, count + cells.len() as u64)
}

/// Phase 2 worker: append every top-band edge to the shared list.
/// Appends are batched `batch` edges per transaction (the same task-size
/// knob as the generation kernel).
fn collect_band(
    g: &Graph,
    ex: &mut ThreadExecutor<'_>,
    lo: usize,
    hi: usize,
    cutoff: u64,
) -> u64 {
    let batch = g.cfg.batch.max(COLLECT_FLUSH);
    let mut pending: Vec<u64> = Vec::with_capacity(batch);
    let mut appended = 0u64;

    let flush = |pending: &mut Vec<u64>, ex: &mut ThreadExecutor<'_>| {
        if pending.is_empty() {
            return;
        }
        ex.execute(&mut |t: &mut dyn TxAccess| -> TxResult<()> {
            append_results(t, g, pending)
        });
        pending.clear();
    };

    for i in lo..hi {
        let cell = g.cell(i);
        let w = g.heap.load(cell + Graph::CELL_WEIGHT);
        // Unallocated cells have weight 0 and never pass the cutoff.
        if w > cutoff {
            pending.push(cell as u64);
            appended += 1;
            if pending.len() == batch {
                flush(&mut pending, ex);
            }
        }
    }
    flush(&mut pending, ex);
    appended
}

/// Run the computation kernel with `threads` workers under `spec`.
/// Thin wrapper over [`run_with`] with a run-local [`Engine`].
pub fn run(
    sys: &TmSystem,
    g: &Graph,
    spec: PolicySpec,
    threads: usize,
    seed: u64,
) -> ComputationResult {
    let mut engine = Engine::new(spec);
    run_with(sys, g, &mut engine, threads, seed)
}

/// Run the computation kernel through an [`Engine`] handle.
///
/// The engine's live backend decides the dispatch at kernel entry;
/// each phase's interval delta is fed back via [`Engine::observe`], and
/// the phase boundary between probe and collect is a re-dispatch point
/// for per-transaction backends ([`Engine::threaded_spec`] — a
/// controller decision to *enter* the batch backend is deferred to the
/// next kernel boundary, where the previous backend has drained).
///
/// Both phases run on the shared worker runtime
/// ([`crate::runtime::workers::run_sharded`]): the cell region is cut
/// into grain-sized scan ranges dealt to pinned workers, and an idle
/// worker steals ranges from its peers instead of idling at the phase
/// barrier (the phase boundary itself is semantic — the cutoff depends
/// on every probe — and stays).
pub fn run_with(
    sys: &TmSystem,
    g: &Graph,
    engine: &mut Engine,
    threads: usize,
    seed: u64,
) -> ComputationResult {
    assert!(threads >= 1);
    let (sizing, exec_spec) = {
        let be = engine.backend("computation", "probe");
        (be.sizing(), be.spec())
    };
    if let Some(ctl) = sizing {
        // Speculative batch backend: same two phases, admitted as
        // controller-sized blocks of deterministic-order transactions.
        let r = crate::batch::workload::run_computation(g, threads, ctl);
        let mut interval = r.stats.total();
        interval.time_ns = r.elapsed.as_nanos() as u64;
        crate::obs::snapshot::record(
            "computation",
            "kernel",
            &interval,
            &[
                ("threads", threads.to_string()),
                ("selected", r.selected.to_string()),
            ],
        );
        engine.observe(&interval);
        return r;
    }
    let total_cells = g.cells_allocated();
    let t0 = Instant::now();
    let mut table = StatsTable::new();
    let grain = kernel_grain(total_cells, threads, g.cfg.batch.max(COLLECT_FLUSH));

    // Phase 1: global max.
    let (phase1_stats, pool1) = run_sharded(
        &PoolConfig::pinned(threads),
        total_cells,
        grain,
        |tid, feed, _| {
            let mut ex = ThreadExecutor::new(sys, exec_spec, tid as u32, seed);
            let t = Instant::now();
            while let Some((lo, hi)) = feed.next() {
                scan_and_merge_max(g, &mut ex, lo, hi);
            }
            ex.stats.time_ns = t.elapsed().as_nanos() as u64;
            ex.stats
        },
    );

    {
        let mut interval = TxStats::new();
        for s in &phase1_stats {
            interval.merge(s);
        }
        interval.time_ns = t0.elapsed().as_nanos() as u64;
        crate::obs::snapshot::record(
            "computation",
            "probe",
            &interval,
            &[("threads", threads.to_string())],
        );
        engine.observe(&interval);
    }

    let max_weight = g.heap.load(g.gmax) as u32;
    let cutoff = g.weight_cutoff() as u64;
    let t1 = Instant::now();

    // Phase boundary: a mid-kernel re-dispatch point for the
    // per-transaction backends.
    let collect_spec = engine.threaded_spec(exec_spec);

    // Phase 2: collect the band.
    let (phase2_stats, pool2) = run_sharded(
        &PoolConfig::pinned(threads),
        total_cells,
        grain,
        |tid, feed, _| {
            let mut ex = ThreadExecutor::new(sys, collect_spec, tid as u32, seed ^ 0xC0);
            let t = Instant::now();
            while let Some((lo, hi)) = feed.next() {
                collect_band(g, &mut ex, lo, hi, cutoff);
            }
            ex.stats.time_ns = t.elapsed().as_nanos() as u64;
            ex.stats
        },
    );

    {
        let mut interval = TxStats::new();
        for s in &phase2_stats {
            interval.merge(s);
        }
        interval.time_ns = t1.elapsed().as_nanos() as u64;
        crate::obs::snapshot::record(
            "computation",
            "collect",
            &interval,
            &[
                ("threads", threads.to_string()),
                ("cutoff", cutoff.to_string()),
            ],
        );
        engine.observe(&interval);
    }

    for (tid, (mut s, p1)) in phase2_stats
        .into_iter()
        .zip(phase1_stats.into_iter())
        .enumerate()
    {
        // Fold the phase-1 merge transactions into the thread's row
        // (times add: the phases are sequential).
        let t2 = s.time_ns;
        s.merge(&p1);
        s.time_ns = t2 + p1.time_ns;
        if tid == 0 {
            s.steals += pool1.steals + pool2.steals;
            s.local_steals += pool1.local_steals + pool2.local_steals;
            s.pinned_workers = pool1.pinned_workers.max(pool2.pinned_workers);
        }
        table.push(tid, s);
    }

    let selected = g.heap.load(g.result_count) as usize;
    ComputationResult {
        max_weight,
        cutoff: cutoff as u32,
        selected,
        elapsed: t0.elapsed(),
        stats: table,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layout::Ssca2Config;
    use crate::graph::verify;
    use crate::graph::{generation, rmat};
    use crate::htm::HtmConfig;
    use std::sync::Arc;

    fn built(scale: u32) -> (TmSystem, Graph, Vec<rmat::EdgeTuple>) {
        let cfg = Ssca2Config::new(scale);
        let g = Graph::alloc(cfg);
        let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        generation::build_serial(&sys, &g, &tuples);
        (sys, g, tuples)
    }

    #[test]
    fn finds_true_max_and_full_band() {
        let (sys, g, tuples) = built(7);
        let r = run(&sys, &g, PolicySpec::DyAd { n: 43 }, 4, 9);
        let true_max = tuples.iter().map(|e| e.weight).max().unwrap();
        assert_eq!(r.max_weight, true_max);
        verify::check_results(&g, &tuples).unwrap();
    }

    #[test]
    fn every_policy_collects_identical_band() {
        let mut counts = Vec::new();
        for spec in [
            PolicySpec::CoarseLock,
            PolicySpec::StmNorec,
            PolicySpec::HtmALock { retries: 8 },
            PolicySpec::Rnd { lo: 1, hi: 50 },
            PolicySpec::DyAd { n: 43 },
            PolicySpec::Batch { block: 128 },
            // Auto resolves to the batch start backend on a fresh
            // engine; the band must come out identical regardless.
            PolicySpec::Auto { hysteresis: 2 },
        ] {
            let (sys, g, tuples) = built(6);
            let r = run(&sys, &g, spec, 4, 11);
            verify::check_results(&g, &tuples)
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name()));
            counts.push(r.selected);
        }
        assert!(counts.windows(2).all(|w| w[0] == w[1]), "{counts:?}");
    }

    #[test]
    fn band_selectivity_is_about_an_eighth() {
        let (sys, g, tuples) = built(8);
        let r = run(&sys, &g, PolicySpec::CoarseLock, 2, 1);
        let frac = r.selected as f64 / tuples.len() as f64;
        assert!(
            (0.09..0.16).contains(&frac),
            "top-1/8 band selected {frac}"
        );
    }

    #[test]
    fn batched_collect_matches_unbatched() {
        let cfg = Ssca2Config::new(6).with_batch(8);
        let g = Graph::alloc(cfg);
        let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        generation::build_serial(&sys, &g, &tuples);
        let r = run(&sys, &g, PolicySpec::DyAd { n: 43 }, 3, 2);
        verify::check_results(&g, &tuples).unwrap();
        assert!(r.selected > 0);
    }

    #[test]
    fn kernel_grain_aligns_to_the_task_size() {
        use crate::graph::generation::kernel_grain;
        for (total, threads, align) in [(1000usize, 3usize, 16usize), (7, 8, 8), (0, 2, 4), (64, 1, 1)]
        {
            let g = kernel_grain(total, threads, align);
            assert!(g >= 1);
            assert_eq!(g % align.max(1), 0, "grain must align to the batch knob");
        }
    }
}
