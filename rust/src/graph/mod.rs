//! The SSCA-2 graph workload (DESIGN.md S8–S10).
//!
//! Scalable Synthetic Compact Applications 2 (Bader et al., 2006): a
//! weighted, directed multigraph generated from R-MAT tuples. The paper
//! uses two of its kernels:
//!
//! * **generation kernel** ([`generation`]) — build the multigraph from
//!   the tuple list. Each edge insert is a critical section updating
//!   the source vertex's adjacency head, its degree, and the edge cell —
//!   a small transaction whose conflicts concentrate on power-law hub
//!   vertices ("symmetric concurrency" in the paper's words).
//! * **computation kernel** ([`computation`]) — extract the heavy edges:
//!   find the maximum weight, then collect every edge in the top weight
//!   band into a shared result list. The list append is a tiny,
//!   all-threads-hit-one-counter critical section — the paper's
//!   "dynamic conflict scenario where threads compete".
//!
//! The tuple list itself comes from either the AOT Pallas artifact
//! (runtime path, `crate::runtime`) or the native generator
//! ([`rmat`]) — both implement the same R-MAT descent and are
//! cross-validated in tests.

pub mod computation;
pub mod generation;
pub mod layout;
pub mod rmat;
pub mod subgraph;
pub mod verify;

pub use computation::ComputationResult;
pub use subgraph::SubgraphResult;
pub use layout::{Graph, Ssca2Config, CELL_WORDS};
pub use rmat::EdgeTuple;
