//! Post-run invariant verification: the workload-level correctness
//! oracle every policy must satisfy (no lost updates, no phantom edges,
//! complete extraction).

use std::collections::HashMap;

use super::layout::Graph;
use super::rmat::EdgeTuple;

/// Check the built multigraph against the input tuple list:
/// * every vertex's stored degree equals its adjacency-list length;
/// * the multiset of (src, dst, weight) edges equals the input multiset;
/// * total edge count matches.
pub fn check_graph(g: &Graph, tuples: &[EdgeTuple]) -> Result<(), String> {
    let n = g.cfg.vertices() as u32;

    let mut expect: HashMap<(u32, u32, u32), i64> = HashMap::new();
    for e in tuples {
        *expect.entry((e.src, e.dst, e.weight)).or_default() += 1;
    }

    let mut total = 0u64;
    for v in 0..n {
        let adj = g.adjacency(v);
        let deg = g.degree_of(v);
        if deg != adj.len() as u64 {
            return Err(format!(
                "vertex {v}: degree word says {deg}, list has {}",
                adj.len()
            ));
        }
        total += deg;
        for (dst, w, id) in adj {
            if id == 0 {
                return Err(format!("vertex {v}: cell with unset edge id"));
            }
            let k = (v, dst, w);
            match expect.get_mut(&k) {
                Some(c) if *c > 0 => *c -= 1,
                _ => return Err(format!("phantom edge {k:?}")),
            }
        }
    }

    if total != tuples.len() as u64 {
        return Err(format!(
            "edge count {total} != input {}",
            tuples.len()
        ));
    }
    if let Some((k, _)) = expect.iter().find(|&(_, &c)| c != 0) {
        return Err(format!("missing edge {k:?}"));
    }
    Ok(())
}

/// Check the computation kernel's output:
/// * `gmax` is the true maximum weight;
/// * the result list contains exactly the edges with weight > cutoff
///   (as a multiset of weights), each exactly once.
pub fn check_results(g: &Graph, tuples: &[EdgeTuple]) -> Result<(), String> {
    let true_max = tuples.iter().map(|e| e.weight).max().unwrap_or(0);
    let gmax = g.heap.load(g.gmax) as u32;
    if gmax != true_max {
        return Err(format!("gmax {gmax} != true max {true_max}"));
    }

    let cutoff = g.weight_cutoff();
    let expect_count = tuples.iter().filter(|e| e.weight > cutoff).count();
    let results = g.results();
    if results.len() != expect_count {
        return Err(format!(
            "selected {} edges, expected {expect_count}",
            results.len()
        ));
    }

    // Each entry must be a distinct allocated cell with weight > cutoff.
    let mut seen = std::collections::HashSet::new();
    let mut weights: HashMap<u32, i64> = HashMap::new();
    for &cell in &results {
        let cell = cell as usize;
        if cell < g.cells_base || cell >= g.cells_end {
            return Err(format!("result entry {cell} outside cell region"));
        }
        if !seen.insert(cell) {
            return Err(format!("cell {cell} collected twice"));
        }
        let w = g.heap.load(cell + Graph::CELL_WEIGHT) as u32;
        if w <= cutoff {
            return Err(format!("collected weight {w} <= cutoff {cutoff}"));
        }
        *weights.entry(w).or_default() += 1;
    }
    for e in tuples.iter().filter(|e| e.weight > cutoff) {
        match weights.get_mut(&e.weight) {
            Some(c) if *c > 0 => *c -= 1,
            _ => return Err(format!("band weight {} missing", e.weight)),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layout::Ssca2Config;
    use crate::graph::{generation, rmat};
    use crate::htm::HtmConfig;
    use crate::hytm::TmSystem;
    use std::sync::Arc;

    #[test]
    fn detects_missing_edge() {
        let cfg = Ssca2Config::new(5);
        let g = Graph::alloc(cfg);
        let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
        let tuples = rmat::generate(1, 5, 8);
        // Build all but one edge.
        generation::build_serial(&sys, &g, &tuples[..tuples.len() - 1]);
        assert!(check_graph(&g, &tuples).is_err());
    }

    #[test]
    fn detects_degree_corruption() {
        let cfg = Ssca2Config::new(5);
        let g = Graph::alloc(cfg);
        let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
        let tuples = rmat::generate(2, 5, 8);
        generation::build_serial(&sys, &g, &tuples);
        // Corrupt a degree word (simulates a lost update).
        let v = tuples[0].src;
        g.heap.store(g.degree(v), g.degree_of(v) + 1);
        let err = check_graph(&g, &tuples).unwrap_err();
        assert!(err.contains("degree"), "{err}");
    }

    #[test]
    fn detects_phantom_results() {
        let cfg = Ssca2Config::new(5);
        let g = Graph::alloc(cfg);
        let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
        let tuples = rmat::generate(3, 5, 8);
        generation::build_serial(&sys, &g, &tuples);
        // Correct gmax but a bogus result entry.
        let true_max = tuples.iter().map(|e| e.weight).max().unwrap();
        g.heap.store(g.gmax, true_max as u64);
        g.heap.store(g.results_base, g.cell(0) as u64);
        g.heap.store(g.result_count, 1);
        assert!(check_results(&g, &tuples).is_err());
    }
}
