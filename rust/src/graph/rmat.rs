//! Native R-MAT tuple generator (DESIGN.md S8).
//!
//! The same quadrant descent as the Pallas kernel
//! (`python/compile/kernels/rmat.py`), in Rust: at each of `scale`
//! levels one uniform draw picks the quadrant (a, b, c, d) = (0.55,
//! 0.10, 0.10, 0.25), contributing one source bit and one destination
//! bit. Weights are uniform in `[1, 2^scale]` (SSCA-2's MaxIntWeight).
//!
//! Used when artifacts are not built, as the oracle the artifact path is
//! cross-validated against, and by the trace capturer. Deterministic per
//! (seed, scale, edge_factor).

use crate::util::rng::Rng;

/// SSCA-2 v2 R-MAT parameters (match kernels/rmat.py).
pub const RMAT_A: f64 = 0.55;
pub const RMAT_B: f64 = 0.10;
pub const RMAT_C: f64 = 0.10;
pub const RMAT_D: f64 = 0.25;

/// One weighted directed edge tuple.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EdgeTuple {
    pub src: u32,
    pub dst: u32,
    pub weight: u32,
}

/// Draw one R-MAT edge.
pub fn rmat_edge(rng: &mut Rng, scale: u32, max_weight: u32) -> EdgeTuple {
    let ab = RMAT_A + RMAT_B;
    let abc = RMAT_A + RMAT_B + RMAT_C;
    let mut src = 0u32;
    let mut dst = 0u32;
    for _ in 0..scale {
        let u = rng.next_f64();
        let src_bit = (u >= ab) as u32;
        let dst_bit = ((u >= RMAT_A && u < ab) || u >= abc) as u32;
        src = (src << 1) | src_bit;
        dst = (dst << 1) | dst_bit;
    }
    let weight = 1 + rng.below(max_weight as u64) as u32;
    EdgeTuple { src, dst, weight }
}

/// Generate the full tuple list for `scale` / `edge_factor`.
pub fn generate(seed: u64, scale: u32, edge_factor: u32) -> Vec<EdgeTuple> {
    let n_edges = (1usize << scale) * edge_factor as usize;
    let max_weight = 1u32 << scale;
    let mut rng = Rng::new(seed);
    (0..n_edges)
        .map(|_| rmat_edge(&mut rng, scale, max_weight))
        .collect()
}

/// Generate only the `i`-th chunk of `chunk` edges — used by per-thread
/// trace capture and streaming workloads. Chunks are independent
/// streams: chunk i is seeded by (seed, i), so any subset can be
/// produced without generating the rest.
pub fn generate_chunk(
    seed: u64,
    chunk_index: u64,
    chunk: usize,
    scale: u32,
    edge_factor: u32,
) -> Vec<EdgeTuple> {
    let n_edges = (1usize << scale) * edge_factor as usize;
    let start = chunk_index as usize * chunk;
    let len = chunk.min(n_edges.saturating_sub(start));
    let max_weight = 1u32 << scale;
    let mut rng = Rng::new(seed ^ chunk_index.wrapping_mul(0xD6E8_FEB8_6659_FD93));
    (0..len)
        .map(|_| rmat_edge(&mut rng, scale, max_weight))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_bounded_by_scale() {
        let edges = generate(1, 10, 8);
        assert_eq!(edges.len(), 8 << 10);
        for e in &edges {
            assert!(e.src < 1 << 10);
            assert!(e.dst < 1 << 10);
            assert!(e.weight >= 1 && e.weight <= 1 << 10);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(generate(7, 8, 8), generate(7, 8, 8));
        assert_ne!(generate(7, 8, 8), generate(8, 8, 8));
    }

    #[test]
    fn quadrant_distribution_matches_parameters() {
        let scale = 14;
        let edges = generate(3, scale, 8);
        let top = 1u32 << (scale - 1);
        let (mut a, mut b, mut c, mut d) = (0f64, 0f64, 0f64, 0f64);
        for e in &edges {
            match (e.src >= top, e.dst >= top) {
                (false, false) => a += 1.0,
                (false, true) => b += 1.0,
                (true, false) => c += 1.0,
                (true, true) => d += 1.0,
            }
        }
        let n = edges.len() as f64;
        assert!((a / n - RMAT_A).abs() < 0.01, "a={}", a / n);
        assert!((b / n - RMAT_B).abs() < 0.01, "b={}", b / n);
        assert!((c / n - RMAT_C).abs() < 0.01, "c={}", c / n);
        assert!((d / n - RMAT_D).abs() < 0.01, "d={}", d / n);
    }

    #[test]
    fn power_law_skew_exists() {
        // R-MAT with a=0.55 concentrates degree on low vertex ids:
        // the busiest vertex should dominate the mean degree.
        let scale = 12;
        let edges = generate(11, scale, 8);
        let mut deg = vec![0u32; 1 << scale];
        for e in &edges {
            deg[e.src as usize] += 1;
        }
        let max = *deg.iter().max().unwrap();
        let mean = edges.len() as f64 / (1 << scale) as f64;
        assert!(
            (max as f64) > 10.0 * mean,
            "no skew: max {max} vs mean {mean}"
        );
    }

    #[test]
    fn chunked_generation_covers_all_edges() {
        let scale = 8;
        let n_edges = 8 << scale;
        let chunk = 100;
        let mut total = 0;
        let mut i = 0;
        loop {
            let c = generate_chunk(5, i, chunk, scale, 8);
            total += c.len();
            if c.len() < chunk {
                break;
            }
            i += 1;
        }
        assert_eq!(total, n_edges);
    }

    #[test]
    fn chunks_are_independent_streams() {
        let a = generate_chunk(5, 3, 100, 12, 8);
        let b = generate_chunk(5, 3, 100, 12, 8);
        assert_eq!(a, b);
        let c = generate_chunk(5, 4, 100, 12, 8);
        assert_ne!(a, c);
    }
}
