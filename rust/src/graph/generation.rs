//! The SSCA-2 generation kernel: concurrent multigraph construction.
//!
//! Each thread owns a slice of the tuple list. Edge cells are reserved
//! in thread-private chunks from the shared pool (a non-transactional
//! fetch-add, as the reference OpenMP implementation reserves array
//! slots), so the *transaction* is exactly the paper's critical section:
//!
//! ```text
//! old          = head[src]
//! cell.dst     = dst            (thread-private cell, no conflicts)
//! cell.weight  = w
//! cell.next    = old
//! cell.id      = edge id
//! head[src]    = cell           (the contended word: power-law hubs)
//! degree[src] += 1
//! ```
//!
//! ~3–5 cache lines touched: small enough for any HTM — except when the
//! `batch` knob raises the task size, which is how the capacity-abort
//! experiments (and DyAdHyTM's reason to exist) are driven.

use std::time::{Duration, Instant};

use crate::engine::Engine;
use crate::hytm::{PolicySpec, ThreadExecutor, TmSystem};
use crate::runtime::workers::{run_sharded, PoolConfig};
use crate::stats::{StatsTable, TxStats};
use crate::tm::access::{TxAccess, TxResult};

use super::layout::{Graph, POOL_CHUNK_CELLS};
use super::rmat::EdgeTuple;

/// The paper's per-edge critical section, shared by every backend that
/// builds the graph (the policy executors here and the speculative
/// batch path in `crate::batch::workload`): link a fresh cell at
/// `cell_index` in front of `e.src`'s adjacency list and bump its
/// degree. Keeping this in one place is what guarantees all backends
/// build bit-identical graphs.
pub fn insert_edge(
    t: &mut dyn TxAccess,
    g: &Graph,
    cell_index: usize,
    e: &EdgeTuple,
) -> TxResult<()> {
    let cell = g.cell(cell_index);
    let head = g.head(e.src);
    let old = t.read(head)?;
    t.write(cell + Graph::CELL_DST, e.dst as u64)?;
    t.write(cell + Graph::CELL_WEIGHT, e.weight as u64)?;
    t.write(cell + Graph::CELL_NEXT, old)?;
    t.write(cell + Graph::CELL_ID, cell_index as u64 + 1)?;
    t.write(head, cell as u64)?;
    let deg = t.read(g.degree(e.src))?;
    t.write(g.degree(e.src), deg + 1)
}

/// Insert `tuples[lo..hi]` as one thread's share; returns this thread's
/// stats. `executor` carries the policy.
pub fn insert_slice(
    g: &Graph,
    ex: &mut ThreadExecutor<'_>,
    tuples: &[EdgeTuple],
) -> u64 {
    let batch = g.cfg.batch.max(1);
    let mut pool_next = 0usize;
    let mut pool_left = 0usize;
    let mut inserted = 0u64;
    let mut consumed = 0usize;

    for chunk in tuples.chunks(batch) {
        // Reserve cells for the whole batch, refilling the private pool
        // from the shared cursor as needed (non-transactional). Never
        // reserve more than this thread's remaining share — the pool is
        // sized to exactly m cells.
        if pool_left < chunk.len() {
            debug_assert_eq!(pool_left, 0, "refill sizes are batch-aligned");
            let remaining = tuples.len() - consumed;
            // Batch-aligned refill so no cell is ever stranded: the pool
            // is sized to exactly m cells.
            let aligned = (POOL_CHUNK_CELLS / batch).max(1) * batch;
            let take = aligned.min(remaining).max(chunk.len());
            pool_next = g.reserve_cells(take);
            pool_left = take;
        }
        let first_cell = pool_next;
        pool_next += chunk.len();
        pool_left -= chunk.len();

        // The critical section: insert `chunk.len()` edges atomically.
        ex.execute(&mut |t: &mut dyn TxAccess| -> TxResult<()> {
            for (k, e) in chunk.iter().enumerate() {
                insert_edge(t, g, first_cell + k, e)?;
            }
            Ok(())
        });
        inserted += chunk.len() as u64;
        consumed += chunk.len();
    }
    inserted
}

/// Steal-grain for the kernel drivers: big enough that a range is real
/// work (amortizing the deque traffic), small enough that a lagging
/// worker's share can be picked clean by its peers. Rounded up to a
/// multiple of `align` (the task-size batch knob) so range boundaries
/// coincide with transaction boundaries — stolen ranges then produce
/// exactly the same transaction count as a static sharding.
pub(crate) fn kernel_grain(total: usize, threads: usize, align: usize) -> usize {
    let align = align.max(1);
    let base = (total / (threads.max(1) * 8)).max(align);
    base.next_multiple_of(align)
}

/// Run the generation kernel with `threads` workers under `spec`.
/// Returns (wall time, per-thread stats). Thin wrapper over
/// [`run_with`] with a run-local [`Engine`] — callers that thread one
/// engine across several kernels (live runs, `k3`) use `run_with`
/// directly so the auto controller's state survives kernel boundaries.
pub fn run(
    sys: &TmSystem,
    g: &Graph,
    tuples: &[EdgeTuple],
    spec: PolicySpec,
    threads: usize,
    seed: u64,
) -> (Duration, StatsTable) {
    let mut engine = Engine::new(spec);
    run_with(sys, g, tuples, &mut engine, threads, seed)
}

/// Run the generation kernel through an [`Engine`] handle: the engine's
/// live backend decides block-speculated vs per-transaction dispatch at
/// entry, and the completed interval is fed back via
/// [`Engine::observe`] so a `--policy auto` controller can re-route the
/// next kernel.
///
/// Non-batch backends run on the shared worker runtime
/// ([`crate::runtime::workers::run_sharded`]): the tuple range is cut
/// into grain-sized chunks dealt contiguously to pinned workers, and an
/// idle worker steals chunks from its peers instead of waiting at the
/// join barrier — steal and pin counts land in the stats table.
pub fn run_with(
    sys: &TmSystem,
    g: &Graph,
    tuples: &[EdgeTuple],
    engine: &mut Engine,
    threads: usize,
    seed: u64,
) -> (Duration, StatsTable) {
    assert!(threads >= 1);
    let (sizing, exec_spec) = {
        let be = engine.backend("generation", "insert");
        (be.sizing(), be.spec())
    };
    let (elapsed, table) = if let Some(ctl) = sizing {
        // The batch backend owns its own worker pool and serialization
        // order; `threads` becomes its concurrency level. The
        // controller pins the block (`batch=N`) or adapts it from the
        // observed conflict rate (`batch=adaptive`).
        crate::batch::workload::run_generation(g, tuples, threads, ctl)
    } else {
        let t0 = Instant::now();
        let mut table = StatsTable::new();
        let grain = kernel_grain(tuples.len(), threads, g.cfg.batch.max(1));

        let (rows, pool) = run_sharded(
            &PoolConfig::pinned(threads),
            tuples.len(),
            grain,
            |tid, feed, _pinned| {
                let mut ex = ThreadExecutor::new(sys, exec_spec, tid as u32, seed);
                let t = Instant::now();
                while let Some((lo, hi)) = feed.next() {
                    insert_slice(g, &mut ex, &tuples[lo..hi]);
                }
                ex.stats.time_ns = t.elapsed().as_nanos() as u64;
                ex.stats
            },
        );
        for (tid, mut stats) in rows.into_iter().enumerate() {
            if tid == 0 {
                stats.steals += pool.steals;
                stats.local_steals += pool.local_steals;
                stats.pinned_workers = pool.pinned_workers;
            }
            table.push(tid, stats);
        }

        (t0.elapsed(), table)
    };
    let mut interval = table.total();
    interval.time_ns = elapsed.as_nanos() as u64;
    crate::obs::snapshot::record(
        "generation",
        "insert",
        &interval,
        &[
            ("threads", threads.to_string()),
            ("tuples", tuples.len().to_string()),
        ],
    );
    engine.observe(&interval);
    (elapsed, table)
}

/// Convenience: single-threaded, direct (lock) insertion — used for
/// setup in computation-kernel-only experiments and tests.
pub fn build_serial(sys: &TmSystem, g: &Graph, tuples: &[EdgeTuple]) -> TxStats {
    let mut ex = ThreadExecutor::new(sys, PolicySpec::CoarseLock, 0, 1);
    let t0 = Instant::now();
    insert_slice(g, &mut ex, tuples);
    ex.stats.time_ns = t0.elapsed().as_nanos() as u64;
    crate::obs::snapshot::record(
        "generation",
        "serial",
        &ex.stats,
        &[("tuples", tuples.len().to_string())],
    );
    ex.stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::rmat;
    use crate::graph::verify;
    use crate::htm::HtmConfig;
    use crate::graph::layout::Ssca2Config;

    fn setup(scale: u32) -> (TmSystem, Graph, Vec<EdgeTuple>) {
        let cfg = Ssca2Config::new(scale);
        let g = Graph::alloc(cfg);
        let sys = TmSystem::new(std::sync::Arc::clone(&g.heap), HtmConfig::broadwell());
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        (sys, g, tuples)
    }

    #[test]
    fn serial_build_is_complete_and_consistent() {
        let (sys, g, tuples) = setup(6);
        build_serial(&sys, &g, &tuples);
        verify::check_graph(&g, &tuples).unwrap();
    }

    #[test]
    fn concurrent_build_every_policy_matches_input() {
        for spec in [
            PolicySpec::CoarseLock,
            PolicySpec::StmNorec,
            PolicySpec::HtmSpin { retries: 8 },
            PolicySpec::DyAd { n: 43 },
            PolicySpec::Batch { block: 256 },
            PolicySpec::batch_adaptive(),
        ] {
            let (sys, g, tuples) = setup(7);
            let (_, table) = run(&sys, &g, &tuples, spec, 4, 99);
            verify::check_graph(&g, &tuples)
                .unwrap_or_else(|e| panic!("{}: {e}", spec.name()));
            assert_eq!(
                table.total().total_commits(),
                tuples.len() as u64,
                "{}",
                spec.name()
            );
        }
    }

    #[test]
    fn batched_build_matches_input() {
        let cfg = Ssca2Config::new(7).with_batch(16);
        let g = Graph::alloc(cfg);
        let sys = TmSystem::new(std::sync::Arc::clone(&g.heap), HtmConfig::broadwell());
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        let (_, table) = run(&sys, &g, &tuples, PolicySpec::DyAd { n: 43 }, 4, 5);
        verify::check_graph(&g, &tuples).unwrap();
        // Batch of 16: 1/16th as many transactions.
        assert_eq!(
            table.total().total_commits(),
            (tuples.len() as u64).div_ceil(16)
        );
    }

    #[test]
    fn large_batches_trigger_capacity_fallbacks_on_tiny_htm() {
        let cfg = Ssca2Config::new(7).with_batch(32);
        let g = Graph::alloc(cfg);
        let sys = TmSystem::new(std::sync::Arc::clone(&g.heap), HtmConfig::tiny());
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        let (_, table) = run(&sys, &g, &tuples, PolicySpec::DyAd { n: 43 }, 2, 5);
        let t = table.total();
        assert!(
            t.aborts_of(crate::tm::AbortCause::Capacity) > 0,
            "batch=32 on tiny HTM must capacity-abort"
        );
        assert!(t.sw_commits > 0, "capacity aborts must drive STM fallbacks");
        verify::check_graph(&g, &tuples).unwrap();
    }

    #[test]
    fn hub_vertices_attract_conflicts() {
        // Under real concurrency the generation kernel's conflicts come
        // from power-law hubs; just assert some HW aborts happen at high
        // thread counts with the pure-HTM policy on a small graph.
        let (sys, g, tuples) = setup(5);
        let (_, table) = run(&sys, &g, &tuples, PolicySpec::HtmSpin { retries: 8 }, 8, 3);
        verify::check_graph(&g, &tuples).unwrap();
        // Not asserting > 0 strictly (timing-dependent), but the stats
        // plumbing must be live:
        assert_eq!(table.rows.len(), 8);
        assert_eq!(table.total().total_commits(), tuples.len() as u64);
    }
}
