//! Heap layout of the SSCA-2 multigraph (DESIGN.md S9's substrate).
//!
//! Everything transactional lives in the [`TxHeap`]:
//!
//! ```text
//! head[v]    n words   address of v's newest edge cell (0 = none)
//! degree[v]  n words   v's out-degree
//! cells      m*4 words edge cells: {dst, weight, next, edge_id}
//! results    m words   computation-kernel output: cell addresses
//! counters   1 line each (padded): pool cursor, result count, gmax
//! ```
//!
//! Edge cells are 4 words, so two cells share a 64-byte line — real
//! false sharing, as a real allocator would produce. Heads and degrees
//! of 8 consecutive vertices share a line, which is exactly where the
//! power-law hubs make the generation kernel conflict.

use std::sync::Arc;

use crate::mem::{Addr, TxHeap, WORDS_PER_LINE};

/// Words per edge cell: {dst, weight, next, edge_id}.
pub const CELL_WORDS: usize = 4;

/// How many cells a thread reserves from the shared pool at once (the
/// non-transactional refill; see generation kernel).
pub const POOL_CHUNK_CELLS: usize = 64;

/// SSCA-2 workload configuration.
#[derive(Clone, Copy, Debug)]
pub struct Ssca2Config {
    /// Graph scale: n = 2^scale vertices.
    pub scale: u32,
    /// Edges per vertex (SSCA-2 default 8): m = n * edge_factor.
    pub edge_factor: u32,
    /// Tuple-list RNG seed.
    pub seed: u64,
    /// Edge inserts per generation transaction (task-size knob; 1 =
    /// paper's per-edge critical section, larger values drive the HTM
    /// into capacity aborts).
    pub batch: usize,
    /// Computation kernel selects weights > maxw - (maxw >> shift):
    /// shift=3 keeps the top 1/8 weight band ("extracts edges by
    /// weight", paper §4).
    pub selectivity_shift: u32,
}

impl Ssca2Config {
    pub fn new(scale: u32) -> Self {
        Self {
            scale,
            edge_factor: 8,
            seed: 0x55CA_2017,
            batch: 1,
            selectivity_shift: 3,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        assert!(batch >= 1);
        self.batch = batch;
        self
    }

    pub fn vertices(&self) -> usize {
        1usize << self.scale
    }

    pub fn edges(&self) -> usize {
        self.vertices() * self.edge_factor as usize
    }

    pub fn max_weight(&self) -> u32 {
        1u32 << self.scale
    }

    /// Heap words needed for this configuration (plus slack).
    pub fn heap_words(&self) -> usize {
        let n = self.vertices();
        let m = self.edges();
        // head + degree + cells + results + kernel-3 marks + counters
        // + slack.
        n + n + m * CELL_WORDS + m + n + 16 * WORDS_PER_LINE
    }
}

/// The laid-out multigraph: base addresses of every region.
pub struct Graph {
    pub heap: Arc<TxHeap>,
    pub cfg: Ssca2Config,
    pub head_base: Addr,
    pub degree_base: Addr,
    pub cells_base: Addr,
    pub cells_end: Addr,
    pub results_base: Addr,
    /// Shared (non-transactional) cell-pool cursor, in cells.
    pub pool_cursor: Addr,
    /// Shared result-list length (transactional).
    pub result_count: Addr,
    /// Shared maximum weight found (transactional).
    pub gmax: Addr,
}

impl Graph {
    /// Allocate all regions on a fresh heap.
    pub fn alloc(cfg: Ssca2Config) -> Graph {
        let heap = Arc::new(TxHeap::new(cfg.heap_words()));
        Self::alloc_on(heap, cfg)
    }

    /// Allocate all regions on the given heap.
    pub fn alloc_on(heap: Arc<TxHeap>, cfg: Ssca2Config) -> Graph {
        let n = cfg.vertices();
        let m = cfg.edges();
        let head_base = heap.alloc_lines(n.div_ceil(WORDS_PER_LINE));
        let degree_base = heap.alloc_lines(n.div_ceil(WORDS_PER_LINE));
        let cells_base =
            heap.alloc_lines((m * CELL_WORDS).div_ceil(WORDS_PER_LINE));
        let cells_end = cells_base + m * CELL_WORDS;
        let results_base = heap.alloc_lines(m.div_ceil(WORDS_PER_LINE));
        // Each counter on its own line: no false sharing between them.
        let pool_cursor = heap.alloc_lines(1);
        let result_count = heap.alloc_lines(1);
        let gmax = heap.alloc_lines(1);
        Graph {
            heap,
            cfg,
            head_base,
            degree_base,
            cells_base,
            cells_end,
            results_base,
            pool_cursor,
            result_count,
            gmax,
        }
    }

    // -- address helpers ------------------------------------------------

    #[inline]
    pub fn head(&self, v: u32) -> Addr {
        self.head_base + v as usize
    }

    #[inline]
    pub fn degree(&self, v: u32) -> Addr {
        self.degree_base + v as usize
    }

    /// Address of cell index `i`.
    #[inline]
    pub fn cell(&self, i: usize) -> Addr {
        self.cells_base + i * CELL_WORDS
    }

    pub const CELL_DST: usize = 0;
    pub const CELL_WEIGHT: usize = 1;
    pub const CELL_NEXT: usize = 2;
    pub const CELL_ID: usize = 3;

    /// Number of cells handed out so far (non-transactional read).
    pub fn cells_allocated(&self) -> usize {
        self.heap.load(self.pool_cursor) as usize
    }

    /// Non-transactional chunk reservation from the shared pool.
    /// Returns the first cell index of a `POOL_CHUNK_CELLS`-cell run.
    pub fn reserve_cells(&self, count: usize) -> usize {
        let first = self.heap.fetch_add(self.pool_cursor, count as u64) as usize;
        assert!(
            self.cell(first + count) <= self.cells_end,
            "edge-cell pool exhausted"
        );
        first
    }

    /// The computation kernel's weight cutoff: strictly-greater-than
    /// this selects the top `1/2^shift` weight band.
    pub fn weight_cutoff(&self) -> u32 {
        let maxw = self.cfg.max_weight();
        maxw - (maxw >> self.cfg.selectivity_shift)
    }

    // -- non-transactional readers (verification / computation scan) ----

    /// Walk v's adjacency list, yielding (dst, weight, edge_id).
    pub fn adjacency(&self, v: u32) -> Vec<(u32, u32, u64)> {
        let mut out = Vec::new();
        let mut cur = self.heap.load(self.head(v)) as usize;
        while cur != 0 {
            out.push((
                self.heap.load(cur + Self::CELL_DST) as u32,
                self.heap.load(cur + Self::CELL_WEIGHT) as u32,
                self.heap.load(cur + Self::CELL_ID),
            ));
            cur = self.heap.load(cur + Self::CELL_NEXT) as usize;
        }
        out
    }

    pub fn degree_of(&self, v: u32) -> u64 {
        self.heap.load(self.degree(v))
    }

    /// Slice of result-list entries (cell addresses).
    pub fn results(&self) -> Vec<u64> {
        let count = self.heap.load(self.result_count) as usize;
        (0..count)
            .map(|i| self.heap.load(self.results_base + i))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_are_disjoint_and_ordered() {
        let g = Graph::alloc(Ssca2Config::new(8));
        let n = g.cfg.vertices();
        assert!(g.head_base + n <= g.degree_base);
        assert!(g.degree_base + n <= g.cells_base);
        assert!(g.cells_end <= g.results_base);
        assert!(g.results_base + g.cfg.edges() <= g.pool_cursor);
        assert_ne!(
            TxHeap::line_of(g.pool_cursor),
            TxHeap::line_of(g.result_count),
            "counters must not share a line"
        );
    }

    #[test]
    fn reserve_cells_is_exclusive() {
        let g = Graph::alloc(Ssca2Config::new(8));
        let a = g.reserve_cells(POOL_CHUNK_CELLS);
        let b = g.reserve_cells(POOL_CHUNK_CELLS);
        assert_eq!(b, a + POOL_CHUNK_CELLS);
        assert_eq!(g.cells_allocated(), 2 * POOL_CHUNK_CELLS);
    }

    #[test]
    #[should_panic(expected = "pool exhausted")]
    fn pool_exhaustion_panics() {
        let g = Graph::alloc(Ssca2Config::new(4));
        let m = g.cfg.edges();
        g.reserve_cells(m + 1);
    }

    #[test]
    fn weight_cutoff_keeps_top_band() {
        let g = Graph::alloc(Ssca2Config::new(8));
        // maxw = 256, shift 3 -> cutoff 224: selects 225..=256.
        assert_eq!(g.weight_cutoff(), 224);
    }

    #[test]
    fn cell_addresses_stride_by_cell_words() {
        let g = Graph::alloc(Ssca2Config::new(6));
        assert_eq!(g.cell(1) - g.cell(0), CELL_WORDS);
        assert_eq!(g.cell(0), g.cells_base);
    }

    #[test]
    fn heap_words_covers_layout() {
        // Alloc must not panic for a range of scales.
        for scale in [4, 8, 12] {
            let _ = Graph::alloc(Ssca2Config::new(scale));
        }
    }
}
