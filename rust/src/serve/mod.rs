//! Continuous-serving session: long-lived multi-producer ingestion
//! with abort-free snapshot reads.
//!
//! A [`ServeSession`] wraps one long-lived pipelined batch system
//! (`BatchSystem::run_pipelined_session`): N producer handles feed
//! the sharded bounded [`ingress`] queues, whose drained chunks
//! become admission blocks in the existing W-deep pipelined window.
//! Promotion remains the epoch boundary — the session's store
//! reclamation keeps a continuous stream's memory flat — and each
//! promotion additionally *absorbs* the block's winning versions
//! into a [`snapshot::VersionLog`] before write-back, so a
//! [`snapshot::SnapshotHandle`] pinned at promoted-block horizon `K`
//! observes exactly blocks `≤ K` forever, without ever touching the
//! scheduler (reads are wait-free and abort-free; the write path's
//! `TxStats` abort counters are untouched by construction).
//!
//! # Tenant partitioning
//!
//! The heap is divided into per-tenant address ranges by a
//! [`TenantLayout`]: tenant `t` owns one contiguous cell-index range
//! holding its vertices' degree + adjacency slots. Every ingested
//! [`Op`] executes through a [`PartitionView`] that panics (and is
//! quarantined by the batch layer) on any access outside the op's
//! declared tenants — single-tenant edges touch one range,
//! cross-tenant [`Op::Bridge`] transactions touch exactly two, and
//! conflicts between them resolve through the existing window chain
//! like any other cross-block dependency.
//!
//! # Lifecycle
//!
//! [`ServeSession::run`] spins the worker pool up, runs the caller's
//! driver closure on the session thread with a [`ServeHandle`]
//! (submit / snapshot / status / quiesce), and tears everything down
//! when the driver returns: producers close, the pipeline drains,
//! workers join, and the final [`ServeReport`] folds the batch
//! report with the serving-plane metrics (ingest rate, queue-depth
//! peak, snapshot age, read-latency histogram, per-tenant read
//! counts). A panicking driver still closes the ingress first, so
//! the pool always joins.

pub mod ingress;
pub mod snapshot;

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::batch::adaptive::BlockSizeController;
use crate::batch::mvmemory::MvMemory;
use crate::batch::{BatchReport, BatchSystem, BatchTxn};
use crate::engine::serve::ServeController;
use crate::mem::{Addr, TxHeap, WORDS_PER_LINE};
use crate::obs::hist::LatencyHist;
use crate::runtime::workers::PoolConfig;
use crate::tm::access::{DirectAccess, TxAccess, TxResult};

pub use ingress::{Closed, Ingress, Ticketed};
pub use snapshot::{ReadStats, SnapshotHandle, VersionLog};

/// Per-tenant address-space partitioning of the heap: tenant `t`
/// owns the contiguous cell range `[base(t), base(t) + span())`,
/// holding `verts` vertices of one degree cell plus `cap` adjacency
/// slots each. The first heap line stays reserved (address 0 is the
/// global null sentinel).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TenantLayout {
    pub tenants: usize,
    pub verts: usize,
    /// Adjacency slots per vertex (degree may exceed it; the list
    /// clamps).
    pub cap: usize,
}

impl TenantLayout {
    pub fn new(tenants: usize, verts: usize, cap: usize) -> Self {
        Self {
            tenants: tenants.max(1),
            verts: verts.max(1),
            cap: cap.max(1),
        }
    }

    /// Cells per tenant partition.
    pub fn span(&self) -> usize {
        self.verts * (1 + self.cap)
    }

    /// First cell of tenant `t`'s partition.
    pub fn base(&self, t: usize) -> Addr {
        debug_assert!(t < self.tenants, "tenant {t} out of range");
        WORDS_PER_LINE + t * self.span()
    }

    /// Tenant `t`'s cell range as `[start, end)`.
    pub fn range(&self, t: usize) -> (Addr, Addr) {
        (self.base(t), self.base(t) + self.span())
    }

    /// Heap words the full layout needs.
    pub fn heap_cells(&self) -> usize {
        WORDS_PER_LINE + self.tenants * self.span()
    }

    /// A heap sized for this layout.
    pub fn make_heap(&self) -> TxHeap {
        TxHeap::new(self.heap_cells())
    }

    /// Degree cell of vertex `v` in tenant `t`.
    pub fn degree_addr(&self, t: usize, v: usize) -> Addr {
        debug_assert!(v < self.verts, "vertex {v} out of range");
        self.base(t) + v * (1 + self.cap)
    }

    /// `i`-th adjacency slot of vertex `v` in tenant `t`.
    pub fn nbr_addr(&self, t: usize, v: usize, i: usize) -> Addr {
        debug_assert!(i < self.cap, "adjacency slot {i} out of range");
        self.degree_addr(t, v) + 1 + i
    }

    /// Which tenant owns `addr` (`None` for the reserved line or
    /// past the last partition).
    pub fn tenant_of(&self, addr: Addr) -> Option<usize> {
        let off = addr.checked_sub(WORDS_PER_LINE)?;
        let t = off / self.span();
        (t < self.tenants).then_some(t)
    }
}

/// One ingested graph mutation. `Copy` data only — the admission
/// path moves ops into `'static` transaction bodies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Directed edge `u -> v` inside one tenant's partition.
    Edge { tenant: usize, u: usize, v: usize },
    /// Cross-tenant transaction: edge `u -> v` in `from` and the
    /// mirror `v -> u` in `to`, atomically (one batch txn).
    Bridge {
        from: usize,
        to: usize,
        u: usize,
        v: usize,
    },
}

impl Op {
    /// The (at most two) tenant partitions this op is allowed to
    /// touch — the writer-isolation contract [`PartitionView`]
    /// enforces.
    pub fn tenants(&self) -> [Option<usize>; 2] {
        match *self {
            Op::Edge { tenant, .. } => [Some(tenant), None],
            Op::Bridge { from, to, .. } => [Some(from), Some(to)],
        }
    }

    /// Execute against any [`TxAccess`] — the same body runs
    /// speculatively inside the batch pipeline and directly in the
    /// sequential oracle, so the determinism suite compares like
    /// with like. Adjacency insert is dedup-scan-then-append: the
    /// degree cell counts distinct insertions, the list clamps at
    /// the layout's capacity.
    pub fn apply(&self, layout: &TenantLayout, t: &mut dyn TxAccess) -> TxResult<()> {
        match *self {
            Op::Edge { tenant, u, v } => add_edge(layout, t, tenant, u, v),
            Op::Bridge { from, to, u, v } => {
                add_edge(layout, t, from, u, v)?;
                add_edge(layout, t, to, v, u)
            }
        }
    }
}

fn add_edge(
    layout: &TenantLayout,
    t: &mut dyn TxAccess,
    tenant: usize,
    u: usize,
    v: usize,
) -> TxResult<()> {
    let (u, v) = (u % layout.verts, v % layout.verts);
    let d_addr = layout.degree_addr(tenant, u);
    let deg = t.read(d_addr)?;
    let cap = layout.cap as u64;
    for i in 0..deg.min(cap) as usize {
        if t.read(layout.nbr_addr(tenant, u, i))? == v as u64 {
            return Ok(()); // duplicate edge: no-op
        }
    }
    if deg < cap {
        t.write(layout.nbr_addr(tenant, u, deg as usize), v as u64)?;
    }
    t.write(d_addr, deg + 1)
}

/// Apply `ops` in order through [`DirectAccess`] — the sequential
/// oracle the serving determinism suite compares final heaps
/// against.
pub fn apply_sequential(heap: &TxHeap, layout: &TenantLayout, ops: &[Op]) {
    let mut acc = DirectAccess { heap };
    for op in ops {
        op.apply(layout, &mut acc)
            .expect("direct access cannot abort");
    }
}

/// Writer-isolation guard: a [`TxAccess`] adapter that panics on any
/// access outside the declared tenant partitions. Inside the batch
/// pipeline the panic is caught by the quarantine machinery, so a
/// buggy (or hostile) op body cannot scribble on another tenant's
/// range — it gets quarantined instead.
pub struct PartitionView<'a> {
    inner: &'a mut dyn TxAccess,
    layout: TenantLayout,
    allowed: [Option<usize>; 2],
}

impl<'a> PartitionView<'a> {
    pub fn new(
        inner: &'a mut dyn TxAccess,
        layout: TenantLayout,
        allowed: [Option<usize>; 2],
    ) -> Self {
        Self {
            inner,
            layout,
            allowed,
        }
    }

    fn check(&self, addr: Addr) {
        let t = self.layout.tenant_of(addr);
        let ok = t.is_some_and(|t| self.allowed.iter().any(|a| *a == Some(t)));
        assert!(
            ok,
            "tenant-partition violation: addr {addr} (tenant {t:?}) outside {:?}",
            self.allowed
        );
    }
}

impl TxAccess for PartitionView<'_> {
    fn read(&mut self, addr: Addr) -> TxResult<u64> {
        self.check(addr);
        self.inner.read(addr)
    }

    fn write(&mut self, addr: Addr, val: u64) -> TxResult<()> {
        self.check(addr);
        self.inner.write(addr, val)
    }
}

/// Knobs of one serving session.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Producer handles feeding the ingress.
    pub producers: usize,
    /// Pipeline worker threads.
    pub workers: usize,
    /// Pipelined window depth (W).
    pub window: usize,
    /// Max operations per admission block (the drain bound).
    pub block: usize,
    /// Per-producer bounded-queue capacity (backpressure point).
    pub queue_cap: usize,
    /// Drive the admission block cap from the `--policy auto`
    /// meta-controller ([`crate::engine::serve::ServeController`]).
    pub auto_policy: bool,
    /// Pin pool workers.
    pub pin: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            producers: 2,
            workers: 2,
            window: 2,
            block: 64,
            queue_cap: 256,
            auto_policy: false,
            pin: false,
        }
    }
}

/// Point-in-time counters for a running session.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStatus {
    pub horizon: u64,
    pub queue_depth: u64,
    pub submitted: u64,
    pub drained: u64,
    pub promoted_txns: u64,
    pub promoted_blocks: u64,
    pub served_reads: u64,
}

/// Final accounting of one session: the folded pipeline report plus
/// the serving-plane metrics.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub batch: BatchReport,
    /// Operations accepted by the ingress (== promoted once the
    /// session drained cleanly).
    pub submitted: u64,
    pub promoted_txns: u64,
    pub promoted_blocks: u64,
    /// Snapshot queries served, total and per tenant.
    pub served_reads: u64,
    pub reads_by_tenant: Vec<u64>,
    /// Promoted operations per second over the session.
    pub ingest_rate: f64,
    /// Peak queued operations observed at promotion boundaries.
    pub queue_depth_peak: u64,
    /// Nanoseconds between the last promotion and session end — how
    /// stale a fresh snapshot was at shutdown.
    pub snapshot_age_ns: u64,
    /// Serving-latency histogram across all snapshot queries.
    pub read_lat: LatencyHist,
    /// Backend switches the auto meta-controller made mid-stream.
    pub policy_switches: u64,
    /// Snapshot-log reclamation: peak live / retired / reclaimed
    /// trimmed version cells.
    pub log_live_peak_cells: u64,
    pub log_retired_cells: u64,
    pub log_reclaimed_cells: u64,
}

struct ServeShared {
    ingress: Ingress,
    log: VersionLog,
    stats: ReadStats,
    layout: TenantLayout,
    ctl: Option<Mutex<ServeController>>,
    promoted_txns: AtomicU64,
    promoted_blocks: AtomicU64,
    last_promote_ns: AtomicU64,
    queue_peak: AtomicU64,
}

/// The driver's window into a running session. `Copy`: hand clones
/// to scoped producer/reader threads freely.
#[derive(Clone, Copy)]
pub struct ServeHandle<'s> {
    shared: &'s ServeShared,
    heap: &'s TxHeap,
}

impl<'s> ServeHandle<'s> {
    /// Submit one op on producer `p` (blocking on a full queue);
    /// returns its per-producer ticket.
    pub fn submit(&self, p: usize, op: Op) -> Result<u64, Closed> {
        self.shared.ingress.submit(p, op)
    }

    /// Close one producer; its queued ops still drain.
    pub fn close_producer(&self, p: usize) {
        self.shared.ingress.close(p);
    }

    /// Close every producer (ends the stream; the driver returning
    /// does this implicitly).
    pub fn close(&self) {
        self.shared.ingress.close_all();
    }

    /// Take an abort-free snapshot pinned at the current promoted
    /// horizon. Queries on the handle are attributed to the
    /// session's read stats.
    pub fn snapshot(&self) -> SnapshotHandle<'s> {
        self.shared
            .log
            .snapshot(self.heap, self.shared.layout, Some(&self.shared.stats))
    }

    pub fn layout(&self) -> TenantLayout {
        self.shared.layout
    }

    pub fn status(&self) -> ServeStatus {
        let (submitted, drained) = self.shared.ingress.totals();
        ServeStatus {
            horizon: self.shared.log.horizon(),
            queue_depth: self.shared.ingress.queue_depth(),
            submitted,
            drained,
            promoted_txns: self.shared.promoted_txns.load(Ordering::SeqCst),
            promoted_blocks: self.shared.promoted_blocks.load(Ordering::SeqCst),
            served_reads: self.shared.stats.served.load(Ordering::Relaxed),
        }
    }

    /// Wait until everything submitted so far has been promoted (a
    /// read-your-writes barrier: a snapshot taken after `quiesce`
    /// observes every prior `submit` from this thread).
    pub fn quiesce(&self) {
        loop {
            let (submitted, drained) = self.shared.ingress.totals();
            if drained == submitted
                && self.shared.promoted_txns.load(Ordering::SeqCst) == submitted
            {
                return;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }
}

/// The continuous-serving session (see module docs).
pub struct ServeSession;

impl ServeSession {
    fn txn_of(layout: TenantLayout, t: Ticketed) -> BatchTxn<'static> {
        let op = t.op;
        BatchTxn::new(move |acc| {
            let mut view = PartitionView::new(acc, layout, op.tenants());
            op.apply(&layout, &mut view)
        })
    }

    /// Run one session: spin up the pipelined pool over `heap`, call
    /// `driver` with a [`ServeHandle`] on the calling thread, and
    /// tear down when it returns (producers close, the window
    /// drains, workers join). Returns the session report and the
    /// driver's result.
    pub fn run<R>(
        heap: &TxHeap,
        layout: TenantLayout,
        cfg: &ServeConfig,
        driver: impl FnOnce(ServeHandle<'_>) -> R,
    ) -> (ServeReport, R) {
        assert!(
            heap.capacity() >= layout.heap_cells(),
            "heap too small for layout: {} < {}",
            heap.capacity(),
            layout.heap_cells()
        );
        let t0 = Instant::now();
        let shared = ServeShared {
            ingress: Ingress::new(cfg.producers, cfg.queue_cap),
            log: VersionLog::new(),
            stats: ReadStats::new(layout.tenants),
            layout,
            ctl: cfg
                .auto_policy
                .then(|| Mutex::new(ServeController::new())),
            promoted_txns: AtomicU64::new(0),
            promoted_blocks: AtomicU64::new(0),
            last_promote_ns: AtomicU64::new(0),
            queue_peak: AtomicU64::new(0),
        };
        let shared = &shared;
        let pool = PoolConfig {
            workers: cfg.workers.max(1),
            pin: cfg.pin,
        };
        let mut ctl = BlockSizeController::fixed(cfg.block.max(1)).with_window(cfg.window.max(1));

        // Source: drained ingress chunks become admission blocks.
        // The auto meta-controller (when on) caps the drain size —
        // small blocks in the high-conflict (latency) regime, the
        // full pipeline block in the sparse (throughput) regime.
        let source = move |size: usize| {
            let cap = match &shared.ctl {
                Some(c) => c.lock().unwrap().drain_cap(),
                None => usize::MAX,
            };
            shared.ingress.drain(size.min(cap)).map(|chunk| {
                chunk
                    .into_iter()
                    .map(|t| Self::txn_of(layout, t))
                    .collect()
            })
        };

        // Promotion hook: absorb the block into the snapshot log
        // (before its write-back — the log's whole consistency story
        // leans on this ordering), then feed the meta-controller.
        let on_promote = move |seq: u64, mv: &MvMemory, rep: &BatchReport| {
            shared.log.absorb(seq, mv, heap);
            shared.promoted_blocks.fetch_add(1, Ordering::SeqCst);
            shared
                .promoted_txns
                .fetch_add(rep.txns as u64, Ordering::SeqCst);
            shared
                .last_promote_ns
                .store(t0.elapsed().as_nanos() as u64, Ordering::SeqCst);
            shared
                .queue_peak
                .fetch_max(shared.ingress.queue_depth(), Ordering::SeqCst);
            if let Some(c) = &shared.ctl {
                c.lock().unwrap().observe_block(rep);
            }
        };

        let (batch, out) = BatchSystem::run_pipelined_session::<MvMemory, _, R, _, _>(
            heap,
            source,
            &pool,
            &mut ctl,
            || {
                let r = catch_unwind(AssertUnwindSafe(|| driver(ServeHandle { shared, heap })));
                // Driver done (or unwinding): end ingestion so the
                // pipeline drains and the pool joins either way.
                shared.ingress.close_all();
                match r {
                    Ok(v) => v,
                    Err(p) => resume_unwind(p),
                }
            },
            on_promote,
        );

        let (submitted, _) = shared.ingress.totals();
        let read_lat = shared.stats.lat.fold();
        let lc = shared.log.counters();
        let elapsed = t0.elapsed();
        let promoted_txns = shared.promoted_txns.load(Ordering::SeqCst);
        let ingest_rate = if elapsed.as_secs_f64() > 0.0 {
            promoted_txns as f64 / elapsed.as_secs_f64()
        } else {
            0.0
        };
        let snapshot_age_ns = (elapsed.as_nanos() as u64)
            .saturating_sub(shared.last_promote_ns.load(Ordering::SeqCst));
        let report = ServeReport {
            submitted,
            promoted_txns,
            promoted_blocks: shared.promoted_blocks.load(Ordering::SeqCst),
            served_reads: shared.stats.served.load(Ordering::Relaxed),
            reads_by_tenant: shared
                .stats
                .by_tenant
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            ingest_rate,
            queue_depth_peak: shared.queue_peak.load(Ordering::SeqCst),
            snapshot_age_ns,
            read_lat,
            policy_switches: shared
                .ctl
                .as_ref()
                .map_or(0, |c| c.lock().unwrap().switches()),
            log_live_peak_cells: lc.live_peak_cells,
            log_retired_cells: lc.retired_cells,
            log_reclaimed_cells: lc.reclaimed_cells,
            batch,
        };
        crate::obs::snapshot::record(
            "serve",
            "session",
            &report.batch.to_stats(),
            &[
                ("ingest_rate", format!("{:.1}", report.ingest_rate)),
                ("queue_depth", report.queue_depth_peak.to_string()),
                ("snapshot_age_ns", report.snapshot_age_ns.to_string()),
                ("serve_read_p99_ns", report.read_lat.p99().to_string()),
            ],
        );
        (report, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> TenantLayout {
        TenantLayout::new(2, 8, 4)
    }

    #[test]
    fn layout_partitions_are_disjoint_and_cover() {
        let lay = layout();
        let (s0, e0) = lay.range(0);
        let (s1, e1) = lay.range(1);
        assert_eq!(s0, WORDS_PER_LINE);
        assert_eq!(e0, s1, "partitions tile the heap contiguously");
        assert_eq!(e1, lay.heap_cells());
        for addr in 0..lay.heap_cells() + 4 {
            let expect = if addr < s0 {
                None
            } else if addr < e0 {
                Some(0)
            } else if addr < e1 {
                Some(1)
            } else {
                None
            };
            assert_eq!(lay.tenant_of(addr), expect, "addr {addr}");
        }
        // Address math round-trips through tenant_of.
        for t in 0..lay.tenants {
            for v in 0..lay.verts {
                assert_eq!(lay.tenant_of(lay.degree_addr(t, v)), Some(t));
                assert_eq!(lay.tenant_of(lay.nbr_addr(t, v, lay.cap - 1)), Some(t));
            }
        }
    }

    #[test]
    fn op_apply_dedups_and_clamps() {
        let lay = layout();
        let heap = lay.make_heap();
        let ops = [
            Op::Edge { tenant: 0, u: 1, v: 2 },
            Op::Edge { tenant: 0, u: 1, v: 2 }, // duplicate
            Op::Edge { tenant: 0, u: 1, v: 3 },
            Op::Bridge { from: 0, to: 1, u: 1, v: 5 },
        ];
        apply_sequential(&heap, &lay, &ops);
        // Vertex 1 in tenant 0: neighbors 2, 3, 5 (dup dropped).
        assert_eq!(heap.load(lay.degree_addr(0, 1)), 3);
        assert_eq!(heap.load(lay.nbr_addr(0, 1, 0)), 2);
        assert_eq!(heap.load(lay.nbr_addr(0, 1, 1)), 3);
        assert_eq!(heap.load(lay.nbr_addr(0, 1, 2)), 5);
        // The bridge mirrored 5 -> 1 into tenant 1.
        assert_eq!(heap.load(lay.degree_addr(1, 5)), 1);
        assert_eq!(heap.load(lay.nbr_addr(1, 5, 0)), 1);
        // Capacity clamp: degree keeps counting, the list stops.
        let more = [
            Op::Edge { tenant: 0, u: 1, v: 6 },
            Op::Edge { tenant: 0, u: 1, v: 7 },
            Op::Edge { tenant: 0, u: 1, v: 4 },
        ];
        apply_sequential(&heap, &lay, &more);
        assert_eq!(heap.load(lay.degree_addr(0, 1)), 6);
        assert_eq!(heap.load(lay.nbr_addr(0, 1, 3)), 6, "last slot filled");
    }

    #[test]
    fn partition_view_blocks_cross_tenant_access() {
        let lay = layout();
        let heap = lay.make_heap();
        // In-partition access passes through.
        {
            let mut acc = DirectAccess { heap: &heap };
            let mut view = PartitionView::new(&mut acc, lay, [Some(0), None]);
            Op::Edge { tenant: 0, u: 0, v: 1 }
                .apply(&lay, &mut view)
                .unwrap();
        }
        assert_eq!(heap.load(lay.degree_addr(0, 0)), 1);
        // Out-of-partition access panics (the quarantine signal).
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut acc = DirectAccess { heap: &heap };
            let mut view = PartitionView::new(&mut acc, lay, [Some(0), None]);
            Op::Edge { tenant: 1, u: 0, v: 1 }.apply(&lay, &mut view)
        }));
        assert!(err.is_err(), "cross-tenant write must be rejected");
        assert_eq!(heap.load(lay.degree_addr(1, 0)), 0, "nothing leaked");
        // A bridge's two declared tenants are both allowed.
        {
            let mut acc = DirectAccess { heap: &heap };
            let op = Op::Bridge { from: 0, to: 1, u: 2, v: 3 };
            let mut view = PartitionView::new(&mut acc, lay, op.tenants());
            op.apply(&lay, &mut view).unwrap();
        }
        assert_eq!(heap.load(lay.degree_addr(1, 3)), 1);
    }

    #[test]
    fn session_round_trip_matches_sequential_oracle() {
        let lay = layout();
        let heap = lay.make_heap();
        let cfg = ServeConfig {
            producers: 2,
            workers: 2,
            window: 2,
            block: 4,
            ..ServeConfig::default()
        };
        // Two producer sequences with an intra- and cross-tenant mix.
        let seq0: Vec<Op> = (0..20)
            .map(|i| Op::Edge { tenant: 0, u: i % 8, v: (i + 1) % 8 })
            .collect();
        let seq1: Vec<Op> = (0..20)
            .map(|i| {
                if i % 5 == 0 {
                    Op::Bridge { from: 0, to: 1, u: i % 8, v: (i + 3) % 8 }
                } else {
                    Op::Edge { tenant: 1, u: i % 8, v: (i + 2) % 8 }
                }
            })
            .collect();
        let (rep, reads) = ServeSession::run(&heap, lay, &cfg, |h| {
            std::thread::scope(|s| {
                let h0 = h;
                let q0 = &seq0;
                s.spawn(move || {
                    for &op in q0 {
                        h0.submit(0, op).unwrap();
                    }
                    h0.close_producer(0);
                });
                let q1 = &seq1;
                s.spawn(move || {
                    for &op in q1 {
                        h0.submit(1, op).unwrap();
                    }
                    h0.close_producer(1);
                });
            });
            h.quiesce();
            let snap = h.snapshot();
            (snap.degree(0, 1), snap.degree(1, 3), snap.horizon())
        });
        assert_eq!(rep.submitted, 40);
        assert_eq!(rep.promoted_txns, 40);
        assert!(rep.promoted_blocks >= 1);
        assert!(rep.served_reads >= 2);

        // Oracle: the deterministic round-robin merge, sequentially.
        let oracle_heap = lay.make_heap();
        let merged = ingress::round_robin_merge(&[seq0, seq1]);
        apply_sequential(&oracle_heap, &lay, &merged);
        for addr in 0..lay.heap_cells() {
            assert_eq!(
                heap.load(addr),
                oracle_heap.load(addr),
                "heap diverged from oracle at addr {addr}"
            );
        }
        let (d0, d1, horizon) = reads;
        assert_eq!(d0, oracle_heap.load(lay.degree_addr(0, 1)));
        assert_eq!(d1, oracle_heap.load(lay.degree_addr(1, 3)));
        assert!(horizon >= 1);
    }
}
