//! Multi-producer ticketed ingress with a deterministic merge order.
//!
//! N producer handles feed per-producer bounded queues; the pipeline's
//! source drains them into admission blocks. The merge is a **strict
//! round-robin**: the drain cursor visits producers in index order,
//! taking one operation per visit, and — crucially — *stops* (rather
//! than skips) at a producer that is open but momentarily empty. A
//! producer only leaves the rotation once it is closed *and* drained.
//! Two consequences:
//!
//! - **Determinism.** The merged operation order is a pure function of
//!   the per-producer operation sequences and their close points
//!   (both fixed by the workload seed), independent of thread timing:
//!   timing can only move *block boundaries*, and the batch layer
//!   guarantees block partitioning never changes the final heap.
//!   The oracle replay is therefore computable offline: rotate
//!   producers `0..N`, one op each, dropping a producer once its
//!   sequence is exhausted.
//! - **Head-of-line blocking.** A stalled producer stalls admission
//!   (the price of a deterministic merge). Producers are expected to
//!   either feed promptly or close.
//!
//! Every accepted operation gets a per-producer **ticket** (its index
//! in that producer's sequence); `pushed`/`drained` totals let the
//! session prove exactly-once ingestion per ticket even under the
//! fault plane: a dropped wakeup (injected on the submit notify path
//! when a [`crate::fault`] spec is armed) is recovered by the drain's
//! bounded wait, never by re-queueing.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::Op;

/// How long a drain sleeps before re-scanning when every ready
/// producer is empty — the recovery bound for dropped wakeups.
const DRAIN_RECHECK: Duration = Duration::from_millis(5);

/// One accepted operation plus its provenance: `ticket` is the
/// 0-based index in `producer`'s own submission sequence.
#[derive(Clone, Copy, Debug)]
pub struct Ticketed {
    pub producer: usize,
    pub ticket: u64,
    pub op: Op,
}

/// Error returned to a submit on a closed producer handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Closed;

struct MergeState {
    queues: Vec<VecDeque<Ticketed>>,
    closed: Vec<bool>,
    /// Next producer the round-robin merge visits.
    cursor: usize,
    /// Accepted submissions per producer (the next ticket).
    pushed: Vec<u64>,
    /// Operations handed to the pipeline, total.
    drained: u64,
}

/// The sharded bounded ingress (see module docs).
pub struct Ingress {
    state: Mutex<MergeState>,
    /// Signalled on submit and close: data may be available.
    data: Condvar,
    /// Signalled on drain and close: queue space may be available.
    space: Condvar,
    cap: usize,
}

impl Ingress {
    /// `producers` bounded queues of `cap` operations each.
    pub fn new(producers: usize, cap: usize) -> Self {
        let n = producers.max(1);
        Self {
            state: Mutex::new(MergeState {
                queues: (0..n).map(|_| VecDeque::new()).collect(),
                closed: vec![false; n],
                cursor: 0,
                pushed: vec![0; n],
                drained: 0,
            }),
            data: Condvar::new(),
            space: Condvar::new(),
            cap: cap.max(1),
        }
    }

    pub fn producers(&self) -> usize {
        self.state.lock().unwrap().queues.len()
    }

    /// Submit one operation on producer `p`, blocking while its queue
    /// is full (bounded ingress = backpressure, not loss). Returns
    /// the operation's ticket, or [`Closed`] once the producer has
    /// been closed. The wakeup of a waiting drain is subject to
    /// `WakeupDrop` fault injection; the drain's bounded re-check
    /// recovers without ever double-queueing the operation.
    pub fn submit(&self, p: usize, op: Op) -> Result<u64, Closed> {
        let mut st = self.state.lock().unwrap();
        assert!(p < st.queues.len(), "producer index {p} out of range");
        loop {
            if st.closed[p] {
                return Err(Closed);
            }
            if st.queues[p].len() < self.cap {
                break;
            }
            st = self.space.wait(st).unwrap();
        }
        let ticket = st.pushed[p];
        st.pushed[p] += 1;
        st.queues[p].push_back(Ticketed {
            producer: p,
            ticket,
            op,
        });
        drop(st);
        if !crate::fault::inject(crate::fault::Site::WakeupDrop) {
            self.data.notify_all();
        }
        Ok(ticket)
    }

    /// Close producer `p`: no further submits are accepted; already
    /// queued operations still drain. Once its queue empties the
    /// producer leaves the merge rotation for good.
    pub fn close(&self, p: usize) {
        let mut st = self.state.lock().unwrap();
        st.closed[p] = true;
        drop(st);
        self.data.notify_all();
        self.space.notify_all();
    }

    /// Close every producer (session shutdown).
    pub fn close_all(&self) {
        let mut st = self.state.lock().unwrap();
        for c in st.closed.iter_mut() {
            *c = true;
        }
        drop(st);
        self.data.notify_all();
        self.space.notify_all();
    }

    /// Pull the next admission block: up to `max` operations in strict
    /// round-robin merge order. Returns a non-empty partial block as
    /// soon as the rotation hits an open-but-empty producer (the
    /// pipeline should not idle on a slow producer when it already
    /// has work), blocks while *nothing* is available, and returns
    /// `None` once every producer is closed and drained.
    pub fn drain(&self, max: usize) -> Option<Vec<Ticketed>> {
        let max = max.max(1);
        let mut st = self.state.lock().unwrap();
        loop {
            let n = st.queues.len();
            let mut out: Vec<Ticketed> = Vec::new();
            let mut finished = 0usize;
            // Scan at most one full rotation of stalled producers
            // between takes; `finished` counts consecutive
            // closed-and-drained skips so a lap of the dead detects
            // end-of-stream.
            while out.len() < max && finished < n {
                let p = st.cursor;
                if let Some(t) = st.queues[p].pop_front() {
                    out.push(t);
                    st.drained += 1;
                    finished = 0;
                    st.cursor = (p + 1) % n;
                } else if st.closed[p] {
                    // Closed and drained: leaves the rotation.
                    finished += 1;
                    st.cursor = (p + 1) % n;
                } else {
                    // Open but empty: stop the merge here — the
                    // cursor stays on `p` so the next drain resumes
                    // at exactly this point of the rotation.
                    break;
                }
            }
            if !out.is_empty() {
                drop(st);
                self.space.notify_all();
                return Some(out);
            }
            if finished == n {
                return None; // every producer closed and drained
            }
            // Nothing ready: bounded wait (recovers dropped wakeups).
            let (next, _) = self.data.wait_timeout(st, DRAIN_RECHECK).unwrap();
            st = next;
        }
    }

    /// Operations currently queued across all producers (sampled).
    pub fn queue_depth(&self) -> u64 {
        let st = self.state.lock().unwrap();
        st.queues.iter().map(|q| q.len() as u64).sum()
    }

    /// Accepted submissions per producer so far.
    pub fn pushed(&self) -> Vec<u64> {
        self.state.lock().unwrap().pushed.clone()
    }

    /// `(total accepted, total drained)` — equal once the session has
    /// pulled everything that was ever submitted.
    pub fn totals(&self) -> (u64, u64) {
        let st = self.state.lock().unwrap();
        (st.pushed.iter().sum(), st.drained)
    }
}

/// Offline replay of the merge order [`Ingress::drain`] produces for
/// the given per-producer sequences (each producer closing after its
/// last op): one op per open producer per rotation, a producer
/// leaving the rotation once exhausted. The serving determinism
/// suite feeds this to the sequential oracle — the runtime merge
/// equals it regardless of thread timing, because a drain never
/// *skips* an open producer (it stops and waits instead).
pub fn round_robin_merge(seqs: &[Vec<Op>]) -> Vec<Op> {
    let mut idx = vec![0usize; seqs.len()];
    let mut out = Vec::new();
    let mut remaining: usize = seqs.iter().map(|s| s.len()).sum();
    while remaining > 0 {
        for (p, s) in seqs.iter().enumerate() {
            if idx[p] < s.len() {
                out.push(s[idx[p]]);
                idx[p] += 1;
                remaining -= 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(tenant: usize, u: usize, v: usize) -> Op {
        Op::Edge { tenant, u, v }
    }

    #[test]
    fn tickets_count_per_producer_submissions() {
        let ing = Ingress::new(2, 8);
        assert_eq!(ing.submit(0, op(0, 0, 1)), Ok(0));
        assert_eq!(ing.submit(0, op(0, 1, 2)), Ok(1));
        assert_eq!(ing.submit(1, op(0, 2, 3)), Ok(0));
        assert_eq!(ing.pushed(), vec![2, 1]);
        ing.close(0);
        assert_eq!(ing.submit(0, op(0, 3, 4)), Err(Closed));
        // Queued ops survive the close.
        assert_eq!(ing.totals(), (3, 0));
    }

    #[test]
    fn drain_merges_strict_round_robin_and_stops_at_open_empty() {
        let ing = Ingress::new(3, 8);
        // Producer 0: a,b ; producer 1: c ; producer 2: (empty, open).
        ing.submit(0, op(0, 0, 1)).unwrap();
        ing.submit(0, op(0, 0, 2)).unwrap();
        ing.submit(1, op(0, 1, 1)).unwrap();
        let chunk = ing.drain(16).unwrap();
        // Rotation 0,1 then stop at open-but-empty 2.
        let order: Vec<(usize, u64)> = chunk.iter().map(|t| (t.producer, t.ticket)).collect();
        assert_eq!(order, vec![(0, 0), (1, 0)]);
        // Cursor stayed on 2; once 2 closes, the rotation resumes
        // there and picks up 0's remaining op.
        ing.close(2);
        let chunk = ing.drain(16).unwrap();
        let order: Vec<(usize, u64)> = chunk.iter().map(|t| (t.producer, t.ticket)).collect();
        assert_eq!(order, vec![(0, 1)]);
        ing.close_all();
        assert!(ing.drain(16).is_none(), "closed and drained ends the stream");
    }

    #[test]
    fn drain_takes_multiple_laps_up_to_max() {
        let ing = Ingress::new(2, 8);
        for i in 0..3 {
            ing.submit(0, op(0, i, i + 1)).unwrap();
            ing.submit(1, op(1, i, i + 1)).unwrap();
        }
        ing.close_all();
        let chunk = ing.drain(4).unwrap();
        let order: Vec<(usize, u64)> = chunk.iter().map(|t| (t.producer, t.ticket)).collect();
        assert_eq!(order, vec![(0, 0), (1, 0), (0, 1), (1, 1)], "two laps");
        let rest = ing.drain(16).unwrap();
        assert_eq!(rest.len(), 2);
        assert!(ing.drain(16).is_none());
        assert_eq!(ing.totals(), (6, 6));
    }

    #[test]
    fn bounded_queue_applies_backpressure_not_loss() {
        let ing = std::sync::Arc::new(Ingress::new(1, 2));
        ing.submit(0, op(0, 0, 1)).unwrap();
        ing.submit(0, op(0, 0, 2)).unwrap();
        let w = {
            let ing = ing.clone();
            std::thread::spawn(move || ing.submit(0, op(0, 0, 3)))
        };
        // The third submit blocks until a drain frees a slot.
        std::thread::sleep(Duration::from_millis(20));
        assert!(!w.is_finished(), "submit must block on a full queue");
        let chunk = ing.drain(1).unwrap();
        assert_eq!(chunk.len(), 1);
        assert_eq!(w.join().unwrap(), Ok(2));
        assert_eq!(ing.totals(), (3, 1));
    }

    #[test]
    fn drain_order_equals_offline_round_robin_replay() {
        // Uneven sequences, submitted up front: the live merge must
        // equal the offline replay op for op.
        let seqs: Vec<Vec<Op>> = vec![
            (0..5).map(|i| op(0, i, i + 1)).collect(),
            (0..2).map(|i| op(1, i, i + 1)).collect(),
            (0..7).map(|i| op(2, i, i + 1)).collect(),
        ];
        let ing = Ingress::new(3, 16);
        for (p, s) in seqs.iter().enumerate() {
            for &o in s {
                ing.submit(p, o).unwrap();
            }
            ing.close(p);
        }
        let mut live = Vec::new();
        while let Some(chunk) = ing.drain(3) {
            live.extend(chunk.into_iter().map(|t| t.op));
        }
        assert_eq!(live, round_robin_merge(&seqs));
    }

    #[test]
    fn concurrent_producers_deliver_every_ticket_exactly_once() {
        const PER: u64 = 200;
        let ing = std::sync::Arc::new(Ingress::new(4, 16));
        let mut handles = Vec::new();
        for p in 0..4usize {
            let ing = ing.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER {
                    let t = ing.submit(p, op(p, i as usize % 7, i as usize % 5)).unwrap();
                    assert_eq!(t, i, "tickets are the producer-local sequence");
                }
                ing.close(p);
            }));
        }
        let mut seen: Vec<Vec<bool>> = vec![vec![false; PER as usize]; 4];
        while let Some(chunk) = ing.drain(32) {
            for t in chunk {
                assert!(
                    !std::mem::replace(&mut seen[t.producer][t.ticket as usize], true),
                    "ticket ({}, {}) delivered twice",
                    t.producer,
                    t.ticket
                );
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(seen.iter().flatten().all(|&s| s), "every ticket delivered");
        assert_eq!(ing.totals(), (4 * PER, 4 * PER));
    }
}
