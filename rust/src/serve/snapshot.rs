//! Abort-free snapshot reads over the promotion stream.
//!
//! The serving plane's read side never touches the scheduler: a
//! [`VersionLog`] absorbs each promoted block's winning `(addr,
//! value)` pairs *before* that block writes back to the heap (the
//! `on_promote` hook of `BatchSystem::run_pipelined_session` fires at
//! exactly that point, under the window lock), so a
//! [`SnapshotHandle`] pinned at promoted-block horizon `K` can keep
//! answering reads as of block `K` forever — wait-free with respect
//! to writers, zero aborts by construction.
//!
//! # Consistency protocol
//!
//! The log's `horizon` is the number of promoted blocks absorbed so
//! far; a snapshot at horizon `h` observes exactly the blocks with
//! admission sequence `< h`. Three orderings make that exact under
//! concurrent promotions:
//!
//! 1. **Insert before publish.** `absorb(seq, ..)` pushes every
//!    winning version into the log *before* storing `horizon = seq +
//!    1` (SeqCst). A snapshot reads the horizon with a SeqCst load,
//!    so reading `h` synchronizes with the store that published it:
//!    every version of every block `< h` is visible to that
//!    snapshot's reads.
//! 2. **Publish before write-back.** The hook runs before
//!    `write_back`, whose heap stores are `store_release`. A reader
//!    that misses an address in the log falls back to an
//!    acquire-load of the heap and then re-checks the log: if the
//!    heap value came from some block's write-back, the acquire load
//!    synchronizes with that release store, making the (earlier)
//!    log insert visible to the re-check — so the raw heap value is
//!    only ever used when *no* promoted block wrote the address,
//!    where it is correct at every horizon.
//! 3. **Horizon before trim, pin under the trim lock.** `absorb`
//!    publishes the new horizon *before* computing the minimum
//!    pinned horizon and trimming, and `pin_snapshot` reads the
//!    horizon *inside* the pins lock. A snapshot racing a trim
//!    therefore either registers first (its horizon bounds the trim)
//!    or sees the already-advanced horizon (consistent with the
//!    trim).
//!
//! # Memory
//!
//! Version chains are trimmed at every absorb: below the minimum
//! pinned horizon only the newest version of each address is
//! reachable by any current or future snapshot, so everything older
//! is unlinked and retired through the log's own epoch-reclamation
//! domain ([`crate::mem::epoch::EpochGc`]). With no pins each
//! address converges to a single node — a continuous session's log
//! stays flat. An old pin holds exactly the nodes its horizon can
//! reach while younger garbage keeps reclaiming, so the domain's
//! `live_peak_cells` plateaus instead of growing (the serving
//! analogue of the store's bounded-memory property).

use std::collections::BTreeMap;
use std::ptr;
use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::batch::mvmemory::MvStore;
use crate::mem::epoch::{EpochGc, GcCounters};
use crate::mem::{Addr, TxHeap};
use crate::obs::hist::AtomicHist;

use super::TenantLayout;

/// Bucket count of the address index. Power of two; the log holds at
/// most one entry per distinct heap address ever written by a
/// promoted block, so load factor tracks the touched footprint.
const LOG_BUCKETS: usize = 1024;

/// One version of one address: written by the block with admission
/// sequence `seq`. Immutable after publication except `next`, which
/// only the (serialized) absorber rewrites when trimming.
struct VerNode {
    seq: u64,
    value: u64,
    next: AtomicPtr<VerNode>,
}

/// Per-address chain head. `base` is the heap value from before any
/// promoted block wrote the address (captured pre-write-back on
/// first insert); `versions` is a descending-`seq` chain of winners.
/// Entries are never removed until the log drops.
struct LogEntry {
    addr: Addr,
    base: u64,
    versions: AtomicPtr<VerNode>,
    next: AtomicPtr<LogEntry>,
}

/// An unlinked descending chain of [`VerNode`]s, retired into the
/// log's epoch domain; `Drop` frees the whole chain.
struct RetiredChain(*mut VerNode);

// SAFETY: the chain is exclusively owned once unlinked (the absorber
// is serialized and readers can no longer reach it — see the trim
// invariant on `VersionLog::absorb`).
unsafe impl Send for RetiredChain {}

impl Drop for RetiredChain {
    fn drop(&mut self) {
        let mut cur = self.0;
        while !cur.is_null() {
            let boxed = unsafe { Box::from_raw(cur) };
            cur = boxed.next.load(Ordering::Relaxed);
        }
    }
}

/// Shared read-side counters for one serving session: total queries
/// served, per-tenant attribution, and the serving-latency
/// histogram (p99 feeds the session report and the bench cells).
#[derive(Debug)]
pub struct ReadStats {
    pub served: AtomicU64,
    pub by_tenant: Box<[AtomicU64]>,
    pub lat: AtomicHist,
}

impl ReadStats {
    pub fn new(tenants: usize) -> Self {
        Self {
            served: AtomicU64::new(0),
            by_tenant: (0..tenants.max(1)).map(|_| AtomicU64::new(0)).collect(),
            lat: AtomicHist::default(),
        }
    }

    fn note(&self, tenant: usize, t0: Instant) {
        self.served.fetch_add(1, Ordering::Relaxed);
        if let Some(c) = self.by_tenant.get(tenant) {
            c.fetch_add(1, Ordering::Relaxed);
        }
        self.lat.record_duration(t0.elapsed());
    }
}

/// The multi-version snapshot log (see the module docs for the
/// protocol). One per serving session; the absorber (promotion hook)
/// is the only writer and is serialized by the pipeline's window
/// lock, while any number of snapshot readers run concurrently.
pub struct VersionLog {
    buckets: Box<[AtomicPtr<LogEntry>]>,
    /// Promoted blocks absorbed so far — the horizon the next
    /// snapshot pins.
    horizon: AtomicU64,
    /// Refcounts of live snapshot horizons; the minimum bounds every
    /// trim.
    pins: Mutex<BTreeMap<u64, usize>>,
    /// The log's own reclamation domain: trimmed chains retire here,
    /// readers take transient reader pins while traversing.
    gc: EpochGc,
}

impl VersionLog {
    pub fn new() -> Self {
        Self::with_reclaim(crate::batch::reclaim_enabled())
    }

    /// A log whose trim either frees through epoch reclamation
    /// (`reclaim` on — the default, following the session-wide
    /// `MV_RECLAIM` switch) or parks garbage in limbo until the log
    /// drops (`reclaim` off — the A/B baseline).
    pub fn with_reclaim(reclaim: bool) -> Self {
        Self {
            buckets: (0..LOG_BUCKETS)
                .map(|_| AtomicPtr::new(ptr::null_mut()))
                .collect(),
            horizon: AtomicU64::new(0),
            pins: Mutex::new(BTreeMap::new()),
            gc: EpochGc::with_reclaim(1, reclaim),
        }
    }

    /// Promoted-block count absorbed so far.
    pub fn horizon(&self) -> u64 {
        self.horizon.load(Ordering::SeqCst)
    }

    /// Counter snapshot of the log's reclamation domain
    /// (`live_peak_cells` is the plateau metric).
    pub fn counters(&self) -> GcCounters {
        self.gc.counters()
    }

    fn bucket(&self, addr: Addr) -> &AtomicPtr<LogEntry> {
        // Same multiplicative hash as the store's shard map.
        let h = (addr as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        &self.buckets[(h >> (64 - 10)) as usize & (LOG_BUCKETS - 1)]
    }

    fn find(&self, addr: Addr) -> Option<&LogEntry> {
        let mut cur = self.bucket(addr).load(Ordering::Acquire);
        while !cur.is_null() {
            let e = unsafe { &*cur };
            if e.addr == addr {
                return Some(e);
            }
            cur = e.next.load(Ordering::Acquire);
        }
        None
    }

    /// Find or insert the entry for `addr`. Absorber-only (the single
    /// serialized writer): inserts are head-pushed with a release
    /// store, and `base` captures the heap value *before* this
    /// block's write-back — which is the pre-promotion-stream value,
    /// because an entry is only missing when no earlier block wrote
    /// the address (its absorb would have inserted it).
    fn entry_for(&self, addr: Addr, heap: &TxHeap) -> &LogEntry {
        if let Some(e) = self.find(addr) {
            return e;
        }
        let bucket = self.bucket(addr);
        let head = bucket.load(Ordering::Relaxed);
        let e = Box::into_raw(Box::new(LogEntry {
            addr,
            base: heap.load(addr),
            versions: AtomicPtr::new(ptr::null_mut()),
            next: AtomicPtr::new(head),
        }));
        bucket.store(e, Ordering::Release);
        unsafe { &*e }
    }

    /// Absorb promoted block `seq`'s winning versions. Must be called
    /// from the pipeline's `on_promote` hook (serialized, in
    /// admission order, before the block's write-back) — every
    /// precondition above leans on that.
    pub fn absorb<M: MvStore>(&self, seq: u64, mv: &M, heap: &TxHeap) {
        let mut touched: Vec<*const LogEntry> = Vec::new();
        mv.for_each_winning(&mut |addr, value| {
            let e = self.entry_for(addr, heap);
            let head = e.versions.load(Ordering::Relaxed);
            debug_assert!(
                head.is_null() || unsafe { &*head }.seq < seq,
                "absorb out of order at addr {addr}"
            );
            let node = Box::into_raw(Box::new(VerNode {
                seq,
                value,
                next: AtomicPtr::new(head),
            }));
            e.versions.store(node, Ordering::Release);
            touched.push(e as *const _);
        });
        // Publish the new horizon BEFORE trimming (protocol step 3).
        self.horizon.store(seq + 1, Ordering::SeqCst);

        // Trim each touched chain below the minimum pinned horizon:
        // for any horizon `h >= min_h`, the first node with
        // `node.seq < h` appears at or before the first node with
        // `node.seq < min_h` (the chain is seq-descending), so
        // everything past that node is unreachable by every live and
        // future snapshot and can retire. Untouched entries keep
        // their (single, post-previous-trim) tail until next touched.
        {
            let pins = self.pins.lock().unwrap();
            let min_h = pins
                .keys()
                .next()
                .copied()
                .unwrap_or(seq + 1)
                .min(seq + 1);
            for &ep in &touched {
                let e = unsafe { &*ep };
                let mut cur = e.versions.load(Ordering::Relaxed);
                while !cur.is_null() && unsafe { &*cur }.seq >= min_h {
                    cur = unsafe { &*cur }.next.load(Ordering::Relaxed);
                }
                if cur.is_null() {
                    continue;
                }
                // `cur` is the newest node every horizon >= min_h can
                // still reach; everything older is dead.
                let keep = unsafe { &*cur };
                let dead = keep.next.swap(ptr::null_mut(), Ordering::SeqCst);
                if dead.is_null() {
                    continue;
                }
                let mut n = dead;
                let mut cells = 0u64;
                while !n.is_null() {
                    cells += 1;
                    n = unsafe { &*n }.next.load(Ordering::Relaxed);
                }
                let bytes = cells * std::mem::size_of::<VerNode>() as u64;
                self.gc.retire(Box::new(RetiredChain(dead)), cells, bytes);
            }
        }
        // One epoch lap per promotion: last lap's garbage is past
        // every reader pinned before it and frees now.
        self.gc.advance();
        self.gc.try_reclaim();
    }

    /// Register a snapshot pin at the current horizon and return it.
    /// The horizon load happens *inside* the pins lock (protocol
    /// step 3). Prefer [`VersionLog::snapshot`].
    pub fn pin_snapshot(&self) -> u64 {
        let mut pins = self.pins.lock().unwrap();
        let h = self.horizon.load(Ordering::SeqCst);
        *pins.entry(h).or_insert(0) += 1;
        h
    }

    fn unpin(&self, h: u64) {
        let mut pins = self.pins.lock().unwrap();
        match pins.get_mut(&h) {
            Some(n) if *n > 1 => *n -= 1,
            Some(_) => {
                pins.remove(&h);
            }
            None => debug_assert!(false, "unpin of unregistered horizon {h}"),
        }
    }

    /// Take an abort-free snapshot at the current horizon.
    pub fn snapshot<'a>(
        &'a self,
        heap: &'a TxHeap,
        layout: TenantLayout,
        stats: Option<&'a ReadStats>,
    ) -> SnapshotHandle<'a> {
        let h = self.pin_snapshot();
        SnapshotHandle {
            log: self,
            heap,
            layout,
            stats,
            h,
        }
    }

    /// Value of `addr` as of horizon `h`.
    fn read_at(&self, addr: Addr, h: u64, heap: &TxHeap) -> u64 {
        // Transient reader pin: holds the log's epoch while we chase
        // pointers (defense in depth — the trim invariant already
        // keeps everything we can reach alive via the pins map).
        let _pin = self.gc.pin_reader();
        if let Some(e) = self.find(addr) {
            return Self::chain_read(e, h);
        }
        // Fallback (protocol step 2): acquire-load the heap, then
        // re-check the log before trusting it.
        let raw = heap.load_acquire(addr);
        match self.find(addr) {
            Some(e) => Self::chain_read(e, h),
            None => raw,
        }
    }

    fn chain_read(e: &LogEntry, h: u64) -> u64 {
        let mut cur = e.versions.load(Ordering::Acquire);
        while !cur.is_null() {
            let n = unsafe { &*cur };
            if n.seq < h {
                return n.value;
            }
            cur = n.next.load(Ordering::Acquire);
        }
        e.base
    }
}

impl Default for VersionLog {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for VersionLog {
    fn drop(&mut self) {
        for b in self.buckets.iter() {
            let mut cur = b.load(Ordering::SeqCst);
            while !cur.is_null() {
                let e = unsafe { Box::from_raw(cur) };
                drop(RetiredChain(e.versions.load(Ordering::SeqCst)));
                cur = e.next.load(Ordering::SeqCst);
            }
        }
    }
}

// SAFETY: all shared state is atomics or mutex-guarded; raw entry
// and node pointers are published with release stores and only freed
// under the exclusive-ownership rules documented above.
unsafe impl Send for VersionLog {}
unsafe impl Sync for VersionLog {}

/// An abort-free read view pinned at promoted-block horizon
/// [`SnapshotHandle::horizon`]: observes exactly the blocks with
/// admission sequence below it, forever, regardless of concurrent
/// promotions. Reads never enter the scheduler, take no locks on the
/// write path, and cannot abort; dropping the handle releases the
/// pin (letting the log trim past it).
pub struct SnapshotHandle<'a> {
    log: &'a VersionLog,
    heap: &'a TxHeap,
    layout: TenantLayout,
    stats: Option<&'a ReadStats>,
    h: u64,
}

impl SnapshotHandle<'_> {
    /// The pinned horizon: promoted blocks `< horizon()` are
    /// visible, everything younger never is.
    pub fn horizon(&self) -> u64 {
        self.h
    }

    /// Raw cell read at this snapshot's horizon.
    pub fn read(&self, addr: Addr) -> u64 {
        self.log.read_at(addr, self.h, self.heap)
    }

    /// Degree of vertex `v` in tenant `t`'s partition.
    pub fn degree(&self, t: usize, v: usize) -> u64 {
        let t0 = Instant::now();
        let d = self.read(self.layout.degree_addr(t, v));
        if let Some(s) = self.stats {
            s.note(t, t0);
        }
        d
    }

    /// Adjacency list of vertex `v` in tenant `t`'s partition
    /// (clamped to the layout's neighbor capacity).
    pub fn neighbors(&self, t: usize, v: usize) -> Vec<u64> {
        let t0 = Instant::now();
        let out = self.neighbors_raw(t, v);
        if let Some(s) = self.stats {
            s.note(t, t0);
        }
        out
    }

    fn neighbors_raw(&self, t: usize, v: usize) -> Vec<u64> {
        let deg = self.read(self.layout.degree_addr(t, v));
        let n = (deg as usize).min(self.layout.cap);
        (0..n)
            .map(|i| self.read(self.layout.nbr_addr(t, v, i)))
            .collect()
    }

    /// Bounded-depth reachability probe from `src` to `dst` inside
    /// tenant `t`'s partition: BFS over the snapshot's adjacency,
    /// at most `max_hops` levels.
    pub fn reachable(&self, t: usize, src: usize, dst: usize, max_hops: usize) -> bool {
        let t0 = Instant::now();
        let hit = self.reachable_raw(t, src, dst, max_hops);
        if let Some(s) = self.stats {
            s.note(t, t0);
        }
        hit
    }

    fn reachable_raw(&self, t: usize, src: usize, dst: usize, max_hops: usize) -> bool {
        if src == dst {
            return true;
        }
        let verts = self.layout.verts;
        let mut seen = vec![false; verts];
        seen[src % verts] = true;
        let mut frontier = vec![src % verts];
        for _ in 0..max_hops {
            let mut next = Vec::new();
            for &u in &frontier {
                for w in self.neighbors_raw(t, u) {
                    let w = w as usize % verts;
                    if w == dst {
                        return true;
                    }
                    if !seen[w] {
                        seen[w] = true;
                        next.push(w);
                    }
                }
            }
            if next.is_empty() {
                return false;
            }
            frontier = next;
        }
        false
    }
}

impl Drop for SnapshotHandle<'_> {
    fn drop(&mut self) {
        self.log.unpin(self.h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::mvmemory::MvMemory;

    fn layout() -> TenantLayout {
        TenantLayout::new(1, 8, 4)
    }

    fn absorb_writes(log: &VersionLog, seq: u64, heap: &TxHeap, writes: &[(Addr, u64)]) {
        let mv = <MvMemory as MvStore>::new(writes.len().max(1));
        for (i, &(addr, value)) in writes.iter().enumerate() {
            mv.record((i, 0), Vec::new(), &[(addr, value)]);
        }
        log.absorb(seq, &mv, heap);
    }

    #[test]
    fn snapshot_sees_exactly_blocks_below_its_horizon() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(2);
        heap.store(a, 7);
        let log = VersionLog::new();

        let s0 = log.snapshot(&heap, layout(), None);
        assert_eq!(s0.horizon(), 0);
        assert_eq!(s0.read(a), 7, "horizon 0 sees the initial heap");

        absorb_writes(&log, 0, &heap, &[(a, 10)]);
        let s1 = log.snapshot(&heap, layout(), None);
        absorb_writes(&log, 1, &heap, &[(a, 20), (a + 1, 5)]);
        let s2 = log.snapshot(&heap, layout(), None);

        // Old snapshots hold their horizon after younger promotions.
        assert_eq!(s0.read(a), 7);
        assert_eq!(s1.read(a), 10);
        assert_eq!(s2.read(a), 20);
        // An address first written at block 1 reads base below it.
        assert_eq!(s1.read(a + 1), 0);
        assert_eq!(s2.read(a + 1), 5);
    }

    #[test]
    fn unabsorbed_address_falls_back_to_heap() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(2);
        heap.store(a, 3);
        heap.store(a + 1, 9);
        let log = VersionLog::new();
        absorb_writes(&log, 0, &heap, &[(a, 4)]);
        let s = log.snapshot(&heap, layout(), None);
        assert_eq!(s.read(a), 4);
        assert_eq!(s.read(a + 1), 9, "never-written address reads the heap");
    }

    #[test]
    fn unpinned_chains_trim_to_one_node_per_address() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(1);
        let log = VersionLog::new();
        for seq in 0..50u64 {
            absorb_writes(&log, seq, &heap, &[(a, 100 + seq)]);
        }
        let c = log.counters();
        // 50 versions were pushed; with no pins every absorb trims
        // the previous one, so 49 retired and (modulo the final
        // epoch lap) nearly all reclaimed — live stays O(1).
        assert_eq!(c.retired_cells, 49, "each absorb supersedes one node");
        assert!(
            c.live_peak_cells <= 2,
            "unpinned log must stay flat, live peak {}",
            c.live_peak_cells
        );
        let s = log.snapshot(&heap, layout(), None);
        assert_eq!(s.read(a), 149);
    }

    #[test]
    fn pinned_snapshot_holds_horizon_while_younger_garbage_reclaims() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(1);
        let log = VersionLog::new();
        absorb_writes(&log, 0, &heap, &[(a, 1000)]);
        let pinned = log.snapshot(&heap, layout(), None);
        assert_eq!(pinned.horizon(), 1);

        for seq in 1..40u64 {
            absorb_writes(&log, seq, &heap, &[(a, 1000 + seq)]);
        }
        let mid = log.counters();
        // The pin protects exactly one node (block 0's version);
        // everything between the pin and each new horizon still
        // trims, so reclamation keeps pace: live is bounded by a
        // small constant, not by the 39 younger versions.
        assert!(
            mid.reclaimed_cells >= mid.retired_cells.saturating_sub(3),
            "younger epochs must keep reclaiming around the pin: {mid:?}"
        );
        assert!(
            mid.live_peak_cells <= 3,
            "pinned log live peak must plateau, got {}",
            mid.live_peak_cells
        );
        assert_eq!(pinned.read(a), 1000, "pin still answers at its horizon");

        drop(pinned);
        absorb_writes(&log, 40, &heap, &[(a, 2000)]);
        let s = log.snapshot(&heap, layout(), None);
        assert_eq!(s.read(a), 2000);
    }

    #[test]
    fn reclaim_disabled_log_parks_garbage_in_limbo() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(1);
        let log = VersionLog::with_reclaim(false);
        for seq in 0..10u64 {
            absorb_writes(&log, seq, &heap, &[(a, seq)]);
        }
        let c = log.counters();
        assert_eq!(c.retired_cells, 9);
        assert_eq!(c.reclaimed_cells, 0, "A/B baseline: nothing frees early");
        let s = log.snapshot(&heap, layout(), None);
        assert_eq!(s.read(a), 9);
    }

    #[test]
    fn graph_queries_read_one_consistent_horizon() {
        let lay = layout();
        let heap = lay.make_heap();
        let log = VersionLog::new();
        // Block 0: edge 0 -> 1 (degree 1, slot 0 = 1).
        absorb_writes(
            &log,
            0,
            &heap,
            &[(lay.degree_addr(0, 0), 1), (lay.nbr_addr(0, 0, 0), 1)],
        );
        let s1 = log.snapshot(&heap, lay, None);
        // Block 1: edge 1 -> 2.
        absorb_writes(
            &log,
            1,
            &heap,
            &[(lay.degree_addr(0, 1), 1), (lay.nbr_addr(0, 1, 0), 2)],
        );
        let s2 = log.snapshot(&heap, lay, None);

        assert_eq!(s1.degree(0, 0), 1);
        assert_eq!(s1.neighbors(0, 0), vec![1]);
        assert!(s1.reachable(0, 0, 1, 4));
        assert!(
            !s1.reachable(0, 0, 2, 4),
            "snapshot 1 must not see block 1's edge"
        );
        assert!(s2.reachable(0, 0, 2, 4), "two hops across both blocks");
        assert!(!s2.reachable(0, 2, 0, 4), "directed: no reverse path");
    }

    #[test]
    fn read_stats_attribute_queries_to_tenants() {
        let lay = TenantLayout::new(2, 4, 2);
        let heap = lay.make_heap();
        let log = VersionLog::new();
        let stats = ReadStats::new(lay.tenants);
        let s = log.snapshot(&heap, lay, Some(&stats));
        s.degree(0, 1);
        s.neighbors(1, 0);
        s.degree(1, 2);
        assert_eq!(stats.served.load(Ordering::Relaxed), 3);
        assert_eq!(stats.by_tenant[0].load(Ordering::Relaxed), 1);
        assert_eq!(stats.by_tenant[1].load(Ordering::Relaxed), 2);
        assert_eq!(stats.lat.fold().count(), 3);
    }

    #[test]
    fn concurrent_readers_race_absorbs_without_tearing() {
        // Readers pin a horizon and hammer reads while the absorber
        // streams promotions; every read must return the value of
        // some block strictly below the reader's horizon (or base),
        // never a torn or future value.
        let heap = TxHeap::new(64);
        let a = heap.alloc(1);
        heap.store(a, 0);
        let log = VersionLog::new();
        const BLOCKS: u64 = 400;
        std::thread::scope(|s| {
            let log = &log;
            let heap = &heap;
            for _ in 0..3 {
                s.spawn(move || {
                    for _ in 0..200 {
                        let snap = log.snapshot(heap, layout(), None);
                        let h = snap.horizon();
                        let v = snap.read(a);
                        // Block k writes value k+1; horizon h admits
                        // blocks < h, i.e. values 0..=h.
                        assert!(
                            v <= h,
                            "snapshot at horizon {h} saw future value {v}"
                        );
                        drop(snap);
                    }
                });
            }
            s.spawn(move || {
                for seq in 0..BLOCKS {
                    absorb_writes(log, seq, heap, &[(a, seq + 1)]);
                }
            });
        });
        let s = log.snapshot(&heap, layout(), None);
        assert_eq!(s.read(a), BLOCKS);
    }
}
