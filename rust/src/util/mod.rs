//! Dependency-free utilities: deterministic RNG, a mini property-testing
//! harness, JSON scraping for the artifact manifest, and simple stats
//! helpers. The offline crate registry has no `rand`/`proptest`/`serde`,
//! so these are hand-rolled (DESIGN.md S16/S17).

pub mod json;
pub mod qcheck;
pub mod rng;
pub mod timer;
pub mod zipf;
