//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding, xoshiro256** for the main stream — the same
//! generators SSCA-2-style workloads typically use, and good enough for
//! R-MAT sampling and retry-count draws. No external crates (offline
//! registry has no `rand`).

/// SplitMix64: used to expand a single u64 seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, bound)` (Lemire-style widening multiply, no modulo
    /// bias worth caring about at our bounds).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 1234567 (checked against the public
        // SplitMix64 reference implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn rng_deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(9);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive_endpoints_reachable() {
        let mut r = Rng::new(11);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
            lo_seen |= x == 3;
            hi_seen |= x == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(13);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for c in counts {
            assert!((9_000..11_000).contains(&c), "bucket {c}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
