//! Mini property-based testing harness (the offline registry has no
//! proptest). Seeded generation + bounded shrinking over a `u64` seed
//! space: on failure we report the seed so the case replays exactly.
//!
//! Usage (`no_run`: doctest binaries don't inherit the xla rpath
//! rustflags):
//! ```no_run
//! use dyadhytm::util::qcheck::qcheck;
//! qcheck("addition commutes", 200, |rng| (rng.next_u32(), rng.next_u32()),
//!        |&(a, b)| a as u64 + b as u64 == b as u64 + a as u64);
//! ```

use super::rng::Rng;

/// Run `iters` random cases of `prop` over values drawn by `gen`.
/// Panics with the failing seed + debug repr on the first failure.
pub fn qcheck<T: std::fmt::Debug>(
    name: &str,
    iters: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> bool,
) {
    // Base seed is fixed so CI is deterministic; vary locally by editing.
    let base = 0xDA2A_0001u64;
    for i in 0..iters {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        if !prop(&case) {
            panic!(
                "qcheck '{name}' failed at iter {i} (seed {seed:#x}):\n  case = {case:?}"
            );
        }
    }
}

/// Like `qcheck` but the property returns `Result<(), String>` for richer
/// failure messages.
pub fn qcheck_res<T: std::fmt::Debug>(
    name: &str,
    iters: u64,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let base = 0xDA2A_0002u64;
    for i in 0..iters {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "qcheck '{name}' failed at iter {i} (seed {seed:#x}): {msg}\n  case = {case:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        qcheck("u32 roundtrip", 100, |r| r.next_u32(), |&x| {
            x as u64 <= u32::MAX as u64
        });
    }

    #[test]
    #[should_panic(expected = "qcheck 'always false'")]
    fn failing_property_panics_with_seed() {
        qcheck("always false", 10, |r| r.next_u32(), |_| false);
    }

    #[test]
    fn res_variant_reports_message() {
        qcheck_res("ok", 10, |r| r.next_u64(), |_| Ok(()));
    }
}
