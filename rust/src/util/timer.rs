//! Wall-clock timing helpers for the bench harness (no criterion in the
//! offline registry; benches are plain `harness = false` binaries).

use std::time::{Duration, Instant};

/// Time a closure, returning (result, elapsed).
pub fn time<R>(f: impl FnOnce() -> R) -> (R, Duration) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed())
}

/// Run `f` `iters` times, returning per-iteration stats in nanoseconds.
pub fn bench_ns(warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    BenchStats::from_samples(samples)
}

/// Simple order statistics over nanosecond samples.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub samples: Vec<u64>,
    pub mean: f64,
    pub median: u64,
    pub p95: u64,
    pub min: u64,
    pub max: u64,
}

impl BenchStats {
    pub fn from_samples(mut samples: Vec<u64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let mean = samples.iter().sum::<u64>() as f64 / n as f64;
        Self {
            median: samples[n / 2],
            p95: samples[(n * 95 / 100).min(n - 1)],
            min: samples[0],
            max: samples[n - 1],
            mean,
            samples,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_order() {
        let s = BenchStats::from_samples(vec![5, 1, 9, 3, 7]);
        assert_eq!(s.min, 1);
        assert_eq!(s.max, 9);
        assert_eq!(s.median, 5);
        assert!((s.mean - 5.0).abs() < 1e-9);
    }

    #[test]
    fn time_returns_result() {
        let (r, d) = time(|| 2 + 2);
        assert_eq!(r, 4);
        assert!(d.as_nanos() > 0);
    }
}
