//! Zipf-distributed sampling — the access skew of real-world graphs
//! (the paper's §1 premise: big-data graphs are sparse but their hubs
//! are hot). Used by the contention microbenchmarks to sweep smoothly
//! between uniform (sparse, TM-friendly) and hub-dominated access.
//!
//! Rejection-free inverse-CDF sampler over `n` ranks with exponent `s`,
//! using a precomputed cumulative table (n is small in our benches).

use super::rng::Rng;

/// Zipf sampler over ranks `0..n` with exponent `s` (s = 0 → uniform).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    /// Draw a rank in `0..n` (rank 0 is the hottest).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        // Binary search the CDF.
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).expect("finite"))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability mass of rank 0 (diagnostics).
    pub fn p0(&self) -> f64 {
        self.cdf[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::qcheck::qcheck;

    #[test]
    fn uniform_when_s_zero() {
        let z = Zipf::new(16, 0.0);
        let mut rng = Rng::new(5);
        let mut counts = [0u32; 16];
        for _ in 0..64_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for c in counts {
            assert!((3_200..4_800).contains(&c), "bucket {c}");
        }
    }

    #[test]
    fn skewed_when_s_one() {
        let z = Zipf::new(64, 1.0);
        let mut rng = Rng::new(7);
        let mut counts = vec![0u32; 64];
        for _ in 0..64_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Rank 0 carries ~1/H_64 ~= 21% of the mass; last rank ~0.3%.
        assert!(counts[0] > 8 * counts[63].max(1), "{counts:?}");
        assert!((z.p0() - 0.21).abs() < 0.03);
    }

    #[test]
    fn prop_samples_in_range() {
        qcheck(
            "zipf in range",
            300,
            |r| {
                let n = 1 + r.below(100) as usize;
                let s = r.next_f64() * 2.0;
                (n, s, r.next_u64())
            },
            |&(n, s, seed)| {
                let z = Zipf::new(n, s);
                let mut rng = Rng::new(seed);
                (0..50).all(|_| z.sample(&mut rng) < n)
            },
        );
    }
}
