//! NOrec: global-sequence-lock STM with value-based validation.
//!
//! One word of global metadata (`seq`): even = quiescent, odd = a writer
//! is writing back. Readers log (addr, value) pairs; whenever the
//! sequence number moves they re-read every logged address and abort on
//! any change (value-based validation — no ownership records, hence the
//! name and the low fixed overhead). Writers serialize their write-back
//! through the sequence lock.
//!
//! This is the HyTM fallback STM: its `attempt` is always called with
//! the caller already holding [`crate::hytm::GblLock`] (counting
//! semantics, so multiple NOrec transactions do run concurrently — their
//! mutual conflicts are resolved right here).

use std::hint;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::mem::layout::PaddedAtomicU64;
use crate::mem::{Addr, TxHeap};
use crate::tm::access::{Abort, TxAccess, TxResult};
use crate::tm::AbortCause;

/// Shared NOrec state.
pub struct NorecEngine {
    pub heap: Arc<TxHeap>,
    seq: PaddedAtomicU64,
}

impl NorecEngine {
    pub fn new(heap: Arc<TxHeap>) -> Self {
        Self {
            heap,
            seq: PaddedAtomicU64::new(0),
        }
    }

    /// Current sequence number (diagnostics / HTM coupling tests).
    pub fn seq(&self) -> u64 {
        self.seq.load(Ordering::Acquire)
    }

    /// Spin until the sequence number is even (no writer in write-back),
    /// return it.
    #[inline]
    fn wait_quiescent(&self) -> u64 {
        loop {
            let s = self.seq.load(Ordering::Acquire);
            if s & 1 == 0 {
                return s;
            }
            hint::spin_loop();
        }
    }

    /// One software transaction attempt (`SW_BEGIN` .. `SW_COMMIT`).
    /// Returns `Err(SwConflict)` on validation failure — the caller
    /// (policy executor) retries, counting `SW_ABORT`s.
    pub fn attempt<R>(
        &self,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
    ) -> Result<R, AbortCause> {
        let mut txn = NorecTxn {
            engine: self,
            rv: self.wait_quiescent(),
            reads: Vec::with_capacity(32),
            writes: Vec::with_capacity(32),
        };

        let value = match body(&mut txn) {
            Ok(v) => v,
            Err(Abort(cause)) => return Err(cause),
        };

        txn.commit()?;
        Ok(value)
    }
}

struct NorecTxn<'e> {
    engine: &'e NorecEngine,
    /// Sequence number this transaction's reads are consistent with.
    rv: u64,
    /// Value log for validation.
    reads: Vec<(Addr, u64)>,
    /// Redo log, program order.
    writes: Vec<(Addr, u64)>,
}

impl NorecTxn<'_> {
    /// Re-read every logged address; abort if any value changed.
    /// On success, returns the new (even) sequence number.
    fn validate(&self) -> TxResult<u64> {
        loop {
            let s = self.engine.wait_quiescent();
            let mut ok = true;
            for &(addr, val) in &self.reads {
                if self.engine.heap.load_acquire(addr) != val {
                    ok = false;
                    break;
                }
            }
            if !ok {
                return Err(Abort(AbortCause::SwConflict));
            }
            // Validation is only meaningful if no writer slipped in
            // while we re-read; otherwise loop.
            if self.engine.seq.load(Ordering::Acquire) == s {
                return Ok(s);
            }
        }
    }

    fn commit(mut self) -> Result<(), AbortCause> {
        if self.writes.is_empty() {
            return Ok(()); // read-only: already consistent at rv
        }
        // Acquire the sequence lock at a validated snapshot.
        loop {
            match self.engine.seq.compare_exchange_weak(
                self.rv,
                self.rv + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(_) => {
                    // Someone committed since rv: revalidate, adopt the
                    // new snapshot, try again.
                    match self.validate() {
                        Ok(s) => self.rv = s,
                        Err(Abort(c)) => return Err(c),
                    }
                }
            }
        }
        // Write back in program order, then release.
        for &(addr, val) in &self.writes {
            self.engine.heap.store_release(addr, val);
        }
        self.engine.seq.store(self.rv + 2, Ordering::Release);
        Ok(())
    }
}

impl TxAccess for NorecTxn<'_> {
    fn read(&mut self, addr: Addr) -> TxResult<u64> {
        // Read-own-write.
        if let Some(&(_, v)) = self.writes.iter().rev().find(|&&(a, _)| a == addr) {
            return Ok(v);
        }
        // NOrec read protocol: read, then if the world moved, revalidate
        // and re-read until stable.
        loop {
            let val = self.engine.heap.load_acquire(addr);
            let s = self.engine.seq.load(Ordering::Acquire);
            if s == self.rv {
                self.reads.push((addr, val));
                return Ok(val);
            }
            self.rv = self.validate()?;
        }
    }

    fn write(&mut self, addr: Addr, val: u64) -> TxResult<()> {
        self.writes.push((addr, val));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> NorecEngine {
        NorecEngine::new(Arc::new(TxHeap::new(1 << 16)))
    }

    #[test]
    fn commit_publishes_and_bumps_seq() {
        let e = engine();
        let a = e.heap.alloc(1);
        let s0 = e.seq();
        let r = e.attempt(&mut |t: &mut dyn TxAccess| {
            t.write(a, 11)?;
            t.read(a)
        });
        assert_eq!(r.unwrap(), 11);
        assert_eq!(e.heap.load(a), 11);
        assert_eq!(e.seq(), s0 + 2);
        assert_eq!(e.seq() & 1, 0);
    }

    #[test]
    fn read_only_does_not_bump_seq() {
        let e = engine();
        let a = e.heap.alloc(1);
        let s0 = e.seq();
        e.attempt(&mut |t: &mut dyn TxAccess| t.read(a)).unwrap();
        assert_eq!(e.seq(), s0);
    }

    #[test]
    fn body_abort_propagates_and_discards_writes() {
        let e = engine();
        let a = e.heap.alloc(1);
        e.heap.store(a, 5);
        let r = e.attempt(&mut |t: &mut dyn TxAccess| {
            t.write(a, 99)?;
            Err::<(), _>(Abort(AbortCause::Explicit))
        });
        assert_eq!(r.unwrap_err(), AbortCause::Explicit);
        assert_eq!(e.heap.load(a), 5);
    }

    #[test]
    fn concurrent_counter_increments_exact() {
        let e = Arc::new(engine());
        let a = e.heap.alloc(1);
        const THREADS: usize = 4;
        const PER: u64 = 3000;
        let mut hs = Vec::new();
        for _ in 0..THREADS {
            let e = Arc::clone(&e);
            hs.push(std::thread::spawn(move || {
                let mut commits = 0;
                let mut aborts = 0u64;
                while commits < PER {
                    match e.attempt(&mut |t: &mut dyn TxAccess| {
                        let v = t.read(a)?;
                        t.write(a, v + 1)
                    }) {
                        Ok(_) => commits += 1,
                        Err(_) => aborts += 1,
                    }
                }
                aborts
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(e.heap.load(a), (THREADS as u64) * PER);
    }

    #[test]
    fn concurrent_transfers_conserve_sum() {
        let e = Arc::new(engine());
        let accounts: Vec<Addr> = (0..8).map(|_| e.heap.alloc_lines(1)).collect();
        for &acc in &accounts {
            e.heap.store(acc, 1000);
        }
        let mut hs = Vec::new();
        for tid in 0..4u64 {
            let e = Arc::clone(&e);
            let accounts = accounts.clone();
            hs.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(tid);
                let mut done = 0;
                while done < 2000 {
                    let from = accounts[rng.below(8) as usize];
                    let to = accounts[rng.below(8) as usize];
                    if from == to {
                        continue;
                    }
                    let r = e.attempt(&mut |t: &mut dyn TxAccess| {
                        let f = t.read(from)?;
                        if f == 0 {
                            return Ok(false);
                        }
                        let g = t.read(to)?;
                        t.write(from, f - 1)?;
                        t.write(to, g + 1)?;
                        Ok(true)
                    });
                    if r == Ok(true) {
                        done += 1;
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let total: u64 = accounts.iter().map(|&a| e.heap.load(a)).sum();
        assert_eq!(total, 8000, "value-based validation must not lose money");
    }

    #[test]
    fn snapshot_isolation_within_txn() {
        // A transaction that reads the same address twice must see the
        // same value even while writers churn (opacity smoke test).
        let e = Arc::new(engine());
        let a = e.heap.alloc(1);
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writer = {
            let e = Arc::clone(&e);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let _ = e.attempt(&mut |t: &mut dyn TxAccess| t.write(a, i));
                    i += 1;
                }
            })
        };
        for _ in 0..2000 {
            let r = e.attempt(&mut |t: &mut dyn TxAccess| {
                let x = t.read(a)?;
                let y = t.read(a)?;
                Ok((x, y))
            });
            if let Ok((x, y)) = r {
                assert_eq!(x, y, "torn snapshot");
            }
        }
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
    }
}
