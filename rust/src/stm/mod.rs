//! Software transactional memory (DESIGN.md S3/S4).
//!
//! Two designs, mirroring the paper's landscape discussion (§5):
//!
//! * [`norec`] — NOrec (Dalessandro et al., PPoPP'10): one global
//!   sequence lock, value-based validation, no ownership records. The
//!   lowest-overhead published STM and the closest open analogue to the
//!   "low overhead GCC STM" the paper uses as its fallback; also what
//!   Hybrid NOrec couples to RTM. **This is the HyTM fallback STM.**
//! * [`tl2`] — TL2 (Dice/Shalev/Shavit, DISC'06): per-line versioned
//!   locks + global version clock. Better writer scalability, higher
//!   per-access overhead. Used standalone and as the A2 ablation
//!   fallback.

pub mod norec;
pub mod tl2;

pub use norec::NorecEngine;
pub use tl2::Tl2Engine;
