//! TL2: versioned-lock STM with a global version clock.
//!
//! The "more complex, better writer scalability, higher overhead" design
//! point from the paper's related work (§5). Unlike NOrec, validation is
//! O(read set) only at commit (per-read it is O(1) against the clock),
//! and writers do not serialize write-backs — they lock disjoint
//! ownership records. Shares the orec machinery with the software HTM
//! but has **no capacity bound** — it is software, after all.

use std::sync::Arc;

use crate::mem::{Addr, Line, TxHeap};
use crate::tm::access::{Abort, TxAccess, TxResult};
use crate::tm::{AbortCause, GlobalClock, LockTable, OrecValue};

/// Shared TL2 state.
pub struct Tl2Engine {
    pub heap: Arc<TxHeap>,
    table: LockTable,
    clock: GlobalClock,
}

impl Tl2Engine {
    pub fn new(heap: Arc<TxHeap>) -> Self {
        Self {
            heap,
            table: LockTable::new(crate::tm::orec::DEFAULT_LOCK_TABLE_BITS),
            clock: GlobalClock::new(),
        }
    }

    /// One software transaction attempt. `owner` is the thread id used
    /// as lock identity.
    pub fn attempt<R>(
        &self,
        owner: u32,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
    ) -> Result<R, AbortCause> {
        let mut txn = Tl2Txn {
            engine: self,
            owner,
            rv: self.clock.now(),
            reads: Vec::with_capacity(32),
            writes: Vec::with_capacity(32),
        };
        let value = match body(&mut txn) {
            Ok(v) => v,
            Err(Abort(cause)) => return Err(cause),
        };
        txn.commit()?;
        Ok(value)
    }
}

struct Tl2Txn<'e> {
    engine: &'e Tl2Engine,
    owner: u32,
    rv: u64,
    reads: Vec<(Line, u64)>,
    writes: Vec<(Addr, u64)>,
}

impl Tl2Txn<'_> {
    #[inline]
    fn readable_version(&self, line: Line) -> TxResult<u64> {
        match self.engine.table.read(line) {
            OrecValue::Locked { .. } => Err(Abort(AbortCause::SwConflict)),
            OrecValue::Version(v) if v > self.rv => Err(Abort(AbortCause::SwConflict)),
            OrecValue::Version(v) => Ok(v),
        }
    }

    fn commit(self) -> Result<(), AbortCause> {
        if self.writes.is_empty() {
            return Ok(());
        }
        let mut wlines: Vec<Line> = self
            .writes
            .iter()
            .map(|&(a, _)| TxHeap::line_of(a))
            .collect();
        wlines.sort_unstable();
        wlines.dedup();

        let mut held: Vec<(Line, u64)> = Vec::with_capacity(wlines.len());
        let rollback = |held: &[(Line, u64)]| {
            for &(l, ov) in held {
                self.engine.table.unlock_restore(l, self.owner, ov);
            }
        };
        for &line in &wlines {
            let v = match self.engine.table.read(line) {
                OrecValue::Version(v) if v <= self.rv => v,
                _ => {
                    rollback(&held);
                    return Err(AbortCause::SwConflict);
                }
            };
            if self.engine.table.try_lock(line, v, self.owner) {
                held.push((line, v));
            } else {
                rollback(&held);
                return Err(AbortCause::SwConflict);
            }
        }

        let wv = self.engine.clock.tick();

        for &(line, seen) in &self.reads {
            match self.engine.table.read(line) {
                OrecValue::Version(v) if v == seen => {}
                OrecValue::Locked { owner } if owner == self.owner => {
                    let pre = held.iter().find(|&&(l, _)| l == line).map(|&(_, v)| v);
                    if pre != Some(seen) {
                        rollback(&held);
                        return Err(AbortCause::SwConflict);
                    }
                }
                _ => {
                    rollback(&held);
                    return Err(AbortCause::SwConflict);
                }
            }
        }

        for &(addr, val) in &self.writes {
            self.engine.heap.store_release(addr, val);
        }
        for &(line, _) in &held {
            self.engine.table.unlock(line, self.owner, wv);
        }
        Ok(())
    }
}

impl TxAccess for Tl2Txn<'_> {
    fn read(&mut self, addr: Addr) -> TxResult<u64> {
        if let Some(&(_, v)) = self.writes.iter().rev().find(|&&(a, _)| a == addr) {
            return Ok(v);
        }
        let line = TxHeap::line_of(addr);
        // Post-load validation only (see htm/engine.rs read docs).
        let val = self.engine.heap.load_acquire(addr);
        let v1 = self.readable_version(line)?;
        if !self.reads.iter().any(|&(l, _)| l == line) {
            self.reads.push((line, v1));
        }
        Ok(val)
    }

    fn write(&mut self, addr: Addr, val: u64) -> TxResult<()> {
        self.writes.push((addr, val));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Tl2Engine {
        Tl2Engine::new(Arc::new(TxHeap::new(1 << 16)))
    }

    #[test]
    fn commit_publishes() {
        let e = engine();
        let a = e.heap.alloc(1);
        let r = e.attempt(0, &mut |t: &mut dyn TxAccess| {
            t.write(a, 77)?;
            t.read(a)
        });
        assert_eq!(r.unwrap(), 77);
        assert_eq!(e.heap.load(a), 77);
    }

    #[test]
    fn disjoint_writers_both_commit() {
        // TL2's design point vs NOrec: writers to disjoint lines do not
        // invalidate each other. Single-threaded check: commit A, then a
        // txn that read an unrelated line before A's commit... requires
        // interleaving; approximate with the concurrent stress below.
        let e = Arc::new(engine());
        let a = e.heap.alloc_lines(1);
        let b = e.heap.alloc_lines(1);
        let ea = Arc::clone(&e);
        let ha = std::thread::spawn(move || {
            for i in 0..5000u64 {
                ea.attempt(1, &mut |t: &mut dyn TxAccess| t.write(a, i))
                    .unwrap();
            }
        });
        let eb = Arc::clone(&e);
        let hb = std::thread::spawn(move || {
            for i in 0..5000u64 {
                eb.attempt(2, &mut |t: &mut dyn TxAccess| t.write(b, i))
                    .unwrap();
            }
        });
        ha.join().unwrap();
        hb.join().unwrap();
        assert_eq!(e.heap.load(a), 4999);
        assert_eq!(e.heap.load(b), 4999);
    }

    #[test]
    fn concurrent_counter_exact() {
        let e = Arc::new(engine());
        let a = e.heap.alloc(1);
        const THREADS: u32 = 4;
        const PER: u64 = 3000;
        let mut hs = Vec::new();
        for tid in 0..THREADS {
            let e = Arc::clone(&e);
            hs.push(std::thread::spawn(move || {
                let mut commits = 0;
                while commits < PER {
                    if e.attempt(tid, &mut |t: &mut dyn TxAccess| {
                        let v = t.read(a)?;
                        t.write(a, v + 1)
                    })
                    .is_ok()
                    {
                        commits += 1;
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(e.heap.load(a), THREADS as u64 * PER);
    }

    #[test]
    fn transfers_conserve_sum() {
        let e = Arc::new(engine());
        let accounts: Vec<Addr> = (0..8).map(|_| e.heap.alloc_lines(1)).collect();
        for &acc in &accounts {
            e.heap.store(acc, 500);
        }
        let mut hs = Vec::new();
        for tid in 0..4u32 {
            let e = Arc::clone(&e);
            let accounts = accounts.clone();
            hs.push(std::thread::spawn(move || {
                let mut rng = crate::util::rng::Rng::new(tid as u64 + 50);
                let mut done = 0;
                while done < 1500 {
                    let from = accounts[rng.below(8) as usize];
                    let to = accounts[rng.below(8) as usize];
                    if from == to {
                        continue;
                    }
                    if e.attempt(tid, &mut |t: &mut dyn TxAccess| {
                        let f = t.read(from)?;
                        let g = t.read(to)?;
                        t.write(from, f.wrapping_sub(1))?;
                        t.write(to, g + 1)?;
                        Ok(())
                    })
                    .is_ok()
                    {
                        done += 1;
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let total: u64 = accounts
            .iter()
            .map(|&a| e.heap.load(a) as i64 as u64)
            .fold(0u64, |s, v| s.wrapping_add(v));
        assert_eq!(total, 4000);
    }
}
