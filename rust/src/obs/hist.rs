//! Log-bucketed latency histograms (telemetry plane, DESIGN.md S14).
//!
//! Each histogram is a fixed array of power-of-two nanosecond buckets:
//! bucket 0 holds a latency of 0 ns, bucket `b >= 1` holds latencies in
//! `[2^(b-1), 2^b - 1]`. Recording is a branch-free index computation
//! plus one array increment, so per-worker instances can sit on the hot
//! path; aggregation happens after join through [`LatencyHist::merge`]
//! (element-wise sum — total count is preserved exactly, percentile
//! estimates are bucket upper bounds).

/// Number of buckets. Bucket 47's upper bound is `2^47 - 1` ns
/// (~39 hours) — anything larger clamps into the last bucket.
pub const BUCKETS: usize = 48;

/// A log2-bucketed histogram of nanosecond latencies.
#[derive(Clone, Copy, Debug)]
pub struct LatencyHist {
    counts: [u64; BUCKETS],
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self {
            counts: [0; BUCKETS],
        }
    }
}

/// Bucket index for a nanosecond value: 0 for 0 ns, else
/// `floor(log2(ns)) + 1`, clamped to the last bucket.
#[inline]
pub fn bucket_of(ns: u64) -> usize {
    ((64 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// Inclusive upper bound of a bucket in nanoseconds.
#[inline]
pub fn bucket_upper(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        (1u64 << b) - 1
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency sample.
    #[inline]
    pub fn record(&mut self, ns: u64) {
        self.counts[bucket_of(ns)] += 1;
    }

    #[inline]
    pub fn record_duration(&mut self, d: std::time::Duration) {
        self.record(d.as_nanos() as u64);
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Raw bucket counts (index = [`bucket_of`]).
    pub fn buckets(&self) -> &[u64; BUCKETS] {
        &self.counts
    }

    /// Bump bucket `b` by `n` — for folding externally accumulated
    /// (e.g. atomic) bucket arrays into an owned histogram.
    pub fn add_bucket(&mut self, b: usize, n: u64) {
        self.counts[b.min(BUCKETS - 1)] += n;
    }

    /// Element-wise sum: total count is the sum of both counts, and any
    /// percentile of the merged histogram lies between the inputs'
    /// percentiles (a quantile of a mixture is bounded by the
    /// components' quantiles).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
    }

    /// Upper bound (ns) of the bucket containing the `p`-quantile
    /// sample (`0.0 < p <= 1.0`). Returns 0 on an empty histogram.
    /// Monotone in `p`: `percentile(a) <= percentile(b)` for `a <= b`.
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((p * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper(b);
            }
        }
        bucket_upper(BUCKETS - 1)
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn p90(&self) -> u64 {
        self.percentile(0.90)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }
}

/// The shared-counter variant of [`LatencyHist`]: relaxed atomic
/// buckets, for recording from many workers at once (e.g.
/// `BatchCounters`). Recording is one relaxed `fetch_add` — lock-free,
/// like the counters it sits beside. Fold into an owned histogram with
/// [`AtomicHist::fold`] after the workers have joined.
#[derive(Debug)]
pub struct AtomicHist {
    counts: [std::sync::atomic::AtomicU64; BUCKETS],
}

impl Default for AtomicHist {
    fn default() -> Self {
        Self {
            counts: std::array::from_fn(|_| std::sync::atomic::AtomicU64::new(0)),
        }
    }
}

impl AtomicHist {
    #[inline]
    pub fn record(&self, ns: u64) {
        self.counts[bucket_of(ns)].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos() as u64);
    }

    pub fn fold(&self) -> LatencyHist {
        let mut h = LatencyHist::new();
        for (b, c) in self.counts.iter().enumerate() {
            h.add_bucket(b, c.load(std::sync::atomic::Ordering::Relaxed));
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_deterministic() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        // Every bucket's upper bound maps back into that bucket.
        for b in 0..BUCKETS - 1 {
            assert_eq!(bucket_of(bucket_upper(b)), b, "bucket {b}");
        }
    }

    #[test]
    fn percentiles_are_monotone_and_bucket_aligned() {
        let mut h = LatencyHist::new();
        for _ in 0..90 {
            h.record(100); // bucket 7, upper 127
        }
        for _ in 0..10 {
            h.record(10_000); // bucket 14, upper 16383
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.p50(), 127);
        assert_eq!(h.p90(), 127);
        assert_eq!(h.p99(), 16383);
        assert!(h.p50() <= h.p90() && h.p90() <= h.p99());
    }

    #[test]
    fn atomic_hist_folds_into_owned() {
        let a = AtomicHist::default();
        a.record(100);
        a.record(100);
        a.record(10_000);
        let h = a.fold();
        assert_eq!(h.count(), 3);
        assert_eq!(h.p50(), 127);
        assert_eq!(h.percentile(1.0), 16383);
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p99(), 0);
    }

    #[test]
    fn merge_preserves_count_and_bounds_percentiles() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        for i in 0..1000u64 {
            a.record(i);
        }
        for i in 0..500u64 {
            b.record(i * 100);
        }
        let (ca, cb) = (a.count(), b.count());
        let (pa, pb) = (a.p99(), b.p99());
        let mut m = a;
        m.merge(&b);
        assert_eq!(m.count(), ca + cb, "merge preserves total count");
        // The merged p99 sits between the inputs' p99s (mixture
        // quantile bound), and the merged percentiles stay monotone.
        assert!(m.p99() >= pa.min(pb) && m.p99() <= pa.max(pb));
        assert!(m.p50() <= m.p90() && m.p90() <= m.p99());
    }
}
