//! Per-worker lock-free ring-buffer event tracing.
//!
//! Each emitting thread is hashed onto one of [`RINGS`] fixed-capacity
//! ring buffers. A ring is an array of packed four-word records
//! (`[t_ns, kind|ring, a, b]`, 32 bytes) plus a cursor; emitting is one
//! relaxed `fetch_add` on the cursor and four relaxed stores — no locks
//! anywhere on the path. When tracing is disabled (the default) every
//! event site reduces to a single relaxed load and a branch (see the
//! overhead contract in [`crate::obs`]).
//!
//! Rings overwrite their oldest records when full (the cursor keeps
//! counting, so the drop count is reported). [`drain`] is meant for
//! after the traced run's threads have joined — the join provides the
//! happens-before edge that makes the relaxed record words safe to
//! read; draining mid-run may observe torn records and is only suitable
//! for diagnostics.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::tm::AbortCause;
use crate::util::json;

/// Number of per-worker rings. Threads beyond this share rings (the
/// cursor `fetch_add` keeps sharing race-free).
pub const RINGS: usize = 64;

/// Records per ring before the oldest are overwritten.
pub const RING_CAPACITY: usize = 16 * 1024;

const WORDS: usize = 4;

/// Event kinds, packed into the record's second word alongside the
/// ring index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A speculation block entered the pipeline window. `a` = block
    /// index, `b` = transactions in the block.
    BlockAdmitted = 1,
    /// The window head completed and wrote back. `a` = block index,
    /// `b` = admit→promote latency in ns.
    BlockPromoted = 2,
    /// A hardware transaction aborted. `a` = [`AbortCause::index`].
    HwAbort = 3,
    /// A batch transaction was re-readied with a bumped incarnation
    /// (validation abort, dependency resume, or cross-block resume).
    /// `a` = transaction index, `b` = new incarnation.
    Reincarnation = 4,
    /// The adaptive controller changed the block size. `a` = old,
    /// `b` = new.
    BlockResize = 5,
    /// The adaptive controller changed the window depth. `a` = old,
    /// `b` = new.
    WindowResize = 6,
    /// A worker stole work from a same-locality-group peer.
    StealLocal = 7,
    /// A worker stole work across locality groups.
    StealRemote = 8,
    /// The `--policy auto` meta-controller committed a backend switch.
    /// `a` = outgoing backend, `b` = incoming backend, both as
    /// [`crate::engine::ordinal`] codes.
    BackendSwitch = 9,
    /// The fault plane fired an injection. `a` = site index
    /// ([`crate::fault::Site`]), `b` = the site's ticket number.
    FaultInjected = 10,
    /// A panicking transaction body was caught and quarantined; the
    /// transaction re-dispatches with a bumped incarnation. `a` =
    /// transaction index, `b` = quarantine count for that transaction.
    Quarantine = 11,
    /// The progress watchdog fired and ran recovery. `a` =
    /// [`crate::fault::watchdog::Diagnosis`] code, `b` = lost wakeups
    /// re-readied by this kick.
    WatchdogKick = 12,
    /// The watchdog escalated the engine to the global-lock serial
    /// backend. `a` = total kicks at escalation, `b` = 0.
    Degraded = 13,
    /// The degraded state lifted after sustained progress (recovery
    /// hysteresis). `a` = total kicks at recovery, `b` = 0.
    Recovered = 14,
    /// An epoch-reclamation pass freed limbo bins every live worker
    /// had passed (`mem::epoch`, fired at block promotion). `a` =
    /// recorded-set cells freed, `b` = bytes freed.
    Reclaim = 15,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::BlockAdmitted => "block-admitted",
            EventKind::BlockPromoted => "block-promoted",
            EventKind::HwAbort => "hw-abort",
            EventKind::Reincarnation => "reincarnation",
            EventKind::BlockResize => "block-resize",
            EventKind::WindowResize => "window-resize",
            EventKind::StealLocal => "steal-local",
            EventKind::StealRemote => "steal-remote",
            EventKind::BackendSwitch => "backend-switch",
            EventKind::FaultInjected => "fault-injected",
            EventKind::Quarantine => "quarantine",
            EventKind::WatchdogKick => "watchdog-kick",
            EventKind::Degraded => "degraded",
            EventKind::Recovered => "recovered",
            EventKind::Reclaim => "reclaim",
        }
    }

    fn from_u64(v: u64) -> Option<Self> {
        Some(match v {
            1 => EventKind::BlockAdmitted,
            2 => EventKind::BlockPromoted,
            3 => EventKind::HwAbort,
            4 => EventKind::Reincarnation,
            5 => EventKind::BlockResize,
            6 => EventKind::WindowResize,
            7 => EventKind::StealLocal,
            8 => EventKind::StealRemote,
            9 => EventKind::BackendSwitch,
            10 => EventKind::FaultInjected,
            11 => EventKind::Quarantine,
            12 => EventKind::WatchdogKick,
            13 => EventKind::Degraded,
            14 => EventKind::Recovered,
            15 => EventKind::Reclaim,
            _ => return None,
        })
    }
}

/// One drained trace record.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Nanoseconds since tracing was enabled.
    pub t_ns: u64,
    /// Ring the emitting thread hashed onto (≈ worker id).
    pub ring: usize,
    pub kind: EventKind,
    pub a: u64,
    pub b: u64,
}

struct Ring {
    cursor: AtomicUsize,
    /// `RING_CAPACITY * WORDS` relaxed words.
    cells: Box<[AtomicU64]>,
}

struct Sink {
    epoch: Instant,
    rings: Vec<Ring>,
    next_slot: AtomicUsize,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: OnceLock<Sink> = OnceLock::new();

thread_local! {
    static SLOT: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}

fn sink() -> &'static Sink {
    SINK.get_or_init(|| Sink {
        epoch: Instant::now(),
        rings: (0..RINGS)
            .map(|_| Ring {
                cursor: AtomicUsize::new(0),
                cells: (0..RING_CAPACITY * WORDS).map(|_| AtomicU64::new(0)).collect(),
            })
            .collect(),
        next_slot: AtomicUsize::new(0),
    })
}

/// Turn tracing on. Allocates the rings on first call; the timestamp
/// epoch is the first `enable()`.
pub fn enable() {
    sink();
    ENABLED.store(true, Ordering::SeqCst);
    super::note_timing_consumer();
}

/// Turn tracing off (event sites go back to load+branch). Buffered
/// records stay drainable.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Is tracing currently on? One relaxed load.
#[inline]
pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Emit one event. When tracing is off this is a relaxed load and a
/// branch — the cold half never runs.
#[inline]
pub fn emit(kind: EventKind, a: u64, b: u64) {
    if !is_enabled() {
        return;
    }
    emit_slow(kind, a, b);
}

#[cold]
fn emit_slow(kind: EventKind, a: u64, b: u64) {
    let sink = sink();
    let slot = SLOT.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            v = sink.next_slot.fetch_add(1, Ordering::Relaxed) % RINGS;
            s.set(v);
        }
        v
    });
    let ring = &sink.rings[slot];
    let i = ring.cursor.fetch_add(1, Ordering::Relaxed) % RING_CAPACITY;
    let t_ns = sink.epoch.elapsed().as_nanos() as u64;
    let base = i * WORDS;
    ring.cells[base].store(t_ns, Ordering::Relaxed);
    ring.cells[base + 1].store(kind as u64, Ordering::Relaxed);
    ring.cells[base + 2].store(a, Ordering::Relaxed);
    ring.cells[base + 3].store(b, Ordering::Relaxed);
}

// -- typed event-site helpers ------------------------------------------

#[inline]
pub fn block_admitted(block: u64, txns: u64) {
    emit(EventKind::BlockAdmitted, block, txns);
}

#[inline]
pub fn block_promoted(block: u64, latency_ns: u64) {
    emit(EventKind::BlockPromoted, block, latency_ns);
}

#[inline]
pub fn hw_abort(cause: AbortCause) {
    emit(EventKind::HwAbort, cause.index() as u64, 0);
}

#[inline]
pub fn reincarnation(txn: u64, incarnation: u64) {
    emit(EventKind::Reincarnation, txn, incarnation);
}

#[inline]
pub fn block_resize(old: u64, new: u64) {
    emit(EventKind::BlockResize, old, new);
}

#[inline]
pub fn window_resize(old: u64, new: u64) {
    emit(EventKind::WindowResize, old, new);
}

#[inline]
pub fn backend_switch(from_ordinal: u64, to_ordinal: u64) {
    emit(EventKind::BackendSwitch, from_ordinal, to_ordinal);
}

#[inline]
pub fn fault_injected(site: u64, ticket: u64) {
    emit(EventKind::FaultInjected, site, ticket);
}

#[inline]
pub fn quarantine(txn: u64, count: u64) {
    emit(EventKind::Quarantine, txn, count);
}

#[inline]
pub fn watchdog_kick(diagnosis: u64, recovered: u64) {
    emit(EventKind::WatchdogKick, diagnosis, recovered);
}

#[inline]
pub fn degraded(kicks: u64) {
    emit(EventKind::Degraded, kicks, 0);
}

#[inline]
pub fn recovered(kicks: u64) {
    emit(EventKind::Recovered, kicks, 0);
}

#[inline]
pub fn reclaim(cells: u64, bytes: u64) {
    emit(EventKind::Reclaim, cells, bytes);
}

#[inline]
pub fn steal(local: bool) {
    emit(
        if local {
            EventKind::StealLocal
        } else {
            EventKind::StealRemote
        },
        0,
        0,
    );
}

// -- draining ----------------------------------------------------------

/// Total records emitted beyond ring capacity (overwritten, lost).
pub fn dropped() -> u64 {
    let Some(sink) = SINK.get() else { return 0 };
    sink.rings
        .iter()
        .map(|r| r.cursor.load(Ordering::Relaxed).saturating_sub(RING_CAPACITY) as u64)
        .sum()
}

/// Drain every ring into a time-sorted vector. Call after the traced
/// threads have joined (see module docs); the rings are reset so a
/// subsequent run traces fresh.
pub fn drain() -> Vec<Event> {
    let Some(sink) = SINK.get() else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for (ri, ring) in sink.rings.iter().enumerate() {
        let written = ring.cursor.swap(0, Ordering::SeqCst);
        let n = written.min(RING_CAPACITY);
        for i in 0..n {
            let base = i * WORDS;
            let kind = ring.cells[base + 1].load(Ordering::Relaxed);
            let Some(kind) = EventKind::from_u64(kind) else {
                continue;
            };
            out.push(Event {
                t_ns: ring.cells[base].load(Ordering::Relaxed),
                ring: ri,
                kind,
                a: ring.cells[base + 2].load(Ordering::Relaxed),
                b: ring.cells[base + 3].load(Ordering::Relaxed),
            });
            ring.cells[base + 1].store(0, Ordering::Relaxed);
        }
    }
    out.sort_by_key(|e| e.t_ns);
    out
}

/// One event as a JSON-lines record.
pub fn event_json(e: &Event) -> String {
    format!(
        "{{\"t_ns\":{},\"worker\":{},\"kind\":\"{}\",\"a\":{},\"b\":{}}}",
        e.t_ns,
        e.ring,
        json::escape(e.kind.name()),
        e.a,
        e.b
    )
}

/// Drain and write all buffered events to `path` as JSON-lines.
/// Returns the number of events written.
pub fn write_jsonl(path: &str) -> std::io::Result<usize> {
    let events = drain();
    let mut out = String::new();
    for e in &events {
        out.push_str(&event_json(e));
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tracing state is process-global and other tests run concurrently
    // in this binary: while this test's enable window is open, foreign
    // threads (worker pools aborting transactions, stealing, …) may
    // emit real events. Everything this test emits carries the marker
    // in `a`, and every assertion filters on it.
    const MARK: u64 = 0xFEED_0B5E;

    #[test]
    fn emit_drain_round_trip() {
        // Disabled: emit is a no-op.
        emit(EventKind::HwAbort, MARK, 2);
        assert!(
            drain().iter().all(|e| e.a != MARK),
            "disabled emit must not record"
        );
        enable();
        emit(EventKind::BlockAdmitted, MARK, 1024);
        emit(EventKind::BlockPromoted, MARK, 5_000);
        hw_abort(AbortCause::Capacity);
        steal(true);
        emit(EventKind::Reincarnation, MARK, 2);
        emit(EventKind::BlockResize, MARK, 512);
        emit(EventKind::WindowResize, MARK, 3);
        emit(EventKind::BackendSwitch, MARK, 9);
        emit(EventKind::FaultInjected, MARK, 41);
        emit(EventKind::Quarantine, MARK, 2);
        emit(EventKind::WatchdogKick, MARK, 3);
        emit(EventKind::Degraded, MARK, 0);
        emit(EventKind::Recovered, MARK, 0);
        emit(EventKind::Reclaim, MARK, 8192);
        disable();
        // Disabled again: not recorded.
        emit(EventKind::HwAbort, MARK, 9);
        let events = drain();
        // The typed helpers are unmarked; assert they landed at all.
        assert!(events
            .iter()
            .any(|e| e.kind == EventKind::HwAbort
                && e.a == AbortCause::Capacity.index() as u64));
        assert!(events.iter().any(|e| e.kind == EventKind::StealLocal));
        let mine: Vec<&Event> = events.iter().filter(|e| e.a == MARK).collect();
        assert_eq!(mine.len(), 12);
        // drain() sorts stably by t_ns, so same-thread (same-ring)
        // emission order is preserved.
        assert_eq!(mine[0].kind, EventKind::BlockAdmitted);
        assert_eq!(mine[0].b, 1024);
        assert_eq!(mine[1].kind, EventKind::BlockPromoted);
        assert_eq!(mine[1].b, 5_000);
        assert_eq!(mine[2].kind, EventKind::Reincarnation);
        assert_eq!(mine[3].kind, EventKind::BlockResize);
        assert_eq!(mine[4].kind, EventKind::WindowResize);
        assert_eq!(mine[5].kind, EventKind::BackendSwitch);
        assert_eq!(mine[5].b, 9);
        assert_eq!(mine[5].kind.name(), "backend-switch");
        assert_eq!(mine[6].kind, EventKind::FaultInjected);
        assert_eq!(mine[6].b, 41);
        assert_eq!(mine[6].kind.name(), "fault-injected");
        assert_eq!(mine[7].kind, EventKind::Quarantine);
        assert_eq!(mine[8].kind, EventKind::WatchdogKick);
        assert_eq!(mine[8].kind.name(), "watchdog-kick");
        assert_eq!(mine[9].kind, EventKind::Degraded);
        assert_eq!(mine[10].kind, EventKind::Recovered);
        assert_eq!(mine[11].kind, EventKind::Reclaim);
        assert_eq!(mine[11].b, 8192);
        assert_eq!(mine[11].kind.name(), "reclaim");
        assert!(events.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
        let line = event_json(mine[0]);
        assert!(line.contains("\"kind\":\"block-admitted\""));
        assert!(line.starts_with('{') && line.ends_with('}'));
        // Drained rings hold none of this test's events.
        assert!(drain().iter().all(|e| e.a != MARK));
    }
}
