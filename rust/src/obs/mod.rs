//! The telemetry plane: event tracing, phase snapshots, latency
//! histograms, and the `[obs]` diagnostic logger shared by all five
//! backends.
//!
//! Three cooperating pieces (this is the substrate the `--policy auto`
//! meta-controller samples):
//!
//! * [`trace`] — per-worker lock-free ring buffers of packed 32-byte
//!   event records (block admitted/promoted, HTM abort+cause,
//!   re-incarnation, block/window resize decisions, local/remote
//!   steals, auto-controller backend switches, and the robustness
//!   plane's fault-injected / quarantine / watchdog-kick /
//!   degraded / recovered events), enabled by `--trace[=PATH]` and
//!   drained post-run to JSON-lines.
//! * [`snapshot`] — the registry that turns `TxStats` /
//!   `BatchReport` / controller counters into interval deltas keyed by
//!   kernel + phase (generation / computation / extraction), exported
//!   as JSON-lines via `--metrics-json PATH`. The DES simulator emits
//!   the same schema in virtual time, so simulated and live tables are
//!   column-compatible.
//! * [`hist`] — log2-bucketed latency histograms (per-txn
//!   attempt→commit, per-block admit→promote) carried in `TxStats`,
//!   merged across workers element-wise, reported as p50/p90/p99.
//!
//! # Overhead contract
//!
//! With telemetry off (the default), every instrumentation point on a
//! transaction hot path costs **at most one relaxed atomic load and
//! one predictable branch — never a lock**:
//!
//! * trace event sites call [`trace::emit`], which is
//!   `if !ENABLED { return }` around a `#[cold]` body;
//! * latency timestamps (`Instant::now` pairs) are guarded by
//!   [`timing_enabled`] — one relaxed load — so disabled runs never
//!   take a clock reading;
//! * snapshot recording only happens at phase boundaries, off the
//!   per-transaction path entirely.
//!
//! The `obs-off` vs `obs-on` A/B cell in `benches/batch_throughput.rs`
//! exercises this contract end to end.
//!
//! # Event schema (`--trace[=PATH]`, JSON-lines)
//!
//! `{"t_ns":u64, "worker":u64, "kind":str, "a":u64, "b":u64}` where
//! `t_ns` is nanoseconds since tracing was enabled, `worker` is the
//! emitting ring index, and `kind`/`a`/`b` are documented per variant
//! on [`trace::EventKind`].
//!
//! The robustness plane (`--faults SPEC`, see `crate::fault`) adds
//! five kinds to the stream:
//!
//! * `fault-injected` — a fault-plane site fired: `a` = site index
//!   (`fault::Site`), `b` = the site's ticket number (the
//!   deterministic draw that fired, replayable from the spec's seed).
//! * `quarantine` — the batch executor caught a panicking transaction
//!   body and requeued it: `a` = transaction index, `b` = times this
//!   transaction has been quarantined.
//! * `watchdog-kick` — the progress watchdog missed its deadline and
//!   forced a resume: `a` = diagnosis (0 lost wakeup, 1 parked
//!   ESTIMATE chain, 2 livelocked retry storm, 3 worker stall —
//!   every remaining task claimed by flat-progress workers, the
//!   signature that freezes a serving session's snapshot horizon),
//!   `b` = transactions recovered from the lost-wakeup set.
//! * `degraded` — kicks without progress escalated the engine to the
//!   global-lock serial backend: `a` = kick count at escalation.
//! * `recovered` — hysteresis cleared and the engine left the
//!   degraded state: `a` = kick count at recovery.
//!
//! The batch pipeline's memory plane (`mem::epoch`) adds one more:
//!
//! * `reclaim` — an epoch-reclamation pass at block promotion freed
//!   limbo bins every live worker had passed: `a` = recorded-set
//!   cells freed, `b` = bytes freed.
//!
//! # Snapshot schema (`--metrics-json PATH`, JSON-lines)
//!
//! One object per completed interval:
//! `seq` (monotone), `kernel` (`generation` / `computation` /
//! `extraction` / `sim`), `phase` (interval within the kernel, e.g.
//! `probe`, `collect`, `level-3`), `time_ns` (wall or virtual),
//! commit/abort counters (`hw_commits`, `hw_attempts`, `hw_retries`,
//! `abort_conflict`, `abort_capacity`, `abort_explicit`,
//! `abort_interrupt`, `abort_sw_conflict`, `sw_commits`, `sw_aborts`,
//! `lock_commits`, `commits`), derived rates (`conflict_rate`,
//! `steal_local_ratio`), controller state (`block`, `window`,
//! `block_grows`, `block_shrinks`, `overlapped_txns`,
//! `backend_switches`, `steals`, `local_steals`), latency percentiles
//! (`txn_lat_count`, `txn_lat_p50_ns`, `txn_lat_p90_ns`,
//! `txn_lat_p99_ns`, `block_lat_count`, `block_lat_p50_ns`,
//! `block_lat_p99_ns`), memory-plane counters from the pipelined
//! batch executor's reclamation domain (`mv_live_cells` peak live
//! recorded-set cells — bounded when reclamation is on, growing when
//! off — `mv_retired`, `mv_reclaimed`, `arena_bytes` peak bump-arena
//! footprint; all zero outside pipelined batch runs), plus
//! kernel-specific extras (e.g. `threads`, `tuples`).
//!
//! A continuous-serving session (`kernel == "serve"`, one row per
//! session) appends four serving-plane extras: `ingest_rate`
//! (promoted operations per second over the session), `queue_depth`
//! (peak queued ingress operations observed at promotion
//! boundaries), `snapshot_age_ns` (nanoseconds from the last
//! promotion to session end — how stale a fresh snapshot was at
//! shutdown), and `serve_read_p99_ns` (p99 of the snapshot-query
//! serving-latency histogram).
//!
//! **Fields the `--policy auto` controller consumes**
//! (`engine::auto::Sample` reads exactly these, and
//! `Sample::from_json` replays them from a recorded stream): the
//! integer commit/abort counters `commits`, `sw_aborts`, the five
//! `abort_*` cause fields (summed; `abort_capacity` also drives the
//! capacity-dominated rule), `hw_attempts`, and `time_ns`. The
//! recorded `conflict_rate` float is *derived* from those integers —
//! the controller recomputes it with the same formula, so live
//! decisions and replayed decisions match bit-for-bit. Everything else
//! in the schema is reporting-only as far as the controller is
//! concerned.

pub mod hist;
pub mod snapshot;
pub mod trace;

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};

static TIMING: AtomicBool = AtomicBool::new(false);

/// Should hot paths take latency timestamps? True once any telemetry
/// consumer (tracing, the snapshot registry, or a bench harness via
/// [`set_timing`]) is enabled. One relaxed load — the guard that keeps
/// `Instant::now` pairs off untelemetered runs.
#[inline]
pub fn timing_enabled() -> bool {
    TIMING.load(Ordering::Relaxed)
}

/// Force latency timing on/off independently of trace/snapshot state
/// (bench harnesses use this to fill histograms without a sink).
pub fn set_timing(on: bool) {
    TIMING.store(on, Ordering::SeqCst);
}

pub(crate) fn note_timing_consumer() {
    TIMING.store(true, Ordering::SeqCst);
}

// -- the [obs] diagnostic logger ---------------------------------------

static VERBOSITY: AtomicU8 = AtomicU8::new(1);

/// Set the diagnostic verbosity: 0 silences `[obs]` lines, 1 (the
/// default) emits run summaries, 2+ is reserved for chattier
/// diagnostics. Wired to `--obs-verbosity N`.
pub fn set_verbosity(v: u8) {
    VERBOSITY.store(v, Ordering::SeqCst);
}

pub fn verbosity() -> u8 {
    VERBOSITY.load(Ordering::Relaxed)
}

/// The single diagnostic logging helper: every ad-hoc stderr
/// diagnostic routes through here so traced runs don't interleave raw
/// `eprintln!` with the event stream. Prints `[obs] <msg>` to stderr
/// when `verbosity() >= level`.
pub fn diag(level: u8, msg: &str) {
    if verbosity() >= level {
        eprintln!("[obs] {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verbosity_gates_diag_levels() {
        // diag writes to stderr; assert only the gating state machine.
        set_verbosity(0);
        assert_eq!(verbosity(), 0);
        set_verbosity(2);
        assert_eq!(verbosity(), 2);
        set_verbosity(1);
        assert_eq!(verbosity(), 1);
    }

    #[test]
    fn timing_follows_consumers() {
        set_timing(false);
        assert!(!timing_enabled());
        set_timing(true);
        assert!(timing_enabled());
        set_timing(false);
    }
}
