//! The snapshot registry: phase-scoped interval metrics as JSON-lines.
//!
//! Kernels call [`record`] once per completed interval (a generation
//! pass, a computation phase, one BFS level of the extraction kernel, a
//! simulator run). Each call turns the interval's [`TxStats`] delta
//! into one self-describing JSON object keyed by `kernel` + `phase`
//! and stamped with a monotone sequence number; [`write_jsonl`] dumps
//! the accumulated rows to the path given by `--metrics-json`.
//!
//! When the registry is disabled (the default) `record` is a relaxed
//! load and a branch — the mutex guarding the row buffer is only ever
//! touched on enabled runs, and only at phase boundaries, never inside
//! a transaction hot path. The simulator emits the same schema (with
//! virtual-time `time_ns`), so `--fig combined` tables and live runs
//! line up column-for-column. See [`crate::obs`] for the schema.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::stats::TxStats;
use crate::tm::AbortCause;
use crate::util::json;

struct Registry {
    seq: u64,
    lines: Vec<String>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();

fn registry() -> &'static Mutex<Registry> {
    REGISTRY.get_or_init(|| Mutex::new(Registry { seq: 0, lines: Vec::new() }))
}

/// Turn the registry on (done by `--metrics-json`).
pub fn enable() {
    registry();
    ENABLED.store(true, Ordering::SeqCst);
    super::note_timing_consumer();
}

/// Turn the registry off. Buffered rows stay writable.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Is the registry on? One relaxed load.
#[inline]
pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

/// Record one interval snapshot. `stats` is the interval's *delta*
/// (per-phase totals already are deltas — phases don't reuse
/// executors). `extra` appends kernel-specific fields; values are
/// spliced in as raw JSON (quote strings yourself via
/// [`json::escape`]).
pub fn record(kernel: &str, phase: &str, stats: &TxStats, extra: &[(&str, String)]) {
    if !is_enabled() {
        return;
    }
    let aborts = stats.hw_aborts_total() + stats.sw_aborts;
    let commits = stats.total_commits();
    let mut line = String::with_capacity(512);
    let mut reg = registry().lock().unwrap();
    line.push_str(&format!(
        "{{\"seq\":{},\"kernel\":\"{}\",\"phase\":\"{}\",\"time_ns\":{}",
        reg.seq,
        json::escape(kernel),
        json::escape(phase),
        stats.time_ns
    ));
    line.push_str(&format!(
        ",\"hw_commits\":{},\"hw_attempts\":{},\"hw_retries\":{}",
        stats.hw_commits, stats.hw_attempts, stats.hw_retries
    ));
    for cause in AbortCause::ALL {
        line.push_str(&format!(
            ",\"abort_{}\":{}",
            cause.name().replace('-', "_"),
            stats.aborts_of(cause)
        ));
    }
    line.push_str(&format!(
        ",\"sw_commits\":{},\"sw_aborts\":{},\"lock_commits\":{},\"commits\":{}",
        stats.sw_commits, stats.sw_aborts, stats.lock_commits, commits
    ));
    line.push_str(&format!(
        ",\"conflict_rate\":{:.6}",
        ratio(aborts, aborts + commits)
    ));
    line.push_str(&format!(
        ",\"steals\":{},\"local_steals\":{},\"steal_local_ratio\":{:.6}",
        stats.steals,
        stats.local_steals,
        ratio(stats.local_steals, stats.steals)
    ));
    line.push_str(&format!(
        ",\"block\":{},\"window\":{},\"block_grows\":{},\"block_shrinks\":{},\"overlapped_txns\":{},\"backend_switches\":{}",
        stats.final_block,
        stats.final_window,
        stats.block_grows,
        stats.block_shrinks,
        stats.overlapped_txns,
        stats.backend_switches
    ));
    line.push_str(&format!(
        ",\"faults_injected\":{},\"quarantines\":{},\"watchdog_kicks\":{},\"degradations\":{}",
        stats.faults_injected, stats.quarantines, stats.watchdog_kicks, stats.degradations
    ));
    line.push_str(&format!(
        ",\"mv_live_cells\":{},\"mv_retired\":{},\"mv_reclaimed\":{},\"arena_bytes\":{}",
        stats.mv_live_cells, stats.mv_retired, stats.mv_reclaimed, stats.arena_bytes
    ));
    line.push_str(&format!(
        ",\"txn_lat_count\":{},\"txn_lat_p50_ns\":{},\"txn_lat_p90_ns\":{},\"txn_lat_p99_ns\":{}",
        stats.txn_lat.count(),
        stats.txn_lat.p50(),
        stats.txn_lat.p90(),
        stats.txn_lat.p99()
    ));
    line.push_str(&format!(
        ",\"block_lat_count\":{},\"block_lat_p50_ns\":{},\"block_lat_p99_ns\":{}",
        stats.block_lat.count(),
        stats.block_lat.p50(),
        stats.block_lat.p99()
    ));
    for (k, v) in extra {
        line.push_str(&format!(",\"{}\":{}", json::escape(k), v));
    }
    line.push('}');
    reg.seq += 1;
    reg.lines.push(line);
}

/// Number of buffered snapshot rows.
pub fn len() -> usize {
    REGISTRY.get().map_or(0, |r| r.lock().unwrap().lines.len())
}

/// Take all buffered rows (clears the buffer, keeps the sequence
/// counter running).
pub fn take_rows() -> Vec<String> {
    match REGISTRY.get() {
        Some(r) => std::mem::take(&mut r.lock().unwrap().lines),
        None => Vec::new(),
    }
}

/// Write all buffered rows to `path` as JSON-lines and clear the
/// buffer. Returns the number of rows written.
pub fn write_jsonl(path: &str) -> std::io::Result<usize> {
    let rows = take_rows();
    let mut out = String::new();
    for r in &rows {
        out.push_str(r);
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(rows.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Registry state is process-global and other tests run concurrently
    // in this binary: while this test's enable window is open, another
    // test's kernel run may record real snapshots. This test uses a
    // kernel name no real code path emits and filters every assertion
    // on it.
    const K: &str = "obs-selftest";

    fn mine(rows: Vec<String>) -> Vec<String> {
        rows.into_iter()
            .filter(|r| json::scrape_str(r, "kernel") == Some(K))
            .collect()
    }

    #[test]
    fn record_is_gated_and_rows_are_scrapable() {
        let mut s = TxStats::new();
        s.sw_commits = 90;
        s.sw_aborts = 10;
        s.steals = 8;
        s.local_steals = 6;
        s.final_block = 1024;
        s.final_window = 3;
        s.mv_live_cells = 96;
        s.mv_retired = 4000;
        s.mv_reclaimed = 3904;
        s.arena_bytes = 65_536;
        s.time_ns = 123_456;
        s.txn_lat.record(100);
        s.txn_lat.record(10_000);
        record(K, "probe", &s, &[]);
        assert!(
            mine(take_rows()).is_empty(),
            "disabled registry must not buffer"
        );
        enable();
        record(K, "probe", &s, &[("threads", "4".into())]);
        record(K, "level-0", &s, &[]);
        disable();
        record(K, "collect", &s, &[]);
        let rows = mine(take_rows());
        assert_eq!(rows.len(), 2);
        let r = &rows[0];
        assert_eq!(json::scrape_str(r, "kernel"), Some(K));
        assert_eq!(json::scrape_str(r, "phase"), Some("probe"));
        assert_eq!(json::scrape_u64(r, "sw_commits"), Some(90));
        assert_eq!(json::scrape_u64(r, "commits"), Some(90));
        assert_eq!(json::scrape_u64(r, "block"), Some(1024));
        assert_eq!(json::scrape_u64(r, "window"), Some(3));
        assert_eq!(json::scrape_u64(r, "backend_switches"), Some(0));
        assert_eq!(json::scrape_u64(r, "faults_injected"), Some(0));
        assert_eq!(json::scrape_u64(r, "quarantines"), Some(0));
        assert_eq!(json::scrape_u64(r, "watchdog_kicks"), Some(0));
        assert_eq!(json::scrape_u64(r, "degradations"), Some(0));
        assert_eq!(json::scrape_u64(r, "mv_live_cells"), Some(96));
        assert_eq!(json::scrape_u64(r, "mv_retired"), Some(4000));
        assert_eq!(json::scrape_u64(r, "mv_reclaimed"), Some(3904));
        assert_eq!(json::scrape_u64(r, "arena_bytes"), Some(65_536));
        assert_eq!(json::scrape_u64(r, "threads"), Some(4));
        assert_eq!(json::scrape_u64(r, "txn_lat_count"), Some(2));
        assert_eq!(json::scrape_u64(r, "txn_lat_p50_ns"), Some(127));
        assert_eq!(json::scrape_u64(r, "txn_lat_p99_ns"), Some(16383));
        assert!(r.contains("\"conflict_rate\":0.100000"));
        assert!(r.contains("\"steal_local_ratio\":0.750000"));
        // Sequence numbers stay monotone across this test's records
        // (foreign rows may interleave, so strictly greater — not +1).
        assert!(json::scrape_u64(&rows[0], "seq").unwrap()
            < json::scrape_u64(&rows[1], "seq").unwrap());
        assert!(mine(take_rows()).is_empty(), "take_rows drains the buffer");
    }
}
