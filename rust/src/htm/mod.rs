//! Software best-effort HTM (DESIGN.md S2): the substitution for Intel
//! TSX/RTM, which this machine does not have.
//!
//! The *conflicts* are real — concurrent threads genuinely speculate
//! against a shared versioned-lock table at cache-line granularity and
//! genuinely abort each other. The *capacity* dimension is modeled: a
//! set-associative footprint bound mirroring RTM's "write set must fit
//! in L1d, read set (roughly) in L2". Abort causes are reported with
//! RTM's taxonomy ([`crate::tm::AbortCause`]) including the
//! may-succeed-on-retry hint — the signal DyAdHyTM's adaptation feeds on.
//!
//! Protocol: lazy versioned-lock speculation (TL2-style) — buffered
//! writes, per-read validation against a global version clock (opacity),
//! commit-time lock acquisition, write-back, versioned release.

pub mod cache;
pub mod engine;

pub use cache::{CacheFootprint, HtmConfig};
pub use engine::{HtmEngine, HtmScratch};
