//! The HTM capacity model: where `_XABORT_CAPACITY` comes from.
//!
//! Intel RTM keeps the transactional write set in the L1 data cache
//! (32 KiB, 8-way, 64 B lines on Broadwell => 64 sets) and tracks the
//! read set approximately in L2. A transaction aborts with CAPACITY when
//! a written line would evict another written line from its set (ways
//! exceeded), or when the read footprint exceeds the read-set bound.

use crate::mem::Line;

/// Static capacity parameters of the modeled HTM.
#[derive(Clone, Debug)]
pub struct HtmConfig {
    /// L1d sets available for the write set (power of two).
    pub wr_sets: usize,
    /// L1d associativity: written lines allowed per set.
    pub wr_ways: usize,
    /// Max distinct lines in the read set (L2-ish bound).
    pub rd_capacity: usize,
    /// Per-transaction probability of an asynchronous abort (context
    /// switch / interrupt). 0 for deterministic runs.
    pub interrupt_prob: f64,
}

impl HtmConfig {
    /// The paper's machine: Broadwell Xeon, HTM in L1/L2.
    /// 32 KiB / 64 B / 8-way = 64 sets x 8 ways; read set bounded by a
    /// 256 KiB L2 slice (4096 lines).
    pub fn broadwell() -> Self {
        Self {
            wr_sets: 64,
            wr_ways: 8,
            rd_capacity: 4096,
            interrupt_prob: 0.0,
        }
    }

    /// A deliberately tiny HTM for tests and capacity-pressure
    /// experiments at laptop scale (DESIGN.md §2: we size the modeled
    /// cache so the capacity-abort mechanism fires at our graph scales).
    pub fn tiny() -> Self {
        Self {
            wr_sets: 8,
            wr_ways: 2,
            rd_capacity: 64,
            interrupt_prob: 0.0,
        }
    }

    pub fn with_interrupts(mut self, p: f64) -> Self {
        self.interrupt_prob = p;
        self
    }

    /// Max write-set size in lines (all sets full).
    pub fn wr_capacity(&self) -> usize {
        self.wr_sets * self.wr_ways
    }
}

impl Default for HtmConfig {
    fn default() -> Self {
        Self::broadwell()
    }
}

/// Incremental footprint tracker for one transaction attempt.
///
/// Write lines are mapped to sets by their line id (as the physical
/// cache indexes by address bits); per-set occupancy is counted and
/// compared against associativity.
#[derive(Clone, Debug)]
pub struct CacheFootprint {
    set_occupancy: Vec<u8>,
    rd_lines: usize,
    wr_lines: usize,
}

impl CacheFootprint {
    pub fn new(cfg: &HtmConfig) -> Self {
        Self {
            set_occupancy: vec![0; cfg.wr_sets],
            rd_lines: 0,
            wr_lines: 0,
        }
    }

    /// Record a (new, distinct) read line. Returns false on capacity
    /// overflow.
    #[inline]
    pub fn note_read(&mut self, cfg: &HtmConfig) -> bool {
        self.rd_lines += 1;
        self.rd_lines <= cfg.rd_capacity
    }

    /// Record a (new, distinct) written line. Returns false on a
    /// set-associativity eviction (capacity abort).
    #[inline]
    pub fn note_write(&mut self, cfg: &HtmConfig, line: Line) -> bool {
        let set = line.set_index(cfg.wr_sets);
        self.set_occupancy[set] += 1;
        self.wr_lines += 1;
        self.set_occupancy[set] as usize <= cfg.wr_ways
    }

    pub fn reset(&mut self) {
        self.set_occupancy.fill(0);
        self.rd_lines = 0;
        self.wr_lines = 0;
    }

    pub fn rd_lines(&self) -> usize {
        self.rd_lines
    }

    pub fn wr_lines(&self) -> usize {
        self.wr_lines
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadwell_matches_l1d_geometry() {
        let c = HtmConfig::broadwell();
        assert_eq!(c.wr_capacity(), 512); // 32 KiB / 64 B
    }

    #[test]
    fn write_capacity_trips_on_set_conflict_not_total() {
        let cfg = HtmConfig {
            wr_sets: 4,
            wr_ways: 2,
            rd_capacity: 100,
            interrupt_prob: 0.0,
        };
        let mut fp = CacheFootprint::new(&cfg);
        // Lines 0,4,8 all map to set 0 under 4 sets.
        assert!(fp.note_write(&cfg, Line(0)));
        assert!(fp.note_write(&cfg, Line(4)));
        assert!(!fp.note_write(&cfg, Line(8)), "3rd way in set 0 must trip");
        // Meanwhile total (3) is far below wr_capacity (8).
    }

    #[test]
    fn spread_writes_fill_to_capacity() {
        let cfg = HtmConfig {
            wr_sets: 4,
            wr_ways: 2,
            rd_capacity: 100,
            interrupt_prob: 0.0,
        };
        let mut fp = CacheFootprint::new(&cfg);
        for i in 0..8 {
            assert!(fp.note_write(&cfg, Line(i)), "line {i}");
        }
        assert!(!fp.note_write(&cfg, Line(8)));
    }

    #[test]
    fn read_capacity_trips_at_bound() {
        let cfg = HtmConfig {
            wr_sets: 4,
            wr_ways: 2,
            rd_capacity: 3,
            interrupt_prob: 0.0,
        };
        let mut fp = CacheFootprint::new(&cfg);
        assert!(fp.note_read(&cfg));
        assert!(fp.note_read(&cfg));
        assert!(fp.note_read(&cfg));
        assert!(!fp.note_read(&cfg));
    }

    #[test]
    fn reset_clears_occupancy() {
        let cfg = HtmConfig::tiny();
        let mut fp = CacheFootprint::new(&cfg);
        for i in 0..4 {
            fp.note_write(&cfg, Line(i));
        }
        fp.reset();
        assert_eq!(fp.wr_lines(), 0);
        assert!(fp.note_write(&cfg, Line(0)));
    }
}
