//! The speculative execution engine behind `HW_BEGIN / HW_COMMIT`.

use std::sync::Arc;

use crate::tm::Subscription;
use crate::mem::{Addr, Line, TxHeap};
use crate::tm::access::{Abort, TxAccess, TxResult};
use crate::tm::{AbortCause, GlobalClock, LockTable, OrecValue};
use crate::util::rng::Rng;

use super::cache::{CacheFootprint, HtmConfig};

/// Reusable per-thread speculation buffers: allocated once, cleared per
/// attempt. The hot path is allocation-free with these (EXPERIMENTS.md
/// §Perf iteration 1: 5 mallocs per attempt -> 0).
pub struct HtmScratch {
    reads: Vec<(Line, u64)>,
    writes: Vec<(Addr, u64)>,
    footprint: CacheFootprint,
    wlines: Vec<Line>,
    held: Vec<(Line, u64)>,
}

impl HtmScratch {
    pub fn new(cfg: &HtmConfig) -> Self {
        Self {
            reads: Vec::with_capacity(64),
            writes: Vec::with_capacity(64),
            footprint: CacheFootprint::new(cfg),
            wlines: Vec::with_capacity(16),
            held: Vec::with_capacity(16),
        }
    }

    fn clear(&mut self) {
        self.reads.clear();
        self.writes.clear();
        self.footprint.reset();
        self.wlines.clear();
        self.held.clear();
    }
}

/// Shared state of the software HTM: one per address space.
pub struct HtmEngine {
    pub heap: Arc<TxHeap>,
    table: LockTable,
    clock: GlobalClock,
    cfg: HtmConfig,
    /// Hardware commits currently in write-back. Real RTM commits
    /// atomically; our write-back is a window, so non-speculative
    /// fallback paths (lock holders, gbllock STMs) must wait for it to
    /// drain before touching memory — see [`Self::quiesce_commits`].
    commits_in_flight: std::sync::atomic::AtomicU64,
}

impl HtmEngine {
    pub fn new(heap: Arc<TxHeap>, cfg: HtmConfig) -> Self {
        Self {
            heap,
            table: LockTable::new(crate::tm::orec::DEFAULT_LOCK_TABLE_BITS),
            clock: GlobalClock::new(),
            cfg,
            commits_in_flight: std::sync::atomic::AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &HtmConfig {
        &self.cfg
    }

    /// Wait until no hardware transaction is mid-write-back.
    ///
    /// Protocol: a committing transaction increments `commits_in_flight`
    /// *before* its final lock-subscription check and decrements after
    /// write-back. A fallback path acquires its lock (which flips the
    /// subscribed word), then calls this. Any committer that checked
    /// before the flip is drained here; any that checks after aborts.
    /// Hardware transactions never wait on the fence, so there is no
    /// circular wait.
    pub fn quiesce_commits(&self) {
        use std::sync::atomic::Ordering;
        while self.commits_in_flight.load(Ordering::SeqCst) > 0 {
            std::hint::spin_loop();
        }
    }

    /// One hardware transaction attempt (`HW_BEGIN` .. `HW_COMMIT`).
    ///
    /// * `owner`  — thread id (lock-word identity).
    /// * `rng`    — drives the interrupt fault model only.
    /// * `gbllock`— if present, the HyTM subscription: abort `Explicit`
    ///   when an STM holds the lock at begin, `Conflict` when the lock
    ///   word changes mid-flight (on real RTM the transactional read of
    ///   the lock word makes any STM increment a data conflict; the
    ///   monotone entry count extends that to completed STM episodes —
    ///   see [`GblLock`]).
    ///
    /// Returns the body's value on commit, or the RTM-style abort cause.
    pub fn attempt<R>(
        &self,
        owner: u32,
        rng: &mut Rng,
        gbllock: Option<&dyn Subscription>,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
    ) -> Result<R, AbortCause> {
        // Convenience path: fresh scratch (tests, one-off callers). The
        // executors hold a reusable scratch and call `attempt_with`.
        let mut scratch = HtmScratch::new(&self.cfg);
        self.attempt_with(&mut scratch, owner, rng, gbllock, body)
    }

    /// `attempt` with caller-provided (reused) speculation buffers —
    /// the allocation-free hot path.
    pub fn attempt_with<R>(
        &self,
        scratch: &mut HtmScratch,
        owner: u32,
        rng: &mut Rng,
        gbllock: Option<&dyn Subscription>,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
    ) -> Result<R, AbortCause> {
        scratch.clear();
        // HW_BEGIN: subscribe to the global lock.
        let gbl_sample = match gbllock {
            Some(gl) => {
                let s = gl.sample();
                if gl.is_held() {
                    return Err(AbortCause::Explicit);
                }
                s
            }
            None => 0,
        };

        // Fault plane (`--faults htm_abort=P`): kill the attempt at
        // HW_BEGIN, before the body runs, so a forced abort is
        // indistinguishable from a real one to every retry policy. The
        // ticket parity alternates the cause so both the
        // conflict-retry and capacity-fallback ladder rungs get
        // exercised. One relaxed load + branch when no plane is
        // installed.
        if let Some(ticket) = crate::fault::inject_ticket(crate::fault::Site::HtmAbort) {
            return Err(if ticket & 1 == 0 {
                AbortCause::Conflict
            } else {
                AbortCause::Capacity
            });
        }

        // Fault model: decide up front whether an async event will kill
        // this attempt, and after how many accesses.
        let interrupt_at = if self.cfg.interrupt_prob > 0.0
            && rng.next_f64() < self.cfg.interrupt_prob
        {
            usize::MAX - 1 // placeholder replaced below
        } else {
            usize::MAX
        };
        let interrupt_at = if interrupt_at == usize::MAX {
            usize::MAX
        } else {
            rng.below(16) as usize + 1
        };

        let mut txn = HwTxn {
            engine: self,
            scratch,
            owner,
            rv: self.clock.now(),
            ops: 0,
            interrupt_at,
            gbllock,
            gbl_sample,
        };

        let value = match body(&mut txn) {
            Ok(v) => v,
            Err(Abort(cause)) => return Err(cause),
        };

        // HW_COMMIT.
        txn.commit()?;
        Ok(value)
    }
}

/// Per-attempt speculative state (buffers borrowed from the scratch).
struct HwTxn<'e> {
    engine: &'e HtmEngine,
    scratch: &'e mut HtmScratch,
    owner: u32,
    /// Read version: global clock at begin (TL2 rule).
    rv: u64,
    ops: usize,
    interrupt_at: usize,
    gbllock: Option<&'e dyn Subscription>,
    gbl_sample: u64,
}

impl HwTxn<'_> {
    #[inline]
    fn tick_op(&mut self) -> TxResult<()> {
        self.ops += 1;
        if self.ops >= self.interrupt_at {
            return Err(Abort(AbortCause::Interrupt));
        }
        // The lock word is in the transactional read set: any STM
        // entry/exit since begin is a data conflict (opacity against
        // STM write-backs).
        if let Some(gl) = self.gbllock {
            if !gl.unchanged_since(self.gbl_sample) {
                return Err(Abort(AbortCause::Conflict));
            }
        }
        Ok(())
    }

    /// Validate-and-read the orec for `line`; returns its version.
    #[inline]
    fn readable_version(&self, line: Line) -> TxResult<u64> {
        match self.engine.table.read(line) {
            OrecValue::Locked { .. } => Err(Abort(AbortCause::Conflict)),
            OrecValue::Version(v) if v > self.rv => Err(Abort(AbortCause::Conflict)),
            OrecValue::Version(v) => Ok(v),
        }
    }

    fn commit(self) -> Result<(), AbortCause> {
        // Read-only fast path: nothing to publish; reads were validated
        // at access time against rv, so the snapshot is consistent.
        if self.scratch.writes.is_empty() {
            return Ok(());
        }

        // Distinct write lines, sorted for canonical acquisition order
        // (prevents deadlock between concurrent committers).
        let (engine, owner, rv) = (self.engine, self.owner, self.rv);
        let scratch = self.scratch;
        scratch.wlines.clear();
        for &(a, _) in &scratch.writes {
            scratch.wlines.push(TxHeap::line_of(a));
        }
        scratch.wlines.sort_unstable();
        scratch.wlines.dedup();

        // Acquire write locks.
        scratch.held.clear();
        let abort_held = |held: &[(Line, u64)]| {
            for &(l, ov) in held {
                engine.table.unlock_restore(l, owner, ov);
            }
        };
        for &line in &scratch.wlines {
            let v = match engine.table.read(line) {
                OrecValue::Version(v) if v <= rv => v,
                // Locked by someone else, or a version beyond our
                // snapshot: data conflict.
                _ => {
                    abort_held(&scratch.held);
                    return Err(AbortCause::Conflict);
                }
            };
            if engine.table.try_lock(line, v, owner) {
                scratch.held.push((line, v));
            } else {
                abort_held(&scratch.held);
                return Err(AbortCause::Conflict);
            }
        }

        // Enter the commit fence, THEN re-check the subscription: either
        // a fallback path sees our in-flight commit and waits, or we see
        // its lock word and abort (see `quiesce_commits`).
        use std::sync::atomic::Ordering;
        engine.commits_in_flight.fetch_add(1, Ordering::SeqCst);
        let exit_fence = || {
            engine.commits_in_flight.fetch_sub(1, Ordering::SeqCst);
        };

        // Lock subscription must still hold at commit: any STM episode
        // since begin is a data conflict on real RTM.
        if let Some(gl) = self.gbllock {
            if !gl.unchanged_since(self.gbl_sample) {
                abort_held(&scratch.held);
                exit_fence();
                return Err(AbortCause::Conflict);
            }
        }

        // Validation below also runs inside the fence; every early
        // return must pair `abort_held` with `exit_fence`.

        // New write version.
        let wv = engine.clock.tick();

        // Validate the read set: every line read must still carry the
        // version we saw (or be locked by us).
        for &(line, seen) in &scratch.reads {
            match engine.table.read(line) {
                OrecValue::Version(v) if v == seen => {}
                OrecValue::Locked { owner: o } if o == owner => {
                    // We locked it for writing; confirm the pre-lock
                    // version we recorded when acquiring.
                    let pre = scratch
                        .held
                        .iter()
                        .find(|&&(l, _)| l == line)
                        .map(|&(_, v)| v)
                        .expect("locked-by-self line missing from held set");
                    if pre != seen {
                        abort_held(&scratch.held);
                        exit_fence();
                        return Err(AbortCause::Conflict);
                    }
                }
                _ => {
                    abort_held(&scratch.held);
                    exit_fence();
                    return Err(AbortCause::Conflict);
                }
            }
        }

        // Write back and release with the new version.
        for &(addr, val) in &scratch.writes {
            engine.heap.store_release(addr, val);
        }
        for &(line, _) in &scratch.held {
            engine.table.unlock(line, owner, wv);
        }
        exit_fence();
        Ok(())
    }
}

impl TxAccess for HwTxn<'_> {
    fn read(&mut self, addr: Addr) -> TxResult<u64> {
        self.tick_op()?;
        // Read-own-write.
        if let Some(&(_, v)) = self
            .scratch
            .writes
            .iter()
            .rev()
            .find(|&&(a, _)| a == addr)
        {
            return Ok(v);
        }
        let line = TxHeap::line_of(addr);
        // Canonical TL2 read: load the value, then validate the orec
        // once. Word loads are atomic (no tearing), and any writer that
        // could have produced a stale value is still locked — or has
        // already bumped the version past rv — at the post-check.
        let val = self.engine.heap.load_acquire(addr);
        let v1 = self.readable_version(line)?;
        if !self.scratch.reads.iter().any(|&(l, _)| l == line) {
            self.scratch.reads.push((line, v1));
            if !self.scratch.footprint.note_read(&self.engine.cfg) {
                return Err(Abort(AbortCause::Capacity));
            }
        }
        Ok(val)
    }

    fn write(&mut self, addr: Addr, val: u64) -> TxResult<()> {
        self.tick_op()?;
        let line = TxHeap::line_of(addr);
        let is_new_line = !self
            .scratch
            .writes
            .iter()
            .any(|&(a, _)| TxHeap::line_of(a) == line);
        self.scratch.writes.push((addr, val));
        if is_new_line && !self.scratch.footprint.note_write(&self.engine.cfg, line) {
            return Err(Abort(AbortCause::Capacity));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hytm::GblLock;
    use std::sync::Arc;

    fn engine(cfg: HtmConfig) -> HtmEngine {
        HtmEngine::new(Arc::new(TxHeap::new(1 << 16)), cfg)
    }

    #[test]
    fn read_write_commit_publishes() {
        let e = engine(HtmConfig::broadwell());
        let a = e.heap.alloc(1);
        let mut rng = Rng::new(1);
        let r = e.attempt(0, &mut rng, None, &mut |t: &mut dyn TxAccess| {
            t.write(a, 123)?;
            t.read(a)
        });
        assert_eq!(r.unwrap(), 123);
        assert_eq!(e.heap.load(a), 123);
    }

    #[test]
    fn read_only_txn_commits_without_clock_tick() {
        let e = engine(HtmConfig::broadwell());
        let a = e.heap.alloc(1);
        e.heap.store(a, 9);
        let before = e.clock.now();
        let mut rng = Rng::new(1);
        let r = e.attempt(0, &mut rng, None, &mut |t: &mut dyn TxAccess| t.read(a));
        assert_eq!(r.unwrap(), 9);
        assert_eq!(e.clock.now(), before);
    }

    #[test]
    fn capacity_abort_on_wide_write_set() {
        let e = engine(HtmConfig::tiny()); // 8 sets x 2 ways = 16 lines max
        let base = e.heap.alloc(8 * 64); // 64 lines
        let mut rng = Rng::new(1);
        let r = e.attempt(0, &mut rng, None, &mut |t: &mut dyn TxAccess| {
            for i in 0..32 {
                t.write(base + i * 8, i as u64)?; // one line each
            }
            Ok(())
        });
        assert_eq!(r.unwrap_err(), AbortCause::Capacity);
    }

    #[test]
    fn capacity_abort_on_wide_read_set() {
        let cfg = HtmConfig {
            rd_capacity: 8,
            ..HtmConfig::tiny()
        };
        let e = engine(cfg);
        let base = e.heap.alloc(8 * 64);
        let mut rng = Rng::new(1);
        let r = e.attempt(0, &mut rng, None, &mut |t: &mut dyn TxAccess| {
            for i in 0..16 {
                t.read(base + i * 8)?;
            }
            Ok(())
        });
        assert_eq!(r.unwrap_err(), AbortCause::Capacity);
    }

    #[test]
    fn explicit_abort_when_gbllock_held() {
        let e = engine(HtmConfig::broadwell());
        let gl = GblLock::new();
        gl.enter_sw();
        let a = e.heap.alloc(1);
        let mut rng = Rng::new(1);
        let r = e.attempt(0, &mut rng, Some(&gl), &mut |t: &mut dyn TxAccess| {
            t.write(a, 1)
        });
        assert_eq!(r.unwrap_err(), AbortCause::Explicit);
        gl.exit_sw();
        let r = e.attempt(0, &mut rng, Some(&gl), &mut |t: &mut dyn TxAccess| {
            t.write(a, 1)
        });
        assert!(r.is_ok());
    }

    #[test]
    fn aborted_body_leaves_heap_untouched() {
        let e = engine(HtmConfig::broadwell());
        let a = e.heap.alloc(1);
        e.heap.store(a, 7);
        let mut rng = Rng::new(1);
        let r = e.attempt(0, &mut rng, None, &mut |t: &mut dyn TxAccess| {
            t.write(a, 99)?;
            Err::<(), _>(Abort(AbortCause::Explicit))
        });
        assert_eq!(r.unwrap_err(), AbortCause::Explicit);
        assert_eq!(e.heap.load(a), 7, "buffered write must not leak");
    }

    #[test]
    fn interrupt_fault_model_fires() {
        let cfg = HtmConfig::broadwell().with_interrupts(1.0);
        let e = engine(cfg);
        let a = e.heap.alloc(1);
        let mut rng = Rng::new(3);
        let mut interrupted = false;
        for _ in 0..10 {
            let r = e.attempt(0, &mut rng, None, &mut |t: &mut dyn TxAccess| {
                for _ in 0..32 {
                    t.read(a)?;
                }
                Ok(())
            });
            if r == Err(AbortCause::Interrupt) {
                interrupted = true;
            }
        }
        assert!(interrupted);
    }

    #[test]
    fn concurrent_increments_never_lose_updates() {
        let e = Arc::new(engine(HtmConfig::broadwell()));
        let a = e.heap.alloc(1);
        const THREADS: u32 = 4;
        const PER: u64 = 2000;
        let mut handles = Vec::new();
        for tid in 0..THREADS {
            let e = Arc::clone(&e);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(tid as u64 + 100);
                let mut commits = 0u64;
                while commits < PER {
                    let r = e.attempt(tid, &mut rng, None, &mut |t: &mut dyn TxAccess| {
                        let v = t.read(a)?;
                        t.write(a, v + 1)
                    });
                    if r.is_ok() {
                        commits += 1;
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(e.heap.load(a), THREADS as u64 * PER);
    }

    #[test]
    fn conflicting_writers_one_aborts() {
        // Deterministic 2-phase interleaving via a barrier is hard with
        // closures; instead: many concurrent multi-line txns and assert
        // serializability of the final state (sum preserved).
        let e = Arc::new(engine(HtmConfig::broadwell()));
        let a = e.heap.alloc(1);
        let b = e.heap.alloc(1);
        e.heap.store(a, 1000);
        e.heap.store(b, 0);
        let mut handles = Vec::new();
        for tid in 0..4u32 {
            let e = Arc::clone(&e);
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(tid as u64);
                let mut moved = 0u64;
                while moved < 250 {
                    // Move one unit a -> b, transactionally.
                    let r = e.attempt(tid, &mut rng, None, &mut |t: &mut dyn TxAccess| {
                        let va = t.read(a)?;
                        let vb = t.read(b)?;
                        t.write(a, va - 1)?;
                        t.write(b, vb + 1)
                    });
                    if r.is_ok() {
                        moved += 1;
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(e.heap.load(a), 0);
        assert_eq!(e.heap.load(b), 1000);
    }
}
