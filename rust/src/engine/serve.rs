//! Mid-stream adaptation for the continuous-serving session.
//!
//! A serving session never stops to re-plan: [`ServeController`]
//! wraps the `--policy auto` meta-controller ([`super::auto`]) and
//! feeds it one [`Sample`] per *promoted block* (the same
//! conflict-rate reduction the engine's interval loop uses), so the
//! regime keeps adapting across an unbounded stream. The serving
//! pipeline has exactly one actuation point — how many ingress
//! operations each admission block drains — so the controller maps
//! the winning backend onto a **drain cap**:
//!
//! - sparse regime (the per-transaction DyAd fast path would win) →
//!   small blocks ([`ServeController::LATENCY_CAP`]): promotions come
//!   fast, snapshots stay fresh, serving p99 drops;
//! - conflicted regime (the batch backend wins) → uncapped blocks:
//!   block speculation absorbs the conflicts and throughput rules.
//!
//! Every switch still goes through the shared trace plane as a
//! `backend-switch` event (same ordinal coding as the engine), so
//! the telemetry story is uniform between `run` and `serve`.

use crate::batch::BatchReport;
use crate::hytm::PolicySpec;

use super::auto::{AutoController, Sample, DEFAULT_HYSTERESIS};

/// Per-block meta-controller of one serving session (see module
/// docs).
pub struct ServeController {
    auto: AutoController,
    switches: u64,
}

impl ServeController {
    /// Drain cap in the latency (sparse) regime: small admission
    /// blocks keep the promoted horizon close behind the ingress.
    pub const LATENCY_CAP: usize = 128;

    pub fn new() -> Self {
        Self::with_hysteresis(DEFAULT_HYSTERESIS)
    }

    pub fn with_hysteresis(h: u32) -> Self {
        Self {
            auto: AutoController::new(h),
            switches: 0,
        }
    }

    /// The backend the controller currently deems best.
    pub fn current(&self) -> PolicySpec {
        self.auto.current()
    }

    /// Backend switches made so far.
    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// Feed one promoted block's report. On a regime switch, emits
    /// the engine-coded `backend-switch` trace event.
    pub fn observe_block(&mut self, rep: &BatchReport) {
        let s = Sample::from_stats(&rep.to_stats());
        if let Some((from, to)) = self.auto.observe(&s) {
            self.switches += 1;
            crate::obs::trace::backend_switch(
                crate::engine::ordinal(from),
                crate::engine::ordinal(to),
            );
        }
    }

    /// The admission-block bound the current regime asks for: the
    /// batch backends run uncapped (throughput mode), everything
    /// per-transaction-shaped caps at [`Self::LATENCY_CAP`]
    /// (latency mode).
    pub fn drain_cap(&self) -> usize {
        match self.auto.current() {
            PolicySpec::Batch { .. } | PolicySpec::BatchAdaptive { .. } => usize::MAX,
            _ => Self::LATENCY_CAP,
        }
    }
}

impl Default for ServeController {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A block report with `txns` commits and `aborts` re-executions
    /// — conflict rate `aborts / (aborts + txns)` after the
    /// stats-plane fold.
    fn block(txns: usize, aborts: u64) -> BatchReport {
        BatchReport {
            txns,
            validation_aborts: aborts,
            ..BatchReport::default()
        }
    }

    #[test]
    fn starts_in_throughput_mode_uncapped() {
        let c = ServeController::new();
        assert_eq!(c.current(), super::super::auto::start_spec());
        assert_eq!(c.drain_cap(), usize::MAX);
        assert_eq!(c.switches(), 0);
    }

    #[test]
    fn sparse_stream_switches_to_latency_cap_and_back() {
        let mut c = ServeController::with_hysteresis(1);
        // Conflict-free blocks: the sparse regime wins, blocks cap.
        for _ in 0..8 {
            c.observe_block(&block(256, 0));
        }
        assert_eq!(c.switches(), 1, "one switch to the sparse backend");
        assert_eq!(c.drain_cap(), ServeController::LATENCY_CAP);
        // A conflict storm flips it back to uncapped batch blocks.
        for _ in 0..8 {
            c.observe_block(&block(64, 64));
        }
        assert_eq!(c.switches(), 2, "and one switch back");
        assert_eq!(c.drain_cap(), usize::MAX);
    }

    #[test]
    fn empty_blocks_carry_no_signal() {
        let mut c = ServeController::with_hysteresis(1);
        for _ in 0..16 {
            c.observe_block(&block(0, 0));
        }
        assert_eq!(c.switches(), 0);
        assert_eq!(c.drain_cap(), usize::MAX);
    }
}
