//! The backend seam: one `engine` handle between the kernels and the
//! five synchronization backends.
//!
//! Before this layer, every kernel, the pipeline, the simulator, and
//! the coordinators matched on [`PolicySpec`] themselves — sixteen
//! files of `if let Some(ctl) = spec.batch_sizing()` — so no controller
//! could switch backends mid-run without touching all of them. Now they
//! ask an [`Engine`] instead:
//!
//! * [`Engine::backend`] — the backend for the next interval: its
//!   [`Backend::sizing`] decides block-speculated vs per-transaction
//!   dispatch, [`Backend::executor`] builds the per-thread driver.
//! * [`Engine::observe`] — feed the interval's [`TxStats`] delta back;
//!   under `--policy auto` the [`auto::AutoController`] may decide to
//!   switch, which materializes at the *next* `backend()` call — i.e.
//!   at a kernel/phase boundary, after the old backend has drained.
//! * [`Engine::threaded_spec`] — mid-kernel re-dispatch among
//!   per-transaction backends only. Entering the batch backend is
//!   deferred to the next kernel boundary: block promotion is the
//!   drain point that keeps kernel-3's bitwise determinism across a
//!   switch (see `tests/batch_determinism.rs`).
//!
//! For a fixed spec the engine is a zero-cost pass-through — same
//! sizing, same executor, no controller — so `--policy dyad` runs
//! exactly as before the seam existed.

pub mod auto;
pub mod serve;

/// The watchdog's last-resort escalation state. When structural
/// recovery (re-readied wakeups, forced revalidation) fails to restart
/// progress, the fault watchdog flips this process-wide `Degraded`
/// flag; every [`Engine::backend`] call then resolves to the
/// global-lock serial backend — the one rung of the ladder that cannot
/// livelock — until the watchdog observes sustained progress
/// ([`crate::fault::watchdog::RECOVERY_HYSTERESIS`] healthy intervals)
/// and lifts it, at which point the engine returns to its requested or
/// controller-chosen backend at the next boundary.
pub mod degraded {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    static DEGRADED: AtomicBool = AtomicBool::new(false);
    static ESCALATIONS: AtomicU64 = AtomicU64::new(0);

    /// Enter the degraded state (idempotent; counts and traces only
    /// the edge).
    pub fn escalate(kicks: u64) {
        if !DEGRADED.swap(true, Ordering::SeqCst) {
            ESCALATIONS.fetch_add(1, Ordering::Relaxed);
            crate::obs::trace::degraded(kicks);
            crate::obs::diag(
                1,
                &format!("watchdog: escalating to serial backend after {kicks} kicks"),
            );
        }
    }

    /// Leave the degraded state (idempotent; traces only the edge).
    pub fn recover(kicks: u64) {
        if DEGRADED.swap(false, Ordering::SeqCst) {
            crate::obs::trace::recovered(kicks);
            crate::obs::diag(1, "watchdog: degraded state lifted, restoring backend");
        }
    }

    /// One relaxed load: is the process currently degraded?
    #[inline]
    pub fn is_degraded() -> bool {
        DEGRADED.load(Ordering::Relaxed)
    }

    /// Escalations since process start.
    pub fn escalations() -> u64 {
        ESCALATIONS.load(Ordering::Relaxed)
    }
}

use crate::batch::adaptive::BlockSizeController;
use crate::hytm::{PolicySpec, ThreadExecutor, TmSystem};
use crate::stats::TxStats;

use auto::AutoController;

/// One synchronization backend behind the seam. Object-safe: the
/// engine holds `Box<dyn Backend>` and swaps it on a controller switch.
pub trait Backend {
    /// The backend's reporting name (the spec family name).
    fn name(&self) -> &'static str {
        self.spec().name()
    }

    /// The concrete spec this backend executes — never
    /// [`PolicySpec::Auto`] (the controller resolves that to one of
    /// these).
    fn spec(&self) -> PolicySpec;

    /// `Some(controller)` when work should be block-speculated through
    /// `crate::batch`, `None` for per-transaction dispatch. The same
    /// seam `PolicySpec::batch_sizing` provided, now virtual.
    fn sizing(&self) -> Option<BlockSizeController> {
        self.spec().batch_sizing()
    }

    /// Build the per-thread driver for the per-transaction path.
    fn executor<'s>(&self, sys: &'s TmSystem, tid: u32, seed: u64) -> ThreadExecutor<'s> {
        ThreadExecutor::new(sys, self.spec(), tid, seed)
    }
}

/// Coarse-grain lock baseline.
pub struct LockBackend;

impl Backend for LockBackend {
    fn spec(&self) -> PolicySpec {
        PolicySpec::CoarseLock
    }
}

/// Pure STM (NOrec or TL2).
pub struct StmBackend {
    pub spec: PolicySpec,
}

impl Backend for StmBackend {
    fn spec(&self) -> PolicySpec {
        self.spec
    }
}

/// Best-effort HTM with a lock fallback (HTMALock / HTMSpin / HLE).
pub struct HtmBackend {
    pub spec: PolicySpec,
}

impl Backend for HtmBackend {
    fn spec(&self) -> PolicySpec {
        self.spec
    }
}

/// The HyTM retry-policy family (RND/Fx/StAd/DyAd/DyAd-TL2) plus PhTM.
pub struct DyadBackend {
    pub spec: PolicySpec,
}

impl Backend for DyadBackend {
    fn spec(&self) -> PolicySpec {
        self.spec
    }
}

/// Block-STM-style speculative batch execution (fixed or adaptive
/// sizing).
pub struct BatchBackend {
    pub spec: PolicySpec,
}

impl Backend for BatchBackend {
    fn spec(&self) -> PolicySpec {
        self.spec
    }
}

/// Adapter lookup: the one place a spec is matched to a backend.
/// [`PolicySpec::Auto`] resolves to the controller's start backend
/// (adaptive batch); the [`Engine`] owns the controller that moves it
/// afterwards.
pub fn backend_for(spec: PolicySpec) -> Box<dyn Backend> {
    match spec {
        PolicySpec::CoarseLock => Box::new(LockBackend),
        PolicySpec::StmNorec | PolicySpec::StmTl2 => Box::new(StmBackend { spec }),
        PolicySpec::HtmALock { .. } | PolicySpec::HtmSpin { .. } | PolicySpec::Hle => {
            Box::new(HtmBackend { spec })
        }
        PolicySpec::Rnd { .. }
        | PolicySpec::Fx { .. }
        | PolicySpec::StAd { .. }
        | PolicySpec::DyAd { .. }
        | PolicySpec::DyAdTl2 { .. }
        | PolicySpec::PhTm { .. } => Box::new(DyadBackend { spec }),
        PolicySpec::Batch { .. } | PolicySpec::BatchAdaptive { .. } => {
            Box::new(BatchBackend { spec })
        }
        PolicySpec::Auto { .. } => Box::new(BatchBackend {
            spec: auto::start_spec(),
        }),
    }
}

/// Stable numeric code per spec family — the payload of the
/// `backend-switch` trace event (`a` = from, `b` = to), so a trace
/// consumer can decode switches without string parsing.
pub fn ordinal(spec: PolicySpec) -> u64 {
    match spec {
        PolicySpec::CoarseLock => 0,
        PolicySpec::StmNorec => 1,
        PolicySpec::StmTl2 => 2,
        PolicySpec::HtmALock { .. } => 3,
        PolicySpec::HtmSpin { .. } => 4,
        PolicySpec::Hle => 5,
        PolicySpec::Rnd { .. } => 6,
        PolicySpec::Fx { .. } => 7,
        PolicySpec::StAd { .. } => 8,
        PolicySpec::DyAd { .. } => 9,
        PolicySpec::DyAdTl2 { .. } => 10,
        PolicySpec::PhTm { .. } => 11,
        PolicySpec::Batch { .. } => 12,
        PolicySpec::BatchAdaptive { .. } => 13,
        PolicySpec::Auto { .. } => 14,
    }
}

/// The engine handle a run threads through its kernels: requested
/// spec, the live backend, and (under `--policy auto`) the
/// meta-controller that moves it.
pub struct Engine {
    requested: PolicySpec,
    controller: Option<AutoController>,
    current: Box<dyn Backend>,
    switches: u64,
    /// The live backend was installed by a degraded-state override
    /// (so the restore on recovery is traced as a switch too).
    degraded_applied: bool,
}

impl Engine {
    pub fn new(spec: PolicySpec) -> Engine {
        let controller = match spec {
            PolicySpec::Auto { hysteresis } => Some(AutoController::new(hysteresis)),
            _ => None,
        };
        Engine {
            requested: spec,
            controller,
            current: backend_for(spec),
            switches: 0,
            degraded_applied: false,
        }
    }

    /// The spec the run was configured with (`Auto { .. }` stays
    /// `Auto` — use [`Engine::current_spec`] for the resolved backend).
    pub fn requested(&self) -> PolicySpec {
        self.requested
    }

    /// The concrete spec of the live backend.
    pub fn current_spec(&self) -> PolicySpec {
        self.current.spec()
    }

    pub fn is_auto(&self) -> bool {
        self.controller.is_some()
    }

    /// Switches committed so far.
    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// The backend for the next interval. Under auto this is where a
    /// pending controller decision materializes — the caller is at a
    /// kernel/phase boundary, so the old backend has drained (its
    /// stats interval was already fed to [`Engine::observe`]).
    /// `kernel`/`phase` are diagnostic labels only.
    pub fn backend(&mut self, _kernel: &str, _phase: &str) -> &dyn Backend {
        self.materialize();
        &*self.current
    }

    /// Mid-kernel re-dispatch for the per-transaction path: the live
    /// backend's spec when it is per-transaction, else `fallback`.
    /// Entering the *batch* backend mid-kernel is deliberately
    /// deferred to the next [`Engine::backend`] call — the kernel
    /// boundary is the clean drain point.
    pub fn threaded_spec(&mut self, fallback: PolicySpec) -> PolicySpec {
        if self.controller.is_some() {
            let want = self.controller.as_ref().unwrap().current();
            if want.batch_sizing().is_none() {
                self.materialize();
                return want;
            }
            return fallback;
        }
        let spec = self.current.spec();
        if spec.batch_sizing().is_some() {
            fallback
        } else {
            spec
        }
    }

    fn materialize(&mut self) {
        // Watchdog escalation overrides everything: while degraded the
        // engine serves the serial lock backend, and recovery restores
        // the requested/controller spec at the next boundary.
        let deg = degraded::is_degraded();
        let want = if deg {
            PolicySpec::CoarseLock
        } else if let Some(ctl) = &self.controller {
            ctl.current()
        } else {
            self.requested
        };
        if want != self.current.spec() {
            // Controller switches were already traced at decision time
            // in `observe`; trace only the degraded edges here.
            if deg || self.degraded_applied {
                self.switches += 1;
                crate::obs::trace::backend_switch(ordinal(self.current.spec()), ordinal(want));
            }
            self.degraded_applied = deg;
            self.current = backend_for(want);
        }
    }

    /// Feed one completed interval's [`TxStats`] delta back. Under a
    /// fixed spec this is a no-op; under auto the controller votes, and
    /// a committed switch is logged (`backend-switch` trace event +
    /// `[obs]` diag line) and counted into `backend_switches`.
    pub fn observe(&mut self, interval: &TxStats) {
        let Some(ctl) = &mut self.controller else {
            return;
        };
        let sample = auto::Sample::from_stats(interval);
        if let Some((from, to)) = ctl.observe(&sample) {
            self.switches += 1;
            crate::obs::trace::backend_switch(ordinal(from), ordinal(to));
            crate::obs::diag(
                1,
                &format!(
                    "auto: backend switch {} -> {} at interval {} (conflict {:.4})",
                    from.name(),
                    to.name(),
                    ctl.intervals(),
                    sample.conflict_rate
                ),
            );
        }
    }

    /// Fold the engine's own counters into a run's merged stats (the
    /// coordinators call this before labeling).
    pub fn apply_to(&self, stats: &mut TxStats) {
        stats.backend_switches += self.switches;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_spec_is_a_passthrough() {
        let specs = [
            PolicySpec::CoarseLock,
            PolicySpec::StmNorec,
            PolicySpec::StmTl2,
            PolicySpec::HtmALock { retries: 8 },
            PolicySpec::Hle,
            PolicySpec::DyAd { n: 43 },
            PolicySpec::PhTm { retries: 4, sw_quantum: 16 },
            PolicySpec::Batch { block: 512 },
            PolicySpec::batch_adaptive(),
        ];
        for spec in specs {
            let mut e = Engine::new(spec);
            assert!(!e.is_auto());
            assert_eq!(e.requested(), spec);
            let be = e.backend("test", "phase");
            assert_eq!(be.spec(), spec);
            assert_eq!(be.name(), spec.name());
            // sizing matches the old seam exactly.
            assert_eq!(
                be.sizing().is_some(),
                spec.batch_sizing().is_some(),
                "{}",
                spec.name()
            );
            // observe is inert; no switches ever.
            let mut s = TxStats::new();
            s.sw_commits = 10;
            e.observe(&s);
            assert_eq!(e.switches(), 0);
            let mut merged = TxStats::new();
            e.apply_to(&mut merged);
            assert_eq!(merged.backend_switches, 0);
        }
    }

    #[test]
    fn threaded_spec_defers_batch_to_kernel_boundaries() {
        // Fixed batch spec: the threaded path runs the fallback.
        let mut e = Engine::new(PolicySpec::Batch { block: 64 });
        assert_eq!(
            e.threaded_spec(PolicySpec::StmNorec),
            PolicySpec::StmNorec
        );
        // Fixed per-txn spec: the spec itself.
        let mut e = Engine::new(PolicySpec::DyAd { n: 43 });
        assert_eq!(e.threaded_spec(PolicySpec::StmNorec), PolicySpec::DyAd { n: 43 });
    }

    #[test]
    fn auto_engine_switches_and_counts() {
        let mut e = Engine::new(PolicySpec::Auto { hysteresis: 1 });
        assert!(e.is_auto());
        // Starts on the adaptive batch backend.
        assert_eq!(e.current_spec(), PolicySpec::batch_adaptive());
        assert!(e.backend("k", "p").sizing().is_some());
        // Two sparse intervals (MIN_DWELL) flip it to dyad.
        let mut sparse = TxStats::new();
        sparse.sw_commits = 1000;
        e.observe(&sparse);
        e.observe(&sparse);
        assert_eq!(e.switches(), 1);
        assert_eq!(e.backend("k", "p").spec(), auto::sparse_spec());
        assert_eq!(e.current_spec(), auto::sparse_spec());
        let mut merged = TxStats::new();
        e.apply_to(&mut merged);
        assert_eq!(merged.backend_switches, 1);
    }

    #[test]
    fn auto_threaded_spec_tracks_controller_but_not_into_batch() {
        let mut e = Engine::new(PolicySpec::Auto { hysteresis: 1 });
        // Controller still on batch: threaded path uses the fallback.
        assert_eq!(
            e.threaded_spec(PolicySpec::StmNorec),
            PolicySpec::StmNorec
        );
        let mut sparse = TxStats::new();
        sparse.sw_commits = 1000;
        e.observe(&sparse);
        e.observe(&sparse);
        // Switched to dyad: the threaded path follows mid-kernel.
        assert_eq!(e.threaded_spec(PolicySpec::StmNorec), auto::sparse_spec());
        // Drive it back to batch: two hot intervals.
        let mut hot = TxStats::new();
        hot.sw_commits = 600;
        hot.sw_aborts = 400;
        e.observe(&hot);
        e.observe(&hot);
        assert_eq!(e.current_spec(), auto::sparse_spec(), "not yet materialized");
        // Mid-kernel the threaded path must NOT enter batch…
        assert_eq!(
            e.threaded_spec(PolicySpec::StmNorec),
            PolicySpec::StmNorec
        );
        // …but the next kernel boundary picks it up.
        assert!(e.backend("k", "p").sizing().is_some());
        assert_eq!(e.switches(), 2);
    }

    #[test]
    fn ordinals_are_distinct_and_stable() {
        let specs = [
            PolicySpec::CoarseLock,
            PolicySpec::StmNorec,
            PolicySpec::StmTl2,
            PolicySpec::HtmALock { retries: 8 },
            PolicySpec::HtmSpin { retries: 8 },
            PolicySpec::Hle,
            PolicySpec::Rnd { lo: 1, hi: 50 },
            PolicySpec::Fx { n: 43 },
            PolicySpec::StAd { n: 6 },
            PolicySpec::DyAd { n: 43 },
            PolicySpec::DyAdTl2 { n: 43 },
            PolicySpec::PhTm { retries: 4, sw_quantum: 16 },
            PolicySpec::Batch { block: 1 },
            PolicySpec::batch_adaptive(),
            PolicySpec::Auto { hysteresis: 2 },
        ];
        let mut seen = std::collections::HashSet::new();
        for (i, spec) in specs.iter().enumerate() {
            assert_eq!(ordinal(*spec), i as u64);
            assert!(seen.insert(ordinal(*spec)));
        }
    }
}
