//! The `--policy auto` meta-controller: runtime backend selection from
//! `obs::snapshot` interval deltas.
//!
//! This is the DyAdHyTM thesis lifted one level up: instead of adapting
//! a retry quota inside one hybrid policy, [`AutoController`] adapts
//! *which backend* runs the next interval. It consumes exactly the
//! fields the snapshot registry records ([`Sample::from_stats`] and
//! [`Sample::from_json`] compute the same `conflict_rate` from the same
//! integer counters), scores them with the AIMD-style thresholds of
//! [`crate::batch::adaptive::BlockSizeController`], and switches with
//! two anti-thrash guards borrowed from PhTM's quantum: a *hysteresis*
//! vote count (the same regime must win `hysteresis` consecutive
//! intervals) and a *minimum dwell* ([`MIN_DWELL`] intervals must pass
//! after a switch before the next one).
//!
//! The decision law:
//! - capacity-dominated HTM abort streams (the transaction footprint
//!   does not fit hardware, no retry count helps) → the multi-version
//!   batch backend, which has no footprint limit;
//! - `conflict_rate >= HI_CONFLICT` → the batch backend: block
//!   speculation absorbs conflicts deterministically instead of
//!   burning HTM retries;
//! - `conflict_rate <= LO_CONFLICT` → DyAdHyTM: the HTM fast path wins
//!   when conflicts are rare;
//! - the dead zone in between votes for nobody (the current backend
//!   keeps running and pending votes reset).
//!
//! Every switch is pushed onto a [`Decision`] log — the deterministic
//! replay seam — and surfaced as an `obs::trace` `backend-switch`
//! event plus a `backend_switches` stats counter by
//! [`crate::engine::Engine`].

use crate::hytm::policies::DyAdPolicy;
use crate::hytm::PolicySpec;
use crate::stats::TxStats;
use crate::tm::AbortCause;
use crate::util::json;

/// Default consecutive-vote requirement (`--policy auto` with no arg).
pub const DEFAULT_HYSTERESIS: u32 = 2;

/// Intervals that must pass after a switch before the next switch.
pub const MIN_DWELL: u32 = 2;

/// Conflict rate at/above which the batch backend wins (mirrors
/// `BlockSizeController::HI_CONFLICT`).
pub const HI_CONFLICT: f64 = 0.10;

/// Conflict rate at/below which the dyad HTM fast path wins (mirrors
/// `BlockSizeController::LO_CONFLICT`).
pub const LO_CONFLICT: f64 = 0.02;

/// The backend the controller starts on: adaptive batch — safe under
/// any conflict regime, and its drain-at-block-promotion is the clean
/// handoff point for the first switch.
pub fn start_spec() -> PolicySpec {
    PolicySpec::batch_adaptive()
}

/// The per-transaction backend the controller switches to in sparse
/// regimes.
pub fn sparse_spec() -> PolicySpec {
    PolicySpec::DyAd {
        n: DyAdPolicy::DEFAULT_N,
    }
}

/// One interval's controller inputs, reduced from a snapshot row or a
/// [`TxStats`] delta. Both constructors compute `conflict_rate` from
/// the same integer counters the snapshot registry writes, so replaying
/// a recorded JSON-lines stream reproduces the live decisions exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Sample {
    /// `aborts / (aborts + commits)` where aborts = hw aborts (all
    /// causes) + sw aborts — identical to the snapshot `conflict_rate`.
    pub conflict_rate: f64,
    /// `TxStats::total_commits()` (hw + sw + lock) for the interval.
    pub commits: u64,
    /// HTM aborts with [`AbortCause::Capacity`].
    pub capacity_aborts: u64,
    /// HTM begin attempts — the denominator of the capacity share.
    pub hw_attempts: u64,
    /// Interval wall (or virtual) time.
    pub time_ns: u64,
}

impl Sample {
    /// Reduce an interval [`TxStats`] delta.
    pub fn from_stats(stats: &TxStats) -> Sample {
        let aborts = stats.hw_aborts_total() + stats.sw_aborts;
        let commits = stats.total_commits();
        Sample {
            conflict_rate: ratio(aborts, aborts + commits),
            commits,
            capacity_aborts: stats.aborts_of(AbortCause::Capacity),
            hw_attempts: stats.hw_attempts,
            time_ns: stats.time_ns,
        }
    }

    /// Reduce one recorded snapshot JSON-lines row (the
    /// `--metrics-json` schema). Only the integer counters are read;
    /// `conflict_rate` is recomputed from them, which matches the
    /// recorded float because [`crate::obs::snapshot::record`] derives
    /// it from the same integers. Returns `None` when the row lacks
    /// the counters (e.g. a non-snapshot line).
    pub fn from_json(row: &str) -> Option<Sample> {
        let commits = json::scrape_u64(row, "commits")?;
        let sw_aborts = json::scrape_u64(row, "sw_aborts")?;
        let mut hw_aborts = 0u64;
        for cause in AbortCause::ALL {
            let key = format!("abort_{}", cause.name().replace('-', "_"));
            hw_aborts += json::scrape_u64(row, &key)?;
        }
        let aborts = hw_aborts + sw_aborts;
        Some(Sample {
            conflict_rate: ratio(aborts, aborts + commits),
            commits,
            capacity_aborts: json::scrape_u64(row, "abort_capacity")?,
            hw_attempts: json::scrape_u64(row, "hw_attempts")?,
            time_ns: json::scrape_u64(row, "time_ns").unwrap_or(0),
        })
    }

    /// Build a synthetic sample from a bare conflict rate — test and
    /// simulator convenience.
    pub fn synthetic(conflict_rate: f64, commits: u64) -> Sample {
        Sample {
            conflict_rate,
            commits,
            capacity_aborts: 0,
            hw_attempts: 0,
            time_ns: 0,
        }
    }

    /// Conflict-regime bucket: 0 = sparse (≤ LO), 2 = hot (≥ HI),
    /// 1 = the dead zone.
    pub fn regime(&self) -> u8 {
        if self.conflict_rate >= HI_CONFLICT {
            2
        } else if self.conflict_rate <= LO_CONFLICT {
            0
        } else {
            1
        }
    }
}

fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

/// One committed switch decision — the replay log entry.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Decision {
    /// 1-based index of the observed interval that triggered the
    /// switch.
    pub interval: u64,
    pub from: PolicySpec,
    pub to: PolicySpec,
}

/// The meta-controller state machine. Pure and deterministic: the same
/// sample sequence always yields the same decision log (asserted by
/// `tests/auto_replay.rs`).
#[derive(Clone, Debug)]
pub struct AutoController {
    hysteresis: u32,
    current: PolicySpec,
    candidate: Option<PolicySpec>,
    votes: u32,
    /// Intervals observed since the last switch (or since start).
    dwell: u32,
    /// Total intervals observed.
    intervals: u64,
    decisions: Vec<Decision>,
}

impl AutoController {
    pub fn new(hysteresis: u32) -> AutoController {
        AutoController {
            hysteresis: hysteresis.max(1),
            current: start_spec(),
            candidate: None,
            votes: 0,
            dwell: 0,
            intervals: 0,
            decisions: Vec::new(),
        }
    }

    /// The backend the next interval should run under.
    pub fn current(&self) -> PolicySpec {
        self.current
    }

    /// Intervals observed so far.
    pub fn intervals(&self) -> u64 {
        self.intervals
    }

    /// The committed switch log, in decision order.
    pub fn decisions(&self) -> &[Decision] {
        &self.decisions
    }

    pub fn switch_count(&self) -> u64 {
        self.decisions.len() as u64
    }

    /// The decision law, with no hysteresis applied: which backend this
    /// sample votes for, or `None` for the dead zone / an empty
    /// interval.
    pub fn target_for(s: &Sample) -> Option<PolicySpec> {
        if s.commits == 0 && s.hw_attempts == 0 {
            return None; // empty interval carries no signal
        }
        // Capacity-dominated: most HTM begins die on footprint. No
        // retry policy fixes that; the MV batch backend has no
        // footprint limit.
        if s.hw_attempts > 0 && s.capacity_aborts * 2 > s.hw_attempts {
            return Some(start_spec());
        }
        if s.conflict_rate >= HI_CONFLICT {
            Some(start_spec())
        } else if s.conflict_rate <= LO_CONFLICT {
            Some(sparse_spec())
        } else {
            None
        }
    }

    /// Observe one interval sample. Returns `Some((from, to))` when the
    /// hysteresis + dwell guards let a switch commit; the caller drains
    /// the old backend at the next kernel/block boundary and routes
    /// subsequent work through `to`.
    pub fn observe(&mut self, s: &Sample) -> Option<(PolicySpec, PolicySpec)> {
        self.intervals += 1;
        self.dwell = self.dwell.saturating_add(1);
        let target = match Self::target_for(s) {
            Some(t) if t != self.current => t,
            _ => {
                // Dead zone or the incumbent's regime: pending votes
                // for a challenger reset.
                self.candidate = None;
                self.votes = 0;
                return None;
            }
        };
        if self.candidate == Some(target) {
            self.votes += 1;
        } else {
            self.candidate = Some(target);
            self.votes = 1;
        }
        if self.votes >= self.hysteresis && self.dwell >= MIN_DWELL {
            return Some(self.commit_switch(target));
        }
        None
    }

    /// Commit a switch unconditionally — the simulator's measured-cost
    /// revert guard uses this to back out of a switch whose realized
    /// throughput regressed.
    pub fn force_switch(&mut self, to: PolicySpec) -> (PolicySpec, PolicySpec) {
        self.commit_switch(to)
    }

    fn commit_switch(&mut self, to: PolicySpec) -> (PolicySpec, PolicySpec) {
        let from = self.current;
        self.decisions.push(Decision {
            interval: self.intervals,
            from,
            to,
        });
        self.current = to;
        self.candidate = None;
        self.votes = 0;
        self.dwell = 0;
        (from, to)
    }

    /// Replay a recorded snapshot stream (JSON-lines rows, e.g. a
    /// `--metrics-json` file) through a fresh controller and return the
    /// decision log. Rows that don't parse as snapshot counters are
    /// skipped, mirroring a reader tailing a mixed log.
    pub fn replay<'a>(
        hysteresis: u32,
        rows: impl IntoIterator<Item = &'a str>,
    ) -> Vec<Decision> {
        let mut ctl = AutoController::new(hysteresis);
        for row in rows {
            if let Some(s) = Sample::from_json(row) {
                ctl.observe(&s);
            }
        }
        ctl.decisions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hot() -> Sample {
        Sample::synthetic(0.4, 1000)
    }

    fn sparse() -> Sample {
        Sample::synthetic(0.001, 1000)
    }

    fn dead_zone() -> Sample {
        Sample::synthetic(0.05, 1000)
    }

    #[test]
    fn law_maps_regimes_to_backends() {
        assert_eq!(AutoController::target_for(&hot()), Some(start_spec()));
        assert_eq!(AutoController::target_for(&sparse()), Some(sparse_spec()));
        assert_eq!(AutoController::target_for(&dead_zone()), None);
        assert_eq!(
            AutoController::target_for(&Sample::synthetic(0.0, 0)),
            None,
            "empty interval carries no signal"
        );
        // Capacity-dominated HTM streams pick batch even at a clean
        // conflict rate.
        let capacity = Sample {
            conflict_rate: 0.0,
            commits: 100,
            capacity_aborts: 80,
            hw_attempts: 100,
            time_ns: 0,
        };
        assert_eq!(
            AutoController::target_for(&capacity),
            Some(start_spec())
        );
    }

    #[test]
    fn hysteresis_requires_consecutive_votes() {
        let mut ctl = AutoController::new(2);
        assert_eq!(ctl.current(), start_spec());
        // First sparse vote: pending, no switch.
        assert_eq!(ctl.observe(&sparse()), None);
        // A hot interval resets the pending vote…
        assert_eq!(ctl.observe(&hot()), None);
        assert_eq!(ctl.observe(&sparse()), None);
        // …so the switch needs two consecutive sparse votes again.
        assert_eq!(
            ctl.observe(&sparse()),
            Some((start_spec(), sparse_spec()))
        );
        assert_eq!(ctl.current(), sparse_spec());
        assert_eq!(ctl.switch_count(), 1);
        assert_eq!(ctl.decisions()[0].interval, 4);
    }

    #[test]
    fn dead_zone_resets_votes() {
        let mut ctl = AutoController::new(2);
        assert_eq!(ctl.observe(&sparse()), None);
        assert_eq!(ctl.observe(&dead_zone()), None);
        assert_eq!(ctl.observe(&sparse()), None, "vote count restarted");
        assert!(ctl.observe(&sparse()).is_some());
    }

    #[test]
    fn min_dwell_blocks_immediate_flapping() {
        // hysteresis=1: every vote would switch, so MIN_DWELL is the
        // only brake.
        let mut ctl = AutoController::new(1);
        assert_eq!(ctl.observe(&sparse()), None, "dwell 1 < MIN_DWELL");
        assert!(ctl.observe(&sparse()).is_some(), "dwell satisfied");
        // Straight back: dwell restarted at the switch.
        assert_eq!(ctl.observe(&hot()), None);
        assert!(ctl.observe(&hot()).is_some());
        assert_eq!(ctl.switch_count(), 2);
    }

    #[test]
    fn force_switch_logs_and_resets_dwell() {
        let mut ctl = AutoController::new(1);
        let (from, to) = ctl.force_switch(sparse_spec());
        assert_eq!((from, to), (start_spec(), sparse_spec()));
        assert_eq!(ctl.current(), sparse_spec());
        assert_eq!(ctl.switch_count(), 1);
        // Dwell restarted: the next regular switch needs MIN_DWELL
        // fresh intervals.
        assert_eq!(ctl.observe(&hot()), None);
        assert!(ctl.observe(&hot()).is_some());
    }

    #[test]
    fn sample_from_stats_matches_snapshot_formula() {
        let mut s = TxStats::new();
        s.sw_commits = 90;
        s.sw_aborts = 10;
        s.hw_attempts = 5;
        s.time_ns = 777;
        let sample = Sample::from_stats(&s);
        assert!((sample.conflict_rate - 0.1).abs() < 1e-12);
        assert_eq!(sample.commits, 90);
        assert_eq!(sample.hw_attempts, 5);
        assert_eq!(sample.time_ns, 777);
    }

    #[test]
    fn sample_from_json_round_trips_a_snapshot_row() {
        let row = "{\"seq\":0,\"kernel\":\"generation\",\"phase\":\"insert\",\
                   \"time_ns\":5000,\"hw_commits\":0,\"hw_attempts\":12,\
                   \"hw_retries\":0,\"abort_conflict\":0,\"abort_capacity\":3,\
                   \"abort_explicit\":0,\"abort_interrupt\":0,\
                   \"abort_sw_conflict\":0,\"sw_commits\":90,\"sw_aborts\":7,\
                   \"lock_commits\":0,\"commits\":90}";
        let s = Sample::from_json(row).unwrap();
        assert_eq!(s.commits, 90);
        assert_eq!(s.capacity_aborts, 3);
        assert_eq!(s.hw_attempts, 12);
        assert_eq!(s.time_ns, 5000);
        assert!((s.conflict_rate - 10.0 / 100.0).abs() < 1e-12);
        assert_eq!(Sample::from_json("{\"not\":\"a snapshot\"}"), None);
    }

    #[test]
    fn replay_is_deterministic() {
        let mk = |commits: u64, sw_aborts: u64| {
            format!(
                "{{\"time_ns\":1,\"hw_attempts\":0,\"abort_conflict\":0,\
                 \"abort_capacity\":0,\"abort_explicit\":0,\
                 \"abort_interrupt\":0,\"abort_sw_conflict\":0,\
                 \"sw_aborts\":{sw_aborts},\"commits\":{commits}}}"
            )
        };
        let rows: Vec<String> = vec![
            mk(900, 600), // hot
            mk(900, 600),
            mk(999, 1), // sparse
            mk(999, 1),
            mk(999, 1),
        ];
        let a = AutoController::replay(2, rows.iter().map(|s| s.as_str()));
        let b = AutoController::replay(2, rows.iter().map(|s| s.as_str()));
        assert_eq!(a, b);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].to, sparse_spec());
    }
}
