//! Multi-version memory for the speculative batch executor.
//!
//! Every speculative write lands here, never in the [`TxHeap`] — the
//! heap stays at its pre-batch snapshot until `write_back`. Per address
//! the structure keeps one entry per *transaction index* (only the
//! latest incarnation of each), so a reader at index `i` picks the
//! highest writer strictly below `i` and falls through to the heap when
//! there is none. Entries of an aborted incarnation are flagged
//! ESTIMATE: readers treat them as "this value is about to be
//! rewritten" and suspend instead of speculating on a known-stale
//! value.
//!
//! # Lock-free layout
//!
//! The store is built so **reads of committed versions take zero
//! locks** — the whole point of speculating in the low-conflict regime
//! the paper says optimism should win:
//!
//! * the address index is an array of [`SHARDS`] `AtomicPtr` heads,
//!   each the top of a CAS-published chain of [`AddrEntry`] nodes
//!   (append-only: nodes are bump-allocated from a store-owned
//!   [`Arena`] and live exactly as long as the store, so raw traversal
//!   needs no per-node reclamation protocol);
//! * each `AddrEntry` owns a grow-only segmented **version vector**:
//!   [`VersionSlot`]s claimed once per writing transaction by a CAS on
//!   the slot's owner word and reused across that transaction's
//!   incarnations. Overflow [`Segment`]s come from the same arena —
//!   the hot path never calls the global allocator;
//! * a slot publishes `(incarnation, flags, value)` through a two-word
//!   **seqlock**: the writer (single per slot — the scheduler
//!   serializes a transaction's incarnations) stores a WRITING-marked
//!   meta word, the value, then the final meta word; readers re-check
//!   the meta word around the value load. Meta words are strictly
//!   monotonic per slot (incarnations only grow, each flag transition
//!   happens once per incarnation), so a stable double-read cannot be
//!   an ABA artifact. All fences are `SeqCst` — plain loads on x86, so
//!   the read hot path is exactly three uncontended loads per slot;
//! * per-transaction read/write sets are published as **immutable
//!   [`RecordedSets`] nodes behind one `AtomicPtr` per transaction**
//!   (the single-owner handoff replacing the old `Mutex<Vec<_>>`
//!   cells): `record` builds the node privately and swaps it in; a
//!   stale validator can still be walking the previous node, and its
//!   stale verdict is dropped by the scheduler's incarnation check.
//!
//! # Memory management
//!
//! What happens to a *superseded* `RecordedSets` node depends on the
//! session mode (see `crate::mem::epoch` and the crate-level "Memory
//! management" section):
//!
//! * **barrier runs** (no attached gc): the node stays alive on a
//!   `prev` chain until the store drops — one block's worth of
//!   garbage, freed at the block boundary, exactly the pre-reclamation
//!   behaviour;
//! * **pipelined sessions** ([`MvStore::attach_gc`]): the swap's
//!   exclusively-owned loser is retired into the session's epoch limbo
//!   instead of chained, and block promotion
//!   ([`MvStore::retire_sets`]) detaches every transaction's final
//!   node the same way. Workers pin a reclamation epoch around each
//!   task-drain iteration, so the limbo frees garbage as soon as every
//!   live worker has passed the retiring epoch — bounded live cells on
//!   an unbounded stream.
//!
//! Validation is batched: `record` publishes the read set sorted by
//! address and the store keeps a **per-shard modification watermark**
//! (bumped *after* every publish / tombstone / estimate flip). Each
//! `ReadDesc` snapshots its shard watermark *before* the read; a
//! validation pass walks the sorted read set and skips the version
//! probe entirely for reads whose shard watermark is unchanged — the
//! common case at low conflict, making re-validation O(1) per
//! untouched shard. A racy skip (publish visible, bump not yet) is
//! repaired by the same scheduler revalidation that already covers
//! stale-verdict races: the deciding validation happens-after the
//! record that bumped the mark.
//!
//! A Mutex-sharded baseline ([`MutexMvMemory`], the PR-1 layout) is
//! kept behind the same [`MvStore`] trait so `benches/batch_throughput`
//! can measure exactly what the lock-free hot path buys.
//!
//! Addresses are word indices (`mem::Addr`), exactly what the
//! [`crate::tm::access::TxAccess`] bodies already traffic in, so the
//! same transaction closures run unchanged under HTM, STM, the locks,
//! or this executor.

use std::cell::UnsafeCell;
use std::collections::{BTreeMap, HashMap};
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering::SeqCst};
use std::sync::{Arc, Mutex, OnceLock};

use crate::mem::epoch::EpochGc;
use crate::mem::{Addr, TxHeap};

use super::scheduler::{Incarnation, TxnIdx, Version};

/// Where a speculative read was served from — the version the read
/// validates against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadOrigin {
    /// Fell through to the base state below this block (the heap, or —
    /// under cross-block pipelining — the still-draining previous
    /// block's winning version). Carries the *observed value*:
    /// validation compares values, which is what makes reads taken
    /// while the predecessor block was still committing safe — the
    /// post-write-back revalidation catches any divergence.
    Base(u64),
    /// Served by a lower transaction's recorded write.
    Version(Version),
}

/// One entry of a transaction's read set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReadDesc {
    pub addr: Addr,
    pub origin: ReadOrigin,
    /// The address's shard watermark ([`MvStore::mark_of`]) sampled
    /// *before* the read. Validation may skip the store probe when the
    /// watermark is still equal — an unchanged shard proves the read's
    /// version chain is untouched. Stores without watermarks record 0
    /// and always re-probe.
    pub mark: u64,
}

/// Result of a speculative read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MvRead {
    /// No lower writer: read the heap.
    Base,
    /// A lower transaction wrote this value.
    Value(Version, u64),
    /// A lower transaction's aborted write: suspend on that index.
    Estimate(TxnIdx),
}

/// The multi-version store contract the batch executor runs against.
/// `MvMemory` is the lock-free production implementation;
/// `MutexMvMemory` is the sharded-mutex baseline kept for the
/// head-to-head benchmark. (`Send + Sync` because the pipelined
/// session shares per-block stores across the worker pool behind
/// `Arc`s.)
pub trait MvStore: Send + Sync {
    /// Fresh store for a batch of `n` transactions.
    fn new(n: usize) -> Self;

    /// Read `addr` as transaction `txn`: the highest writer below
    /// `txn`, or the heap when none exists.
    fn read(&self, addr: Addr, txn: TxnIdx) -> MvRead;

    /// Record a finished incarnation's read and write sets. Stale
    /// entries from the previous incarnation (addresses no longer
    /// written) are removed. Returns `true` when the incarnation wrote
    /// to an address its predecessor did not — the scheduler then
    /// forces higher transactions to revalidate.
    fn record(&self, version: Version, reads: Vec<ReadDesc>, writes: &[(Addr, u64)]) -> bool;

    /// Mark every write of `txn`'s last incarnation as an ESTIMATE
    /// (called right after a validation abort wins, before the
    /// re-execution is scheduled).
    fn convert_writes_to_estimates(&self, txn: TxnIdx);

    /// Re-read `txn`'s recorded read set and check every observed
    /// version still matches. ESTIMATEs and changed versions fail.
    /// `base` resolves the value *below* this block for addresses with
    /// no lower in-block writer (the heap for a barrier run; the
    /// previous block's winning version under cross-block pipelining);
    /// `None` means the base is itself unresolved (a predecessor
    /// ESTIMATE), which fails the validation so the transaction
    /// re-executes and parks. Generic over the resolver so each call
    /// site monomorphizes its base lookup — the per-read virtual
    /// dispatch the old `&dyn Fn` signature paid is gone.
    fn validate_read_set<F: Fn(Addr) -> Option<u64>>(&self, txn: TxnIdx, base: F) -> bool;

    /// After the batch completes: flush the winning (highest-index)
    /// version of every address into the heap. Equivalent to committing
    /// the transactions one by one in index order.
    fn write_back(&self, heap: &TxHeap);

    /// Visit the winning (highest-index) version of every address
    /// this block wrote — the exact set of `(addr, value)` pairs
    /// [`write_back`](Self::write_back) would flush. The serving
    /// plane's snapshot log captures these *before* write-back so
    /// pinned readers keep seeing the pre-promotion value of each
    /// address after the heap moves on. Must only be called once the
    /// block's scheduler is done (same precondition as `write_back`).
    fn for_each_winning(&self, f: &mut dyn FnMut(Addr, u64));

    /// The modification watermark of `addr`'s shard, sampled into each
    /// [`ReadDesc`] before the read. Default 0: stores without
    /// watermarks never let validation skip.
    fn mark_of(&self, _addr: Addr) -> u64 {
        0
    }

    /// Attach the pipelined session's epoch-reclamation domain:
    /// superseded recorded sets retire into its limbo instead of
    /// accumulating on `prev` chains. Default: ignore (barrier runs
    /// and the mutex baseline keep store-owned garbage).
    fn attach_gc(&self, _gc: &Arc<EpochGc>) {}

    /// Detach every transaction's recorded sets into the attached
    /// gc's limbo. Called once per block at promotion, after
    /// `write_back` — the scheduler is done, so no in-flight validator
    /// can acquire a fresh reference. No-op without an attached gc.
    fn retire_sets(&self) {}

    /// Approximate bytes of arena backing owned by this store (0 when
    /// not arena-backed). Sampled at promotion for the `arena_bytes`
    /// report peak.
    fn mem_bytes(&self) -> u64 {
        0
    }
}

// --------------------------------------------------------------------
// Lock-free implementation
// --------------------------------------------------------------------

/// Shard count for the address index (power of two). Sized so typical
/// per-block footprints (thousands of distinct addresses) keep chains
/// a couple of nodes long.
const SHARD_BITS: u32 = 12;
const SHARDS: usize = 1 << SHARD_BITS;

/// Version slots per segment of an address's version vector. Most
/// addresses have a single writer; hubs chain additional segments.
const SLOTS_PER_SEG: usize = 8;

/// Slot meta word: `(incarnation + 1) << 3 | flags`; `0` = never
/// written. The `+ 1` keeps a published meta distinct from the empty
/// word. Meta values are strictly monotonic per slot (incarnations only
/// grow, ESTIMATE/TOMBSTONE each fire once per incarnation), which is
/// what makes the seqlock's stable double-read conclusive.
const FLAG_WRITING: u64 = 1;
const FLAG_ESTIMATE: u64 = 2;
const FLAG_TOMBSTONE: u64 = 4;
const META_EMPTY: u64 = 0;

#[inline]
fn meta_pack(incarnation: Incarnation, flags: u64) -> u64 {
    ((incarnation as u64 + 1) << 3) | flags
}

#[inline]
fn meta_incarnation(meta: u64) -> Incarnation {
    ((meta >> 3) - 1) as Incarnation
}

/// One `(address, writing transaction)` cell. Claimed once (owner CAS),
/// then republished across incarnations by its single serialized
/// writer through the seqlock protocol.
struct VersionSlot {
    /// Writing transaction's index + 1; 0 = unclaimed.
    owner: AtomicUsize,
    meta: AtomicU64,
    value: AtomicU64,
}

impl VersionSlot {
    fn empty() -> Self {
        Self {
            owner: AtomicUsize::new(0),
            meta: AtomicU64::new(META_EMPTY),
            value: AtomicU64::new(0),
        }
    }

    /// Seqlock read: a stable, non-WRITING meta word sampled on both
    /// sides of the value load is conclusive (meta monotonicity rules
    /// out ABA). The WRITING window is two stores wide, so the spin is
    /// normally a handful of iterations; the bounded-spin-then-yield
    /// keeps a reader from livelocking against a preempted writer on
    /// an oversubscribed core.
    fn read_consistent(&self) -> (u64, u64) {
        let mut spins = 0u32;
        loop {
            let m1 = self.meta.load(SeqCst);
            if m1 & FLAG_WRITING != 0 {
                spins += 1;
                if spins < 64 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
                continue;
            }
            let v = self.value.load(SeqCst);
            let m2 = self.meta.load(SeqCst);
            if m1 == m2 {
                return (m1, v);
            }
        }
    }

    /// Publish `(incarnation, value)`. Only the slot's serialized owner
    /// calls this; the WRITING pre-phase keeps concurrent readers from
    /// pairing the new value with the old meta.
    fn publish(&self, incarnation: Incarnation, value: u64) {
        self.meta.store(meta_pack(incarnation, FLAG_WRITING), SeqCst);
        self.value.store(value, SeqCst);
        self.meta.store(meta_pack(incarnation, 0), SeqCst);
    }

    /// Retract the slot (the new incarnation no longer writes this
    /// address). `incarnation` is the retracting incarnation, keeping
    /// the meta word monotonic.
    fn tombstone(&self, incarnation: Incarnation) {
        self.meta.store(meta_pack(incarnation, FLAG_TOMBSTONE), SeqCst);
    }

    /// Flag the current publication as an aborted incarnation's write.
    fn mark_estimate(&self) {
        self.meta.fetch_or(FLAG_ESTIMATE, SeqCst);
    }
}

/// A grow-only block of version slots.
struct Segment {
    slots: [VersionSlot; SLOTS_PER_SEG],
    next: AtomicPtr<Segment>,
}

impl Segment {
    fn new() -> Self {
        Self {
            slots: std::array::from_fn(|_| VersionSlot::empty()),
            next: AtomicPtr::new(std::ptr::null_mut()),
        }
    }
}

// --------------------------------------------------------------------
// Bump arenas
// --------------------------------------------------------------------

/// Nodes per arena chunk. One chunk of `AddrEntry`s covers a typical
/// block footprint shard-side; hub-heavy blocks chain a few more.
const ARENA_CHUNK: usize = 256;

/// One chunk of a lock-free bump arena. `used` may overshoot the
/// capacity (racers that lose the bump retry on a fresh chunk); `Drop`
/// clamps it back.
struct ArenaChunk<T> {
    used: AtomicUsize,
    /// The previously-filled chunk (newest-first chain from the head).
    next: AtomicPtr<ArenaChunk<T>>,
    items: Box<[UnsafeCell<MaybeUninit<T>>]>,
}

/// Lock-free chunked bump allocator. `alloc` is a `fetch_add` plus a
/// write in the common case — no global-allocator call, no locks —
/// and the returned reference is stable for the arena's whole
/// lifetime: chunks are only freed when the arena drops, which is what
/// lets the store hand out raw `&'store` pointers into it. Slots
/// orphaned by CAS losers elsewhere in the store simply stay initialized
/// until the arena drops (rare, a few nodes per contended block).
struct Arena<T> {
    head: AtomicPtr<ArenaChunk<T>>,
}

// SAFETY: a slot is claimed by exactly one thread (the `fetch_add`
// winner) before its single initializing write; after `alloc` returns,
// the slot is only reached through the store's own atomics-published
// pointers. The `UnsafeCell` is never aliased mutably.
unsafe impl<T: Send> Send for Arena<T> {}
unsafe impl<T: Send + Sync> Sync for Arena<T> {}

impl<T> Arena<T> {
    fn new() -> Self {
        Self {
            head: AtomicPtr::new(Box::into_raw(Self::chunk())),
        }
    }

    fn chunk() -> Box<ArenaChunk<T>> {
        Box::new(ArenaChunk {
            used: AtomicUsize::new(0),
            next: AtomicPtr::new(std::ptr::null_mut()),
            items: (0..ARENA_CHUNK)
                .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
                .collect(),
        })
    }

    /// Bump-allocate `value`, growing by a CAS-prepended chunk on
    /// overflow (the loser frees its empty chunk and retries on the
    /// winner's).
    fn alloc(&self, value: T) -> &T {
        let mut value = Some(value);
        loop {
            let headp = self.head.load(SeqCst);
            let chunk = unsafe { &*headp };
            let idx = chunk.used.fetch_add(1, SeqCst);
            if idx < ARENA_CHUNK {
                let cell = &chunk.items[idx];
                unsafe {
                    let slot = (*cell.get()).as_mut_ptr();
                    slot.write(value.take().unwrap());
                    return &*slot;
                }
            }
            let fresh = Box::into_raw(Self::chunk());
            unsafe { (*fresh).next.store(headp, SeqCst) };
            if self
                .head
                .compare_exchange(headp, fresh, SeqCst, SeqCst)
                .is_err()
            {
                drop(unsafe { Box::from_raw(fresh) });
            }
        }
    }

    /// Approximate bytes of backing memory across all chunks.
    fn bytes(&self) -> u64 {
        let per_chunk = (std::mem::size_of::<ArenaChunk<T>>()
            + ARENA_CHUNK * std::mem::size_of::<T>()) as u64;
        let mut n = 0u64;
        let mut cur = self.head.load(SeqCst);
        while !cur.is_null() {
            n += per_chunk;
            cur = unsafe { &*cur }.next.load(SeqCst);
        }
        n
    }
}

impl<T> Drop for Arena<T> {
    fn drop(&mut self) {
        let mut cur = *self.head.get_mut();
        while !cur.is_null() {
            let mut chunk = unsafe { Box::from_raw(cur) };
            let used = (*chunk.used.get_mut()).min(ARENA_CHUNK);
            for cell in &mut chunk.items[..used] {
                unsafe { cell.get_mut().assume_init_drop() };
            }
            cur = *chunk.next.get_mut();
        }
    }
}

/// One address's version vector plus its link in the shard chain.
/// Arena-owned and append-only: never freed before the store drops, so
/// readers may traverse raw pointers without a per-node reclamation
/// protocol.
struct AddrEntry {
    addr: Addr,
    first: Segment,
    chain: AtomicPtr<AddrEntry>,
}

impl AddrEntry {
    /// Scan the claimed slots for the best (highest) writer strictly
    /// below `txn`: `(writer, incarnation, estimate, value)`. The scan
    /// is linear over the address's writers (bounded per block by the
    /// controller; only hub addresses grow long), but it short-circuits
    /// the moment the immediate predecessor `txn - 1` is found — on
    /// hub-dense batches, where every index writes the hub, that is
    /// almost always the first claimed slot or two.
    fn best_below(&self, txn: TxnIdx) -> Option<(TxnIdx, Incarnation, bool, u64)> {
        let mut best: Option<(TxnIdx, Incarnation, bool, u64)> = None;
        let mut seg: &Segment = &self.first;
        loop {
            for slot in &seg.slots {
                let o = slot.owner.load(SeqCst);
                if o == 0 {
                    continue;
                }
                let writer = o - 1;
                if writer >= txn {
                    continue;
                }
                if matches!(best, Some((b, ..)) if writer <= b) {
                    continue;
                }
                let (meta, value) = slot.read_consistent();
                if meta == META_EMPTY || meta & FLAG_TOMBSTONE != 0 {
                    continue;
                }
                best = Some((
                    writer,
                    meta_incarnation(meta),
                    meta & FLAG_ESTIMATE != 0,
                    value,
                ));
                if writer + 1 == txn {
                    // No lower writer can beat the immediate
                    // predecessor: stop scanning.
                    return best;
                }
            }
            let next = seg.next.load(SeqCst);
            if next.is_null() {
                return best;
            }
            seg = unsafe { &*next };
        }
    }

    /// The slot already claimed by `txn`, if any.
    fn slot_of(&self, txn: TxnIdx) -> Option<&VersionSlot> {
        let want = txn + 1;
        let mut seg: &Segment = &self.first;
        loop {
            for slot in &seg.slots {
                if slot.owner.load(SeqCst) == want {
                    return Some(slot);
                }
            }
            let next = seg.next.load(SeqCst);
            if next.is_null() {
                return None;
            }
            seg = unsafe { &*next };
        }
    }

    /// Find-or-claim the slot for `txn`, appending an arena-allocated
    /// segment when the vector is full. Claims are one CAS; they never
    /// release. A CAS loser's pre-bumped segment stays orphaned in the
    /// arena until the store drops.
    fn claim_slot<'s>(&'s self, txn: TxnIdx, segs: &'s Arena<Segment>) -> &'s VersionSlot {
        let want = txn + 1;
        let mut seg: &Segment = &self.first;
        loop {
            for slot in &seg.slots {
                let o = slot.owner.load(SeqCst);
                if o == want {
                    return slot;
                }
                if o == 0
                    && slot
                        .owner
                        .compare_exchange(0, want, SeqCst, SeqCst)
                        .is_ok()
                {
                    return slot;
                }
            }
            let next = seg.next.load(SeqCst);
            if !next.is_null() {
                seg = unsafe { &*next };
                continue;
            }
            let fresh = segs.alloc(Segment::new()) as *const Segment as *mut Segment;
            match seg
                .next
                .compare_exchange(std::ptr::null_mut(), fresh, SeqCst, SeqCst)
            {
                Ok(_) => seg = unsafe { &*fresh },
                Err(existing) => seg = unsafe { &*existing },
            }
        }
    }
}

/// A finished incarnation's read/write sets: immutable once published,
/// reads and write addresses sorted by address. A superseded node
/// either chains on `prev` (barrier runs: freed when the store drops)
/// or is detached into the epoch limbo (pipelined sessions) — see the
/// module docs.
struct RecordedSets {
    reads: Vec<ReadDesc>,
    write_addrs: Vec<Addr>,
    prev: *mut RecordedSets,
}

/// Limbo-owned handle to a detached `RecordedSets` chain: dropping it
/// frees the node(s). The holder must own the only path to the chain
/// (the pointer was just swapped out of its `TxnSets` cell).
struct RetiredSets(*mut RecordedSets);

// SAFETY: the chain is exclusively owned once swapped out; dropping it
// on another thread is plain `Box` deallocation.
unsafe impl Send for RetiredSets {}

impl Drop for RetiredSets {
    fn drop(&mut self) {
        let mut p = self.0;
        while !p.is_null() {
            let node = unsafe { Box::from_raw(p) };
            p = node.prev;
        }
    }
}

/// Counter weight of a recorded-sets chain head: `(cells, bytes)`.
/// At least one cell per node so even empty-footprint retires register
/// in the live-cell accounting.
fn sets_weight(p: *mut RecordedSets) -> (u64, u64) {
    let s = unsafe { &*p };
    let cells = ((s.reads.len() + s.write_addrs.len()) as u64).max(1);
    let bytes = (std::mem::size_of::<RecordedSets>()
        + s.reads.capacity() * std::mem::size_of::<ReadDesc>()
        + s.write_addrs.capacity() * std::mem::size_of::<Addr>()) as u64;
    (cells, bytes)
}

/// Single-owner handoff cell for one transaction's recorded sets.
struct TxnSets {
    sets: AtomicPtr<RecordedSets>,
}

/// The lock-free multi-version store (see the module docs for the
/// layout, the seqlock protocol, and the reclamation contract).
pub struct MvMemory {
    shards: Box<[AtomicPtr<AddrEntry>]>,
    /// Per-shard modification watermarks, bumped after every publish /
    /// tombstone / estimate flip — the validation short-circuit.
    marks: Box<[AtomicU64]>,
    txns: Box<[TxnSets]>,
    entries: Arena<AddrEntry>,
    segments: Arena<Segment>,
    /// The session's reclamation domain, when pipelining attached one.
    gc: OnceLock<Arc<EpochGc>>,
}

impl MvMemory {
    #[inline]
    fn shard_of(addr: Addr) -> usize {
        (((addr as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)) >> (64 - SHARD_BITS)) as usize
    }

    #[inline]
    fn bump_mark(&self, addr: Addr) {
        self.marks[Self::shard_of(addr)].fetch_add(1, SeqCst);
    }

    fn find_entry(&self, addr: Addr) -> Option<&AddrEntry> {
        let mut cur = self.shards[Self::shard_of(addr)].load(SeqCst);
        while !cur.is_null() {
            let e = unsafe { &*cur };
            if e.addr == addr {
                return Some(e);
            }
            cur = e.chain.load(SeqCst);
        }
        None
    }

    /// Find the entry for `addr`, CAS-inserting an arena-allocated one
    /// at the shard head if absent. A losing CAS always rescans from
    /// the new head, so two racers for the same address converge on one
    /// entry; a pre-allocated node that loses to a same-address racer
    /// stays orphaned in the arena until the store drops.
    fn entry_or_insert(&self, addr: Addr) -> &AddrEntry {
        let head = &self.shards[Self::shard_of(addr)];
        let mut fresh: *mut AddrEntry = std::ptr::null_mut();
        loop {
            let first = head.load(SeqCst);
            let mut cur = first;
            while !cur.is_null() {
                let e = unsafe { &*cur };
                if e.addr == addr {
                    return e;
                }
                cur = e.chain.load(SeqCst);
            }
            if fresh.is_null() {
                fresh = self.entries.alloc(AddrEntry {
                    addr,
                    first: Segment::new(),
                    chain: AtomicPtr::new(first),
                }) as *const AddrEntry as *mut AddrEntry;
            } else {
                unsafe { (*fresh).chain.store(first, SeqCst) };
            }
            if head.compare_exchange(first, fresh, SeqCst, SeqCst).is_ok() {
                return unsafe { &*fresh };
            }
        }
    }

    fn current_sets(&self, txn: TxnIdx) -> Option<&RecordedSets> {
        let p = self.txns[txn].sets.load(SeqCst);
        if p.is_null() {
            None
        } else {
            Some(unsafe { &*p })
        }
    }
}

impl MvStore for MvMemory {
    fn new(n: usize) -> Self {
        Self {
            shards: (0..SHARDS)
                .map(|_| AtomicPtr::new(std::ptr::null_mut()))
                .collect(),
            marks: (0..SHARDS).map(|_| AtomicU64::new(0)).collect(),
            txns: (0..n)
                .map(|_| TxnSets {
                    sets: AtomicPtr::new(std::ptr::null_mut()),
                })
                .collect(),
            entries: Arena::new(),
            segments: Arena::new(),
            gc: OnceLock::new(),
        }
    }

    fn read(&self, addr: Addr, txn: TxnIdx) -> MvRead {
        match self.find_entry(addr).and_then(|e| e.best_below(txn)) {
            None => MvRead::Base,
            Some((writer, incarnation, estimate, value)) => {
                if estimate {
                    MvRead::Estimate(writer)
                } else {
                    MvRead::Value((writer, incarnation), value)
                }
            }
        }
    }

    fn record(&self, version: Version, mut reads: Vec<ReadDesc>, writes: &[(Addr, u64)]) -> bool {
        let (txn, incarnation) = version;
        for &(addr, value) in writes {
            self.entry_or_insert(addr)
                .claim_slot(txn, &self.segments)
                .publish(incarnation, value);
            // Watermark bump strictly AFTER the publish: a validator
            // still holding the old mark must also still be able to
            // see the old version (bump-before-publish would let an
            // unchanged-mark skip miss this write).
            self.bump_mark(addr);
        }
        // Publish both sets sorted by address: validation walks the
        // reads in address order (cache-friendly shard/mark probes)
        // and the incarnation diff below becomes one linear merge.
        reads.sort_unstable_by_key(|r| r.addr);
        let mut write_addrs: Vec<Addr> = writes.iter().map(|&(a, _)| a).collect();
        write_addrs.sort_unstable();
        let prev_ptr = self.txns[txn].sets.load(SeqCst);
        let prev_writes: &[Addr] = if prev_ptr.is_null() {
            &[]
        } else {
            unsafe { &(*prev_ptr).write_addrs }
        };
        // Sort-merge the incarnation diff (both lists sorted): new
        // addresses flip `wrote_new`, vanished ones are tombstoned —
        // one linear pass instead of the old O(writes × prev_writes)
        // `contains` rescans.
        let mut wrote_new = false;
        let (mut i, mut j) = (0usize, 0usize);
        loop {
            match (write_addrs.get(i), prev_writes.get(j)) {
                (Some(&w), Some(&p)) if w == p => {
                    i += 1;
                    j += 1;
                }
                (Some(&w), Some(&p)) if w < p => {
                    wrote_new = true;
                    i += 1;
                }
                (Some(_), None) => {
                    wrote_new = true;
                    i += 1;
                }
                (Some(_), Some(&p)) | (None, Some(&p)) => {
                    if let Some(slot) = self.find_entry(p).and_then(|e| e.slot_of(txn)) {
                        slot.tombstone(incarnation);
                        self.bump_mark(p);
                    }
                    j += 1;
                }
                (None, None) => break,
            }
        }
        let gc = self.gc.get();
        let fresh = Box::into_raw(Box::new(RecordedSets {
            reads,
            write_addrs,
            // With a gc attached the superseded node is retired below
            // instead of chained, so the fresh node must not alias it.
            prev: if gc.is_some() {
                std::ptr::null_mut()
            } else {
                prev_ptr
            },
        }));
        let old = self.txns[txn].sets.swap(fresh, SeqCst);
        if let Some(gc) = gc {
            if !old.is_null() {
                // The swap made us the exclusive owner of `old`
                // (single serialized writer per transaction), so this
                // retire happens exactly once per superseded node.
                let (cells, bytes) = sets_weight(old);
                gc.retire(Box::new(RetiredSets(old)), cells, bytes);
            }
        }
        wrote_new
    }

    fn convert_writes_to_estimates(&self, txn: TxnIdx) {
        let Some(sets) = self.current_sets(txn) else {
            return;
        };
        for &addr in &sets.write_addrs {
            if let Some(slot) = self.find_entry(addr).and_then(|e| e.slot_of(txn)) {
                slot.mark_estimate();
                self.bump_mark(addr);
            }
        }
    }

    fn validate_read_set<F: Fn(Addr) -> Option<u64>>(&self, txn: TxnIdx, base: F) -> bool {
        let Some(sets) = self.current_sets(txn) else {
            return true;
        };
        // The reads are sorted by address (record() sorts), so the
        // mark/shard probes below walk the shard array coherently.
        sets.reads.iter().all(|r| {
            let unchanged = self.marks[Self::shard_of(r.addr)].load(SeqCst) == r.mark;
            match r.origin {
                ReadOrigin::Version(then) => {
                    // Unchanged shard watermark ⇒ no publish, tombstone
                    // or estimate flip touched this shard since the
                    // read: the recorded version still stands and the
                    // probe is skipped entirely.
                    if unchanged {
                        return true;
                    }
                    matches!(self.read(r.addr, txn), MvRead::Value(now, _) if now == then)
                }
                ReadOrigin::Base(v) => {
                    // The watermark only covers THIS store: even with
                    // an unchanged shard the base below the block (the
                    // still-draining predecessor / the heap) may have
                    // moved, so the base resolver always runs — only
                    // the store probe is skipped.
                    if unchanged {
                        return base(r.addr) == Some(v);
                    }
                    match self.read(r.addr, txn) {
                        MvRead::Base => base(r.addr) == Some(v),
                        _ => false,
                    }
                }
            }
        })
    }

    fn write_back(&self, heap: &TxHeap) {
        for head in self.shards.iter() {
            let mut cur = head.load(SeqCst);
            while !cur.is_null() {
                let e = unsafe { &*cur };
                if let Some((_, _, estimate, value)) = e.best_below(usize::MAX) {
                    debug_assert!(
                        !estimate,
                        "ESTIMATE survived to write-back at addr {}",
                        e.addr
                    );
                    heap.store_release(e.addr, value);
                }
                cur = e.chain.load(SeqCst);
            }
        }
    }

    fn for_each_winning(&self, f: &mut dyn FnMut(Addr, u64)) {
        for head in self.shards.iter() {
            let mut cur = head.load(SeqCst);
            while !cur.is_null() {
                let e = unsafe { &*cur };
                if let Some((_, _, estimate, value)) = e.best_below(usize::MAX) {
                    debug_assert!(
                        !estimate,
                        "ESTIMATE survived to promotion at addr {}",
                        e.addr
                    );
                    f(e.addr, value);
                }
                cur = e.chain.load(SeqCst);
            }
        }
    }

    fn mark_of(&self, addr: Addr) -> u64 {
        self.marks[Self::shard_of(addr)].load(SeqCst)
    }

    fn attach_gc(&self, gc: &Arc<EpochGc>) {
        let _ = self.gc.set(Arc::clone(gc));
    }

    fn retire_sets(&self) {
        let Some(gc) = self.gc.get() else {
            return;
        };
        for t in self.txns.iter() {
            let p = t.sets.swap(std::ptr::null_mut(), SeqCst);
            if !p.is_null() {
                let (cells, bytes) = sets_weight(p);
                gc.retire(Box::new(RetiredSets(p)), cells, bytes);
            }
        }
    }

    fn mem_bytes(&self) -> u64 {
        self.entries.bytes() + self.segments.bytes()
    }
}

impl Drop for MvMemory {
    fn drop(&mut self) {
        // AddrEntry nodes and Segments are arena-owned: the two Arena
        // drops free them wholesale (no shard walk). Recorded sets are
        // limbo-owned once retired; whatever is still linked here —
        // barrier-mode prev chains, or sets of a store dropped before
        // promotion — is freed now. A retired chain can never also be
        // reachable from these cells (retire only happens to pointers
        // swapped out of them), so there is no double free.
        for t in self.txns.iter_mut() {
            let p = *t.sets.get_mut();
            if !p.is_null() {
                drop(RetiredSets(p));
            }
        }
    }
}

// --------------------------------------------------------------------
// Sharded-mutex baseline (the PR-1 layout), kept for the benchmark
// --------------------------------------------------------------------

/// Shard count of the baseline store.
const MUTEX_SHARDS: usize = 64;

#[derive(Clone, Copy, Debug)]
struct Cell {
    incarnation: Incarnation,
    estimate: bool,
    value: u64,
}

/// The original `Vec<Mutex<HashMap<..>>>` multi-version store: every
/// read takes a shard lock, read/write sets live behind per-txn
/// mutexes. Selected by `BatchSystem::run_baseline_mutex`; exists so
/// `benches/batch_throughput` can price the lock traffic the lock-free
/// store removes.
pub struct MutexMvMemory {
    shards: Vec<Mutex<HashMap<Addr, BTreeMap<TxnIdx, Cell>>>>,
    reads: Vec<Mutex<Vec<ReadDesc>>>,
    writes: Vec<Mutex<Vec<Addr>>>,
}

impl MutexMvMemory {
    #[inline]
    fn shard(&self, addr: Addr) -> &Mutex<HashMap<Addr, BTreeMap<TxnIdx, Cell>>> {
        &self.shards[addr % MUTEX_SHARDS]
    }
}

impl MvStore for MutexMvMemory {
    fn new(n: usize) -> Self {
        Self {
            shards: (0..MUTEX_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            reads: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
            writes: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    fn read(&self, addr: Addr, txn: TxnIdx) -> MvRead {
        let shard = self.shard(addr).lock().unwrap();
        match shard.get(&addr).and_then(|m| m.range(..txn).next_back()) {
            None => MvRead::Base,
            Some((&writer, cell)) => {
                if cell.estimate {
                    MvRead::Estimate(writer)
                } else {
                    MvRead::Value((writer, cell.incarnation), cell.value)
                }
            }
        }
    }

    fn record(&self, version: Version, reads: Vec<ReadDesc>, writes: &[(Addr, u64)]) -> bool {
        let (txn, incarnation) = version;
        for &(addr, value) in writes {
            let mut shard = self.shard(addr).lock().unwrap();
            shard.entry(addr).or_default().insert(
                txn,
                Cell {
                    incarnation,
                    estimate: false,
                    value,
                },
            );
        }
        let mut prev = self.writes[txn].lock().unwrap();
        let wrote_new = writes.iter().any(|&(addr, _)| !prev.contains(&addr));
        for &addr in prev.iter() {
            if !writes.iter().any(|&(a, _)| a == addr) {
                let mut shard = self.shard(addr).lock().unwrap();
                let emptied = match shard.get_mut(&addr) {
                    Some(m) => {
                        m.remove(&txn);
                        m.is_empty()
                    }
                    None => false,
                };
                if emptied {
                    shard.remove(&addr);
                }
            }
        }
        *prev = writes.iter().map(|&(addr, _)| addr).collect();
        drop(prev);
        *self.reads[txn].lock().unwrap() = reads;
        wrote_new
    }

    fn convert_writes_to_estimates(&self, txn: TxnIdx) {
        let prev = self.writes[txn].lock().unwrap();
        for &addr in prev.iter() {
            let mut shard = self.shard(addr).lock().unwrap();
            if let Some(cell) = shard.get_mut(&addr).and_then(|m| m.get_mut(&txn)) {
                cell.estimate = true;
            }
        }
    }

    fn validate_read_set<F: Fn(Addr) -> Option<u64>>(&self, txn: TxnIdx, base: F) -> bool {
        let snapshot = self.reads[txn].lock().unwrap().clone();
        snapshot.iter().all(|r| match (self.read(r.addr, txn), r.origin) {
            (MvRead::Base, ReadOrigin::Base(v)) => base(r.addr) == Some(v),
            (MvRead::Value(now, _), ReadOrigin::Version(then)) => now == then,
            _ => false,
        })
    }

    fn write_back(&self, heap: &TxHeap) {
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            for (&addr, versions) in shard.iter() {
                if let Some((_, cell)) = versions.iter().next_back() {
                    debug_assert!(
                        !cell.estimate,
                        "ESTIMATE survived to write-back at addr {addr}"
                    );
                    heap.store_release(addr, cell.value);
                }
            }
        }
    }

    fn for_each_winning(&self, f: &mut dyn FnMut(Addr, u64)) {
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            for (&addr, versions) in shard.iter() {
                if let Some((_, cell)) = versions.iter().next_back() {
                    debug_assert!(
                        !cell.estimate,
                        "ESTIMATE survived to promotion at addr {addr}"
                    );
                    f(addr, cell.value);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_read_falls_through_to_base_then_sees_writers<M: MvStore>() {
        let mv = M::new(4);
        assert_eq!(mv.read(100, 2), MvRead::Base);
        mv.record((1, 0), Vec::new(), &[(100, 7)]);
        assert_eq!(mv.read(100, 2), MvRead::Value((1, 0), 7));
        // A reader at or below the writer's index never sees it.
        assert_eq!(mv.read(100, 1), MvRead::Base);
        assert_eq!(mv.read(100, 0), MvRead::Base);
    }

    fn check_highest_lower_writer_wins<M: MvStore>() {
        let mv = M::new(5);
        mv.record((0, 0), Vec::new(), &[(8, 10)]);
        mv.record((2, 0), Vec::new(), &[(8, 20)]);
        assert_eq!(mv.read(8, 1), MvRead::Value((0, 0), 10));
        assert_eq!(mv.read(8, 3), MvRead::Value((2, 0), 20));
        assert_eq!(mv.read(8, 4), MvRead::Value((2, 0), 20));
    }

    fn check_estimates_surface_the_blocking_txn<M: MvStore>() {
        let mv = M::new(3);
        mv.record((1, 0), Vec::new(), &[(64, 5)]);
        mv.convert_writes_to_estimates(1);
        assert_eq!(mv.read(64, 2), MvRead::Estimate(1));
        // Re-execution replaces the estimate.
        mv.record((1, 1), Vec::new(), &[(64, 6)]);
        assert_eq!(mv.read(64, 2), MvRead::Value((1, 1), 6));
    }

    fn check_record_removes_stale_addresses_and_reports_new_ones<M: MvStore>() {
        let mv = M::new(3);
        assert!(mv.record((1, 0), Vec::new(), &[(8, 1), (16, 2)]));
        // Same footprint: not new.
        assert!(!mv.record((1, 1), Vec::new(), &[(8, 3), (16, 4)]));
        // Different footprint: 24 is new, 16 goes stale.
        assert!(mv.record((1, 2), Vec::new(), &[(8, 5), (24, 6)]));
        assert_eq!(mv.read(16, 2), MvRead::Base, "stale entry must vanish");
        assert_eq!(mv.read(24, 2), MvRead::Value((1, 2), 6));
    }

    fn check_validation_tracks_version_changes<M: MvStore>() {
        let mv = M::new(4);
        let base = |_addr: Addr| Some(7u64);
        mv.record((0, 0), Vec::new(), &[(8, 1)]);
        // txn 2 read (0,0) at addr 8 and the base value 7 at addr 16.
        // Marks recorded as 0 (a stale watermark) so the lock-free
        // store must take the full probe path, same as the baseline.
        mv.record(
            (2, 0),
            vec![
                ReadDesc { addr: 8, origin: ReadOrigin::Version((0, 0)), mark: 0 },
                ReadDesc { addr: 16, origin: ReadOrigin::Base(7), mark: 0 },
            ],
            &[],
        );
        assert!(mv.validate_read_set(2, &base));
        // The base itself moving (a previous block's write-back landing
        // at addr 16) fails the value comparison.
        assert!(!mv.validate_read_set(2, &|_| Some(8u64)));
        // An unresolved base (predecessor ESTIMATE) fails too.
        assert!(!mv.validate_read_set(2, &|_| None));
        // txn 1 writes addr 16: txn 2's base read is now stale even
        // with the base value unchanged.
        mv.record((1, 0), Vec::new(), &[(16, 9)]);
        assert!(!mv.validate_read_set(2, &base));
    }

    fn check_write_back_commits_highest_version<M: MvStore>() {
        let heap = TxHeap::new(256);
        let a = heap.alloc(1);
        heap.store(a, 1);
        let mv = M::new(3);
        mv.record((0, 0), Vec::new(), &[(a, 10)]);
        mv.record((2, 1), Vec::new(), &[(a, 30)]);
        mv.write_back(&heap);
        assert_eq!(heap.load(a), 30);
    }

    fn check_for_each_winning_matches_write_back<M: MvStore>() {
        let heap = TxHeap::new(256);
        let a = heap.alloc(1);
        let b = heap.alloc(1);
        let mv = M::new(4);
        mv.record((0, 0), Vec::new(), &[(a, 10), (b, 5)]);
        mv.record((2, 1), Vec::new(), &[(a, 30)]);
        let mut seen = std::collections::BTreeMap::new();
        mv.for_each_winning(&mut |addr, v| {
            assert!(seen.insert(addr, v).is_none(), "address visited twice");
        });
        mv.write_back(&heap);
        assert_eq!(
            seen.into_iter().collect::<Vec<_>>(),
            vec![(a, heap.load(a)), (b, heap.load(b))],
            "the visited winners must be exactly what write_back flushes"
        );
        assert_eq!(heap.load(a), 30);
        assert_eq!(heap.load(b), 5);
    }

    macro_rules! store_suite {
        ($modname:ident, $store:ty) => {
            mod $modname {
                use super::*;

                #[test]
                fn read_falls_through_to_base_then_sees_writers() {
                    check_read_falls_through_to_base_then_sees_writers::<$store>();
                }
                #[test]
                fn highest_lower_writer_wins() {
                    check_highest_lower_writer_wins::<$store>();
                }
                #[test]
                fn estimates_surface_the_blocking_txn() {
                    check_estimates_surface_the_blocking_txn::<$store>();
                }
                #[test]
                fn record_removes_stale_addresses_and_reports_new_ones() {
                    check_record_removes_stale_addresses_and_reports_new_ones::<$store>();
                }
                #[test]
                fn validation_tracks_version_changes() {
                    check_validation_tracks_version_changes::<$store>();
                }
                #[test]
                fn write_back_commits_highest_version() {
                    check_write_back_commits_highest_version::<$store>();
                }
                #[test]
                fn for_each_winning_matches_write_back() {
                    check_for_each_winning_matches_write_back::<$store>();
                }
            }
        };
    }

    store_suite!(lockfree, MvMemory);
    store_suite!(mutex_baseline, MutexMvMemory);

    #[test]
    fn lockfree_many_writers_chain_segments() {
        // More writers on one address than a single segment holds:
        // segment append + full-scan read must still pick the highest.
        let mv = MvMemory::new(64);
        for t in 0..40usize {
            mv.record((t, 0), Vec::new(), &[(72, 1000 + t as u64)]);
        }
        assert_eq!(mv.read(72, 40), MvRead::Value((39, 0), 1039));
        assert_eq!(mv.read(72, 17), MvRead::Value((16, 0), 1016));
        assert_eq!(mv.read(72, 0), MvRead::Base);
    }

    #[test]
    fn lockfree_concurrent_readers_see_only_published_values() {
        // Hammer one address with serialized republications of txn 1
        // while reader threads poll: every observed value must be one
        // that was actually published (seqlock consistency), never a
        // torn pair.
        use std::sync::atomic::AtomicBool;
        let mv = MvMemory::new(4);
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    while !stop.load(SeqCst) {
                        match mv.read(88, 2) {
                            MvRead::Base => {}
                            MvRead::Estimate(t) => assert_eq!(t, 1),
                            MvRead::Value((t, inc), v) => {
                                assert_eq!(t, 1);
                                assert_eq!(
                                    v,
                                    7000 + inc as u64,
                                    "value must match its incarnation"
                                );
                            }
                        }
                    }
                });
            }
            for inc in 0..600u32 {
                mv.record((1, inc), Vec::new(), &[(88, 7000 + inc as u64)]);
                if inc % 3 == 0 {
                    mv.convert_writes_to_estimates(1);
                }
            }
            stop.store(true, SeqCst);
        });
    }

    #[test]
    fn seqlock_slot_reuse_across_incarnations_never_tears() {
        // The ABA regression for the two-word seqlock: one slot is
        // forced through publish → ESTIMATE → tombstone → re-publish
        // cycles (the writing txn's footprint drops addr 96 and picks
        // it back up across incarnations, so the SAME claimed slot is
        // reused with strictly growing meta words). Readers double-read
        // throughout; the value is derived from its incarnation, so any
        // torn pairing of one incarnation's meta with another's value —
        // the classic seqlock ABA — trips the assertion. Monotonic meta
        // words are exactly what makes a stable double-read conclusive;
        // this test is the executable form of that claim.
        use std::sync::atomic::AtomicBool;
        let mv = MvMemory::new(4);
        let stop = AtomicBool::new(false);
        const ADDR: Addr = 96;
        let value_of = |inc: Incarnation| 0xA000 + inc as u64 * 3;
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    while !stop.load(SeqCst) {
                        match mv.read(ADDR, 2) {
                            // Tombstoned (or never-written) windows fall
                            // through to base.
                            MvRead::Base => {}
                            MvRead::Estimate(t) => assert_eq!(t, 1),
                            MvRead::Value((t, inc), v) => {
                                assert_eq!(t, 1);
                                assert_eq!(
                                    v,
                                    value_of(inc),
                                    "torn (incarnation, value) pair after slot reuse"
                                );
                            }
                        }
                    }
                });
            }
            // Writer: serialized incarnations of txn 1, cycling the
            // footprint so the slot is retracted and reused, with
            // ESTIMATE phases in between — every lifecycle transition
            // the slot's meta word can take, each at a fresh
            // incarnation.
            for inc in 0..900u32 {
                match inc % 3 {
                    0 => {
                        mv.record((1, inc), Vec::new(), &[(ADDR, value_of(inc))]);
                        mv.convert_writes_to_estimates(1);
                    }
                    1 => {
                        // Footprint drops ADDR: the claimed slot is
                        // tombstoned at this incarnation...
                        mv.record((1, inc), Vec::new(), &[(ADDR + 8, inc as u64)]);
                    }
                    _ => {
                        // ...and republished by the next one — same
                        // slot, higher meta.
                        mv.record((1, inc), Vec::new(), &[(ADDR, value_of(inc))]);
                    }
                }
            }
            stop.store(true, SeqCst);
        });
        // The last cycle ends on a publish: the slot must be live.
        assert_eq!(mv.read(ADDR, 2), MvRead::Value((1, 899), value_of(899)));
    }

    #[test]
    fn lockfree_dense_addresses_spread_and_resolve() {
        // Neighbouring word addresses (the dense SSCA-2 pattern) land
        // in distinct chains but all resolve correctly.
        let mv = MvMemory::new(8);
        for addr in 0..512usize {
            mv.record((1, 0), Vec::new(), &[(addr, addr as u64 * 3)]);
        }
        for addr in 0..512usize {
            assert_eq!(mv.read(addr, 5), MvRead::Value((1, 0), addr as u64 * 3));
        }
        let heap = TxHeap::new(1 << 10);
        mv.write_back(&heap);
        for addr in 0..512usize {
            assert_eq!(heap.load(addr), addr as u64 * 3);
        }
    }

    #[test]
    fn lockfree_arena_backing_grows_with_footprint() {
        // Dense inserts overflow the first arena chunks: mem_bytes must
        // report the growth, and everything must still resolve (i.e.
        // chunk-prepend kept every handed-out reference stable).
        let mv = MvMemory::new(4);
        let empty = mv.mem_bytes();
        assert!(empty > 0, "fresh arenas still own one chunk each");
        for addr in 0..2048usize {
            mv.record((1, 0), Vec::new(), &[(addr, addr as u64)]);
        }
        assert!(
            mv.mem_bytes() > empty,
            "2048 entries cannot fit the initial chunk"
        );
        for addr in (0..2048usize).step_by(97) {
            assert_eq!(mv.read(addr, 3), MvRead::Value((1, 0), addr as u64));
        }
    }

    #[test]
    fn lockfree_watermark_skips_and_catches_changes() {
        let mv = MvMemory::new(8);
        mv.record((0, 0), Vec::new(), &[(8, 1)]);
        // Record txn 2's read with the CURRENT watermark, the way the
        // executor's view does: validation may now skip the probe.
        let m8 = mv.mark_of(8);
        assert!(m8 > 0, "the publish must have bumped the shard mark");
        mv.record(
            (2, 0),
            vec![ReadDesc { addr: 8, origin: ReadOrigin::Version((0, 0)), mark: m8 }],
            &[],
        );
        let base = |_addr: Addr| -> Option<u64> { None };
        assert!(
            mv.validate_read_set(2, &base),
            "unchanged watermark validates without touching the base"
        );
        // A lower writer republishing bumps the shard mark: the skip
        // no longer applies and the version comparison fails.
        mv.record((1, 0), Vec::new(), &[(8, 2)]);
        assert!(!mv.validate_read_set(2, &base));
    }

    #[test]
    fn lockfree_gc_retires_superseded_and_final_sets() {
        use crate::mem::epoch::EpochGc;
        let gc = Arc::new(EpochGc::new(1));
        let mv = MvMemory::new(4);
        mv.attach_gc(&gc);
        // Two incarnations: the second record supersedes the first
        // node, which must land in limbo (not on a prev chain).
        mv.record(
            (1, 0),
            vec![ReadDesc { addr: 8, origin: ReadOrigin::Base(0), mark: 0 }],
            &[(16, 1)],
        );
        mv.record((1, 1), Vec::new(), &[(16, 2)]);
        let after_supersede = gc.counters().retired_cells;
        assert!(after_supersede > 0, "superseded sets must retire");
        // Promotion retires the final nodes too.
        mv.retire_sets();
        let k = gc.counters();
        assert!(k.retired_cells > after_supersede, "final sets must retire");
        // With nothing pinned, a flush reclaims every retired cell.
        gc.flush();
        assert_eq!(gc.counters().reclaimed_cells, k.retired_cells);
        assert_eq!(gc.live_cells(), 0);
        // The store still resolves reads after retiring its sets.
        assert_eq!(mv.read(16, 3), MvRead::Value((1, 1), 2));
    }
}
