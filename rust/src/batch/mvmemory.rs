//! Multi-version memory for the speculative batch executor.
//!
//! Every speculative write lands here, never in the [`TxHeap`] — the
//! heap stays at its pre-batch snapshot until `write_back`. Per address
//! the structure keeps one entry per *transaction index* (only the
//! latest incarnation of each), so a reader at index `i` picks the
//! highest writer strictly below `i` and falls through to the heap when
//! there is none. Entries of an aborted incarnation are flagged
//! ESTIMATE: readers treat them as "this value is about to be
//! rewritten" and suspend instead of speculating on a known-stale
//! value.
//!
//! # Lock-free layout
//!
//! The store is built so **reads of committed versions take zero
//! locks** — the whole point of speculating in the low-conflict regime
//! the paper says optimism should win:
//!
//! * the address index is an array of [`SHARDS`] `AtomicPtr` heads,
//!   each the top of a CAS-published chain of [`AddrEntry`] nodes
//!   (append-only: nodes are only freed when the store drops, so raw
//!   traversal needs no reclamation protocol);
//! * each `AddrEntry` owns a grow-only segmented **version vector**:
//!   [`VersionSlot`]s claimed once per writing transaction by a CAS on
//!   the slot's owner word and reused across that transaction's
//!   incarnations;
//! * a slot publishes `(incarnation, flags, value)` through a two-word
//!   **seqlock**: the writer (single per slot — the scheduler
//!   serializes a transaction's incarnations) stores a WRITING-marked
//!   meta word, the value, then the final meta word; readers re-check
//!   the meta word around the value load. Meta words are strictly
//!   monotonic per slot (incarnations only grow, each flag transition
//!   happens once per incarnation), so a stable double-read cannot be
//!   an ABA artifact. All fences are `SeqCst` — plain loads on x86, so
//!   the read hot path is exactly three uncontended loads per slot;
//! * per-transaction read/write sets are published as **immutable
//!   [`RecordedSets`] nodes behind one `AtomicPtr` per transaction**
//!   (the single-owner handoff replacing the old `Mutex<Vec<_>>`
//!   cells): `record` builds the node privately and swaps it in, a
//!   stale validator can still be walking the previous node — which
//!   stays alive on a `prev` chain until the store drops — and its
//!   stale verdict is dropped by the scheduler's incarnation check.
//!
//! A Mutex-sharded baseline ([`MutexMvMemory`], the PR-1 layout) is
//! kept behind the same [`MvStore`] trait so `benches/batch_throughput`
//! can measure exactly what the lock-free hot path buys.
//!
//! Addresses are word indices (`mem::Addr`), exactly what the
//! [`crate::tm::access::TxAccess`] bodies already traffic in, so the
//! same transaction closures run unchanged under HTM, STM, the locks,
//! or this executor.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering::SeqCst};
use std::sync::Mutex;

use crate::mem::{Addr, TxHeap};

use super::scheduler::{Incarnation, TxnIdx, Version};

/// Where a speculative read was served from — the version the read
/// validates against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadOrigin {
    /// Fell through to the base state below this block (the heap, or —
    /// under cross-block pipelining — the still-draining previous
    /// block's winning version). Carries the *observed value*:
    /// validation compares values, which is what makes reads taken
    /// while the predecessor block was still committing safe — the
    /// post-write-back revalidation catches any divergence.
    Base(u64),
    /// Served by a lower transaction's recorded write.
    Version(Version),
}

/// One entry of a transaction's read set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReadDesc {
    pub addr: Addr,
    pub origin: ReadOrigin,
}

/// Result of a speculative read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MvRead {
    /// No lower writer: read the heap.
    Base,
    /// A lower transaction wrote this value.
    Value(Version, u64),
    /// A lower transaction's aborted write: suspend on that index.
    Estimate(TxnIdx),
}

/// The multi-version store contract the batch executor runs against.
/// `MvMemory` is the lock-free production implementation;
/// `MutexMvMemory` is the sharded-mutex baseline kept for the
/// head-to-head benchmark. (`Send + Sync` because the pipelined
/// session shares per-block stores across the worker pool behind
/// `Arc`s.)
pub trait MvStore: Send + Sync {
    /// Fresh store for a batch of `n` transactions.
    fn new(n: usize) -> Self;

    /// Read `addr` as transaction `txn`: the highest writer below
    /// `txn`, or the heap when none exists.
    fn read(&self, addr: Addr, txn: TxnIdx) -> MvRead;

    /// Record a finished incarnation's read and write sets. Stale
    /// entries from the previous incarnation (addresses no longer
    /// written) are removed. Returns `true` when the incarnation wrote
    /// to an address its predecessor did not — the scheduler then
    /// forces higher transactions to revalidate.
    fn record(&self, version: Version, reads: Vec<ReadDesc>, writes: &[(Addr, u64)]) -> bool;

    /// Mark every write of `txn`'s last incarnation as an ESTIMATE
    /// (called right after a validation abort wins, before the
    /// re-execution is scheduled).
    fn convert_writes_to_estimates(&self, txn: TxnIdx);

    /// Re-read `txn`'s recorded read set and check every observed
    /// version still matches. ESTIMATEs and changed versions fail.
    /// `base` resolves the value *below* this block for addresses with
    /// no lower in-block writer (the heap for a barrier run; the
    /// previous block's winning version under cross-block pipelining);
    /// `None` means the base is itself unresolved (a predecessor
    /// ESTIMATE), which fails the validation so the transaction
    /// re-executes and parks.
    fn validate_read_set(&self, txn: TxnIdx, base: &dyn Fn(Addr) -> Option<u64>) -> bool;

    /// After the batch completes: flush the winning (highest-index)
    /// version of every address into the heap. Equivalent to committing
    /// the transactions one by one in index order.
    fn write_back(&self, heap: &TxHeap);
}

// --------------------------------------------------------------------
// Lock-free implementation
// --------------------------------------------------------------------

/// Shard count for the address index (power of two). Sized so typical
/// per-block footprints (thousands of distinct addresses) keep chains
/// a couple of nodes long.
const SHARD_BITS: u32 = 12;
const SHARDS: usize = 1 << SHARD_BITS;

/// Version slots per segment of an address's version vector. Most
/// addresses have a single writer; hubs chain additional segments.
const SLOTS_PER_SEG: usize = 8;

/// Slot meta word: `(incarnation + 1) << 3 | flags`; `0` = never
/// written. The `+ 1` keeps a published meta distinct from the empty
/// word. Meta values are strictly monotonic per slot (incarnations only
/// grow, ESTIMATE/TOMBSTONE each fire once per incarnation), which is
/// what makes the seqlock's stable double-read conclusive.
const FLAG_WRITING: u64 = 1;
const FLAG_ESTIMATE: u64 = 2;
const FLAG_TOMBSTONE: u64 = 4;
const META_EMPTY: u64 = 0;

#[inline]
fn meta_pack(incarnation: Incarnation, flags: u64) -> u64 {
    ((incarnation as u64 + 1) << 3) | flags
}

#[inline]
fn meta_incarnation(meta: u64) -> Incarnation {
    ((meta >> 3) - 1) as Incarnation
}

/// One `(address, writing transaction)` cell. Claimed once (owner CAS),
/// then republished across incarnations by its single serialized
/// writer through the seqlock protocol.
struct VersionSlot {
    /// Writing transaction's index + 1; 0 = unclaimed.
    owner: AtomicUsize,
    meta: AtomicU64,
    value: AtomicU64,
}

impl VersionSlot {
    fn empty() -> Self {
        Self {
            owner: AtomicUsize::new(0),
            meta: AtomicU64::new(META_EMPTY),
            value: AtomicU64::new(0),
        }
    }

    /// Seqlock read: a stable, non-WRITING meta word sampled on both
    /// sides of the value load is conclusive (meta monotonicity rules
    /// out ABA). The WRITING window is two stores wide, so the spin is
    /// normally a handful of iterations; the bounded-spin-then-yield
    /// keeps a reader from livelocking against a preempted writer on
    /// an oversubscribed core.
    fn read_consistent(&self) -> (u64, u64) {
        let mut spins = 0u32;
        loop {
            let m1 = self.meta.load(SeqCst);
            if m1 & FLAG_WRITING != 0 {
                spins += 1;
                if spins < 64 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
                continue;
            }
            let v = self.value.load(SeqCst);
            let m2 = self.meta.load(SeqCst);
            if m1 == m2 {
                return (m1, v);
            }
        }
    }

    /// Publish `(incarnation, value)`. Only the slot's serialized owner
    /// calls this; the WRITING pre-phase keeps concurrent readers from
    /// pairing the new value with the old meta.
    fn publish(&self, incarnation: Incarnation, value: u64) {
        self.meta.store(meta_pack(incarnation, FLAG_WRITING), SeqCst);
        self.value.store(value, SeqCst);
        self.meta.store(meta_pack(incarnation, 0), SeqCst);
    }

    /// Retract the slot (the new incarnation no longer writes this
    /// address). `incarnation` is the retracting incarnation, keeping
    /// the meta word monotonic.
    fn tombstone(&self, incarnation: Incarnation) {
        self.meta.store(meta_pack(incarnation, FLAG_TOMBSTONE), SeqCst);
    }

    /// Flag the current publication as an aborted incarnation's write.
    fn mark_estimate(&self) {
        self.meta.fetch_or(FLAG_ESTIMATE, SeqCst);
    }
}

/// A grow-only block of version slots.
struct Segment {
    slots: [VersionSlot; SLOTS_PER_SEG],
    next: AtomicPtr<Segment>,
}

impl Segment {
    fn new() -> Self {
        Self {
            slots: std::array::from_fn(|_| VersionSlot::empty()),
            next: AtomicPtr::new(std::ptr::null_mut()),
        }
    }
}

/// One address's version vector plus its link in the shard chain.
/// Append-only: never freed before the store drops, so readers may
/// traverse raw pointers without a reclamation protocol.
struct AddrEntry {
    addr: Addr,
    first: Segment,
    chain: AtomicPtr<AddrEntry>,
}

impl AddrEntry {
    /// Scan the claimed slots for the best (highest) writer strictly
    /// below `txn`: `(writer, incarnation, estimate, value)`. The scan
    /// is linear over the address's writers (bounded per block by the
    /// controller; only hub addresses grow long), but it short-circuits
    /// the moment the immediate predecessor `txn - 1` is found — on
    /// hub-dense batches, where every index writes the hub, that is
    /// almost always the first claimed slot or two.
    fn best_below(&self, txn: TxnIdx) -> Option<(TxnIdx, Incarnation, bool, u64)> {
        let mut best: Option<(TxnIdx, Incarnation, bool, u64)> = None;
        let mut seg: &Segment = &self.first;
        loop {
            for slot in &seg.slots {
                let o = slot.owner.load(SeqCst);
                if o == 0 {
                    continue;
                }
                let writer = o - 1;
                if writer >= txn {
                    continue;
                }
                if matches!(best, Some((b, ..)) if writer <= b) {
                    continue;
                }
                let (meta, value) = slot.read_consistent();
                if meta == META_EMPTY || meta & FLAG_TOMBSTONE != 0 {
                    continue;
                }
                best = Some((
                    writer,
                    meta_incarnation(meta),
                    meta & FLAG_ESTIMATE != 0,
                    value,
                ));
                if writer + 1 == txn {
                    // No lower writer can beat the immediate
                    // predecessor: stop scanning.
                    return best;
                }
            }
            let next = seg.next.load(SeqCst);
            if next.is_null() {
                return best;
            }
            seg = unsafe { &*next };
        }
    }

    /// The slot already claimed by `txn`, if any.
    fn slot_of(&self, txn: TxnIdx) -> Option<&VersionSlot> {
        let want = txn + 1;
        let mut seg: &Segment = &self.first;
        loop {
            for slot in &seg.slots {
                if slot.owner.load(SeqCst) == want {
                    return Some(slot);
                }
            }
            let next = seg.next.load(SeqCst);
            if next.is_null() {
                return None;
            }
            seg = unsafe { &*next };
        }
    }

    /// Find-or-claim the slot for `txn`, appending a segment when the
    /// vector is full. Claims are one CAS; they never release.
    fn claim_slot(&self, txn: TxnIdx) -> &VersionSlot {
        let want = txn + 1;
        let mut seg: &Segment = &self.first;
        loop {
            for slot in &seg.slots {
                let o = slot.owner.load(SeqCst);
                if o == want {
                    return slot;
                }
                if o == 0
                    && slot
                        .owner
                        .compare_exchange(0, want, SeqCst, SeqCst)
                        .is_ok()
                {
                    return slot;
                }
            }
            let next = seg.next.load(SeqCst);
            if !next.is_null() {
                seg = unsafe { &*next };
                continue;
            }
            let fresh = Box::into_raw(Box::new(Segment::new()));
            match seg
                .next
                .compare_exchange(std::ptr::null_mut(), fresh, SeqCst, SeqCst)
            {
                Ok(_) => seg = unsafe { &*fresh },
                Err(existing) => {
                    // Another writer appended first: free ours, use theirs.
                    drop(unsafe { Box::from_raw(fresh) });
                    seg = unsafe { &*existing };
                }
            }
        }
    }
}

/// A finished incarnation's read/write sets: immutable once published.
/// `prev` chains every superseded publication — a stale validator may
/// still be reading one, so nothing is freed before the store drops.
struct RecordedSets {
    reads: Vec<ReadDesc>,
    write_addrs: Vec<Addr>,
    prev: *mut RecordedSets,
}

/// Single-owner handoff cell for one transaction's recorded sets.
struct TxnSets {
    sets: AtomicPtr<RecordedSets>,
}

/// The lock-free multi-version store (see the module docs for the
/// layout and the seqlock protocol).
pub struct MvMemory {
    shards: Box<[AtomicPtr<AddrEntry>]>,
    txns: Box<[TxnSets]>,
}

impl MvMemory {
    #[inline]
    fn shard_of(addr: Addr) -> usize {
        (((addr as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)) >> (64 - SHARD_BITS)) as usize
    }

    fn find_entry(&self, addr: Addr) -> Option<&AddrEntry> {
        let mut cur = self.shards[Self::shard_of(addr)].load(SeqCst);
        while !cur.is_null() {
            let e = unsafe { &*cur };
            if e.addr == addr {
                return Some(e);
            }
            cur = e.chain.load(SeqCst);
        }
        None
    }

    /// Find the entry for `addr`, CAS-inserting a fresh one at the
    /// shard head if absent. A losing CAS always rescans from the new
    /// head, so two racers for the same address converge on one entry.
    fn entry_or_insert(&self, addr: Addr) -> &AddrEntry {
        let head = &self.shards[Self::shard_of(addr)];
        let mut fresh: *mut AddrEntry = std::ptr::null_mut();
        loop {
            let first = head.load(SeqCst);
            let mut cur = first;
            while !cur.is_null() {
                let e = unsafe { &*cur };
                if e.addr == addr {
                    if !fresh.is_null() {
                        drop(unsafe { Box::from_raw(fresh) });
                    }
                    return e;
                }
                cur = e.chain.load(SeqCst);
            }
            if fresh.is_null() {
                fresh = Box::into_raw(Box::new(AddrEntry {
                    addr,
                    first: Segment::new(),
                    chain: AtomicPtr::new(first),
                }));
            } else {
                unsafe { (*fresh).chain.store(first, SeqCst) };
            }
            if head.compare_exchange(first, fresh, SeqCst, SeqCst).is_ok() {
                return unsafe { &*fresh };
            }
        }
    }

    fn current_sets(&self, txn: TxnIdx) -> Option<&RecordedSets> {
        let p = self.txns[txn].sets.load(SeqCst);
        if p.is_null() {
            None
        } else {
            Some(unsafe { &*p })
        }
    }
}

impl MvStore for MvMemory {
    fn new(n: usize) -> Self {
        Self {
            shards: (0..SHARDS)
                .map(|_| AtomicPtr::new(std::ptr::null_mut()))
                .collect(),
            txns: (0..n)
                .map(|_| TxnSets {
                    sets: AtomicPtr::new(std::ptr::null_mut()),
                })
                .collect(),
        }
    }

    fn read(&self, addr: Addr, txn: TxnIdx) -> MvRead {
        match self.find_entry(addr).and_then(|e| e.best_below(txn)) {
            None => MvRead::Base,
            Some((writer, incarnation, estimate, value)) => {
                if estimate {
                    MvRead::Estimate(writer)
                } else {
                    MvRead::Value((writer, incarnation), value)
                }
            }
        }
    }

    fn record(&self, version: Version, reads: Vec<ReadDesc>, writes: &[(Addr, u64)]) -> bool {
        let (txn, incarnation) = version;
        for &(addr, value) in writes {
            self.entry_or_insert(addr)
                .claim_slot(txn)
                .publish(incarnation, value);
        }
        let prev_ptr = self.txns[txn].sets.load(SeqCst);
        let prev_writes: &[Addr] = if prev_ptr.is_null() {
            &[]
        } else {
            unsafe { &(*prev_ptr).write_addrs }
        };
        let wrote_new = writes.iter().any(|&(a, _)| !prev_writes.contains(&a));
        for &addr in prev_writes {
            if !writes.iter().any(|&(a, _)| a == addr) {
                if let Some(slot) = self.find_entry(addr).and_then(|e| e.slot_of(txn)) {
                    slot.tombstone(incarnation);
                }
            }
        }
        let fresh = Box::new(RecordedSets {
            reads,
            write_addrs: writes.iter().map(|&(a, _)| a).collect(),
            prev: prev_ptr,
        });
        self.txns[txn].sets.store(Box::into_raw(fresh), SeqCst);
        wrote_new
    }

    fn convert_writes_to_estimates(&self, txn: TxnIdx) {
        let Some(sets) = self.current_sets(txn) else {
            return;
        };
        for &addr in &sets.write_addrs {
            if let Some(slot) = self.find_entry(addr).and_then(|e| e.slot_of(txn)) {
                slot.mark_estimate();
            }
        }
    }

    fn validate_read_set(&self, txn: TxnIdx, base: &dyn Fn(Addr) -> Option<u64>) -> bool {
        let Some(sets) = self.current_sets(txn) else {
            return true;
        };
        sets.reads
            .iter()
            .all(|r| match (self.read(r.addr, txn), r.origin) {
                (MvRead::Base, ReadOrigin::Base(v)) => base(r.addr) == Some(v),
                (MvRead::Value(now, _), ReadOrigin::Version(then)) => now == then,
                _ => false,
            })
    }

    fn write_back(&self, heap: &TxHeap) {
        for head in self.shards.iter() {
            let mut cur = head.load(SeqCst);
            while !cur.is_null() {
                let e = unsafe { &*cur };
                if let Some((_, _, estimate, value)) = e.best_below(usize::MAX) {
                    debug_assert!(
                        !estimate,
                        "ESTIMATE survived to write-back at addr {}",
                        e.addr
                    );
                    heap.store_release(e.addr, value);
                }
                cur = e.chain.load(SeqCst);
            }
        }
    }
}

impl Drop for MvMemory {
    fn drop(&mut self) {
        for head in self.shards.iter_mut() {
            let mut cur = *head.get_mut();
            while !cur.is_null() {
                let mut entry = unsafe { Box::from_raw(cur) };
                cur = *entry.chain.get_mut();
                let mut seg = *entry.first.next.get_mut();
                while !seg.is_null() {
                    let mut s = unsafe { Box::from_raw(seg) };
                    seg = *s.next.get_mut();
                }
            }
        }
        for t in self.txns.iter_mut() {
            let mut p = *t.sets.get_mut();
            while !p.is_null() {
                let sets = unsafe { Box::from_raw(p) };
                p = sets.prev;
            }
        }
    }
}

// --------------------------------------------------------------------
// Sharded-mutex baseline (the PR-1 layout), kept for the benchmark
// --------------------------------------------------------------------

/// Shard count of the baseline store.
const MUTEX_SHARDS: usize = 64;

#[derive(Clone, Copy, Debug)]
struct Cell {
    incarnation: Incarnation,
    estimate: bool,
    value: u64,
}

/// The original `Vec<Mutex<HashMap<..>>>` multi-version store: every
/// read takes a shard lock, read/write sets live behind per-txn
/// mutexes. Selected by `BatchSystem::run_baseline_mutex`; exists so
/// `benches/batch_throughput` can price the lock traffic the lock-free
/// store removes.
pub struct MutexMvMemory {
    shards: Vec<Mutex<HashMap<Addr, BTreeMap<TxnIdx, Cell>>>>,
    reads: Vec<Mutex<Vec<ReadDesc>>>,
    writes: Vec<Mutex<Vec<Addr>>>,
}

impl MutexMvMemory {
    #[inline]
    fn shard(&self, addr: Addr) -> &Mutex<HashMap<Addr, BTreeMap<TxnIdx, Cell>>> {
        &self.shards[addr % MUTEX_SHARDS]
    }
}

impl MvStore for MutexMvMemory {
    fn new(n: usize) -> Self {
        Self {
            shards: (0..MUTEX_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            reads: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
            writes: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    fn read(&self, addr: Addr, txn: TxnIdx) -> MvRead {
        let shard = self.shard(addr).lock().unwrap();
        match shard.get(&addr).and_then(|m| m.range(..txn).next_back()) {
            None => MvRead::Base,
            Some((&writer, cell)) => {
                if cell.estimate {
                    MvRead::Estimate(writer)
                } else {
                    MvRead::Value((writer, cell.incarnation), cell.value)
                }
            }
        }
    }

    fn record(&self, version: Version, reads: Vec<ReadDesc>, writes: &[(Addr, u64)]) -> bool {
        let (txn, incarnation) = version;
        for &(addr, value) in writes {
            let mut shard = self.shard(addr).lock().unwrap();
            shard.entry(addr).or_default().insert(
                txn,
                Cell {
                    incarnation,
                    estimate: false,
                    value,
                },
            );
        }
        let mut prev = self.writes[txn].lock().unwrap();
        let wrote_new = writes.iter().any(|&(addr, _)| !prev.contains(&addr));
        for &addr in prev.iter() {
            if !writes.iter().any(|&(a, _)| a == addr) {
                let mut shard = self.shard(addr).lock().unwrap();
                let emptied = match shard.get_mut(&addr) {
                    Some(m) => {
                        m.remove(&txn);
                        m.is_empty()
                    }
                    None => false,
                };
                if emptied {
                    shard.remove(&addr);
                }
            }
        }
        *prev = writes.iter().map(|&(addr, _)| addr).collect();
        drop(prev);
        *self.reads[txn].lock().unwrap() = reads;
        wrote_new
    }

    fn convert_writes_to_estimates(&self, txn: TxnIdx) {
        let prev = self.writes[txn].lock().unwrap();
        for &addr in prev.iter() {
            let mut shard = self.shard(addr).lock().unwrap();
            if let Some(cell) = shard.get_mut(&addr).and_then(|m| m.get_mut(&txn)) {
                cell.estimate = true;
            }
        }
    }

    fn validate_read_set(&self, txn: TxnIdx, base: &dyn Fn(Addr) -> Option<u64>) -> bool {
        let snapshot = self.reads[txn].lock().unwrap().clone();
        snapshot.iter().all(|r| match (self.read(r.addr, txn), r.origin) {
            (MvRead::Base, ReadOrigin::Base(v)) => base(r.addr) == Some(v),
            (MvRead::Value(now, _), ReadOrigin::Version(then)) => now == then,
            _ => false,
        })
    }

    fn write_back(&self, heap: &TxHeap) {
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            for (&addr, versions) in shard.iter() {
                if let Some((_, cell)) = versions.iter().next_back() {
                    debug_assert!(
                        !cell.estimate,
                        "ESTIMATE survived to write-back at addr {addr}"
                    );
                    heap.store_release(addr, cell.value);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_read_falls_through_to_base_then_sees_writers<M: MvStore>() {
        let mv = M::new(4);
        assert_eq!(mv.read(100, 2), MvRead::Base);
        mv.record((1, 0), Vec::new(), &[(100, 7)]);
        assert_eq!(mv.read(100, 2), MvRead::Value((1, 0), 7));
        // A reader at or below the writer's index never sees it.
        assert_eq!(mv.read(100, 1), MvRead::Base);
        assert_eq!(mv.read(100, 0), MvRead::Base);
    }

    fn check_highest_lower_writer_wins<M: MvStore>() {
        let mv = M::new(5);
        mv.record((0, 0), Vec::new(), &[(8, 10)]);
        mv.record((2, 0), Vec::new(), &[(8, 20)]);
        assert_eq!(mv.read(8, 1), MvRead::Value((0, 0), 10));
        assert_eq!(mv.read(8, 3), MvRead::Value((2, 0), 20));
        assert_eq!(mv.read(8, 4), MvRead::Value((2, 0), 20));
    }

    fn check_estimates_surface_the_blocking_txn<M: MvStore>() {
        let mv = M::new(3);
        mv.record((1, 0), Vec::new(), &[(64, 5)]);
        mv.convert_writes_to_estimates(1);
        assert_eq!(mv.read(64, 2), MvRead::Estimate(1));
        // Re-execution replaces the estimate.
        mv.record((1, 1), Vec::new(), &[(64, 6)]);
        assert_eq!(mv.read(64, 2), MvRead::Value((1, 1), 6));
    }

    fn check_record_removes_stale_addresses_and_reports_new_ones<M: MvStore>() {
        let mv = M::new(3);
        assert!(mv.record((1, 0), Vec::new(), &[(8, 1), (16, 2)]));
        // Same footprint: not new.
        assert!(!mv.record((1, 1), Vec::new(), &[(8, 3), (16, 4)]));
        // Different footprint: 24 is new, 16 goes stale.
        assert!(mv.record((1, 2), Vec::new(), &[(8, 5), (24, 6)]));
        assert_eq!(mv.read(16, 2), MvRead::Base, "stale entry must vanish");
        assert_eq!(mv.read(24, 2), MvRead::Value((1, 2), 6));
    }

    fn check_validation_tracks_version_changes<M: MvStore>() {
        let mv = M::new(4);
        let base = |_addr: Addr| Some(7u64);
        mv.record((0, 0), Vec::new(), &[(8, 1)]);
        // txn 2 read (0,0) at addr 8 and the base value 7 at addr 16.
        mv.record(
            (2, 0),
            vec![
                ReadDesc { addr: 8, origin: ReadOrigin::Version((0, 0)) },
                ReadDesc { addr: 16, origin: ReadOrigin::Base(7) },
            ],
            &[],
        );
        assert!(mv.validate_read_set(2, &base));
        // The base itself moving (a previous block's write-back landing
        // at addr 16) fails the value comparison.
        assert!(!mv.validate_read_set(2, &|_| Some(8u64)));
        // An unresolved base (predecessor ESTIMATE) fails too.
        assert!(!mv.validate_read_set(2, &|_| None));
        // txn 1 writes addr 16: txn 2's base read is now stale even
        // with the base value unchanged.
        mv.record((1, 0), Vec::new(), &[(16, 9)]);
        assert!(!mv.validate_read_set(2, &base));
    }

    fn check_write_back_commits_highest_version<M: MvStore>() {
        let heap = TxHeap::new(256);
        let a = heap.alloc(1);
        heap.store(a, 1);
        let mv = M::new(3);
        mv.record((0, 0), Vec::new(), &[(a, 10)]);
        mv.record((2, 1), Vec::new(), &[(a, 30)]);
        mv.write_back(&heap);
        assert_eq!(heap.load(a), 30);
    }

    macro_rules! store_suite {
        ($modname:ident, $store:ty) => {
            mod $modname {
                use super::*;

                #[test]
                fn read_falls_through_to_base_then_sees_writers() {
                    check_read_falls_through_to_base_then_sees_writers::<$store>();
                }
                #[test]
                fn highest_lower_writer_wins() {
                    check_highest_lower_writer_wins::<$store>();
                }
                #[test]
                fn estimates_surface_the_blocking_txn() {
                    check_estimates_surface_the_blocking_txn::<$store>();
                }
                #[test]
                fn record_removes_stale_addresses_and_reports_new_ones() {
                    check_record_removes_stale_addresses_and_reports_new_ones::<$store>();
                }
                #[test]
                fn validation_tracks_version_changes() {
                    check_validation_tracks_version_changes::<$store>();
                }
                #[test]
                fn write_back_commits_highest_version() {
                    check_write_back_commits_highest_version::<$store>();
                }
            }
        };
    }

    store_suite!(lockfree, MvMemory);
    store_suite!(mutex_baseline, MutexMvMemory);

    #[test]
    fn lockfree_many_writers_chain_segments() {
        // More writers on one address than a single segment holds:
        // segment append + full-scan read must still pick the highest.
        let mv = MvMemory::new(64);
        for t in 0..40usize {
            mv.record((t, 0), Vec::new(), &[(72, 1000 + t as u64)]);
        }
        assert_eq!(mv.read(72, 40), MvRead::Value((39, 0), 1039));
        assert_eq!(mv.read(72, 17), MvRead::Value((16, 0), 1016));
        assert_eq!(mv.read(72, 0), MvRead::Base);
    }

    #[test]
    fn lockfree_concurrent_readers_see_only_published_values() {
        // Hammer one address with serialized republications of txn 1
        // while reader threads poll: every observed value must be one
        // that was actually published (seqlock consistency), never a
        // torn pair.
        use std::sync::atomic::AtomicBool;
        let mv = MvMemory::new(4);
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    while !stop.load(SeqCst) {
                        match mv.read(88, 2) {
                            MvRead::Base => {}
                            MvRead::Estimate(t) => assert_eq!(t, 1),
                            MvRead::Value((t, inc), v) => {
                                assert_eq!(t, 1);
                                assert_eq!(
                                    v,
                                    7000 + inc as u64,
                                    "value must match its incarnation"
                                );
                            }
                        }
                    }
                });
            }
            for inc in 0..600u32 {
                mv.record((1, inc), Vec::new(), &[(88, 7000 + inc as u64)]);
                if inc % 3 == 0 {
                    mv.convert_writes_to_estimates(1);
                }
            }
            stop.store(true, SeqCst);
        });
    }

    #[test]
    fn seqlock_slot_reuse_across_incarnations_never_tears() {
        // The ABA regression for the two-word seqlock: one slot is
        // forced through publish → ESTIMATE → tombstone → re-publish
        // cycles (the writing txn's footprint drops addr 96 and picks
        // it back up across incarnations, so the SAME claimed slot is
        // reused with strictly growing meta words). Readers double-read
        // throughout; the value is derived from its incarnation, so any
        // torn pairing of one incarnation's meta with another's value —
        // the classic seqlock ABA — trips the assertion. Monotonic meta
        // words are exactly what makes a stable double-read conclusive;
        // this test is the executable form of that claim.
        use std::sync::atomic::AtomicBool;
        let mv = MvMemory::new(4);
        let stop = AtomicBool::new(false);
        const ADDR: Addr = 96;
        let value_of = |inc: Incarnation| 0xA000 + inc as u64 * 3;
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    while !stop.load(SeqCst) {
                        match mv.read(ADDR, 2) {
                            // Tombstoned (or never-written) windows fall
                            // through to base.
                            MvRead::Base => {}
                            MvRead::Estimate(t) => assert_eq!(t, 1),
                            MvRead::Value((t, inc), v) => {
                                assert_eq!(t, 1);
                                assert_eq!(
                                    v,
                                    value_of(inc),
                                    "torn (incarnation, value) pair after slot reuse"
                                );
                            }
                        }
                    }
                });
            }
            // Writer: serialized incarnations of txn 1, cycling the
            // footprint so the slot is retracted and reused, with
            // ESTIMATE phases in between — every lifecycle transition
            // the slot's meta word can take, each at a fresh
            // incarnation.
            for inc in 0..900u32 {
                match inc % 3 {
                    0 => {
                        mv.record((1, inc), Vec::new(), &[(ADDR, value_of(inc))]);
                        mv.convert_writes_to_estimates(1);
                    }
                    1 => {
                        // Footprint drops ADDR: the claimed slot is
                        // tombstoned at this incarnation...
                        mv.record((1, inc), Vec::new(), &[(ADDR + 8, inc as u64)]);
                    }
                    _ => {
                        // ...and republished by the next one — same
                        // slot, higher meta.
                        mv.record((1, inc), Vec::new(), &[(ADDR, value_of(inc))]);
                    }
                }
            }
            stop.store(true, SeqCst);
        });
        // The last cycle ends on a publish: the slot must be live.
        assert_eq!(mv.read(ADDR, 2), MvRead::Value((1, 899), value_of(899)));
    }

    #[test]
    fn lockfree_dense_addresses_spread_and_resolve() {
        // Neighbouring word addresses (the dense SSCA-2 pattern) land
        // in distinct chains but all resolve correctly.
        let mv = MvMemory::new(8);
        for addr in 0..512usize {
            mv.record((1, 0), Vec::new(), &[(addr, addr as u64 * 3)]);
        }
        for addr in 0..512usize {
            assert_eq!(mv.read(addr, 5), MvRead::Value((1, 0), addr as u64 * 3));
        }
        let heap = TxHeap::new(1 << 10);
        mv.write_back(&heap);
        for addr in 0..512usize {
            assert_eq!(heap.load(addr), addr as u64 * 3);
        }
    }
}
