//! Multi-version memory for the speculative batch executor.
//!
//! Every speculative write lands here, never in the [`TxHeap`] — the
//! heap stays at its pre-batch snapshot until [`MvMemory::write_back`].
//! Per address the structure keeps one entry per *transaction index*
//! (only the latest incarnation of each), ordered, so a reader at index
//! `i` picks the highest writer strictly below `i` and falls through to
//! the heap when there is none. Entries of an aborted incarnation are
//! flagged ESTIMATE: readers treat them as "this value is about to be
//! rewritten" and suspend instead of speculating on a known-stale value.
//!
//! Addresses are word indices (`mem::Addr`), exactly what the
//! [`crate::tm::access::TxAccess`] bodies already traffic in, so the
//! same transaction closures run unchanged under HTM, STM, the locks,
//! or this executor. Sharded mutex-protected hash maps keep neighbour
//! cache lines in different shards (addresses are dense and small);
//! each map value is a `BTreeMap<TxnIdx, _>` for the range scan.

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

use crate::mem::{Addr, TxHeap};

use super::scheduler::{Incarnation, TxnIdx, Version};

/// Shard count: a power of two well above any worker count we run.
const SHARDS: usize = 64;

#[derive(Clone, Copy, Debug)]
struct Cell {
    incarnation: Incarnation,
    /// ESTIMATE marker: the owning incarnation was aborted and will
    /// re-execute; readers must wait rather than consume the value.
    estimate: bool,
    value: u64,
}

/// Where a speculative read was served from — the version the read
/// validates against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadOrigin {
    /// Fell through to the (pre-batch) heap snapshot.
    Base,
    /// Served by a lower transaction's recorded write.
    Version(Version),
}

/// One entry of a transaction's read set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReadDesc {
    pub addr: Addr,
    pub origin: ReadOrigin,
}

/// Result of a speculative read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MvRead {
    /// No lower writer: read the heap.
    Base,
    /// A lower transaction wrote this value.
    Value(Version, u64),
    /// A lower transaction's aborted write: suspend on that index.
    Estimate(TxnIdx),
}

/// The multi-version store plus per-transaction read/write-set records.
pub struct MvMemory {
    shards: Vec<Mutex<HashMap<Addr, BTreeMap<TxnIdx, Cell>>>>,
    /// Read set of each transaction's last *completed* incarnation.
    reads: Vec<Mutex<Vec<ReadDesc>>>,
    /// Write-set addresses of each transaction's last incarnation.
    writes: Vec<Mutex<Vec<Addr>>>,
}

impl MvMemory {
    pub fn new(n: usize) -> Self {
        Self {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            reads: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
            writes: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    #[inline]
    fn shard(&self, addr: Addr) -> &Mutex<HashMap<Addr, BTreeMap<TxnIdx, Cell>>> {
        &self.shards[addr % SHARDS]
    }

    /// Read `addr` as transaction `txn`: the highest writer below `txn`,
    /// or the heap when none exists.
    pub fn read(&self, addr: Addr, txn: TxnIdx) -> MvRead {
        let shard = self.shard(addr).lock().unwrap();
        match shard.get(&addr).and_then(|m| m.range(..txn).next_back()) {
            None => MvRead::Base,
            Some((&writer, cell)) => {
                if cell.estimate {
                    MvRead::Estimate(writer)
                } else {
                    MvRead::Value((writer, cell.incarnation), cell.value)
                }
            }
        }
    }

    /// Record a finished incarnation's read and write sets. Stale
    /// entries from the previous incarnation (addresses no longer
    /// written) are removed. Returns `true` when the incarnation wrote
    /// to an address its predecessor did not — the scheduler then
    /// forces higher transactions to revalidate.
    pub fn record(&self, version: Version, reads: Vec<ReadDesc>, writes: &[(Addr, u64)]) -> bool {
        let (txn, incarnation) = version;
        for &(addr, value) in writes {
            let mut shard = self.shard(addr).lock().unwrap();
            shard.entry(addr).or_default().insert(
                txn,
                Cell {
                    incarnation,
                    estimate: false,
                    value,
                },
            );
        }
        let mut prev = self.writes[txn].lock().unwrap();
        let wrote_new = writes.iter().any(|&(addr, _)| !prev.contains(&addr));
        for &addr in prev.iter() {
            if !writes.iter().any(|&(a, _)| a == addr) {
                let mut shard = self.shard(addr).lock().unwrap();
                let emptied = match shard.get_mut(&addr) {
                    Some(m) => {
                        m.remove(&txn);
                        m.is_empty()
                    }
                    None => false,
                };
                if emptied {
                    shard.remove(&addr);
                }
            }
        }
        *prev = writes.iter().map(|&(addr, _)| addr).collect();
        drop(prev);
        *self.reads[txn].lock().unwrap() = reads;
        wrote_new
    }

    /// Mark every write of `txn`'s last incarnation as an ESTIMATE
    /// (called right after a validation abort wins, before the
    /// re-execution is scheduled).
    pub fn convert_writes_to_estimates(&self, txn: TxnIdx) {
        let prev = self.writes[txn].lock().unwrap();
        for &addr in prev.iter() {
            let mut shard = self.shard(addr).lock().unwrap();
            if let Some(cell) = shard.get_mut(&addr).and_then(|m| m.get_mut(&txn)) {
                cell.estimate = true;
            }
        }
    }

    /// Re-read `txn`'s recorded read set and check every observed
    /// version still matches. ESTIMATEs and changed versions fail.
    pub fn validate_read_set(&self, txn: TxnIdx) -> bool {
        let snapshot = self.reads[txn].lock().unwrap().clone();
        snapshot.iter().all(|r| match (self.read(r.addr, txn), r.origin) {
            (MvRead::Base, ReadOrigin::Base) => true,
            (MvRead::Value(now, _), ReadOrigin::Version(then)) => now == then,
            _ => false,
        })
    }

    /// After the batch completes: flush the winning (highest-index)
    /// version of every address into the heap. Equivalent to committing
    /// the transactions one by one in index order.
    pub fn write_back(&self, heap: &TxHeap) {
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            for (&addr, versions) in shard.iter() {
                if let Some((_, cell)) = versions.iter().next_back() {
                    debug_assert!(
                        !cell.estimate,
                        "ESTIMATE survived to write-back at addr {addr}"
                    );
                    heap.store_release(addr, cell.value);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_falls_through_to_base_then_sees_writers() {
        let mv = MvMemory::new(4);
        assert_eq!(mv.read(100, 2), MvRead::Base);
        mv.record((1, 0), Vec::new(), &[(100, 7)]);
        assert_eq!(mv.read(100, 2), MvRead::Value((1, 0), 7));
        // A reader at or below the writer's index never sees it.
        assert_eq!(mv.read(100, 1), MvRead::Base);
        assert_eq!(mv.read(100, 0), MvRead::Base);
    }

    #[test]
    fn highest_lower_writer_wins() {
        let mv = MvMemory::new(5);
        mv.record((0, 0), Vec::new(), &[(8, 10)]);
        mv.record((2, 0), Vec::new(), &[(8, 20)]);
        assert_eq!(mv.read(8, 1), MvRead::Value((0, 0), 10));
        assert_eq!(mv.read(8, 3), MvRead::Value((2, 0), 20));
        assert_eq!(mv.read(8, 4), MvRead::Value((2, 0), 20));
    }

    #[test]
    fn estimates_surface_the_blocking_txn() {
        let mv = MvMemory::new(3);
        mv.record((1, 0), Vec::new(), &[(64, 5)]);
        mv.convert_writes_to_estimates(1);
        assert_eq!(mv.read(64, 2), MvRead::Estimate(1));
        // Re-execution replaces the estimate.
        mv.record((1, 1), Vec::new(), &[(64, 6)]);
        assert_eq!(mv.read(64, 2), MvRead::Value((1, 1), 6));
    }

    #[test]
    fn record_removes_stale_addresses_and_reports_new_ones() {
        let mv = MvMemory::new(3);
        assert!(mv.record((1, 0), Vec::new(), &[(8, 1), (16, 2)]));
        // Same footprint: not new.
        assert!(!mv.record((1, 1), Vec::new(), &[(8, 3), (16, 4)]));
        // Different footprint: 24 is new, 16 goes stale.
        assert!(mv.record((1, 2), Vec::new(), &[(8, 5), (24, 6)]));
        assert_eq!(mv.read(16, 2), MvRead::Base, "stale entry must vanish");
        assert_eq!(mv.read(24, 2), MvRead::Value((1, 2), 6));
    }

    #[test]
    fn validation_tracks_version_changes() {
        let mv = MvMemory::new(4);
        mv.record((0, 0), Vec::new(), &[(8, 1)]);
        // txn 2 read (0,0) at addr 8 and base at addr 16.
        mv.record(
            (2, 0),
            vec![
                ReadDesc { addr: 8, origin: ReadOrigin::Version((0, 0)) },
                ReadDesc { addr: 16, origin: ReadOrigin::Base },
            ],
            &[],
        );
        assert!(mv.validate_read_set(2));
        // txn 1 writes addr 16: txn 2's base read is now stale.
        mv.record((1, 0), Vec::new(), &[(16, 9)]);
        assert!(!mv.validate_read_set(2));
    }

    #[test]
    fn write_back_commits_highest_version() {
        let heap = TxHeap::new(256);
        let a = heap.alloc(1);
        heap.store(a, 1);
        let mv = MvMemory::new(3);
        mv.record((0, 0), Vec::new(), &[(a, 10)]);
        mv.record((2, 1), Vec::new(), &[(a, 30)]);
        mv.write_back(&heap);
        assert_eq!(heap.load(a), 30);
    }
}
