//! The batch worker loop: execute → record → validate → abort/re-incarnate.
//!
//! Each worker pulls [`Task`]s from the shared [`Scheduler`]. Execution
//! runs the transaction body against an [`MvView`] — a
//! [`crate::tm::access::TxAccess`] implementation that reads through
//! the multi-version store (recording the observed version per read)
//! and buffers writes locally. Validation re-reads the recorded read
//! set; on mismatch the incarnation's writes become ESTIMATEs and the
//! transaction re-executes with a bumped incarnation number.
//!
//! The worker is generic over the [`MvStore`] implementation so the
//! same loop drives both the lock-free production store and the
//! sharded-mutex baseline the benchmark compares it against.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::mem::{Addr, TxHeap};
use crate::tm::access::{Abort, TxAccess, TxResult};
use crate::tm::AbortCause;

use super::mvmemory::{MvRead, MvStore, ReadDesc, ReadOrigin};
use super::scheduler::{Scheduler, Task, TxnIdx, Version};
use super::BatchTxn;

/// Cumulative counters shared by all workers of one batch run.
#[derive(Debug, Default)]
pub struct BatchCounters {
    /// Incarnation executions started (≥ batch size; the excess is
    /// speculation waste).
    pub executions: AtomicU64,
    /// Validation tasks performed.
    pub validations: AtomicU64,
    /// Validations that aborted an incarnation.
    pub validation_aborts: AtomicU64,
    /// Executions suspended on an ESTIMATE of a lower transaction.
    pub dependencies: AtomicU64,
}

/// Speculative memory view of one executing incarnation. The read and
/// write sets are plain single-owner `Vec`s — only this worker touches
/// them until `record` publishes them into the store.
struct MvView<'r, M: MvStore> {
    heap: &'r TxHeap,
    mv: &'r M,
    txn: TxnIdx,
    reads: Vec<ReadDesc>,
    writes: Vec<(Addr, u64)>,
    blocked_on: Option<TxnIdx>,
}

impl<M: MvStore> TxAccess for MvView<'_, M> {
    fn read(&mut self, addr: Addr) -> TxResult<u64> {
        // Read-your-own-writes from the local buffer first.
        if let Some(w) = self.writes.iter().rev().find(|w| w.0 == addr) {
            return Ok(w.1);
        }
        match self.mv.read(addr, self.txn) {
            MvRead::Value(version, v) => {
                self.reads.push(ReadDesc {
                    addr,
                    origin: ReadOrigin::Version(version),
                });
                Ok(v)
            }
            MvRead::Base => {
                self.reads.push(ReadDesc {
                    addr,
                    origin: ReadOrigin::Base,
                });
                Ok(self.heap.load_acquire(addr))
            }
            MvRead::Estimate(blocking) => {
                // A lower transaction is about to rewrite this value:
                // abort the attempt and suspend on it.
                self.blocked_on = Some(blocking);
                Err(Abort(AbortCause::Conflict))
            }
        }
    }

    fn write(&mut self, addr: Addr, val: u64) -> TxResult<()> {
        if let Some(slot) = self.writes.iter_mut().find(|w| w.0 == addr) {
            slot.1 = val;
        } else {
            self.writes.push((addr, val));
        }
        Ok(())
    }
}

/// One worker's borrowed view of the shared batch-run state.
pub(super) struct Worker<'r, 'b, M: MvStore> {
    pub heap: &'r TxHeap,
    pub txns: &'r [BatchTxn<'b>],
    pub mv: &'r M,
    pub scheduler: &'r Scheduler,
    pub counters: &'r BatchCounters,
}

impl<M: MvStore> Worker<'_, '_, M> {
    /// Pull and run tasks until the whole batch is executed+validated.
    pub fn run(&self) {
        let mut task: Option<Task> = None;
        loop {
            task = match task {
                Some(Task::Execution(v)) => self.try_execute(v),
                Some(Task::Validation(v)) => self.try_validate(v),
                None => {
                    if self.scheduler.done() {
                        return;
                    }
                    std::hint::spin_loop();
                    self.scheduler.next_task()
                }
            };
        }
    }

    fn try_execute(&self, version: Version) -> Option<Task> {
        let (txn, incarnation) = version;
        loop {
            self.counters.executions.fetch_add(1, Ordering::Relaxed);
            let mut view = MvView {
                heap: self.heap,
                mv: self.mv,
                txn,
                reads: Vec::new(),
                writes: Vec::new(),
                blocked_on: None,
            };
            match (self.txns[txn].body)(&mut view) {
                Ok(()) => {
                    let wrote_new = self.mv.record(version, view.reads, &view.writes);
                    return self.scheduler.finish_execution(txn, incarnation, wrote_new);
                }
                Err(_) => {
                    let blocking = view.blocked_on.expect(
                        "batch transaction bodies must be infallible apart from \
                         ESTIMATE dependencies raised by the view itself",
                    );
                    self.counters.dependencies.fetch_add(1, Ordering::Relaxed);
                    if self.scheduler.add_dependency(txn, blocking) {
                        // Suspended; a later finish_execution re-readies
                        // it with the next incarnation number.
                        return None;
                    }
                    // The blocking transaction finished in the window
                    // between our read and now: just re-run in place.
                }
            }
        }
    }

    fn try_validate(&self, version: Version) -> Option<Task> {
        let (txn, incarnation) = version;
        self.counters.validations.fetch_add(1, Ordering::Relaxed);
        let valid = self.mv.validate_read_set(txn);
        let aborted = !valid && self.scheduler.try_validation_abort(txn, incarnation);
        if aborted {
            self.counters.validation_aborts.fetch_add(1, Ordering::Relaxed);
            self.mv.convert_writes_to_estimates(txn);
        }
        self.scheduler.finish_validation(txn, aborted)
    }
}
