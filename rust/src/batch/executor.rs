//! The batch worker loop: execute → record → validate → abort/re-incarnate.
//!
//! Each worker pulls [`Task`]s from the shared [`Scheduler`] (its own
//! deque first, then a chunked stream refill, then steals from peers —
//! see the scheduler docs). Execution runs the transaction body against
//! an [`MvView`] — a [`crate::tm::access::TxAccess`] implementation
//! that reads through the multi-version store (recording the observed
//! version per read) and buffers writes locally. Validation re-reads
//! the recorded read set; on mismatch the incarnation's writes become
//! ESTIMATEs and the transaction re-executes with a bumped incarnation
//! number.
//!
//! Reads that find no lower in-block writer resolve through a
//! [`BaseSource`]: the heap for a barrier run (and for the head block
//! of a pipelined stream), or — under W-deep cross-block pipelining —
//! a **chain of draining predecessors**, nearest first: block N+k
//! peeks block N+k-1's winning versions, falls through to N+k-2's, and
//! so on down to the heap. A written-back link short-circuits to the
//! heap (blocks complete in admission order, so everything older is
//! already flushed), and a read that hits *any* live predecessor's
//! ESTIMATE parks the transaction on its immediate predecessor via
//! [`CrossBlockPark`] until that block completes.
//!
//! The worker is generic over the [`MvStore`] implementation so the
//! same loop drives both the lock-free production store and the
//! sharded-mutex baseline the benchmark compares it against.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::mem::{Addr, TxHeap};
use crate::tm::access::{Abort, TxAccess, TxResult};
use crate::tm::AbortCause;

use super::mvmemory::{MvRead, MvStore, ReadDesc, ReadOrigin};
use super::scheduler::{Scheduler, Task, TxnIdx, Version};
use super::BatchTxn;

/// Cumulative counters shared by all workers of one batch run.
#[derive(Debug, Default)]
pub struct BatchCounters {
    /// Incarnation executions started (≥ batch size; the excess is
    /// speculation waste).
    pub executions: AtomicU64,
    /// Validation tasks performed.
    pub validations: AtomicU64,
    /// Validations that aborted an incarnation.
    pub validation_aborts: AtomicU64,
    /// Executions suspended on an ESTIMATE of a lower transaction.
    pub dependencies: AtomicU64,
    /// Execution attempts started while the *previous block* was still
    /// draining (cross-block pipelining overlap).
    pub overlapped: AtomicU64,
    /// Winning execution-attempt latency per transaction. Only fed
    /// while `obs::timing_enabled()` (the guard is one relaxed load);
    /// recording is a relaxed `fetch_add`, lock-free like the counters
    /// above.
    pub txn_lat: crate::obs::hist::AtomicHist,
    /// Transaction bodies that panicked mid-execution and were caught,
    /// quarantined, and re-dispatched instead of killing the pool.
    pub quarantines: AtomicU64,
    /// Watchdog interventions: an elected kicker re-readied lost
    /// wakeups and forced a revalidation pass after the progress
    /// deadline expired.
    pub watchdog_kicks: AtomicU64,
    /// Watchdog escalations to the degraded serial backend
    /// ([`crate::engine::degraded`]) after repeated fruitless kicks.
    pub degradations: AtomicU64,
}

/// One link of the predecessor chain a pipelined block resolves its
/// base reads through: a draining predecessor's store plus its
/// written-back flag.
pub(super) struct PrevLink<'r, M: MvStore> {
    pub mv: &'r M,
    pub done: &'r AtomicBool,
}

/// Where a read with no lower in-block writer resolves.
pub(super) enum BaseSource<'r, M: MvStore> {
    /// The pre-batch heap snapshot (barrier runs, and the head block of
    /// a pipelined run).
    Heap,
    /// The chain of draining predecessors of a W-deep pipelined run,
    /// **nearest predecessor first** (block N+k-1, then N+k-2, …).
    /// A link that reports `Base` defers to the next-older link; a
    /// written-back link (`done`) short-circuits to the heap — blocks
    /// complete strictly in admission order, so a flushed link implies
    /// every older link is flushed too. `None` = some live link's value
    /// is an ESTIMATE — unresolved, park.
    Chain { links: Vec<PrevLink<'r, M>> },
}

impl<M: MvStore> BaseSource<'_, M> {
    fn value(&self, heap: &TxHeap, addr: Addr) -> Option<u64> {
        match self {
            BaseSource::Heap => Some(heap.load_acquire(addr)),
            BaseSource::Chain { links } => {
                for link in links {
                    if link.done.load(Ordering::SeqCst) {
                        break;
                    }
                    match link.mv.read(addr, usize::MAX) {
                        MvRead::Value(_, v) => return Some(v),
                        MvRead::Base => continue,
                        MvRead::Estimate(_) => return None,
                    }
                }
                Some(heap.load_acquire(addr))
            }
        }
    }

    /// Is this block still overlapping a live predecessor?
    fn overlapping(&self) -> bool {
        match self {
            BaseSource::Heap => false,
            BaseSource::Chain { links } => links
                .first()
                .is_some_and(|l| !l.done.load(Ordering::SeqCst)),
        }
    }
}

/// Cross-block parking state shared with `BatchSystem::run_pipelined`:
/// the list of this block's transactions suspended on the previous
/// block. The mutex serializes parking against the promotion path
/// (which flips `prev_done` and drains the list under the same lock),
/// closing the lost-wakeup window exactly like the in-block dependency
/// protocol does.
pub(super) struct CrossBlockPark<'r> {
    pub prev_done: &'r AtomicBool,
    pub parked: &'r Mutex<Vec<TxnIdx>>,
}

impl CrossBlockPark<'_> {
    /// Suspend `txn` (currently Executing) until the previous block
    /// completes. Returns `false` when the predecessor already
    /// finished — the caller simply re-executes in place.
    fn park(&self, txn: TxnIdx, scheduler: &Scheduler) -> bool {
        let mut list = self.parked.lock().unwrap();
        if self.prev_done.load(Ordering::SeqCst) {
            return false;
        }
        scheduler.suspend_external(txn);
        list.push(txn);
        true
    }
}

/// Speculative memory view of one executing incarnation. The read and
/// write sets are plain single-owner `Vec`s — only this worker touches
/// them until `record` publishes them into the store.
struct MvView<'r, M: MvStore> {
    heap: &'r TxHeap,
    mv: &'r M,
    base: &'r BaseSource<'r, M>,
    txn: TxnIdx,
    reads: Vec<ReadDesc>,
    writes: Vec<(Addr, u64)>,
    blocked_on: Option<TxnIdx>,
    blocked_on_prev: bool,
}

impl<M: MvStore> TxAccess for MvView<'_, M> {
    fn read(&mut self, addr: Addr) -> TxResult<u64> {
        // Read-your-own-writes from the local buffer first.
        if let Some(w) = self.writes.iter().rev().find(|w| w.0 == addr) {
            return Ok(w.1);
        }
        // Sample the shard watermark BEFORE the store probe: if the
        // mark is still equal at validation time, no publish since this
        // point can have touched the shard, so the probe is skippable.
        // (Sampling after the read would leave a window where a write
        // lands between read and sample and hides behind an "unchanged"
        // mark.)
        let mark = self.mv.mark_of(addr);
        match self.mv.read(addr, self.txn) {
            MvRead::Value(version, v) => {
                self.reads.push(ReadDesc {
                    addr,
                    origin: ReadOrigin::Version(version),
                    mark,
                });
                Ok(v)
            }
            MvRead::Base => match self.base.value(self.heap, addr) {
                Some(v) => {
                    self.reads.push(ReadDesc {
                        addr,
                        origin: ReadOrigin::Base(v),
                        mark,
                    });
                    Ok(v)
                }
                None => {
                    // The previous block is about to rewrite this value:
                    // abort the attempt and park on that block.
                    self.blocked_on_prev = true;
                    Err(Abort(AbortCause::Conflict))
                }
            },
            MvRead::Estimate(blocking) => {
                // A lower transaction is about to rewrite this value:
                // abort the attempt and suspend on it.
                self.blocked_on = Some(blocking);
                Err(Abort(AbortCause::Conflict))
            }
        }
    }

    fn write(&mut self, addr: Addr, val: u64) -> TxResult<()> {
        if let Some(slot) = self.writes.iter_mut().find(|w| w.0 == addr) {
            slot.1 = val;
        } else {
            self.writes.push((addr, val));
        }
        Ok(())
    }
}

/// One worker's borrowed view of the shared batch-run state.
pub(super) struct Worker<'r, 'b, M: MvStore> {
    pub heap: &'r TxHeap,
    pub txns: &'r [BatchTxn<'b>],
    pub mv: &'r M,
    pub scheduler: &'r Scheduler,
    pub counters: &'r BatchCounters,
    /// Where base reads (no lower in-block writer) resolve.
    pub base: BaseSource<'r, M>,
    /// Cross-block parking (pipelined runs only).
    pub park: Option<CrossBlockPark<'r>>,
    /// The run's shared progress watchdog (barrier runs with the fault
    /// plane installed; `None` otherwise — pipelined sessions poll
    /// their watchdog in the window loop instead, where the whole
    /// window is in scope).
    pub wd: Option<&'r crate::fault::watchdog::Watchdog>,
}

impl<M: MvStore> Worker<'_, '_, M> {
    /// Barrier-mode driver for pool worker `w`: pull and run tasks
    /// until the whole batch is executed+validated.
    pub fn run(&self, w: usize) {
        loop {
            if self.scheduler.done() {
                return;
            }
            // Fault plane: a bounded injected stall before the next
            // task (one relaxed load + branch when no plane is
            // installed). Recovery needs no help here — the stalled
            // worker simply resumes; the watchdog only steps in if
            // every worker stalls past the scaled deadline.
            crate::fault::maybe_stall();
            match self.scheduler.next_task(w) {
                Some(task) => self.step(task),
                None => {
                    // Idle: the only regime a genuine stall (lost
                    // wakeup, every peer asleep) is visible from. The
                    // poll is on the workers — never the joining thread
                    // — so a kick that reopens validation always has a
                    // live worker (this one) to drain what it reopened.
                    if let Some(wd) = self.wd {
                        self.watchdog_poll(wd);
                    }
                    std::hint::spin_loop();
                }
            }
        }
    }

    /// One watchdog poll from an idle worker: feed the commit-latency
    /// EWMA, report the progress counter, and — if this worker wins
    /// the kicker election after a missed deadline — run the recovery
    /// pass: re-ready recorded lost wakeups, force a revalidation
    /// pass, and escalate to the degraded serial backend after
    /// repeated fruitless kicks. Only ever called with the fault plane
    /// installed.
    #[cold]
    fn watchdog_poll(&self, wd: &crate::fault::watchdog::Watchdog) {
        use crate::fault::watchdog::Diagnosis;
        let lat = self.counters.txn_lat.fold();
        if lat.count() > 0 {
            wd.observe_commit_latency(lat.p50().max(1));
        }
        let progress = self.counters.executions.load(Ordering::Relaxed)
            + self.counters.validations.load(Ordering::Relaxed);
        if !wd.poll(progress) {
            if crate::engine::degraded::is_degraded() && wd.ready_to_recover() {
                crate::engine::degraded::recover(wd.kicks());
            }
            return;
        }
        let recovered = self.scheduler.recover_lost();
        self.scheduler.reopen_validation();
        let diag = if recovered > 0 {
            Diagnosis::LostWakeup
        } else {
            Diagnosis::Livelock
        };
        crate::obs::trace::watchdog_kick(diag as u64, recovered as u64);
        self.counters.watchdog_kicks.fetch_add(1, Ordering::Relaxed);
        if wd.should_escalate() && !crate::engine::degraded::is_degraded() {
            crate::engine::degraded::escalate(wd.kicks());
            self.counters.degradations.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Run one claimed task and every follow-up task the scheduler
    /// chains onto it (in-place validation, in-place re-execution).
    pub fn step(&self, task: Task) {
        let mut task = Some(task);
        while let Some(t) = task {
            task = match t {
                Task::Execution(v) => self.try_execute(v),
                Task::Validation(v) => self.try_validate(v),
            };
        }
    }

    fn try_execute(&self, version: Version) -> Option<Task> {
        let (txn, incarnation) = version;
        loop {
            let t0 = if crate::obs::timing_enabled() {
                Some(std::time::Instant::now())
            } else {
                None
            };
            self.counters.executions.fetch_add(1, Ordering::Relaxed);
            if self.base.overlapping() {
                self.counters.overlapped.fetch_add(1, Ordering::Relaxed);
            }
            let mut view = MvView {
                heap: self.heap,
                mv: self.mv,
                base: &self.base,
                txn,
                reads: Vec::new(),
                writes: Vec::new(),
                blocked_on: None,
                blocked_on_prev: false,
            };
            // The body runs under `catch_unwind`: a poisoned
            // transaction (a genuine bug, or `--faults panic=P`) is
            // quarantined and re-dispatched instead of crashing the
            // pool. Nothing has been published at this point — writes
            // only reach the store via `mv.record` below — so the
            // catch can never leak partial state. `AssertUnwindSafe`
            // is justified by exactly that: the view is local, and
            // the shared structures are only touched after a
            // successful body.
            let body_result = {
                let inject = crate::fault::active()
                    && self.scheduler.quarantine_count(txn) < crate::fault::MAX_INJECT_PER_TXN
                    && crate::fault::inject(crate::fault::Site::Panic);
                let view = &mut view;
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    if inject {
                        panic!("injected fault: poisoned transaction body");
                    }
                    (self.txns[txn].body)(view)
                }))
            };
            match body_result {
                Err(payload) => {
                    // Past the requeue budget the panic is genuine and
                    // persistent (injection self-suppresses first, at
                    // MAX_INJECT_PER_TXN < MAX_REQUEUE): re-raise so a
                    // real bug still surfaces instead of retrying
                    // forever.
                    if self.scheduler.quarantine_count(txn) >= crate::fault::MAX_REQUEUE {
                        std::panic::resume_unwind(payload);
                    }
                    self.counters.quarantines.fetch_add(1, Ordering::Relaxed);
                    self.scheduler.requeue_panicked(txn, incarnation);
                    return None;
                }
                Ok(Ok(())) => {
                    let wrote_new = self.mv.record(version, view.reads, &view.writes);
                    if let Some(t0) = t0 {
                        self.counters.txn_lat.record_duration(t0.elapsed());
                    }
                    return self.scheduler.finish_execution(txn, incarnation, wrote_new);
                }
                Ok(Err(_)) => {
                    if view.blocked_on_prev {
                        let park = self.park.as_ref().expect(
                            "cross-block base read outside a pipelined run",
                        );
                        self.counters.dependencies.fetch_add(1, Ordering::Relaxed);
                        if park.park(txn, self.scheduler) {
                            // Parked; the promotion path re-readies it
                            // with the next incarnation number.
                            return None;
                        }
                        // The previous block completed in the window
                        // between our read and now: re-run in place.
                        continue;
                    }
                    let blocking = view.blocked_on.expect(
                        "batch transaction bodies must be infallible apart from \
                         ESTIMATE dependencies raised by the view itself",
                    );
                    self.counters.dependencies.fetch_add(1, Ordering::Relaxed);
                    if self.scheduler.add_dependency(txn, blocking) {
                        // Suspended; a later finish_execution re-readies
                        // it with the next incarnation number.
                        return None;
                    }
                    // The blocking transaction finished in the window
                    // between our read and now: just re-run in place.
                }
            }
        }
    }

    fn try_validate(&self, version: Version) -> Option<Task> {
        let (txn, incarnation) = version;
        self.counters.validations.fetch_add(1, Ordering::Relaxed);
        // The base resolver dispatch is hoisted out of the per-read
        // loop: each arm hands `validate_read_set` a concrete closure,
        // so the walk monomorphizes per source instead of paying a
        // virtual call per read — and the heap fast path is a single
        // inlined acquire load.
        let mut valid = match &self.base {
            BaseSource::Heap => self
                .mv
                .validate_read_set(txn, |addr: Addr| Some(self.heap.load_acquire(addr))),
            chain => self
                .mv
                .validate_read_set(txn, |addr: Addr| chain.value(self.heap, addr)),
        };
        // Fault plane (`--faults validation_fail=P`): force a passing
        // validation to fail. The abort flows through the genuine
        // convert-to-ESTIMATES + re-incarnate path, so the final state
        // is untouched — only extra (priced) work is induced.
        if valid && crate::fault::inject(crate::fault::Site::ValidationFail) {
            valid = false;
        }
        let aborted = !valid && self.scheduler.try_validation_abort(txn, incarnation);
        if aborted {
            self.counters.validation_aborts.fetch_add(1, Ordering::Relaxed);
            self.mv.convert_writes_to_estimates(txn);
        }
        self.scheduler.finish_validation(txn, aborted)
    }
}
