//! The Block-STM collaborative scheduler, on the shared work-stealing
//! worker runtime.
//!
//! Two logical task streams — execution and validation — are still
//! anchored by two atomic counters over the batch's transaction
//! indices, but workers no longer fight over the counters one index at
//! a time. Each worker owns a [`StealDeque`] of *candidates*; when it
//! runs dry it refills a whole chunk of indices from whichever stream
//! is further behind (one `fetch_add` per [`REFILL_CHUNK`] candidates
//! instead of one per task), and when both streams are drained it
//! steals candidates from its peers' deques — **same-locality-group
//! peers first** ([`Scheduler::with_groups`] carries the topology the
//! worker runtime's `PinPlan` detected, so candidate chunks migrate
//! within an L3/socket domain before any cross-socket steal; the
//! local/remote split is reported through [`Scheduler::local_steals`]).
//! A transaction's lifecycle is tracked per index:
//!
//! ```text
//! ReadyToExecute --try_incarnate--> Executing --finish_execution--> Executed
//!       ^                              |                               |
//!       | set_ready (incarnation+1)    | add_dependency (ESTIMATE      | try_validation_abort
//!       |                              v  read: suspend on lower txn)  v
//!       +---------------------------- Aborting <-----------------------+
//! ```
//!
//! A buffered candidate is only a *hint*: the claim happens at pop/steal
//! time (`try_incarnate` CAS for executions, an `Executed` status load
//! for validations), so duplicated or stale candidates — e.g. re-added
//! by a counter decrease while an older copy still sits in a deque —
//! resolve to at most one claim. Every buffered candidate is counted in
//! `num_active` *before* its stream counter advances (the same order
//! the old per-index dispatch used), so the done-check can never
//! observe "counters past `n` and nobody active" while claimable work
//! is still parked in a deque.
//!
//! The lifecycle lives in one **packed atomic status word per
//! transaction** — `incarnation << 2 | state` in an `AtomicU64`, every
//! transition a single store or CAS — so claiming an execution,
//! publishing `Executed`, and winning a validation abort never take a
//! lock. The only mutex left is the per-transaction *dependency list*
//! (the rare ESTIMATE-suspension path): `finish_execution` publishes
//! `Executed` *before* draining the list while `add_dependency`
//! re-checks the status word under the list lock, which closes the
//! lost-wakeup window.
//!
//! The counters only ever move *down* through `fetch_min` when work is
//! invalidated (a lower transaction re-executed or aborted), and a
//! `decrease_cnt` generation counter makes the done-check safe against
//! racing decreases — the same protocol as the Block-STM paper's
//! Algorithm 4.
//!
//! Cross-block pipelining (`BatchSystem::run_pipelined`) adds three
//! hooks: [`Scheduler::suspend_external`] parks an executing
//! transaction on the *previous block* (its ESTIMATE lives in the
//! predecessor's store, not this one), [`Scheduler::resume_external`]
//! re-readies the parked set once the predecessor completes, and
//! [`Scheduler::reopen_validation`] forces a full revalidation pass —
//! the step that makes speculative reads taken while the predecessor
//! was still draining safe to commit.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::runtime::workers::{steal_from_peers, StealDeque};

/// Index of a transaction inside one batch.
pub type TxnIdx = usize;

/// How many times a transaction has been (re-)executed.
pub type Incarnation = u32;

/// One executable unit: `(transaction index, incarnation)`.
pub type Version = (TxnIdx, Incarnation);

/// What a worker should do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Run the transaction body speculatively and record its effects.
    Execution(Version),
    /// Re-read the recorded read set and compare observed versions.
    Validation(Version),
}

/// Candidates refilled per stream grab (one counter `fetch_add` covers
/// this many tasks). The per-worker deques are sized to hold exactly
/// one chunk — refills only happen into an empty deque.
pub const REFILL_CHUNK: usize = 8;

// Candidate encoding in the deques: `idx << 1 | kind`.
const CAND_EXECUTION: u64 = 0;
const CAND_VALIDATION: u64 = 1;

#[inline]
fn pack_candidate(idx: TxnIdx, kind: u64) -> u64 {
    ((idx as u64) << 1) | kind
}

#[inline]
fn candidate_idx(c: u64) -> TxnIdx {
    (c >> 1) as TxnIdx
}

#[inline]
fn candidate_kind(c: u64) -> u64 {
    c & 1
}

// Status-word state encoding (low two bits).
const ST_READY: u64 = 0;
const ST_EXECUTING: u64 = 1;
const ST_EXECUTED: u64 = 2;
const ST_ABORTING: u64 = 3;
const ST_MASK: u64 = 3;

#[inline]
fn pack(incarnation: Incarnation, state: u64) -> u64 {
    ((incarnation as u64) << 2) | state
}

#[inline]
fn state_of(word: u64) -> u64 {
    word & ST_MASK
}

#[inline]
fn incarnation_of(word: u64) -> Incarnation {
    (word >> 2) as Incarnation
}

/// One transaction's packed `incarnation << 2 | state` word, padded to
/// a cache line so neighbouring transactions' CAS traffic doesn't
/// false-share.
#[repr(align(64))]
struct StatusWord(AtomicU64);

/// Shared scheduler state for one batch run.
pub struct Scheduler {
    n: usize,
    execution_idx: AtomicUsize,
    validation_idx: AtomicUsize,
    /// Bumped on every counter decrease; lets `check_done` detect a
    /// decrease racing its reads of the two indices.
    decrease_cnt: AtomicUsize,
    num_active: AtomicUsize,
    /// Done marker with a reopen generation: `generation << 1 |
    /// done_bit`. `check_done` publishes done via a CAS against the
    /// word it observed *before* checking the counters, so a
    /// `reopen_validation` (which bumps the generation) between the
    /// check and the store fails the CAS instead of being silently
    /// overwritten by a stale "done" — the race that would let a
    /// cross-block promotion's forced revalidation be skipped.
    done_word: AtomicU64,
    /// Packed per-transaction lifecycle words (see module docs).
    status: Box<[StatusWord]>,
    /// Transactions suspended waiting on each index (cold path: only
    /// the ESTIMATE-dependency protocol touches these locks).
    deps: Box<[Mutex<Vec<TxnIdx>>]>,
    /// Per-worker candidate deques (worker `w` owns `deques[w]`; any
    /// worker may steal from any other).
    deques: Box<[StealDeque]>,
    /// Locality-group id per worker (from the pool's `PinPlan`; all
    /// zero under the flat fallback): the steal scan drains same-group
    /// peers before crossing sockets.
    groups: Box<[usize]>,
    /// Candidates taken from a peer's deque.
    steal_cnt: AtomicU64,
    /// The subset of `steal_cnt` whose victim shared the thief's
    /// locality group.
    local_steal_cnt: AtomicU64,
    /// Dependents whose resume wakeup was dropped by the fault plane
    /// (`--faults wakeup_drop=P`). Each victim keeps one `num_active`
    /// count held, so `check_done` can never declare the batch done
    /// with work silently lost — an induced lost wakeup is a
    /// *recoverable stall*, never a wrong answer. The watchdog's
    /// recovery pass drains this via [`Scheduler::recover_lost`].
    lost: Mutex<Vec<TxnIdx>>,
    /// Wakeups dropped so far (monotone; survives recovery).
    lost_total: AtomicU64,
    /// Per-transaction quarantine counts: how many times this
    /// transaction's body panicked and was re-dispatched. Bounds the
    /// requeue loop (`fault::MAX_REQUEUE`) and suppresses further
    /// *injected* panics past `fault::MAX_INJECT_PER_TXN`.
    quarantines: Box<[AtomicU32]>,
    /// Latched by [`Scheduler::halt`], separate from the done bit so a
    /// concurrent [`Scheduler::reopen_validation`] (e.g. a watchdog
    /// kick racing a panic) can never resurrect a halted scheduler and
    /// strand workers on it.
    halted: AtomicBool,
}

impl Scheduler {
    /// Scheduler for a batch of `n` transactions driven by `workers`
    /// pool workers (worker indices `0..workers` passed to
    /// [`Scheduler::next_task`]) with a flat (single-group) topology.
    pub fn new(n: usize, workers: usize) -> Self {
        Self::with_groups(n, workers, &[])
    }

    /// [`Scheduler::new`] with the pool's locality-group layout:
    /// `groups[w]` is worker `w`'s socket/L3 group (missing entries
    /// default to group 0, so a short or empty slice is the flat
    /// topology).
    pub fn with_groups(n: usize, workers: usize, groups: &[usize]) -> Self {
        let workers = workers.max(1);
        Self {
            n,
            execution_idx: AtomicUsize::new(0),
            validation_idx: AtomicUsize::new(0),
            decrease_cnt: AtomicUsize::new(0),
            num_active: AtomicUsize::new(0),
            done_word: AtomicU64::new(if n == 0 { 1 } else { 0 }),
            status: (0..n)
                .map(|_| StatusWord(AtomicU64::new(pack(0, ST_READY))))
                .collect(),
            deps: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
            deques: (0..workers).map(|_| StealDeque::new(REFILL_CHUNK)).collect(),
            groups: (0..workers)
                .map(|w| groups.get(w).copied().unwrap_or(0))
                .collect(),
            steal_cnt: AtomicU64::new(0),
            local_steal_cnt: AtomicU64::new(0),
            lost: Mutex::new(Vec::new()),
            lost_total: AtomicU64::new(0),
            quarantines: (0..n).map(|_| AtomicU32::new(0)).collect(),
            halted: AtomicBool::new(false),
        }
    }

    /// Has every transaction been executed and validated (or has the
    /// scheduler been halted)?
    #[inline]
    pub fn done(&self) -> bool {
        self.done_word.load(Ordering::SeqCst) & 1 == 1 || self.halted.load(Ordering::SeqCst)
    }

    /// Candidates taken from a peer's deque so far.
    pub fn steals(&self) -> u64 {
        self.steal_cnt.load(Ordering::SeqCst)
    }

    /// The subset of [`Scheduler::steals`] served by a same-group peer
    /// (equals `steals()` under the flat topology).
    pub fn local_steals(&self) -> u64 {
        self.local_steal_cnt.load(Ordering::SeqCst)
    }

    /// Has the execution stream handed out every index at least once?
    /// (A decrease can drag it back down; this is the admission
    /// heuristic cross-block pipelining gates on, not a completion
    /// proof — completion is [`Scheduler::done`].)
    #[inline]
    pub fn execution_drained(&self) -> bool {
        self.execution_idx.load(Ordering::SeqCst) >= self.n
    }

    /// Execution-stream indices not yet handed out to any worker
    /// (claimed-but-unfinished work is *not* counted). With
    /// [`Scheduler::validation_backlog`], the watchdog's kick
    /// diagnosis: a flat-progress block with zero backlog on both
    /// streams has every remaining task claimed by a stalled worker —
    /// in a serving session, the stall that freezes the snapshot
    /// horizon.
    pub fn execution_backlog(&self) -> usize {
        self.n
            .saturating_sub(self.execution_idx.load(Ordering::SeqCst))
    }

    /// Validation-stream indices not yet handed out to any worker.
    pub fn validation_backlog(&self) -> usize {
        self.n
            .saturating_sub(self.validation_idx.load(Ordering::SeqCst))
    }

    /// Emergency stop: flips the done marker so every worker drops out
    /// of its polling loop. Used by the panic guard in
    /// `BatchSystem::run` — one panicking worker (e.g. a transaction
    /// body violating the infallibility contract) must not strand its
    /// peers spinning forever on a `num_active` count that can no
    /// longer reach zero.
    pub fn halt(&self) {
        // The dedicated latch (not just the done bit): `reopen_validation`
        // rebuilds the done word without its low bit, so a watchdog kick
        // racing the halt could otherwise clear the emergency stop.
        self.halted.store(true, Ordering::SeqCst);
        self.done_word.fetch_or(1, Ordering::SeqCst);
    }

    fn decrease_execution_idx(&self, t: TxnIdx) {
        self.execution_idx.fetch_min(t, Ordering::SeqCst);
        self.decrease_cnt.fetch_add(1, Ordering::SeqCst);
    }

    fn decrease_validation_idx(&self, t: TxnIdx) {
        self.validation_idx.fetch_min(t, Ordering::SeqCst);
        self.decrease_cnt.fetch_add(1, Ordering::SeqCst);
    }

    fn check_done(&self) {
        // Snapshot the done word FIRST: the publishing CAS below then
        // fails if a reopen_validation bumped the generation anywhere
        // between this read and the store — a plain store here could
        // land arbitrarily late and clobber the reopen.
        let w0 = self.done_word.load(Ordering::SeqCst);
        if w0 & 1 == 1 {
            return;
        }
        let observed = self.decrease_cnt.load(Ordering::SeqCst);
        if self.execution_idx.load(Ordering::SeqCst) >= self.n
            && self.validation_idx.load(Ordering::SeqCst) >= self.n
            && self.num_active.load(Ordering::SeqCst) == 0
            && observed == self.decrease_cnt.load(Ordering::SeqCst)
        {
            // A failed CAS means a racing reopen (or another checker's
            // done): either way, dropping this verdict is correct.
            let _ = self.done_word.compare_exchange(
                w0,
                w0 | 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            );
        }
    }

    fn try_incarnate(&self, t: TxnIdx) -> Option<Version> {
        let s = &self.status[t].0;
        let mut cur = s.load(Ordering::SeqCst);
        while state_of(cur) == ST_READY {
            let inc = incarnation_of(cur);
            match s.compare_exchange_weak(
                cur,
                pack(inc, ST_EXECUTING),
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return Some((t, inc)),
                Err(now) => cur = now,
            }
        }
        None
    }

    /// Grab up to [`REFILL_CHUNK`] indices from `counter` into worker
    /// `w`'s deque. Candidates are pushed highest-first so the owner's
    /// LIFO pop hands them out in ascending index order (stealers take
    /// the top — the highest index — which is exactly the work most
    /// likely to still be claimable).
    fn refill_stream(&self, counter: &AtomicUsize, w: usize, kind: u64) -> bool {
        // Count the chunk active BEFORE advancing the stream counter:
        // the done-check must never observe "counters past n, nobody
        // active" while claimable candidates sit in a deque.
        self.num_active.fetch_add(REFILL_CHUNK, Ordering::SeqCst);
        let base = counter.fetch_add(REFILL_CHUNK, Ordering::SeqCst);
        if base >= self.n {
            self.num_active.fetch_sub(REFILL_CHUNK, Ordering::SeqCst);
            return false;
        }
        let take = REFILL_CHUNK.min(self.n - base);
        if take < REFILL_CHUNK {
            self.num_active
                .fetch_sub(REFILL_CHUNK - take, Ordering::SeqCst);
        }
        for i in (base..base + take).rev() {
            let pushed = self.deques[w].push(pack_candidate(i, kind));
            debug_assert!(pushed, "refill must target an empty deque");
        }
        true
    }

    /// Refill worker `w`'s deque from whichever stream is further
    /// behind, preferring validations (they are cheap and unblock the
    /// commit prefix).
    fn refill(&self, w: usize) -> bool {
        let vi = self.validation_idx.load(Ordering::SeqCst);
        let ei = self.execution_idx.load(Ordering::SeqCst);
        if vi < self.n && vi < ei {
            if self.refill_stream(&self.validation_idx, w, CAND_VALIDATION) {
                return true;
            }
        }
        if ei < self.n && self.refill_stream(&self.execution_idx, w, CAND_EXECUTION) {
            return true;
        }
        if vi < self.n && self.refill_stream(&self.validation_idx, w, CAND_VALIDATION) {
            return true;
        }
        false
    }

    /// Claim a buffered candidate, releasing its `num_active` count if
    /// the claim fails (someone else already ran or invalidated it).
    fn resolve(&self, c: u64) -> Option<Task> {
        let idx = candidate_idx(c);
        if candidate_kind(c) == CAND_EXECUTION {
            if let Some(v) = self.try_incarnate(idx) {
                return Some(Task::Execution(v));
            }
        } else {
            // One atomic load snapshots (state, incarnation) together.
            let word = self.status[idx].0.load(Ordering::SeqCst);
            if state_of(word) == ST_EXECUTED {
                return Some(Task::Validation((idx, incarnation_of(word))));
            }
        }
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        None
    }

    /// Pull the next task for pool worker `w`: drain the worker's own
    /// deque, refill it from the lagging stream, steal from peers.
    /// Returns `None` when no task is claimable *right now* (the
    /// caller re-polls until [`Scheduler::done`]).
    pub fn next_task(&self, w: usize) -> Option<Task> {
        loop {
            if self.done() {
                return None;
            }
            if let Some(c) = self.deques[w].pop() {
                match self.resolve(c) {
                    Some(t) => return Some(t),
                    None => continue,
                }
            }
            if self.refill(w) {
                continue;
            }
            if let Some(c) = steal_from_peers(
                &self.deques,
                w,
                &self.groups,
                &self.steal_cnt,
                &self.local_steal_cnt,
            ) {
                match self.resolve(c) {
                    Some(t) => return Some(t),
                    None => continue,
                }
            }
            // No buffered, refillable, or stealable work: workers that
            // reach this point hold no active count, so the done-check
            // can observe num_active == 0.
            self.check_done();
            return None;
        }
    }

    /// The executing `txn` read an ESTIMATE written by `blocking`
    /// (always a lower index): suspend it until `blocking` finishes.
    /// Returns `false` when `blocking` already finished — the caller
    /// should simply re-execute instead of suspending.
    pub fn add_dependency(&self, txn: TxnIdx, blocking: TxnIdx) -> bool {
        debug_assert!(blocking < txn, "dependencies only point down");
        // The Executed re-check under the deps lock pairs with
        // finish_execution's store-Executed-then-drain order: either we
        // see Executed here (and re-execute in place), or our push is
        // visible to the drain. No lost wakeup.
        let mut deps = self.deps[blocking].lock().unwrap();
        if state_of(self.status[blocking].0.load(Ordering::SeqCst)) == ST_EXECUTED {
            return false;
        }
        let s = &self.status[txn].0;
        let cur = s.load(Ordering::SeqCst);
        debug_assert_eq!(state_of(cur), ST_EXECUTING);
        // Only the executing owner transitions out of Executing: a
        // plain store suffices.
        s.store(pack(incarnation_of(cur), ST_ABORTING), Ordering::SeqCst);
        deps.push(txn);
        drop(deps);
        // The execution task halts here; the dependency resume path
        // re-dispatches it.
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        true
    }

    /// Cross-block suspension: `txn` (currently Executing) read an
    /// ESTIMATE from the *previous block's* store. The caller holds the
    /// park-list lock that serializes against
    /// [`Scheduler::resume_external`], so the suspend cannot race the
    /// resume.
    pub(crate) fn suspend_external(&self, txn: TxnIdx) {
        let s = &self.status[txn].0;
        let cur = s.load(Ordering::SeqCst);
        debug_assert_eq!(state_of(cur), ST_EXECUTING);
        s.store(pack(incarnation_of(cur), ST_ABORTING), Ordering::SeqCst);
        self.num_active.fetch_sub(1, Ordering::SeqCst);
    }

    /// Re-ready a batch of externally suspended transactions (the
    /// previous block completed) and drag the execution stream back to
    /// the lowest of them.
    pub(crate) fn resume_external(&self, txns: &[TxnIdx]) {
        if let Some(&min_t) = txns.iter().min() {
            for &t in txns {
                self.set_ready(t);
            }
            self.decrease_execution_idx(min_t);
        }
    }

    /// Force a full revalidation pass: every transaction revalidates
    /// against the now-final base state (the cross-block promotion
    /// step; single caller, serialized under the session's window
    /// lock). Drags the validation stream to 0 *first*, then bumps the
    /// done word's reopen generation and clears its done bit — any
    /// in-flight `check_done` that based its verdict on the old
    /// generation now fails its publishing CAS instead of resurrecting
    /// a stale done.
    pub(crate) fn reopen_validation(&self) {
        self.decrease_validation_idx(0);
        let w = self.done_word.load(Ordering::SeqCst);
        self.done_word
            .store(((w >> 1) + 1) << 1, Ordering::SeqCst);
    }

    fn set_ready(&self, t: TxnIdx) {
        let s = &self.status[t].0;
        let cur = s.load(Ordering::SeqCst);
        debug_assert_eq!(state_of(cur), ST_ABORTING);
        // Single resumer (the abort claimant or the dependency
        // drainer): store the bumped incarnation. Every re-incarnation
        // — validation abort, dependency resume, cross-block resume —
        // funnels through here, so this is the trace event site.
        let next = incarnation_of(cur) + 1;
        s.store(pack(next, ST_READY), Ordering::SeqCst);
        crate::obs::trace::reincarnation(t as u64, next as u64);
    }

    /// Incarnation `(txn, incarnation)` finished executing and its
    /// effects are recorded. Resumes suspended dependents and decides
    /// what (if anything) to validate next. Returns a follow-up task
    /// for the same worker, or `None` (task complete).
    pub fn finish_execution(
        &self,
        txn: TxnIdx,
        incarnation: Incarnation,
        wrote_new_location: bool,
    ) -> Option<Task> {
        let s = &self.status[txn].0;
        debug_assert_eq!(s.load(Ordering::SeqCst), pack(incarnation, ST_EXECUTING));
        // Publish Executed BEFORE draining the dependency list: a
        // racing add_dependency either observes it (and re-executes in
        // place) or lands its push where the drain below collects it.
        s.store(pack(incarnation, ST_EXECUTED), Ordering::SeqCst);
        let deps = std::mem::take(&mut *self.deps[txn].lock().unwrap());
        if !deps.is_empty() {
            // Fault plane (`--faults wakeup_drop=P`): this drain is
            // exactly the window the store-Executed-before-drain
            // protocol exists to close, so it is where an induced lost
            // wakeup strikes. A dropped dependent stays parked in
            // Aborting; `record_lost` keeps its active count held (the
            // batch stalls instead of finishing without it) until the
            // watchdog re-readies it via `recover_lost`.
            let mut min_dep = usize::MAX;
            let mut dropped: Vec<TxnIdx> = Vec::new();
            for &d in &deps {
                if crate::fault::inject(crate::fault::Site::WakeupDrop) {
                    dropped.push(d);
                } else {
                    self.set_ready(d);
                    min_dep = min_dep.min(d);
                }
            }
            if min_dep != usize::MAX {
                self.decrease_execution_idx(min_dep);
            }
            if !dropped.is_empty() {
                self.record_lost(dropped);
            }
        }
        if self.validation_idx.load(Ordering::SeqCst) > txn {
            if wrote_new_location {
                // Writes appeared at fresh addresses: everything at or
                // above this index must revalidate.
                self.decrease_validation_idx(txn);
            } else {
                // Same write footprint as before: only this transaction
                // needs validating, and this worker does it in place.
                return Some(Task::Validation((txn, incarnation)));
            }
        }
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        None
    }

    /// Park `dropped` (already in Aborting with their active counts
    /// released) as lost-wakeup victims: re-hold one active count per
    /// victim — the caller still holds its own count, so the done
    /// check cannot slip through between the drop and this hold —
    /// and record them for [`Scheduler::recover_lost`].
    fn record_lost(&self, dropped: Vec<TxnIdx>) {
        self.num_active.fetch_add(dropped.len(), Ordering::SeqCst);
        self.lost_total
            .fetch_add(dropped.len() as u64, Ordering::SeqCst);
        self.lost.lock().unwrap().extend(dropped);
    }

    /// Re-ready every recorded lost-wakeup victim and drag the
    /// execution stream back to the lowest of them. Returns how many
    /// were recovered. Called by the watchdog's recovery pass; safe to
    /// call concurrently with running workers (the re-ready before the
    /// active-count release keeps the done check conservative).
    pub fn recover_lost(&self) -> usize {
        let lost = std::mem::take(&mut *self.lost.lock().unwrap());
        if lost.is_empty() {
            return 0;
        }
        let mut min_t = usize::MAX;
        for &t in &lost {
            self.set_ready(t);
            min_t = min_t.min(t);
        }
        self.decrease_execution_idx(min_t);
        // Release the held counts only after the stream has been
        // dragged back: a done check in between sees num_active > 0.
        self.num_active.fetch_sub(lost.len(), Ordering::SeqCst);
        lost.len()
    }

    /// Wakeups dropped by the fault plane so far (monotone).
    pub fn lost_wakeups(&self) -> u64 {
        self.lost_total.load(Ordering::SeqCst)
    }

    /// Lost-wakeup victims currently awaiting recovery.
    pub fn lost_pending(&self) -> usize {
        self.lost.lock().unwrap().len()
    }

    /// How many times `txn`'s body has panicked and been quarantined.
    pub fn quarantine_count(&self, txn: TxnIdx) -> u32 {
        self.quarantines[txn].load(Ordering::SeqCst)
    }

    /// Quarantine `(txn, incarnation)` after its body panicked
    /// mid-execution: nothing was published (writes only record on a
    /// successful body), so the transaction is simply re-readied with
    /// a bumped incarnation and re-offered to the execution stream.
    /// Returns the transaction's new quarantine count.
    pub fn requeue_panicked(&self, txn: TxnIdx, incarnation: Incarnation) -> u32 {
        let count = self.quarantines[txn].fetch_add(1, Ordering::SeqCst) + 1;
        let s = &self.status[txn].0;
        debug_assert_eq!(s.load(Ordering::SeqCst), pack(incarnation, ST_EXECUTING));
        // The panicking worker still owns the Executing state: a plain
        // store transitions straight to Ready with the next
        // incarnation.
        s.store(pack(incarnation + 1, ST_READY), Ordering::SeqCst);
        crate::obs::trace::quarantine(txn as u64, count as u64);
        self.decrease_execution_idx(txn);
        // Release this dispatch's active count only after the stream
        // was dragged back, mirroring recover_lost.
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        count
    }

    /// Try to claim the abort of `(txn, incarnation)` after a failed
    /// validation — one CAS; only one claimant wins and a loser's stale
    /// verdict is simply dropped.
    pub fn try_validation_abort(&self, txn: TxnIdx, incarnation: Incarnation) -> bool {
        self.status[txn]
            .0
            .compare_exchange(
                pack(incarnation, ST_EXECUTED),
                pack(incarnation, ST_ABORTING),
                Ordering::SeqCst,
                Ordering::SeqCst,
            )
            .is_ok()
    }

    /// Wrap up a validation task. On abort: bump the incarnation,
    /// force higher transactions to revalidate, and hand the
    /// re-execution to this worker when possible.
    pub fn finish_validation(&self, txn: TxnIdx, aborted: bool) -> Option<Task> {
        if aborted {
            self.set_ready(txn);
            self.decrease_validation_idx(txn + 1);
            if self.execution_idx.load(Ordering::SeqCst) > txn {
                if let Some(v) = self.try_incarnate(txn) {
                    return Some(Task::Execution(v));
                }
            }
        }
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_batch_is_done_immediately() {
        let s = Scheduler::new(0, 1);
        assert!(s.done());
        assert_eq!(s.next_task(0), None);
    }

    #[test]
    fn single_txn_execute_then_validate_then_done() {
        let s = Scheduler::new(1, 1);
        let t = s.next_task(0).unwrap();
        assert_eq!(t, Task::Execution((0, 0)));
        // First incarnation wrote new locations but nothing is above
        // it; validation_idx == 0 is not > 0, so no inline validation.
        assert_eq!(s.finish_execution(0, 0, true), None);
        let t = s.next_task(0).unwrap();
        assert_eq!(t, Task::Validation((0, 0)));
        assert_eq!(s.finish_validation(0, false), None);
        // Drain the counters past n; the done marker flips.
        for _ in 0..4 {
            if s.next_task(0).is_some() {
                panic!("no tasks should remain");
            }
            if s.done() {
                return;
            }
        }
        panic!("scheduler never reached done");
    }

    #[test]
    fn chunked_refill_hands_out_ascending_executions() {
        // One refill buffers the whole batch; the owner's pop order is
        // ascending index (candidates are pushed highest-first).
        let s = Scheduler::new(3, 1);
        assert_eq!(s.next_task(0), Some(Task::Execution((0, 0))));
        assert_eq!(s.next_task(0), Some(Task::Execution((1, 0))));
        assert_eq!(s.next_task(0), Some(Task::Execution((2, 0))));
    }

    #[test]
    fn validation_abort_reincarnates() {
        let s = Scheduler::new(2, 1);
        assert_eq!(s.next_task(0), Some(Task::Execution((0, 0))));
        // The refill buffered txn 1's execution candidate too.
        assert_eq!(s.next_task(0), Some(Task::Execution((1, 0))));
        assert_eq!(s.finish_execution(0, 0, true), None);
        assert_eq!(s.finish_execution(1, 0, true), None);
        // Validate 0 fine, abort 1.
        assert_eq!(s.next_task(0), Some(Task::Validation((0, 0))));
        assert_eq!(s.finish_validation(0, false), None);
        assert_eq!(s.next_task(0), Some(Task::Validation((1, 0))));
        assert!(s.try_validation_abort(1, 0));
        // Second claimant loses.
        assert!(!s.try_validation_abort(1, 0));
        let t = s.finish_validation(1, true);
        assert_eq!(t, Some(Task::Execution((1, 1))), "re-incarnated in place");
        assert_eq!(s.finish_execution(1, 1, false), Some(Task::Validation((1, 1))));
        assert_eq!(s.finish_validation(1, false), None);
        while !s.done() {
            assert_eq!(s.next_task(0), None);
        }
    }

    #[test]
    fn dependency_suspends_and_resumes() {
        let s = Scheduler::new(2, 1);
        assert_eq!(s.next_task(0), Some(Task::Execution((0, 0))));
        assert_eq!(s.next_task(0), Some(Task::Execution((1, 0))));
        // txn 1 reads an ESTIMATE from txn 0: suspend.
        assert!(s.add_dependency(1, 0));
        // txn 0 finishing must resume txn 1 with incarnation 1.
        assert_eq!(s.finish_execution(0, 0, true), None);
        let mut saw_exec1 = false;
        for _ in 0..16 {
            match s.next_task(0) {
                Some(Task::Execution((1, 1))) => {
                    saw_exec1 = true;
                    break;
                }
                Some(Task::Validation((0, 0))) => {
                    s.finish_validation(0, false);
                }
                Some(other) => panic!("unexpected task {other:?}"),
                None => {}
            }
        }
        assert!(saw_exec1, "suspended txn was never re-dispatched");
    }

    #[test]
    fn add_dependency_fails_after_blocking_executed() {
        let s = Scheduler::new(2, 1);
        assert_eq!(s.next_task(0), Some(Task::Execution((0, 0))));
        assert_eq!(s.next_task(0), Some(Task::Execution((1, 0))));
        assert_eq!(s.finish_execution(0, 0, true), None);
        assert!(!s.add_dependency(1, 0), "blocking txn already executed");
    }

    #[test]
    fn idle_worker_steals_buffered_candidates() {
        // Worker 0's refill buffers both execution candidates but only
        // claims the first; worker 1 finds its own streams drained and
        // must steal the second from worker 0's deque.
        let s = Scheduler::new(2, 2);
        assert_eq!(s.next_task(0), Some(Task::Execution((0, 0))));
        assert_eq!(s.next_task(1), Some(Task::Execution((1, 0))));
        assert_eq!(s.steals(), 1, "worker 1's task came from worker 0's deque");
        assert_eq!(s.local_steals(), 1, "flat topology: every steal is local");
    }

    #[test]
    fn grouped_scheduler_counts_same_group_steals_as_local() {
        // Workers 0 and 1 share a locality group: worker 0's refill
        // buffers both candidates, worker 1's steal is in-group.
        let s = Scheduler::with_groups(2, 3, &[0, 0, 1]);
        assert_eq!(s.next_task(0), Some(Task::Execution((0, 0))));
        assert_eq!(s.next_task(1), Some(Task::Execution((1, 0))));
        assert_eq!((s.steals(), s.local_steals()), (1, 1));
    }

    #[test]
    fn grouped_scheduler_crosses_groups_only_when_local_is_dry() {
        // Worker 1 sits alone against group 0: its steal must still
        // succeed, but be accounted as remote.
        let s = Scheduler::with_groups(2, 2, &[0, 1]);
        assert_eq!(s.next_task(0), Some(Task::Execution((0, 0))));
        assert_eq!(s.next_task(1), Some(Task::Execution((1, 0))));
        assert_eq!(s.steals(), 1);
        assert_eq!(s.local_steals(), 0, "cross-group steal is not local");
    }

    #[test]
    fn suspend_and_resume_external_round_trip() {
        // The cross-block parking path: an executing txn suspends on
        // the previous block, then resumes with a bumped incarnation.
        let s = Scheduler::new(2, 1);
        assert_eq!(s.next_task(0), Some(Task::Execution((0, 0))));
        assert_eq!(s.next_task(0), Some(Task::Execution((1, 0))));
        s.suspend_external(1);
        assert_eq!(s.finish_execution(0, 0, true), None);
        s.resume_external(&[1]);
        let mut saw = false;
        for _ in 0..16 {
            match s.next_task(0) {
                Some(Task::Execution((1, 1))) => {
                    saw = true;
                    break;
                }
                Some(Task::Validation((0, 0))) => {
                    s.finish_validation(0, false);
                }
                Some(other) => panic!("unexpected task {other:?}"),
                None => {}
            }
        }
        assert!(saw, "externally parked txn was never re-dispatched");
    }

    #[test]
    fn reopen_validation_revalidates_a_done_scheduler() {
        // Drive a 1-txn batch to done, then reopen: the validation
        // stream must hand the transaction out again.
        let s = Scheduler::new(1, 1);
        assert_eq!(s.next_task(0), Some(Task::Execution((0, 0))));
        assert_eq!(s.finish_execution(0, 0, true), None);
        assert_eq!(s.next_task(0), Some(Task::Validation((0, 0))));
        assert_eq!(s.finish_validation(0, false), None);
        while !s.done() {
            assert_eq!(s.next_task(0), None);
        }
        s.reopen_validation();
        assert!(!s.done());
        assert_eq!(s.next_task(0), Some(Task::Validation((0, 0))));
        assert_eq!(s.finish_validation(0, false), None);
        while !s.done() {
            assert_eq!(s.next_task(0), None);
        }
    }

    #[test]
    fn lost_wakeup_holds_done_open_until_recovered() {
        // The store-Executed-before-drain window, with the wakeup
        // dropped: emulate exactly what the `wakeup_drop` injector does
        // inside finish_execution's drain (this binary never installs
        // the global fault plane — see fault::tests), then prove the
        // scheduler stalls instead of completing without the victim,
        // and that recover_lost drives it to a correct finish.
        let s = Scheduler::new(2, 1);
        assert_eq!(s.next_task(0), Some(Task::Execution((0, 0))));
        assert_eq!(s.next_task(0), Some(Task::Execution((1, 0))));
        // txn 1 parks on txn 0's ESTIMATE.
        assert!(s.add_dependency(1, 0));
        // Drop the wakeup: steal the dependency list before txn 0's
        // finish can drain it, and record the victim the way the
        // injection site does.
        let stolen = std::mem::take(&mut *s.deps[0].lock().unwrap());
        assert_eq!(stolen, vec![1]);
        s.record_lost(stolen);
        assert_eq!(s.lost_pending(), 1);
        assert_eq!(s.finish_execution(0, 0, true), None);
        // Drain everything reachable: txn 0 validates, txn 1 is lost.
        for _ in 0..64 {
            match s.next_task(0) {
                Some(Task::Validation((0, 0))) => {
                    s.finish_validation(0, false);
                }
                Some(other) => panic!("unexpected task {other:?}"),
                None => {}
            }
        }
        assert!(
            !s.done(),
            "a dropped wakeup must stall the batch, never complete it"
        );
        // The watchdog's recovery pass.
        assert_eq!(s.recover_lost(), 1);
        assert_eq!(s.lost_pending(), 0);
        let t = loop {
            if let Some(t) = s.next_task(0) {
                break t;
            }
        };
        assert_eq!(t, Task::Execution((1, 1)), "victim re-readied, bumped");
        assert_eq!(s.finish_execution(1, 1, true), None);
        for _ in 0..64 {
            if s.done() {
                break;
            }
            if let Some(Task::Validation((1, 1))) = s.next_task(0) {
                s.finish_validation(1, false);
            }
        }
        assert!(s.done(), "recovery must drive the batch to done");
    }

    #[test]
    fn requeue_panicked_reincarnates_without_publishing() {
        let s = Scheduler::new(2, 1);
        assert_eq!(s.next_task(0), Some(Task::Execution((0, 0))));
        assert_eq!(s.quarantine_count(0), 0);
        // txn 0's body "panicked": quarantine it.
        assert_eq!(s.requeue_panicked(0, 0), 1);
        assert_eq!(s.quarantine_count(0), 1);
        // It comes back as incarnation 1 and the batch still completes.
        let mut saw = false;
        for _ in 0..64 {
            match s.next_task(0) {
                Some(Task::Execution((0, 1))) => {
                    saw = true;
                    s.finish_execution(0, 1, true);
                }
                Some(Task::Execution((1, 0))) => {
                    s.finish_execution(1, 0, true);
                }
                Some(Task::Validation((t, inc))) => {
                    s.finish_validation(t, false);
                    let _ = inc;
                }
                None => {
                    if s.done() {
                        break;
                    }
                }
            }
        }
        assert!(saw, "quarantined txn must re-dispatch as incarnation 1");
        assert!(s.done());
    }

    #[test]
    fn status_word_packs_incarnation_and_state() {
        for inc in [0u32, 1, 7, u32::MAX] {
            for st in [ST_READY, ST_EXECUTING, ST_EXECUTED, ST_ABORTING] {
                let w = pack(inc, st);
                assert_eq!(state_of(w), st);
                assert_eq!(incarnation_of(w), inc);
            }
        }
    }

    #[test]
    fn concurrent_claims_admit_each_incarnation_once() {
        // Many threads race try_incarnate over a fresh scheduler: each
        // transaction's incarnation 0 must be claimed exactly once.
        let s = Scheduler::new(64, 4);
        let claimed: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for t in 0..64 {
                        if s.try_incarnate(t).is_some() {
                            claimed[t].fetch_add(1, Ordering::SeqCst);
                        }
                    }
                });
            }
        });
        for (t, c) in claimed.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "txn {t} claimed wrong count");
        }
    }

    #[test]
    fn concurrent_workers_drain_a_batch_through_the_deques() {
        // End-to-end scheduler stress without an executor: four threads
        // pull tasks and complete them immediately; every txn must be
        // executed and validated exactly once and the batch must reach
        // done.
        let s = Scheduler::new(128, 4);
        let executed: Vec<AtomicUsize> = (0..128).map(|_| AtomicUsize::new(0)).collect();
        let validated: Vec<AtomicUsize> = (0..128).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|scope| {
            for w in 0..4 {
                let s = &s;
                let executed = &executed;
                let validated = &validated;
                scope.spawn(move || {
                    let mut task = None;
                    loop {
                        task = match task {
                            Some(Task::Execution((t, inc))) => {
                                executed[t].fetch_add(1, Ordering::SeqCst);
                                s.finish_execution(t, inc, false)
                            }
                            Some(Task::Validation((t, _inc))) => {
                                validated[t].fetch_add(1, Ordering::SeqCst);
                                s.finish_validation(t, false)
                            }
                            None => {
                                if s.done() {
                                    return;
                                }
                                std::hint::spin_loop();
                                s.next_task(w)
                            }
                        };
                    }
                });
            }
        });
        for t in 0..128 {
            assert_eq!(executed[t].load(Ordering::SeqCst), 1, "txn {t} exec count");
            assert!(
                validated[t].load(Ordering::SeqCst) >= 1,
                "txn {t} never validated"
            );
        }
        assert!(s.done());
    }
}
