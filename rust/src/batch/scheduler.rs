//! The Block-STM collaborative scheduler.
//!
//! Two logical task streams — execution and validation — are driven by
//! two atomic counters over the batch's transaction indices. Workers
//! pull whichever stream is further behind, preferring validations
//! (they are cheap and unblock the commit prefix). A transaction's
//! lifecycle is tracked per index:
//!
//! ```text
//! ReadyToExecute --try_incarnate--> Executing --finish_execution--> Executed
//!       ^                              |                               |
//!       | set_ready (incarnation+1)    | add_dependency (ESTIMATE      | try_validation_abort
//!       |                              v  read: suspend on lower txn)  v
//!       +---------------------------- Aborting <-----------------------+
//! ```
//!
//! The counters only ever move *down* through `fetch_min` when work is
//! invalidated (a lower transaction re-executed or aborted), and a
//! `decrease_cnt` generation counter makes the done-check safe against
//! racing decreases — the same protocol as the Block-STM paper's
//! Algorithm 4 and the scheduler in the SNIPPETS exemplars.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Index of a transaction inside one batch.
pub type TxnIdx = usize;

/// How many times a transaction has been (re-)executed.
pub type Incarnation = u32;

/// One executable unit: `(transaction index, incarnation)`.
pub type Version = (TxnIdx, Incarnation);

/// What a worker should do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Run the transaction body speculatively and record its effects.
    Execution(Version),
    /// Re-read the recorded read set and compare observed versions.
    Validation(Version),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Status {
    ReadyToExecute,
    Executing,
    Executed,
    Aborting,
}

struct TxnState {
    incarnation: Incarnation,
    status: Status,
    /// Transactions suspended waiting for this one to finish executing.
    deps: Vec<TxnIdx>,
}

/// Shared scheduler state for one batch run.
pub struct Scheduler {
    n: usize,
    execution_idx: AtomicUsize,
    validation_idx: AtomicUsize,
    /// Bumped on every counter decrease; lets `check_done` detect a
    /// decrease racing its reads of the two indices.
    decrease_cnt: AtomicUsize,
    num_active: AtomicUsize,
    done_marker: AtomicBool,
    txns: Vec<Mutex<TxnState>>,
}

impl Scheduler {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            execution_idx: AtomicUsize::new(0),
            validation_idx: AtomicUsize::new(0),
            decrease_cnt: AtomicUsize::new(0),
            num_active: AtomicUsize::new(0),
            done_marker: AtomicBool::new(n == 0),
            txns: (0..n)
                .map(|_| {
                    Mutex::new(TxnState {
                        incarnation: 0,
                        status: Status::ReadyToExecute,
                        deps: Vec::new(),
                    })
                })
                .collect(),
        }
    }

    /// Has every transaction been executed and validated?
    #[inline]
    pub fn done(&self) -> bool {
        self.done_marker.load(Ordering::SeqCst)
    }

    /// Emergency stop: flips the done marker so every worker drops out
    /// of its polling loop. Used by the panic guard in
    /// `BatchSystem::run` — one panicking worker (e.g. a transaction
    /// body violating the infallibility contract) must not strand its
    /// peers spinning forever on a `num_active` count that can no
    /// longer reach zero.
    pub fn halt(&self) {
        self.done_marker.store(true, Ordering::SeqCst);
    }

    fn decrease_execution_idx(&self, t: TxnIdx) {
        self.execution_idx.fetch_min(t, Ordering::SeqCst);
        self.decrease_cnt.fetch_add(1, Ordering::SeqCst);
    }

    fn decrease_validation_idx(&self, t: TxnIdx) {
        self.validation_idx.fetch_min(t, Ordering::SeqCst);
        self.decrease_cnt.fetch_add(1, Ordering::SeqCst);
    }

    fn check_done(&self) {
        let observed = self.decrease_cnt.load(Ordering::SeqCst);
        if self.execution_idx.load(Ordering::SeqCst) >= self.n
            && self.validation_idx.load(Ordering::SeqCst) >= self.n
            && self.num_active.load(Ordering::SeqCst) == 0
            && observed == self.decrease_cnt.load(Ordering::SeqCst)
        {
            self.done_marker.store(true, Ordering::SeqCst);
        }
    }

    fn try_incarnate(&self, t: TxnIdx) -> Option<Version> {
        let mut s = self.txns[t].lock().unwrap();
        if s.status == Status::ReadyToExecute {
            s.status = Status::Executing;
            Some((t, s.incarnation))
        } else {
            None
        }
    }

    fn next_version_to_execute(&self) -> Option<Version> {
        if self.execution_idx.load(Ordering::SeqCst) >= self.n {
            // Counted-active workers never sit in this branch, so the
            // done-check can observe num_active == 0.
            self.check_done();
            return None;
        }
        self.num_active.fetch_add(1, Ordering::SeqCst);
        let idx = self.execution_idx.fetch_add(1, Ordering::SeqCst);
        if idx < self.n {
            if let Some(v) = self.try_incarnate(idx) {
                return Some(v);
            }
        }
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        None
    }

    fn next_version_to_validate(&self) -> Option<Version> {
        if self.validation_idx.load(Ordering::SeqCst) >= self.n {
            self.check_done();
            return None;
        }
        self.num_active.fetch_add(1, Ordering::SeqCst);
        let idx = self.validation_idx.fetch_add(1, Ordering::SeqCst);
        if idx < self.n {
            let s = self.txns[idx].lock().unwrap();
            if s.status == Status::Executed {
                return Some((idx, s.incarnation));
            }
        }
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        None
    }

    /// Pull the next task, preferring the stream that is further
    /// behind. Returns `None` when no task was available *right now*
    /// (the caller re-polls until [`Scheduler::done`]).
    pub fn next_task(&self) -> Option<Task> {
        if self.done() {
            return None;
        }
        if self.validation_idx.load(Ordering::SeqCst)
            < self.execution_idx.load(Ordering::SeqCst)
        {
            self.next_version_to_validate().map(Task::Validation)
        } else {
            self.next_version_to_execute().map(Task::Execution)
        }
    }

    /// The executing `txn` read an ESTIMATE written by `blocking`
    /// (always a lower index): suspend it until `blocking` finishes.
    /// Returns `false` when `blocking` already finished — the caller
    /// should simply re-execute instead of suspending.
    pub fn add_dependency(&self, txn: TxnIdx, blocking: TxnIdx) -> bool {
        debug_assert!(blocking < txn, "dependencies only point down");
        // Locks are taken in ascending index order everywhere, so the
        // (blocking, txn) pair cannot deadlock.
        let mut b = self.txns[blocking].lock().unwrap();
        if b.status == Status::Executed {
            return false;
        }
        {
            let mut t = self.txns[txn].lock().unwrap();
            debug_assert_eq!(t.status, Status::Executing);
            t.status = Status::Aborting;
        }
        b.deps.push(txn);
        drop(b);
        // The execution task halts here; the dependency resume path
        // re-dispatches it.
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        true
    }

    fn set_ready(&self, t: TxnIdx) {
        let mut s = self.txns[t].lock().unwrap();
        debug_assert_eq!(s.status, Status::Aborting);
        s.incarnation += 1;
        s.status = Status::ReadyToExecute;
    }

    /// Incarnation `(txn, incarnation)` finished executing and its
    /// effects are recorded. Resumes suspended dependents and decides
    /// what (if anything) to validate next. Returns a follow-up task
    /// for the same worker, or `None` (task complete).
    pub fn finish_execution(
        &self,
        txn: TxnIdx,
        incarnation: Incarnation,
        wrote_new_location: bool,
    ) -> Option<Task> {
        let deps = {
            let mut s = self.txns[txn].lock().unwrap();
            debug_assert_eq!(s.status, Status::Executing);
            debug_assert_eq!(s.incarnation, incarnation);
            s.status = Status::Executed;
            std::mem::take(&mut s.deps)
        };
        if let Some(&min_dep) = deps.iter().min() {
            for &d in &deps {
                self.set_ready(d);
            }
            self.decrease_execution_idx(min_dep);
        }
        if self.validation_idx.load(Ordering::SeqCst) > txn {
            if wrote_new_location {
                // Writes appeared at fresh addresses: everything at or
                // above this index must revalidate.
                self.decrease_validation_idx(txn);
            } else {
                // Same write footprint as before: only this transaction
                // needs validating, and this worker does it in place.
                return Some(Task::Validation((txn, incarnation)));
            }
        }
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        None
    }

    /// Try to claim the abort of `(txn, incarnation)` after a failed
    /// validation. Only one claimant wins; a loser's stale verdict is
    /// simply dropped.
    pub fn try_validation_abort(&self, txn: TxnIdx, incarnation: Incarnation) -> bool {
        let mut s = self.txns[txn].lock().unwrap();
        if s.status == Status::Executed && s.incarnation == incarnation {
            s.status = Status::Aborting;
            true
        } else {
            false
        }
    }

    /// Wrap up a validation task. On abort: bump the incarnation,
    /// force higher transactions to revalidate, and hand the
    /// re-execution to this worker when possible.
    pub fn finish_validation(&self, txn: TxnIdx, aborted: bool) -> Option<Task> {
        if aborted {
            self.set_ready(txn);
            self.decrease_validation_idx(txn + 1);
            if self.execution_idx.load(Ordering::SeqCst) > txn {
                if let Some(v) = self.try_incarnate(txn) {
                    return Some(Task::Execution(v));
                }
            }
        }
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_batch_is_done_immediately() {
        let s = Scheduler::new(0);
        assert!(s.done());
        assert_eq!(s.next_task(), None);
    }

    #[test]
    fn single_txn_execute_then_validate_then_done() {
        let s = Scheduler::new(1);
        let t = s.next_task().unwrap();
        assert_eq!(t, Task::Execution((0, 0)));
        // First incarnation wrote new locations but nothing is above
        // it; validation_idx == 0 is not > 0, so no inline validation.
        assert_eq!(s.finish_execution(0, 0, true), None);
        let t = s.next_task().unwrap();
        assert_eq!(t, Task::Validation((0, 0)));
        assert_eq!(s.finish_validation(0, false), None);
        // Drain the counters past n; the done marker flips.
        for _ in 0..4 {
            if s.next_task().is_some() {
                panic!("no tasks should remain");
            }
            if s.done() {
                return;
            }
        }
        panic!("scheduler never reached done");
    }

    #[test]
    fn validation_abort_reincarnates() {
        let s = Scheduler::new(2);
        assert_eq!(s.next_task(), Some(Task::Execution((0, 0))));
        assert_eq!(s.next_task(), Some(Task::Execution((1, 0))));
        assert_eq!(s.finish_execution(0, 0, true), None);
        assert_eq!(s.finish_execution(1, 0, true), None);
        // Validate 0 fine, abort 1.
        assert_eq!(s.next_task(), Some(Task::Validation((0, 0))));
        assert_eq!(s.finish_validation(0, false), None);
        assert_eq!(s.next_task(), Some(Task::Validation((1, 0))));
        assert!(s.try_validation_abort(1, 0));
        // Second claimant loses.
        assert!(!s.try_validation_abort(1, 0));
        let t = s.finish_validation(1, true);
        assert_eq!(t, Some(Task::Execution((1, 1))), "re-incarnated in place");
        assert_eq!(s.finish_execution(1, 1, false), Some(Task::Validation((1, 1))));
        assert_eq!(s.finish_validation(1, false), None);
        while !s.done() {
            assert_eq!(s.next_task(), None);
        }
    }

    #[test]
    fn dependency_suspends_and_resumes() {
        let s = Scheduler::new(2);
        assert_eq!(s.next_task(), Some(Task::Execution((0, 0))));
        assert_eq!(s.next_task(), Some(Task::Execution((1, 0))));
        // txn 1 reads an ESTIMATE from txn 0: suspend.
        assert!(s.add_dependency(1, 0));
        // txn 0 finishing must resume txn 1 with incarnation 1.
        assert_eq!(s.finish_execution(0, 0, true), None);
        let mut saw_exec1 = false;
        for _ in 0..8 {
            match s.next_task() {
                Some(Task::Execution((1, 1))) => {
                    saw_exec1 = true;
                    break;
                }
                Some(Task::Validation((0, 0))) => {
                    s.finish_validation(0, false);
                }
                Some(other) => panic!("unexpected task {other:?}"),
                None => {}
            }
        }
        assert!(saw_exec1, "suspended txn was never re-dispatched");
    }

    #[test]
    fn add_dependency_fails_after_blocking_executed() {
        let s = Scheduler::new(2);
        assert_eq!(s.next_task(), Some(Task::Execution((0, 0))));
        assert_eq!(s.next_task(), Some(Task::Execution((1, 0))));
        assert_eq!(s.finish_execution(0, 0, true), None);
        assert!(!s.add_dependency(1, 0), "blocking txn already executed");
    }
}
