//! The Block-STM collaborative scheduler.
//!
//! Two logical task streams — execution and validation — are driven by
//! two atomic counters over the batch's transaction indices. Workers
//! pull whichever stream is further behind, preferring validations
//! (they are cheap and unblock the commit prefix). A transaction's
//! lifecycle is tracked per index:
//!
//! ```text
//! ReadyToExecute --try_incarnate--> Executing --finish_execution--> Executed
//!       ^                              |                               |
//!       | set_ready (incarnation+1)    | add_dependency (ESTIMATE      | try_validation_abort
//!       |                              v  read: suspend on lower txn)  v
//!       +---------------------------- Aborting <-----------------------+
//! ```
//!
//! The lifecycle lives in one **packed atomic status word per
//! transaction** — `incarnation << 2 | state` in an `AtomicU64`, every
//! transition a single store or CAS (the Block-STM scheduler shape the
//! SNIPPETS exemplars quote) — so claiming an execution, publishing
//! `Executed`, and winning a validation abort never take a lock. The
//! only mutex left is the per-transaction *dependency list* (the rare
//! ESTIMATE-suspension path): `finish_execution` publishes `Executed`
//! *before* draining the list while `add_dependency` re-checks the
//! status word under the list lock, which closes the lost-wakeup
//! window.
//!
//! The counters only ever move *down* through `fetch_min` when work is
//! invalidated (a lower transaction re-executed or aborted), and a
//! `decrease_cnt` generation counter makes the done-check safe against
//! racing decreases — the same protocol as the Block-STM paper's
//! Algorithm 4.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Index of a transaction inside one batch.
pub type TxnIdx = usize;

/// How many times a transaction has been (re-)executed.
pub type Incarnation = u32;

/// One executable unit: `(transaction index, incarnation)`.
pub type Version = (TxnIdx, Incarnation);

/// What a worker should do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Run the transaction body speculatively and record its effects.
    Execution(Version),
    /// Re-read the recorded read set and compare observed versions.
    Validation(Version),
}

// Status-word state encoding (low two bits).
const ST_READY: u64 = 0;
const ST_EXECUTING: u64 = 1;
const ST_EXECUTED: u64 = 2;
const ST_ABORTING: u64 = 3;
const ST_MASK: u64 = 3;

#[inline]
fn pack(incarnation: Incarnation, state: u64) -> u64 {
    ((incarnation as u64) << 2) | state
}

#[inline]
fn state_of(word: u64) -> u64 {
    word & ST_MASK
}

#[inline]
fn incarnation_of(word: u64) -> Incarnation {
    (word >> 2) as Incarnation
}

/// One transaction's packed `incarnation << 2 | state` word, padded to
/// a cache line so neighbouring transactions' CAS traffic doesn't
/// false-share.
#[repr(align(64))]
struct StatusWord(AtomicU64);

/// Shared scheduler state for one batch run.
pub struct Scheduler {
    n: usize,
    execution_idx: AtomicUsize,
    validation_idx: AtomicUsize,
    /// Bumped on every counter decrease; lets `check_done` detect a
    /// decrease racing its reads of the two indices.
    decrease_cnt: AtomicUsize,
    num_active: AtomicUsize,
    done_marker: AtomicBool,
    /// Packed per-transaction lifecycle words (see module docs).
    status: Box<[StatusWord]>,
    /// Transactions suspended waiting on each index (cold path: only
    /// the ESTIMATE-dependency protocol touches these locks).
    deps: Box<[Mutex<Vec<TxnIdx>>]>,
}

impl Scheduler {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            execution_idx: AtomicUsize::new(0),
            validation_idx: AtomicUsize::new(0),
            decrease_cnt: AtomicUsize::new(0),
            num_active: AtomicUsize::new(0),
            done_marker: AtomicBool::new(n == 0),
            status: (0..n)
                .map(|_| StatusWord(AtomicU64::new(pack(0, ST_READY))))
                .collect(),
            deps: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// Has every transaction been executed and validated?
    #[inline]
    pub fn done(&self) -> bool {
        self.done_marker.load(Ordering::SeqCst)
    }

    /// Emergency stop: flips the done marker so every worker drops out
    /// of its polling loop. Used by the panic guard in
    /// `BatchSystem::run` — one panicking worker (e.g. a transaction
    /// body violating the infallibility contract) must not strand its
    /// peers spinning forever on a `num_active` count that can no
    /// longer reach zero.
    pub fn halt(&self) {
        self.done_marker.store(true, Ordering::SeqCst);
    }

    fn decrease_execution_idx(&self, t: TxnIdx) {
        self.execution_idx.fetch_min(t, Ordering::SeqCst);
        self.decrease_cnt.fetch_add(1, Ordering::SeqCst);
    }

    fn decrease_validation_idx(&self, t: TxnIdx) {
        self.validation_idx.fetch_min(t, Ordering::SeqCst);
        self.decrease_cnt.fetch_add(1, Ordering::SeqCst);
    }

    fn check_done(&self) {
        let observed = self.decrease_cnt.load(Ordering::SeqCst);
        if self.execution_idx.load(Ordering::SeqCst) >= self.n
            && self.validation_idx.load(Ordering::SeqCst) >= self.n
            && self.num_active.load(Ordering::SeqCst) == 0
            && observed == self.decrease_cnt.load(Ordering::SeqCst)
        {
            self.done_marker.store(true, Ordering::SeqCst);
        }
    }

    fn try_incarnate(&self, t: TxnIdx) -> Option<Version> {
        let s = &self.status[t].0;
        let mut cur = s.load(Ordering::SeqCst);
        while state_of(cur) == ST_READY {
            let inc = incarnation_of(cur);
            match s.compare_exchange_weak(
                cur,
                pack(inc, ST_EXECUTING),
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return Some((t, inc)),
                Err(now) => cur = now,
            }
        }
        None
    }

    fn next_version_to_execute(&self) -> Option<Version> {
        if self.execution_idx.load(Ordering::SeqCst) >= self.n {
            // Counted-active workers never sit in this branch, so the
            // done-check can observe num_active == 0.
            self.check_done();
            return None;
        }
        self.num_active.fetch_add(1, Ordering::SeqCst);
        let idx = self.execution_idx.fetch_add(1, Ordering::SeqCst);
        if idx < self.n {
            if let Some(v) = self.try_incarnate(idx) {
                return Some(v);
            }
        }
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        None
    }

    fn next_version_to_validate(&self) -> Option<Version> {
        if self.validation_idx.load(Ordering::SeqCst) >= self.n {
            self.check_done();
            return None;
        }
        self.num_active.fetch_add(1, Ordering::SeqCst);
        let idx = self.validation_idx.fetch_add(1, Ordering::SeqCst);
        if idx < self.n {
            // One atomic load snapshots (state, incarnation) together —
            // what the old per-txn mutex existed to make atomic.
            let word = self.status[idx].0.load(Ordering::SeqCst);
            if state_of(word) == ST_EXECUTED {
                return Some((idx, incarnation_of(word)));
            }
        }
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        None
    }

    /// Pull the next task, preferring the stream that is further
    /// behind. Returns `None` when no task was available *right now*
    /// (the caller re-polls until [`Scheduler::done`]).
    pub fn next_task(&self) -> Option<Task> {
        if self.done() {
            return None;
        }
        if self.validation_idx.load(Ordering::SeqCst)
            < self.execution_idx.load(Ordering::SeqCst)
        {
            self.next_version_to_validate().map(Task::Validation)
        } else {
            self.next_version_to_execute().map(Task::Execution)
        }
    }

    /// The executing `txn` read an ESTIMATE written by `blocking`
    /// (always a lower index): suspend it until `blocking` finishes.
    /// Returns `false` when `blocking` already finished — the caller
    /// should simply re-execute instead of suspending.
    pub fn add_dependency(&self, txn: TxnIdx, blocking: TxnIdx) -> bool {
        debug_assert!(blocking < txn, "dependencies only point down");
        // The Executed re-check under the deps lock pairs with
        // finish_execution's store-Executed-then-drain order: either we
        // see Executed here (and re-execute in place), or our push is
        // visible to the drain. No lost wakeup.
        let mut deps = self.deps[blocking].lock().unwrap();
        if state_of(self.status[blocking].0.load(Ordering::SeqCst)) == ST_EXECUTED {
            return false;
        }
        let s = &self.status[txn].0;
        let cur = s.load(Ordering::SeqCst);
        debug_assert_eq!(state_of(cur), ST_EXECUTING);
        // Only the executing owner transitions out of Executing: a
        // plain store suffices.
        s.store(pack(incarnation_of(cur), ST_ABORTING), Ordering::SeqCst);
        deps.push(txn);
        drop(deps);
        // The execution task halts here; the dependency resume path
        // re-dispatches it.
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        true
    }

    fn set_ready(&self, t: TxnIdx) {
        let s = &self.status[t].0;
        let cur = s.load(Ordering::SeqCst);
        debug_assert_eq!(state_of(cur), ST_ABORTING);
        // Single resumer (the abort claimant or the dependency
        // drainer): store the bumped incarnation.
        s.store(pack(incarnation_of(cur) + 1, ST_READY), Ordering::SeqCst);
    }

    /// Incarnation `(txn, incarnation)` finished executing and its
    /// effects are recorded. Resumes suspended dependents and decides
    /// what (if anything) to validate next. Returns a follow-up task
    /// for the same worker, or `None` (task complete).
    pub fn finish_execution(
        &self,
        txn: TxnIdx,
        incarnation: Incarnation,
        wrote_new_location: bool,
    ) -> Option<Task> {
        let s = &self.status[txn].0;
        debug_assert_eq!(s.load(Ordering::SeqCst), pack(incarnation, ST_EXECUTING));
        // Publish Executed BEFORE draining the dependency list: a
        // racing add_dependency either observes it (and re-executes in
        // place) or lands its push where the drain below collects it.
        s.store(pack(incarnation, ST_EXECUTED), Ordering::SeqCst);
        let deps = std::mem::take(&mut *self.deps[txn].lock().unwrap());
        if let Some(&min_dep) = deps.iter().min() {
            for &d in &deps {
                self.set_ready(d);
            }
            self.decrease_execution_idx(min_dep);
        }
        if self.validation_idx.load(Ordering::SeqCst) > txn {
            if wrote_new_location {
                // Writes appeared at fresh addresses: everything at or
                // above this index must revalidate.
                self.decrease_validation_idx(txn);
            } else {
                // Same write footprint as before: only this transaction
                // needs validating, and this worker does it in place.
                return Some(Task::Validation((txn, incarnation)));
            }
        }
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        None
    }

    /// Try to claim the abort of `(txn, incarnation)` after a failed
    /// validation — one CAS; only one claimant wins and a loser's stale
    /// verdict is simply dropped.
    pub fn try_validation_abort(&self, txn: TxnIdx, incarnation: Incarnation) -> bool {
        self.status[txn]
            .0
            .compare_exchange(
                pack(incarnation, ST_EXECUTED),
                pack(incarnation, ST_ABORTING),
                Ordering::SeqCst,
                Ordering::SeqCst,
            )
            .is_ok()
    }

    /// Wrap up a validation task. On abort: bump the incarnation,
    /// force higher transactions to revalidate, and hand the
    /// re-execution to this worker when possible.
    pub fn finish_validation(&self, txn: TxnIdx, aborted: bool) -> Option<Task> {
        if aborted {
            self.set_ready(txn);
            self.decrease_validation_idx(txn + 1);
            if self.execution_idx.load(Ordering::SeqCst) > txn {
                if let Some(v) = self.try_incarnate(txn) {
                    return Some(Task::Execution(v));
                }
            }
        }
        self.num_active.fetch_sub(1, Ordering::SeqCst);
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_batch_is_done_immediately() {
        let s = Scheduler::new(0);
        assert!(s.done());
        assert_eq!(s.next_task(), None);
    }

    #[test]
    fn single_txn_execute_then_validate_then_done() {
        let s = Scheduler::new(1);
        let t = s.next_task().unwrap();
        assert_eq!(t, Task::Execution((0, 0)));
        // First incarnation wrote new locations but nothing is above
        // it; validation_idx == 0 is not > 0, so no inline validation.
        assert_eq!(s.finish_execution(0, 0, true), None);
        let t = s.next_task().unwrap();
        assert_eq!(t, Task::Validation((0, 0)));
        assert_eq!(s.finish_validation(0, false), None);
        // Drain the counters past n; the done marker flips.
        for _ in 0..4 {
            if s.next_task().is_some() {
                panic!("no tasks should remain");
            }
            if s.done() {
                return;
            }
        }
        panic!("scheduler never reached done");
    }

    #[test]
    fn validation_abort_reincarnates() {
        let s = Scheduler::new(2);
        assert_eq!(s.next_task(), Some(Task::Execution((0, 0))));
        // Validation is preferred once the execution stream is ahead,
        // but txn 0 is still executing: the pull is consumed and yields
        // nothing (its eventual finish_execution drags validation_idx
        // back down). Workers absorb the None by re-polling.
        assert_eq!(s.next_task(), None);
        assert_eq!(s.next_task(), Some(Task::Execution((1, 0))));
        assert_eq!(s.finish_execution(0, 0, true), None);
        assert_eq!(s.finish_execution(1, 0, true), None);
        // Validate 0 fine, abort 1.
        assert_eq!(s.next_task(), Some(Task::Validation((0, 0))));
        assert_eq!(s.finish_validation(0, false), None);
        assert_eq!(s.next_task(), Some(Task::Validation((1, 0))));
        assert!(s.try_validation_abort(1, 0));
        // Second claimant loses.
        assert!(!s.try_validation_abort(1, 0));
        let t = s.finish_validation(1, true);
        assert_eq!(t, Some(Task::Execution((1, 1))), "re-incarnated in place");
        assert_eq!(s.finish_execution(1, 1, false), Some(Task::Validation((1, 1))));
        assert_eq!(s.finish_validation(1, false), None);
        while !s.done() {
            assert_eq!(s.next_task(), None);
        }
    }

    #[test]
    fn dependency_suspends_and_resumes() {
        let s = Scheduler::new(2);
        assert_eq!(s.next_task(), Some(Task::Execution((0, 0))));
        // Preferred-but-premature validation pull (see above).
        assert_eq!(s.next_task(), None);
        assert_eq!(s.next_task(), Some(Task::Execution((1, 0))));
        // txn 1 reads an ESTIMATE from txn 0: suspend.
        assert!(s.add_dependency(1, 0));
        // txn 0 finishing must resume txn 1 with incarnation 1.
        assert_eq!(s.finish_execution(0, 0, true), None);
        let mut saw_exec1 = false;
        for _ in 0..8 {
            match s.next_task() {
                Some(Task::Execution((1, 1))) => {
                    saw_exec1 = true;
                    break;
                }
                Some(Task::Validation((0, 0))) => {
                    s.finish_validation(0, false);
                }
                Some(other) => panic!("unexpected task {other:?}"),
                None => {}
            }
        }
        assert!(saw_exec1, "suspended txn was never re-dispatched");
    }

    #[test]
    fn add_dependency_fails_after_blocking_executed() {
        let s = Scheduler::new(2);
        assert_eq!(s.next_task(), Some(Task::Execution((0, 0))));
        // Preferred-but-premature validation pull (see above).
        assert_eq!(s.next_task(), None);
        assert_eq!(s.next_task(), Some(Task::Execution((1, 0))));
        assert_eq!(s.finish_execution(0, 0, true), None);
        assert!(!s.add_dependency(1, 0), "blocking txn already executed");
    }

    #[test]
    fn status_word_packs_incarnation_and_state() {
        for inc in [0u32, 1, 7, u32::MAX] {
            for st in [ST_READY, ST_EXECUTING, ST_EXECUTED, ST_ABORTING] {
                let w = pack(inc, st);
                assert_eq!(state_of(w), st);
                assert_eq!(incarnation_of(w), inc);
            }
        }
    }

    #[test]
    fn concurrent_claims_admit_each_incarnation_once() {
        // Many threads race try_incarnate over a fresh scheduler: each
        // transaction's incarnation 0 must be claimed exactly once.
        let s = Scheduler::new(64);
        let claimed: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for t in 0..64 {
                        if s.try_incarnate(t).is_some() {
                            claimed[t].fetch_add(1, Ordering::SeqCst);
                        }
                    }
                });
            }
        });
        for (t, c) in claimed.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "txn {t} claimed wrong count");
        }
    }
}
