//! Runtime-adaptive block sizing for the batch backend.
//!
//! DyAdHyTM's thesis is that the winning TM configuration must be
//! *chosen at runtime from observed abort behaviour* (§3.6, Figure 1b).
//! [`BlockSizeController`] applies the same adapt-loop shape to the
//! batch backend's one tuning knob, the admission block size: every
//! completed block reports how much speculation it wasted
//! (re-incarnations / executions), and the controller resizes the next
//! block with an AIMD law —
//!
//! * **multiplicative decrease** when the conflict rate spikes above
//!   [`BlockSizeController::HI_CONFLICT`] (halve the block: fewer
//!   transactions in flight means fewer lower-index writers to
//!   invalidate a read), mirroring DyAdHyTM's capacity short-circuit
//!   (`tries = 0` the moment the abort flags prove retrying is futile);
//! * **additive increase** while the block runs clean (below
//!   [`BlockSizeController::LO_CONFLICT`]): grow by
//!   [`BlockSizeController::GROW_STEP`] to amortize per-block barrier
//!   and write-back cost, the analogue of staying in hardware while
//!   the abort flags stay quiet.
//!
//! The controller also owns the **pipelining window depth** — how many
//! blocks `BatchSystem::run_pipelined` keeps in flight at once
//! ([`BlockSizeController::current_window`], configured by
//! [`BlockSizeController::with_window`] / `--policy
//! batch=adaptive:window=W`). Depth is co-tuned with block size by the
//! same AIMD signals: a conflict spike (or latency overrun) that
//! halves the block also shallows the window one step (deep cross-block
//! speculation is exactly the waste amplifier in a hot regime), and a
//! clean block that grows the block also deepens the window back
//! toward its configured ceiling. A fixed controller pins both knobs.
//!
//! Both the live executors (`batch::workload`, `runtime::pipeline`) and
//! the discrete-event simulator (`sim::engine`'s `Mode::MultiVersion`)
//! drive this same controller, so `--policy batch=adaptive` is priced
//! and measured by one state machine in both worlds — exactly how the
//! paper's retry policies are shared between `hytm::policies` and the
//! simulator.
//!
//! Determinism is untouched by any controller trajectory: blocks are
//! executed to completion in admission order, so *any* partition of the
//! transaction stream into blocks commits the same sequential-order
//! state bit for bit (enforced by the `batch_determinism` qcheck
//! property comparing fixed against adaptive sizing).

use std::time::Duration;

use crate::stats::TxStats;

/// AIMD block-size controller. [`BlockSizeController::fixed`] pins the
/// block (the `--policy batch=N` behaviour: `observe` never moves it),
/// [`BlockSizeController::adaptive`] enables the law above
/// (`--policy batch=adaptive`).
///
/// An adaptive controller can additionally carry a **latency target**
/// ([`BlockSizeController::with_latency_target`], the CLI's `--policy
/// batch=adaptive:latency=MS`): when a completed block's observed wall
/// time exceeds the deadline the block halves *even at low conflict
/// rate* — blocks sized by deadline, not only by waste, which is what
/// the streaming pipeline needs to bound end-to-end latency. While a
/// target is set, additive increase is additionally gated on the block
/// finishing within half the deadline (headroom guard), so the
/// controller doesn't oscillate across the deadline every other block.
#[derive(Clone, Debug)]
pub struct BlockSizeController {
    block: usize,
    min: usize,
    max: usize,
    grow: usize,
    hi: f64,
    lo: f64,
    /// Shrink when a block's wall time exceeds this deadline.
    latency_target: Option<Duration>,
    /// Configured pipelining-window ceiling (blocks in flight at once).
    window_max: usize,
    /// Current co-tuned window depth, in `[window_floor, window_max]`.
    window: usize,
    /// Additive-increase decisions taken.
    pub grows: u64,
    /// Multiplicative-decrease decisions taken (conflict + latency).
    pub shrinks: u64,
    /// The subset of `shrinks` forced by the latency target.
    pub latency_shrinks: u64,
    /// Window-deepening decisions taken.
    pub window_grows: u64,
    /// Window-shallowing decisions taken.
    pub window_shrinks: u64,
    /// Blocks observed.
    pub samples: u64,
}

impl BlockSizeController {
    /// Starting block for the adaptive controller: mid-scale, so both
    /// laws have room to act.
    pub const ADAPTIVE_INITIAL: usize = 1024;
    /// Floor of the multiplicative decrease.
    pub const MIN_BLOCK: usize = 256;
    /// Ceiling of the additive increase.
    pub const MAX_BLOCK: usize = 4096;
    /// Additive-increase step per clean block.
    pub const GROW_STEP: usize = 256;
    /// Wasted-execution fraction above which the block halves.
    pub const HI_CONFLICT: f64 = 0.10;
    /// Wasted-execution fraction below which the block grows.
    pub const LO_CONFLICT: f64 = 0.02;
    /// Default pipelining window: head + one overlap block (the PR-4
    /// behaviour).
    pub const DEFAULT_WINDOW: usize = 2;

    /// A pinned block size: `observe` is a no-op (modulo counters).
    pub fn fixed(block: usize) -> Self {
        let b = block.max(1);
        Self {
            block: b,
            min: b,
            max: b,
            grow: 0,
            hi: Self::HI_CONFLICT,
            lo: Self::LO_CONFLICT,
            latency_target: None,
            window_max: Self::DEFAULT_WINDOW,
            window: Self::DEFAULT_WINDOW,
            grows: 0,
            shrinks: 0,
            latency_shrinks: 0,
            window_grows: 0,
            window_shrinks: 0,
            samples: 0,
        }
    }

    /// The default adaptive controller.
    pub fn adaptive() -> Self {
        Self::with_bounds(
            Self::ADAPTIVE_INITIAL,
            Self::MIN_BLOCK,
            Self::MAX_BLOCK,
            Self::GROW_STEP,
        )
    }

    /// Adaptive controller with explicit bounds (tests, benches, and
    /// workloads whose natural block scale differs from the default).
    pub fn with_bounds(initial: usize, min: usize, max: usize, grow: usize) -> Self {
        let min = min.max(1);
        let max = max.max(min);
        Self {
            block: initial.clamp(min, max),
            min,
            max,
            grow: grow.max(1),
            hi: Self::HI_CONFLICT,
            lo: Self::LO_CONFLICT,
            latency_target: None,
            window_max: Self::DEFAULT_WINDOW,
            window: Self::DEFAULT_WINDOW,
            grows: 0,
            shrinks: 0,
            latency_shrinks: 0,
            window_grows: 0,
            window_shrinks: 0,
            samples: 0,
        }
    }

    /// Configure the pipelining window depth `w` (blocks in flight at
    /// once; `--policy batch=adaptive:window=W`). The window starts at
    /// `w` and — for an adaptive controller — is co-tuned downward to
    /// the floor (2, or 1 when `w == 1`) under conflict/latency
    /// pressure and back up to `w` on clean blocks. A fixed controller
    /// pins it at `w`. `w == 1` disables cross-block overlap entirely
    /// (a pure per-block barrier stream).
    pub fn with_window(mut self, w: usize) -> Self {
        let w = w.max(1);
        self.window_max = w;
        self.window = w;
        self
    }

    /// The pipelining window depth the session should run with now.
    #[inline]
    pub fn current_window(&self) -> usize {
        self.window
    }

    /// The configured window ceiling.
    #[inline]
    pub fn window_max(&self) -> usize {
        self.window_max
    }

    fn window_floor(&self) -> usize {
        self.window_max.min(Self::DEFAULT_WINDOW).max(1)
    }

    fn shallow_window(&mut self) {
        let next = self.window.saturating_sub(1).max(self.window_floor());
        if next != self.window {
            self.window = next;
            self.window_shrinks += 1;
        }
    }

    fn deepen_window(&mut self) {
        let next = (self.window + 1).min(self.window_max);
        if next != self.window {
            self.window = next;
            self.window_grows += 1;
        }
    }

    /// Attach a latency deadline (see the type docs): a completed
    /// block whose wall time exceeds `target` halves the next block
    /// even when its conflict rate was clean. Only meaningful for an
    /// adaptive controller; a fixed block ignores it.
    pub fn with_latency_target(mut self, target: Duration) -> Self {
        self.latency_target = Some(target);
        self
    }

    /// The configured latency deadline, if any.
    #[inline]
    pub fn latency_target(&self) -> Option<Duration> {
        self.latency_target
    }

    /// The block size the next admission should use.
    #[inline]
    pub fn current(&self) -> usize {
        self.block
    }

    /// Whether `observe` can move the block at all.
    #[inline]
    pub fn is_adaptive(&self) -> bool {
        self.min != self.max
    }

    /// Feed one completed block's outcome without timing information:
    /// the conflict-rate AIMD law only (the latency target never fires
    /// on a zero wall time). Kept for callers that have no meaningful
    /// block wall-clock; the execution paths call
    /// [`BlockSizeController::observe_block`].
    pub fn observe(&mut self, executions: u64, committed: u64) {
        self.observe_block(executions, committed, Duration::ZERO);
    }

    /// Feed one completed block's outcome: `executions` incarnation
    /// starts against `committed` transactions (`executions >=
    /// committed`; the excess is wasted speculation), and the block's
    /// observed wall time. The latency deadline is checked first —
    /// an overrun halves the block even at a clean conflict rate —
    /// then the AIMD law picks the next block size.
    pub fn observe_block(&mut self, executions: u64, committed: u64, wall: Duration) {
        let (b0, w0) = (self.block, self.window);
        self.decide(executions, committed, wall);
        // Resize decisions are block-granular (never inside a
        // transaction), so tracing them here costs nothing on the
        // per-txn hot path.
        if self.block != b0 {
            crate::obs::trace::block_resize(b0 as u64, self.block as u64);
        }
        if self.window != w0 {
            crate::obs::trace::window_resize(w0 as u64, self.window as u64);
        }
    }

    fn decide(&mut self, executions: u64, committed: u64, wall: Duration) {
        self.samples += 1;
        if !self.is_adaptive() || committed == 0 {
            return;
        }
        if let Some(target) = self.latency_target {
            if wall > target {
                let next = (self.block / 2).max(self.min);
                if next != self.block {
                    self.block = next;
                    self.shrinks += 1;
                    self.latency_shrinks += 1;
                }
                // A deadline overrun also shallows the window: deep
                // lookahead extends the in-flight tail the deadline is
                // trying to bound.
                self.shallow_window();
                return;
            }
        }
        let executions = executions.max(committed);
        let conflict = 1.0 - committed as f64 / executions as f64;
        if conflict > self.hi {
            let next = (self.block / 2).max(self.min);
            if next != self.block {
                self.block = next;
                self.shrinks += 1;
            }
            // Co-tune: a hot regime makes cross-block speculation the
            // waste amplifier — shallow the window with the block.
            self.shallow_window();
        } else if conflict < self.lo {
            // Headroom guard: with a deadline set, only grow while the
            // block finishes within half of it.
            if self
                .latency_target
                .map_or(true, |target| wall <= target / 2)
            {
                let next = (self.block + self.grow).min(self.max);
                if next != self.block {
                    self.block = next;
                    self.grows += 1;
                }
                // Co-tune: clean blocks re-deepen the window toward
                // its configured ceiling.
                self.deepen_window();
            }
        }
    }

    /// Fold the controller's outcome into the stats plane: decision
    /// counts plus the block size and window depth the run converged
    /// to (what `PolicySpec::label` reports for `batch=adaptive`).
    pub fn apply_to(&self, stats: &mut TxStats) {
        stats.block_grows += self.grows;
        stats.block_shrinks += self.shrinks;
        stats.final_block = self.block as u64;
        stats.final_window = self.window as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_controller_never_moves() {
        let mut c = BlockSizeController::fixed(512);
        assert!(!c.is_adaptive());
        for _ in 0..10 {
            c.observe(1000, 100); // 90% waste: would halve if adaptive
            assert_eq!(c.current(), 512);
        }
        c.observe(100, 100); // perfectly clean: would grow
        assert_eq!(c.current(), 512);
        assert_eq!((c.grows, c.shrinks), (0, 0));
        assert_eq!(c.samples, 11);
    }

    #[test]
    fn clean_blocks_grow_additively_to_the_ceiling() {
        let mut c = BlockSizeController::with_bounds(100, 50, 400, 100);
        c.observe(1000, 1000);
        assert_eq!(c.current(), 200, "additive step");
        c.observe(1000, 995); // 0.5% waste: still clean
        assert_eq!(c.current(), 300);
        c.observe(1000, 1000);
        assert_eq!(c.current(), 400);
        c.observe(1000, 1000); // clamped at the ceiling
        assert_eq!(c.current(), 400);
        assert_eq!(c.grows, 3, "a clamped step is not a decision");
    }

    #[test]
    fn conflict_spikes_halve_multiplicatively_to_the_floor() {
        let mut c = BlockSizeController::with_bounds(400, 60, 400, 100);
        c.observe(1000, 800); // 20% waste
        assert_eq!(c.current(), 200, "multiplicative decrease");
        c.observe(1000, 800);
        assert_eq!(c.current(), 100);
        c.observe(1000, 800);
        assert_eq!(c.current(), 60, "clamped at the floor");
        c.observe(1000, 800);
        assert_eq!(c.current(), 60);
        assert_eq!(c.shrinks, 3);
    }

    #[test]
    fn mid_band_conflict_holds_the_block() {
        let mut c = BlockSizeController::with_bounds(128, 32, 512, 32);
        c.observe(1000, 950); // 5% waste: between LO and HI
        assert_eq!(c.current(), 128);
        assert_eq!((c.grows, c.shrinks), (0, 0));
    }

    #[test]
    fn decrease_wins_back_and_forth() {
        // AIMD converges from above and below to the same regime.
        let mut up = BlockSizeController::adaptive();
        let mut down = BlockSizeController::adaptive();
        for _ in 0..64 {
            up.observe(100, 100); // clean
            down.observe(100, 50); // 50% waste
        }
        assert_eq!(up.current(), BlockSizeController::MAX_BLOCK);
        assert_eq!(down.current(), BlockSizeController::MIN_BLOCK);
    }

    #[test]
    fn observe_tolerates_degenerate_counters() {
        let mut c = BlockSizeController::adaptive();
        let b0 = c.current();
        c.observe(0, 0); // empty block
        assert_eq!(c.current(), b0);
        c.observe(10, 20); // executions < committed: clamped, clean
        assert_eq!(c.current(), b0 + BlockSizeController::GROW_STEP);
    }

    #[test]
    fn latency_overrun_shrinks_even_when_clean() {
        let mut c = BlockSizeController::with_bounds(400, 50, 400, 100)
            .with_latency_target(Duration::from_millis(10));
        assert_eq!(c.latency_target(), Some(Duration::from_millis(10)));
        // Perfectly clean block, but 3x over deadline: halve.
        c.observe_block(1000, 1000, Duration::from_millis(30));
        assert_eq!(c.current(), 200, "deadline overrun must shrink");
        c.observe_block(1000, 1000, Duration::from_millis(11));
        assert_eq!(c.current(), 100);
        assert_eq!(c.latency_shrinks, 2);
        assert_eq!(c.shrinks, 2, "latency shrinks count as shrinks");
        assert_eq!(c.grows, 0);
    }

    #[test]
    fn latency_headroom_gates_growth() {
        let mut c = BlockSizeController::with_bounds(100, 50, 400, 100)
            .with_latency_target(Duration::from_millis(10));
        // Clean and within deadline, but past the half-deadline
        // headroom: hold, don't grow.
        c.observe_block(1000, 1000, Duration::from_millis(8));
        assert_eq!(c.current(), 100, "no growth without headroom");
        // Clean and fast: grow as usual.
        c.observe_block(1000, 1000, Duration::from_millis(2));
        assert_eq!(c.current(), 200);
        assert_eq!(c.grows, 1);
    }

    #[test]
    fn untimed_observe_never_trips_the_deadline() {
        // Callers without wall-clock data (Duration::ZERO) keep the
        // pure conflict law even with a target configured.
        let mut c = BlockSizeController::with_bounds(100, 50, 400, 100)
            .with_latency_target(Duration::from_millis(1));
        c.observe(1000, 1000);
        assert_eq!(c.current(), 200, "zero wall time is always within deadline");
        assert_eq!(c.latency_shrinks, 0);
    }

    #[test]
    fn fixed_controller_ignores_latency_target() {
        let mut c = BlockSizeController::fixed(128).with_latency_target(Duration::from_nanos(1));
        c.observe_block(100, 100, Duration::from_secs(5));
        assert_eq!(c.current(), 128);
        assert_eq!((c.shrinks, c.latency_shrinks), (0, 0));
    }

    #[test]
    fn apply_to_reports_the_converged_block() {
        let mut c = BlockSizeController::with_bounds(100, 50, 400, 100);
        c.observe(10, 10);
        c.observe(10, 5);
        let mut s = TxStats::new();
        c.apply_to(&mut s);
        assert_eq!(s.block_grows, 1);
        assert_eq!(s.block_shrinks, 1);
        assert_eq!(s.final_block, c.current() as u64);
        assert_eq!(s.final_window, c.current_window() as u64);
    }

    #[test]
    fn default_window_is_two_and_with_window_overrides() {
        let c = BlockSizeController::adaptive();
        assert_eq!(c.current_window(), BlockSizeController::DEFAULT_WINDOW);
        let c = BlockSizeController::adaptive().with_window(4);
        assert_eq!((c.current_window(), c.window_max()), (4, 4));
        // w=0 clamps to 1 (barrier stream), never 0.
        assert_eq!(BlockSizeController::fixed(8).with_window(0).current_window(), 1);
    }

    #[test]
    fn conflict_pressure_shallows_the_window_to_the_floor() {
        let mut c = BlockSizeController::with_bounds(400, 60, 400, 100).with_window(4);
        c.observe(1000, 800); // 20% waste
        assert_eq!(c.current_window(), 3, "shrink co-tunes the window");
        c.observe(1000, 800);
        assert_eq!(c.current_window(), 2);
        c.observe(1000, 800);
        assert_eq!(c.current_window(), 2, "floor is 2 (head + one overlap)");
        assert_eq!(c.window_shrinks, 2);
        // Clean blocks deepen back toward the ceiling.
        c.observe(1000, 1000);
        c.observe(1000, 1000);
        assert_eq!(c.current_window(), 4);
        assert_eq!(c.window_grows, 2);
    }

    #[test]
    fn window_one_stays_a_barrier_stream() {
        // w=1 floors at 1: no co-tuning can re-enable overlap.
        let mut c = BlockSizeController::with_bounds(400, 60, 400, 100).with_window(1);
        c.observe(1000, 800);
        c.observe(1000, 1000);
        assert_eq!(c.current_window(), 1);
        assert_eq!((c.window_grows, c.window_shrinks), (0, 0));
    }

    #[test]
    fn fixed_controller_pins_the_window() {
        let mut c = BlockSizeController::fixed(128).with_window(3);
        c.observe(1000, 500); // would shallow if adaptive
        c.observe(1000, 1000); // would deepen if adaptive
        assert_eq!(c.current_window(), 3);
        assert_eq!((c.window_grows, c.window_shrinks), (0, 0));
    }

    #[test]
    fn latency_overrun_shallows_the_window_too() {
        let mut c = BlockSizeController::with_bounds(400, 50, 400, 100)
            .with_latency_target(Duration::from_millis(10))
            .with_window(3);
        c.observe_block(1000, 1000, Duration::from_millis(30));
        assert_eq!(c.current_window(), 2, "deadline overrun shallows lookahead");
        assert_eq!(c.window_shrinks, 1);
    }
}
