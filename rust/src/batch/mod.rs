//! `batch` — a Block-STM-style speculative batch executor: the fifth
//! synchronization backend.
//!
//! The paper's executors ([`crate::hytm`]) admit transactions one at a
//! time per thread and synchronize each against all concurrent peers.
//! This subsystem instead admits a whole *batch* (a block) of
//! transactions with a fixed serialization order — their index in the
//! batch — and executes them optimistically in parallel:
//!
//! * [`mvmemory`] — the multi-version store. The production
//!   implementation is **lock-free on the hot path**: the address
//!   index is CAS-published chains off an atomic shard array, each
//!   address owns a grow-only version vector whose `(txn, incarnation,
//!   value)` cells publish through a two-word seqlock, and each
//!   transaction's read/write sets are immutable nodes handed off
//!   through one `AtomicPtr` — reads of committed versions take zero
//!   locks, writes CAS-publish. The PR-1 sharded-mutex layout survives
//!   as `MutexMvMemory` behind the same `MvStore` trait, purely so the
//!   benchmark can price what the locks cost;
//! * [`scheduler`] — execution/validation task streams over atomic
//!   index counters, fronted by **per-worker work-stealing deques**
//!   ([`crate::runtime::workers`]): a worker drains its own deque,
//!   refills a whole chunk of indices in one `fetch_add`, and steals
//!   candidates from its peers when both streams are drained. Each
//!   transaction's lifecycle stays packed in a single
//!   `incarnation << 2 | state` atomic status word (CAS transitions;
//!   the only mutex left guards the rare ESTIMATE-dependency lists);
//! * [`executor`] — the worker loop: execute against a recording
//!   [`crate::tm::access::TxAccess`] view → record read/write sets →
//!   validate → abort/re-incarnate;
//! * [`adaptive`] — the [`adaptive::BlockSizeController`]: AIMD block
//!   sizing from each block's observed re-incarnation rate, plus an
//!   optional **latency target** (`--policy
//!   batch=adaptive:latency=MS`) that shrinks the block whenever its
//!   wall time overruns the deadline even at low conflict — the knob
//!   the streaming pipeline sizes by;
//! * [`workload`] — adapters feeding the SSCA-2 kernels (generation,
//!   computation, and kernel-3 subgraph extraction as a
//!   level-synchronous batch BFS whose per-level candidate stream is
//!   consumed lazily, never materialized whole) and the simulator's
//!   [`crate::sim::workload::TxnDesc`] shapes through the batch API.
//!
//! # Cross-block pipelining
//!
//! [`BatchSystem::run`] executes one block to a full barrier — the
//! benchmark baseline. The shipped paths instead stream blocks through
//! [`BatchSystem::run_pipelined`], which keeps **one persistent pinned
//! worker pool** for the whole stream and overlaps adjacent blocks:
//! while block *N*'s validation tail drains, workers already execute
//! block *N+1*'s transactions. Block *N+1*'s base reads (no lower
//! in-block writer) peek block *N*'s winning versions (recording the
//! *value*, [`mvmemory::ReadOrigin::Base`]); a read that hits a block-N
//! ESTIMATE parks the transaction until block *N* completes. The moment
//! block *N* writes back, block *N+1* is promoted: parked transactions
//! resume and its scheduler is forced through a **full revalidation
//! pass** against the now-final heap — any speculative read that
//! guessed wrong re-executes, which is what keeps the final state
//! bit-identical to sequential execution across the whole stream. The
//! window is two blocks deep (head + one overlap), and block *N+1* is
//! only admitted once block *N*'s execution stream has drained, so the
//! overlap targets exactly the validation tail the admission barrier
//! used to waste.
//!
//! **Determinism guarantee.** Whatever interleaving the workers take —
//! whatever block sizes the controller picks, and whether blocks run to
//! a barrier or pipelined — the final heap state equals executing the
//! stream *sequentially in index order* — bit for bit. That is what
//! makes the backend measurable head-to-head against the paper's
//! policies: same inputs, same outputs, different concurrency control.
//! The guarantee is enforced by tests in this module and the
//! `batch_determinism` property suite (including pipelined-vs-oracle
//! and fixed-vs-adaptive sizing properties).
//!
//! **Full routing.** Select it end-to-end with `--policy batch[=N]`,
//! `--policy batch=adaptive`, or `--policy batch=adaptive:latency=MS`
//! ([`crate::hytm::PolicySpec::Batch`] / `PolicySpec::BatchAdaptive`):
//! all three SSCA-2 kernels and the streaming pipeline
//! ([`crate::runtime::pipeline`]) run through the pipelined session. No
//! path silently degrades to per-transaction NOrec: a batch spec
//! reaching `ThreadExecutor::execute` is loudly warned, accounted under
//! the `norec_fallback` stats counter, and reported as
//! `batch(fallback:norec)`. The simulator prices the backend with its
//! own multi-version cost mode (`sim::engine`'s `Mode::MultiVersion`):
//! estimate-wait, validation, re-incarnation charges and an
//! **overlapped block drain** (one block of admission lookahead, the
//! model of `run_pipelined`) driven by the *same* `BlockSizeController`
//! as the live runs.

pub mod adaptive;
pub mod executor;
pub mod mvmemory;
pub mod scheduler;
pub mod workload;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::mem::TxHeap;
use crate::runtime::workers::{run_pool, run_pool_with, PoolConfig};
use crate::stats::TxStats;
use crate::tm::access::{TxAccess, TxResult};

use adaptive::BlockSizeController;
use executor::{BaseSource, BatchCounters, CrossBlockPark, Worker};
use mvmemory::{MutexMvMemory, MvMemory, MvStore};
use scheduler::{Scheduler, TxnIdx};

/// Default number of transactions admitted per speculative block
/// (`--policy batch=N` overrides it; `--policy batch=adaptive` lets
/// the controller pick).
pub const DEFAULT_BLOCK: usize = 2048;

/// A batch transaction body. Must be a pure function of the values it
/// reads through the access handle (it may be re-executed any number of
/// times, concurrently with other transactions), and must not return
/// `Err` of its own — only the speculative view aborts an attempt.
pub type BatchBody<'b> = Box<dyn Fn(&mut dyn TxAccess) -> TxResult<()> + Send + Sync + 'b>;

/// One transaction of a batch.
pub struct BatchTxn<'b> {
    pub body: BatchBody<'b>,
}

impl<'b> BatchTxn<'b> {
    pub fn new(body: impl Fn(&mut dyn TxAccess) -> TxResult<()> + Send + Sync + 'b) -> Self {
        Self {
            body: Box::new(body),
        }
    }
}

/// Outcome counters of one (or several, merged) batch runs.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchReport {
    /// Transactions committed (= batch size; every txn commits).
    pub txns: usize,
    /// Incarnation executions started.
    pub executions: u64,
    /// Validation tasks performed.
    pub validations: u64,
    /// Validation aborts (re-incarnations forced by a read-set change).
    pub validation_aborts: u64,
    /// Executions suspended on a lower transaction's ESTIMATE (in-block
    /// dependencies plus cross-block parks).
    pub dependencies: u64,
    /// Candidates taken from a peer worker's deque.
    pub steals: u64,
    /// Execution attempts started while the previous block was still
    /// draining (cross-block pipelining overlap; 0 for barrier runs).
    pub overlapped_txns: u64,
    /// Pool workers whose core pin was applied.
    pub pinned_workers: u64,
    pub elapsed: Duration,
}

impl BatchReport {
    /// Accumulate another run (e.g. the next block of a long stream).
    pub fn merge(&mut self, other: &BatchReport) {
        self.txns += other.txns;
        self.executions += other.executions;
        self.validations += other.validations;
        self.validation_aborts += other.validation_aborts;
        self.dependencies += other.dependencies;
        self.steals += other.steals;
        self.overlapped_txns += other.overlapped_txns;
        self.pinned_workers = self.pinned_workers.max(other.pinned_workers);
        self.elapsed += other.elapsed;
    }

    /// Fold into the stats-plane shape: batch commits are software
    /// commits (speculation in software, like an STM), re-executions
    /// count as software aborts; the worker-runtime counters ride
    /// along.
    pub fn to_stats(&self) -> TxStats {
        let mut s = TxStats::new();
        s.sw_commits = self.txns as u64;
        s.sw_aborts = self.validation_aborts + self.dependencies;
        s.steals = self.steals;
        s.overlapped_txns = self.overlapped_txns;
        s.pinned_workers = self.pinned_workers;
        s.time_ns = self.elapsed.as_nanos() as u64;
        s
    }
}

/// One admitted block of a pipelined run: its transactions plus the
/// per-block scheduler, store, and counters.
struct BlockRun<'b, M: MvStore> {
    txns: Vec<BatchTxn<'b>>,
    scheduler: Scheduler,
    mv: M,
    counters: BatchCounters,
    /// The predecessor block has completed (written back). The first
    /// block of a stream starts true.
    prev_done: AtomicBool,
    /// Transactions parked on the predecessor (see
    /// [`executor::CrossBlockPark`]).
    parked: Mutex<Vec<TxnIdx>>,
    /// Write-back claimed (exactly one worker completes a block).
    completed: AtomicBool,
    admitted: Instant,
}

impl<'b, M: MvStore> BlockRun<'b, M> {
    fn new(txns: Vec<BatchTxn<'b>>, workers: usize) -> Self {
        let n = txns.len();
        Self {
            txns,
            scheduler: Scheduler::new(n, workers),
            mv: M::new(n),
            counters: BatchCounters::default(),
            prev_done: AtomicBool::new(false),
            parked: Mutex::new(Vec::new()),
            completed: AtomicBool::new(false),
            admitted: Instant::now(),
        }
    }

    /// This block's contribution to the stream report (elapsed and
    /// pin counts are session-level and filled in by the caller).
    fn report(&self) -> BatchReport {
        BatchReport {
            txns: self.txns.len(),
            executions: self.counters.executions.load(Ordering::Relaxed),
            validations: self.counters.validations.load(Ordering::Relaxed),
            validation_aborts: self.counters.validation_aborts.load(Ordering::Relaxed),
            dependencies: self.counters.dependencies.load(Ordering::Relaxed),
            steals: self.scheduler.steals(),
            overlapped_txns: self.counters.overlapped.load(Ordering::Relaxed),
            pinned_workers: 0,
            elapsed: Duration::ZERO,
        }
    }
}

/// The batch backend entry point.
pub struct BatchSystem;

impl BatchSystem {
    /// Execute `txns` as ONE block with `concurrency` workers over the
    /// lock-free multi-version store, to a full barrier. Blocks until
    /// every transaction has committed, then flushes the winning
    /// versions to `heap`. The final heap state is bit-identical to
    /// running the batch sequentially in index order. (The streamed,
    /// cross-block-overlapping variant is [`BatchSystem::run_pipelined`];
    /// this barrier form is the benchmark baseline and the single-block
    /// primitive.)
    pub fn run(heap: &TxHeap, txns: &[BatchTxn<'_>], concurrency: usize) -> BatchReport {
        Self::run_with::<MvMemory>(heap, txns, concurrency)
    }

    /// Same contract as [`BatchSystem::run`], but over the PR-1
    /// sharded-mutex store — the baseline `benches/batch_throughput`
    /// measures the lock-free hot path against. Not used by any
    /// shipped path.
    pub fn run_baseline_mutex(
        heap: &TxHeap,
        txns: &[BatchTxn<'_>],
        concurrency: usize,
    ) -> BatchReport {
        Self::run_with::<MutexMvMemory>(heap, txns, concurrency)
    }

    fn run_with<M: MvStore>(
        heap: &TxHeap,
        txns: &[BatchTxn<'_>],
        concurrency: usize,
    ) -> BatchReport {
        let t0 = Instant::now();
        if txns.is_empty() {
            return BatchReport {
                elapsed: t0.elapsed(),
                ..BatchReport::default()
            };
        }
        let workers = concurrency.max(1).min(txns.len());
        let scheduler = Scheduler::new(txns.len(), workers);
        let mv = M::new(txns.len());
        let counters = BatchCounters::default();
        // If a worker panics (a body violating the infallibility
        // contract, or a bug in a user closure), it unwinds with
        // `num_active` still elevated and the done-check could never
        // fire — stranding its peers in the polling loop and hanging
        // the join below. This guard halts the scheduler on the way
        // out of a panicking worker; the pool then joins everyone and
        // re-raises the original panic.
        struct HaltOnPanic<'a>(&'a Scheduler);
        impl Drop for HaltOnPanic<'_> {
            fn drop(&mut self) {
                if std::thread::panicking() {
                    self.0.halt();
                }
            }
        }
        let pins = run_pool(&PoolConfig::pinned(workers), |w, pinned| {
            let _guard = HaltOnPanic(&scheduler);
            let worker = Worker {
                heap,
                txns,
                mv: &mv,
                scheduler: &scheduler,
                counters: &counters,
                base: BaseSource::Heap,
                park: None,
            };
            worker.run(w);
            pinned
        });
        mv.write_back(heap);
        BatchReport {
            txns: txns.len(),
            executions: counters.executions.load(Ordering::Relaxed),
            validations: counters.validations.load(Ordering::Relaxed),
            validation_aborts: counters.validation_aborts.load(Ordering::Relaxed),
            dependencies: counters.dependencies.load(Ordering::Relaxed),
            steals: scheduler.steals(),
            overlapped_txns: 0,
            pinned_workers: pins.iter().filter(|&&p| p).count() as u64,
            elapsed: t0.elapsed(),
        }
    }

    /// Stream blocks through one persistent pinned worker pool with
    /// cross-block pipelining (see the module docs). `source` is called
    /// with the controller's current block size and returns the next
    /// block of transactions — `None` (or an empty block) ends the
    /// stream. Each completed block feeds the controller (conflict rate
    /// *and* wall time, for the latency target). The final heap state
    /// is bit-identical to sequential execution of the concatenated
    /// stream.
    pub fn run_pipelined<'b, M, S>(
        heap: &TxHeap,
        source: S,
        concurrency: usize,
        ctl: &mut BlockSizeController,
    ) -> BatchReport
    where
        M: MvStore,
        S: FnMut(usize) -> Option<Vec<BatchTxn<'b>>> + Send,
    {
        Self::run_pipelined_with::<M, S, (), _>(heap, source, concurrency, ctl, || ()).0
    }

    /// [`BatchSystem::run_pipelined`] plus a `main` job that runs on
    /// the *calling thread* while the pool works — the streaming
    /// pipeline's producer side (which may be thread-pinned, e.g. the
    /// PJRT client) runs there.
    pub fn run_pipelined_with<'b, M, S, R, F>(
        heap: &TxHeap,
        source: S,
        concurrency: usize,
        ctl: &mut BlockSizeController,
        main: F,
    ) -> (BatchReport, R)
    where
        M: MvStore,
        S: FnMut(usize) -> Option<Vec<BatchTxn<'b>>> + Send,
        F: FnOnce() -> R,
    {
        let t0 = Instant::now();
        let workers = concurrency.max(1);
        let source = Mutex::new(source);
        let ctl = Mutex::new(ctl);
        let report = Mutex::new(BatchReport::default());
        let window: Mutex<VecDeque<Arc<BlockRun<'b, M>>>> = Mutex::new(VecDeque::new());
        let exhausted = AtomicBool::new(false);
        let halted = AtomicBool::new(false);
        let pinned = AtomicU64::new(0);

        // Pull the next block from the source and admit it. Single
        // puller at a time (try_lock); the source may block (e.g. a
        // channel recv) without holding up head completion, which only
        // needs the window lock.
        let admit = |_w: usize| {
            let Ok(mut src) = source.try_lock() else {
                std::thread::yield_now();
                return;
            };
            if exhausted.load(Ordering::SeqCst) {
                return;
            }
            {
                let win = window.lock().unwrap();
                match win.len() {
                    0 => {}
                    // Overlap admission waits for the head's execution
                    // stream to drain: the overlap targets the
                    // validation tail, not the whole block.
                    1 => {
                        if !win[0].scheduler.execution_drained() {
                            return;
                        }
                    }
                    _ => return,
                }
            }
            let size = { ctl.lock().unwrap().current().max(1) };
            match (*src)(size) {
                Some(txns) if !txns.is_empty() => {
                    let run = Arc::new(BlockRun::new(txns, workers));
                    let mut win = window.lock().unwrap();
                    if win.is_empty() {
                        run.prev_done.store(true, Ordering::SeqCst);
                    }
                    win.push_back(run);
                }
                _ => exhausted.store(true, Ordering::SeqCst),
            }
        };

        // Complete the head block: exactly one worker claims the
        // write-back (under the window lock, so admission and the next
        // completion are ordered after it), feeds the controller, and
        // promotes the overlap block — resume its parked transactions
        // and force a full revalidation pass against the now-final
        // heap.
        let complete_head = |head: &Arc<BlockRun<'b, M>>| {
            let mut win = window.lock().unwrap();
            match win.front() {
                Some(front) if Arc::ptr_eq(front, head) => {}
                _ => return, // someone else already completed it
            }
            if !head.scheduler.done() || head.completed.swap(true, Ordering::SeqCst) {
                return;
            }
            head.mv.write_back(heap);
            ctl.lock().unwrap().observe_block(
                head.counters.executions.load(Ordering::Relaxed),
                head.txns.len() as u64,
                head.admitted.elapsed(),
            );
            report.lock().unwrap().merge(&head.report());
            win.pop_front();
            if let Some(next) = win.front() {
                let mut parked = next.parked.lock().unwrap();
                next.prev_done.store(true, Ordering::SeqCst);
                let resume = std::mem::take(&mut *parked);
                drop(parked);
                next.scheduler.resume_external(&resume);
                next.scheduler.reopen_validation();
            }
        };

        let (_, r) = run_pool_with(
            &PoolConfig::pinned(workers),
            |w, is_pinned| {
                if is_pinned {
                    pinned.fetch_add(1, Ordering::SeqCst);
                }
                // A panicking worker must not strand its peers: flag the
                // session halted and halt every admitted scheduler.
                struct Guard<'a, 'b, M: MvStore> {
                    halted: &'a AtomicBool,
                    window: &'a Mutex<VecDeque<Arc<BlockRun<'b, M>>>>,
                }
                impl<M: MvStore> Drop for Guard<'_, '_, M> {
                    fn drop(&mut self) {
                        if std::thread::panicking() {
                            self.halted.store(true, Ordering::SeqCst);
                            if let Ok(win) = self.window.lock() {
                                for b in win.iter() {
                                    b.scheduler.halt();
                                }
                            }
                        }
                    }
                }
                let _guard = Guard {
                    halted: &halted,
                    window: &window,
                };
                loop {
                    if halted.load(Ordering::SeqCst) {
                        return;
                    }
                    let (head, overlap) = {
                        let win = window.lock().unwrap();
                        (win.front().cloned(), win.get(1).cloned())
                    };
                    let Some(head) = head else {
                        if exhausted.load(Ordering::SeqCst) {
                            return;
                        }
                        admit(w);
                        continue;
                    };
                    // 1) Head work first: it gates everything behind
                    // it. Drain the head scheduler in place — one
                    // window-lock snapshot amortizes over a whole run
                    // of tasks, keeping the mutex off the per-task hot
                    // path. (A snapshot can go stale while we drain;
                    // that's fine: a completed-elsewhere head's
                    // scheduler just hands out no more tasks.)
                    let mut did_work = false;
                    {
                        let worker = Worker {
                            heap,
                            txns: head.txns.as_slice(),
                            mv: &head.mv,
                            scheduler: &head.scheduler,
                            counters: &head.counters,
                            base: BaseSource::Heap,
                            park: None,
                        };
                        while let Some(task) = head.scheduler.next_task(w) {
                            worker.step(task);
                            did_work = true;
                        }
                    }
                    if did_work {
                        continue;
                    }
                    if head.scheduler.done() {
                        complete_head(&head);
                        continue;
                    }
                    // 2) Head is draining its validation tail: overlap
                    // into the next block (same in-place drain).
                    if let Some(ov) = overlap.as_ref() {
                        let worker = Worker {
                            heap,
                            txns: ov.txns.as_slice(),
                            mv: &ov.mv,
                            scheduler: &ov.scheduler,
                            counters: &ov.counters,
                            base: BaseSource::Prev {
                                mv: &head.mv,
                                done: &ov.prev_done,
                            },
                            park: Some(CrossBlockPark {
                                prev_done: &ov.prev_done,
                                parked: &ov.parked,
                            }),
                        };
                        while let Some(task) = ov.scheduler.next_task(w) {
                            worker.step(task);
                            did_work = true;
                        }
                        if did_work {
                            continue;
                        }
                    } else if head.scheduler.execution_drained()
                        && !exhausted.load(Ordering::SeqCst)
                    {
                        admit(w);
                        continue;
                    }
                    std::hint::spin_loop();
                }
            },
            main,
        );

        let mut rep = { report.lock().unwrap().clone() };
        rep.elapsed = t0.elapsed();
        rep.pinned_workers = pinned.load(Ordering::SeqCst);
        (rep, r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::access::DirectAccess;

    fn counter_txns<'h>(addr: usize, n: usize) -> Vec<BatchTxn<'h>> {
        (0..n)
            .map(|_| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(addr)?;
                    t.write(addr, v + 1)
                })
            })
            .collect()
    }

    /// Drain `txns` into `block`-sized chunks through the pipelined
    /// session (the same shipped source the workloads use).
    fn run_pipelined_chunks(
        heap: &TxHeap,
        txns: Vec<BatchTxn<'_>>,
        block: usize,
        workers: usize,
    ) -> BatchReport {
        let mut ctl = BlockSizeController::fixed(block);
        workload::run_txns_pipelined(heap, txns, workers, &mut ctl)
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let heap = TxHeap::new(64);
        let r = BatchSystem::run(&heap, &[], 4);
        assert_eq!(r.txns, 0);
        assert_eq!(r.executions, 0);
    }

    #[test]
    fn single_worker_matches_sequential() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(1);
        let r = BatchSystem::run(&heap, &counter_txns(a, 50), 1);
        assert_eq!(r.txns, 50);
        assert_eq!(heap.load(a), 50);
    }

    #[test]
    fn high_conflict_counter_is_exact_under_concurrency() {
        // Every transaction RMWs the same word: worst case for
        // speculation, but the result must still be exact — on both
        // stores.
        for workers in [2usize, 4, 8] {
            let heap = TxHeap::new(64);
            let a = heap.alloc(1);
            heap.store(a, 1000);
            let r = BatchSystem::run(&heap, &counter_txns(a, 200), workers);
            assert_eq!(heap.load(a), 1200, "workers={workers}");
            assert!(r.executions >= 200, "every txn executes at least once");
            assert_eq!(r.txns, 200);

            let heap_m = TxHeap::new(64);
            let a_m = heap_m.alloc(1);
            heap_m.store(a_m, 1000);
            let rm = BatchSystem::run_baseline_mutex(&heap_m, &counter_txns(a_m, 200), workers);
            assert_eq!(heap_m.load(a_m), 1200, "mutex baseline, workers={workers}");
            assert_eq!(rm.txns, 200);
        }
    }

    #[test]
    fn disjoint_txns_commit_without_aborts() {
        let heap = TxHeap::new(1 << 12);
        let base = heap.alloc(256);
        let txns: Vec<BatchTxn> = (0..64)
            .map(|i| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(base + i)?;
                    t.write(base + i, v + 10 + i as u64)
                })
            })
            .collect();
        let r = BatchSystem::run(&heap, &txns, 4);
        assert_eq!(r.validation_aborts, 0, "disjoint batch must not abort");
        for i in 0..64usize {
            assert_eq!(heap.load(base + i), 10 + i as u64);
        }
    }

    #[test]
    fn read_chain_respects_index_order() {
        // txn i reads slot[i-1] and writes slot[i] = slot[i-1] + 1: the
        // only correct outcome is the fully propagated chain, which
        // forces the executor through dependencies/re-incarnations.
        const N: usize = 32;
        let heap = TxHeap::new(1 << 10);
        let base = heap.alloc(N + 1);
        heap.store(base, 7);
        let txns: Vec<BatchTxn> = (0..N)
            .map(|i| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(base + i)?;
                    t.write(base + i + 1, v + 1)
                })
            })
            .collect();
        for workers in [1usize, 3, 8] {
            let heap2 = TxHeap::new(1 << 10);
            let b2 = heap2.alloc(N + 1);
            assert_eq!(b2, base);
            heap2.store(b2, 7);
            BatchSystem::run(&heap2, &txns, workers);
            for i in 0..=N {
                assert_eq!(heap2.load(b2 + i), 7 + i as u64, "slot {i}, workers {workers}");
            }
        }
    }

    #[test]
    fn data_dependent_write_sets_match_sequential() {
        // Append-to-log shape (the computation kernel's collect phase):
        // the write address depends on a value read — write sets change
        // across incarnations.
        const N: usize = 40;
        let run_seq = |heap: &TxHeap, txns: &[BatchTxn]| {
            let mut acc = DirectAccess { heap };
            for t in txns {
                (t.body)(&mut acc).unwrap();
            }
        };
        let mk_txns = |count_addr: usize, log_base: usize| -> Vec<BatchTxn<'static>> {
            (0..N)
                .map(|i| {
                    BatchTxn::new(move |t: &mut dyn TxAccess| {
                        let n = t.read(count_addr)?;
                        t.write(log_base + n as usize, 1000 + i as u64)?;
                        t.write(count_addr, n + 1)
                    })
                })
                .collect()
        };
        let heap_a = TxHeap::new(1 << 10);
        let count_a = heap_a.alloc_lines(1);
        let log_a = heap_a.alloc(N);
        run_seq(&heap_a, &mk_txns(count_a, log_a));

        let heap_b = TxHeap::new(1 << 10);
        let count_b = heap_b.alloc_lines(1);
        let log_b = heap_b.alloc(N);
        assert_eq!((count_a, log_a), (count_b, log_b));
        BatchSystem::run(&heap_b, &mk_txns(count_b, log_b), 4);

        assert_eq!(heap_a.load(count_a), heap_b.load(count_b));
        for i in 0..N {
            assert_eq!(heap_a.load(log_a + i), heap_b.load(log_b + i), "log slot {i}");
        }
    }

    #[test]
    fn pipelined_counter_chain_is_exact_across_blocks() {
        // The worst case for cross-block speculation: every transaction
        // RMWs the same word, so every block-N+1 base read guesses a
        // value the block-N tail is still changing. The forced
        // revalidation at promotion must repair all of it.
        for (workers, block) in [(1usize, 8usize), (2, 16), (4, 8), (3, 64)] {
            let heap = TxHeap::new(64);
            let a = heap.alloc(1);
            heap.store(a, 500);
            let r = run_pipelined_chunks(&heap, counter_txns(a, 200), block, workers);
            assert_eq!(r.txns, 200, "workers={workers} block={block}");
            assert_eq!(
                heap.load(a),
                700,
                "workers={workers} block={block}: pipelined chain must be exact"
            );
        }
    }

    #[test]
    fn pipelined_read_chain_matches_sequential_across_blocks() {
        const N: usize = 48;
        let mk = |base: usize| -> Vec<BatchTxn<'static>> {
            (0..N)
                .map(|i| {
                    BatchTxn::new(move |t: &mut dyn TxAccess| {
                        let v = t.read(base + i)?;
                        t.write(base + i + 1, v + 1)
                    })
                })
                .collect()
        };
        for workers in [1usize, 2, 4] {
            let heap = TxHeap::new(1 << 10);
            let base = heap.alloc(N + 1);
            heap.store(base, 3);
            run_pipelined_chunks(&heap, mk(base), 8, workers);
            for i in 0..=N {
                assert_eq!(heap.load(base + i), 3 + i as u64, "slot {i}, workers {workers}");
            }
        }
    }

    #[test]
    fn pipelined_disjoint_stream_reports_no_aborts() {
        let heap = TxHeap::new(1 << 12);
        let base = heap.alloc(256);
        let txns: Vec<BatchTxn> = (0..128)
            .map(|i| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(base + i)?;
                    t.write(base + i, v + 1 + i as u64)
                })
            })
            .collect();
        let r = run_pipelined_chunks(&heap, txns, 16, 3);
        assert_eq!(r.txns, 128);
        assert_eq!(r.validation_aborts, 0, "disjoint stream must not abort");
        for i in 0..128usize {
            assert_eq!(heap.load(base + i), 1 + i as u64);
        }
    }

    #[test]
    fn pipelined_empty_source_is_a_noop() {
        let heap = TxHeap::new(64);
        let mut ctl = BlockSizeController::fixed(8);
        let r = BatchSystem::run_pipelined::<MvMemory, _>(&heap, |_| None, 3, &mut ctl);
        assert_eq!(r.txns, 0);
        assert_eq!(r.executions, 0);
    }

    #[test]
    fn pipelined_session_feeds_the_controller_per_block() {
        let heap = TxHeap::new(1 << 10);
        let base = heap.alloc(64);
        let txns: Vec<BatchTxn> = (0..64)
            .map(|i| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(base + i)?;
                    t.write(base + i, v + 1)
                })
            })
            .collect();
        let mut ctl = BlockSizeController::with_bounds(8, 4, 64, 8);
        let r = workload::run_txns_pipelined(&heap, txns, 2, &mut ctl);
        assert_eq!(r.txns, 64);
        assert!(ctl.samples >= 2, "every completed block must be observed");
        assert!(
            ctl.current() > 8,
            "a clean disjoint stream must grow the block"
        );
    }

    #[test]
    fn report_merge_accumulates() {
        let mut a = BatchReport {
            txns: 10,
            executions: 12,
            validations: 11,
            validation_aborts: 2,
            dependencies: 1,
            steals: 3,
            overlapped_txns: 4,
            pinned_workers: 2,
            elapsed: Duration::from_millis(5),
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.txns, 20);
        assert_eq!(a.executions, 24);
        assert_eq!(a.steals, 6);
        assert_eq!(a.overlapped_txns, 8);
        assert_eq!(a.pinned_workers, 2, "pin count is a run property: max, not sum");
        assert_eq!(a.elapsed, Duration::from_millis(10));
        let s = a.to_stats();
        assert_eq!(s.sw_commits, 20);
        assert_eq!(s.sw_aborts, 6);
        assert_eq!(s.steals, 6);
        assert_eq!(s.overlapped_txns, 8);
        assert_eq!(s.total_commits(), 20);
    }
}
