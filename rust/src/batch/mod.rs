//! `batch` — a Block-STM-style speculative batch executor: the fifth
//! synchronization backend.
//!
//! The paper's executors ([`crate::hytm`]) admit transactions one at a
//! time per thread and synchronize each against all concurrent peers.
//! This subsystem instead admits a whole *batch* (a block) of
//! transactions with a fixed serialization order — their index in the
//! batch — and executes them optimistically in parallel:
//!
//! * [`mvmemory`] — the multi-version store. The production
//!   implementation is **lock-free on the hot path**: the address
//!   index is CAS-published chains off an atomic shard array, each
//!   address owns a grow-only version vector whose `(txn, incarnation,
//!   value)` cells publish through a two-word seqlock, and each
//!   transaction's read/write sets are immutable nodes handed off
//!   through one `AtomicPtr` — reads of committed versions take zero
//!   locks, writes CAS-publish. The PR-1 sharded-mutex layout survives
//!   as `MutexMvMemory` behind the same `MvStore` trait, purely so the
//!   benchmark can price what the locks cost;
//! * [`scheduler`] — execution/validation task streams over atomic
//!   index counters, fronted by **per-worker work-stealing deques**
//!   ([`crate::runtime::workers`]): a worker drains its own deque,
//!   refills a whole chunk of indices in one `fetch_add`, and steals
//!   candidates from its peers when both streams are drained. Each
//!   transaction's lifecycle stays packed in a single
//!   `incarnation << 2 | state` atomic status word (CAS transitions;
//!   the only mutex left guards the rare ESTIMATE-dependency lists);
//! * [`executor`] — the worker loop: execute against a recording
//!   [`crate::tm::access::TxAccess`] view → record read/write sets →
//!   validate → abort/re-incarnate;
//! * [`adaptive`] — the [`adaptive::BlockSizeController`]: AIMD block
//!   sizing from each block's observed re-incarnation rate, plus an
//!   optional **latency target** (`--policy
//!   batch=adaptive:latency=MS`) that shrinks the block whenever its
//!   wall time overruns the deadline even at low conflict — the knob
//!   the streaming pipeline sizes by;
//! * [`workload`] — adapters feeding the SSCA-2 kernels (generation,
//!   computation, and kernel-3 subgraph extraction as a
//!   level-synchronous batch BFS whose per-level candidate stream is
//!   consumed lazily, never materialized whole) and the simulator's
//!   [`crate::sim::workload::TxnDesc`] shapes through the batch API.
//!
//! # Cross-block pipelining: the W-deep window
//!
//! [`BatchSystem::run`] executes one block to a full barrier — the
//! benchmark baseline. The shipped paths instead stream blocks through
//! [`BatchSystem::run_pipelined`], which keeps **one persistent pinned,
//! topology-aware worker pool** for the whole stream and overlaps up to
//! **W adjacent blocks** (`BlockSizeController::current_window`;
//! default 2, `--policy batch=adaptive:window=W` raises the ceiling and
//! lets the controller co-tune depth with block size): while block
//! *N*'s validation tail drains, workers already execute blocks *N+1*
//! … *N+W-1*.
//!
//! **The chained base-peek contract.** Block *N+k*'s base reads (no
//! lower in-block writer) resolve through the chain of its draining
//! predecessors, nearest first: peek *N+k-1*'s winning versions; a
//! `Base` answer defers to *N+k-2*, and so on down to the heap. Each
//! resolved read records the observed *value*
//! ([`mvmemory::ReadOrigin::Base`]), never the link it came from — the
//! chain is a guess amplifier, not a correctness dependency. A
//! written-back link short-circuits to the heap (blocks complete
//! strictly in admission order, so a flushed link implies every older
//! link is flushed), and a read that hits *any* live link's ESTIMATE
//! parks the transaction on its immediate predecessor. Promotion stays
//! strictly in admission order: the moment block *N* writes back, block
//! *N+1* — and only it — is promoted to head: parked transactions
//! resume and its scheduler is forced through a **full revalidation
//! pass** against the now-final heap, so every transaction's read set
//! is re-checked against the real base before its own block can write
//! back. Any speculative read that guessed wrong — through however
//! many chain links — re-executes, which is what keeps the final state
//! bit-identical to sequential execution across the whole stream for
//! every window depth. Block *N+k* is only admitted once block
//! *N+k-1*'s execution stream has drained, so every level of the
//! window targets a predecessor's validation tail, never raw execution
//! backlog.
//!
//! **Determinism guarantee.** Whatever interleaving the workers take —
//! whatever block sizes the controller picks, and whether blocks run to
//! a barrier or pipelined — the final heap state equals executing the
//! stream *sequentially in index order* — bit for bit. That is what
//! makes the backend measurable head-to-head against the paper's
//! policies: same inputs, same outputs, different concurrency control.
//! The guarantee is enforced by tests in this module and the
//! `batch_determinism` property suite (including pipelined-vs-oracle
//! and fixed-vs-adaptive sizing properties).
//!
//! **Full routing.** Select it end-to-end with `--policy batch[=N]`,
//! `--policy batch=adaptive`, or `--policy batch=adaptive:latency=MS`
//! ([`crate::hytm::PolicySpec::Batch`] / `PolicySpec::BatchAdaptive`):
//! all three SSCA-2 kernels and the streaming pipeline
//! ([`crate::runtime::pipeline`]) run through the pipelined session. No
//! path silently degrades to per-transaction NOrec: a batch spec
//! reaching `ThreadExecutor::execute` is loudly warned, accounted under
//! the `norec_fallback` stats counter, and reported as
//! `batch(fallback:norec)`. The simulator prices the backend with its
//! own multi-version cost mode (`sim::engine`'s `Mode::MultiVersion`):
//! estimate-wait, validation, re-incarnation charges and an
//! **overlapped block drain** with the same W-block admission
//! lookahead as `run_pipelined`, driven by the *same*
//! `BlockSizeController` (block size co-tuned with window depth) as
//! the live runs.

pub mod adaptive;
pub mod executor;
pub mod mvmemory;
pub mod scheduler;
pub mod workload;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::mem::epoch::EpochGc;
use crate::mem::TxHeap;
use crate::obs::hist::LatencyHist;
use crate::runtime::workers::{run_pool_plan_with, PinPlan, PoolConfig};
use crate::stats::TxStats;
use crate::tm::access::{TxAccess, TxResult};

use adaptive::BlockSizeController;
use executor::{BaseSource, BatchCounters, CrossBlockPark, PrevLink, Worker};
use mvmemory::{MutexMvMemory, MvMemory, MvStore};
use scheduler::{Scheduler, TxnIdx};

/// Default number of transactions admitted per speculative block
/// (`--policy batch=N` overrides it; `--policy batch=adaptive` lets
/// the controller pick).
pub const DEFAULT_BLOCK: usize = 2048;

/// Deadline floor the batch drivers hand the fault-plane watchdog —
/// deliberately far below `watchdog::DEFAULT_BASE_DEADLINE`: batch
/// commits take microseconds, so 30ms of a flat progress counter with
/// the plane installed is decisive, and the commit-latency EWMA term
/// (`SLACK_FACTOR × p50`) still raises the deadline on genuinely slow
/// single-core or debug runs.
const WATCHDOG_BASE: Duration = Duration::from_millis(30);

/// The run's watchdog, if one should exist: only fault-plane runs pay
/// for progress polling.
fn watchdog() -> Option<crate::fault::watchdog::Watchdog> {
    crate::fault::active()
        .then(|| crate::fault::watchdog::Watchdog::new(WATCHDOG_BASE))
}

// -- epoch-reclamation toggle ------------------------------------------

static RECLAIM: AtomicBool = AtomicBool::new(true);
static RECLAIM_ENV: OnceLock<()> = OnceLock::new();

/// Toggle epoch reclamation for pipelined sessions (read once per
/// session at construction). On by default; the bench A/B and the
/// determinism suite flip it to price/verify the leaky baseline.
/// Calling this pins the value — a later `MV_RECLAIM` env read cannot
/// override an explicit choice.
pub fn set_reclaim(on: bool) {
    RECLAIM_ENV.get_or_init(|| ());
    RECLAIM.store(on, Ordering::SeqCst);
}

/// Is epoch reclamation enabled for new pipelined sessions?
/// `MV_RECLAIM=0` in the environment flips the default off (honored
/// once, on first query, unless [`set_reclaim`] already ran).
pub fn reclaim_enabled() -> bool {
    RECLAIM_ENV.get_or_init(|| {
        if std::env::var("MV_RECLAIM").is_ok_and(|v| v == "0") {
            RECLAIM.store(false, Ordering::SeqCst);
        }
    });
    RECLAIM.load(Ordering::SeqCst)
}

/// A batch transaction body. Must be a pure function of the values it
/// reads through the access handle (it may be re-executed any number of
/// times, concurrently with other transactions), and must not return
/// `Err` of its own — only the speculative view aborts an attempt.
pub type BatchBody<'b> = Box<dyn Fn(&mut dyn TxAccess) -> TxResult<()> + Send + Sync + 'b>;

/// One transaction of a batch.
pub struct BatchTxn<'b> {
    pub body: BatchBody<'b>,
}

impl<'b> BatchTxn<'b> {
    pub fn new(body: impl Fn(&mut dyn TxAccess) -> TxResult<()> + Send + Sync + 'b) -> Self {
        Self {
            body: Box::new(body),
        }
    }
}

/// Outcome counters of one (or several, merged) batch runs.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchReport {
    /// Transactions committed (= batch size; every txn commits).
    pub txns: usize,
    /// Incarnation executions started.
    pub executions: u64,
    /// Validation tasks performed.
    pub validations: u64,
    /// Validation aborts (re-incarnations forced by a read-set change).
    pub validation_aborts: u64,
    /// Executions suspended on a lower transaction's ESTIMATE (in-block
    /// dependencies plus cross-block parks).
    pub dependencies: u64,
    /// Candidates taken from a peer worker's deque.
    pub steals: u64,
    /// The subset of `steals` whose victim shared the thief's
    /// socket/L3 locality group (equals `steals` on flat topologies).
    pub local_steals: u64,
    /// Execution attempts started while the previous block was still
    /// draining (cross-block pipelining overlap; 0 for barrier runs).
    pub overlapped_txns: u64,
    /// Pool workers whose core pin was applied.
    pub pinned_workers: u64,
    /// Blocks admitted into a pipelined window (0 for barrier runs).
    pub window_admissions: u64,
    /// Sum over admissions of the window depth *after* the admission —
    /// `window_depth_sum / window_admissions` is the mean blocks in
    /// flight, the W-deep window's utilization.
    pub window_depth_sum: u64,
    /// Transaction bodies that panicked, were caught before publishing
    /// anything, quarantined, and re-dispatched.
    pub quarantines: u64,
    /// Watchdog recovery passes (lost-wakeup re-ready + forced
    /// revalidation) after a missed progress deadline.
    pub watchdog_kicks: u64,
    /// Watchdog escalations to the degraded serial backend.
    pub degradations: u64,
    /// Faults the installed plane injected process-wide while this run
    /// executed (0 when no `--faults` plane is installed).
    pub faults_injected: u64,
    /// Peak live (retired − reclaimed) recorded-set cells in the
    /// session's epoch limbo — the bounded-memory metric: a plateau
    /// under reclamation, ≈ `mv_retired` with reclamation off. 0 for
    /// barrier runs (no reclamation domain).
    pub mv_live_cells: u64,
    /// Recorded-set cells retired into the epoch limbo (superseded
    /// incarnations plus promotion-time final sets).
    pub mv_retired: u64,
    /// Retired cells actually freed (their epoch passed every live
    /// worker). Equals `mv_retired` by session end with reclamation
    /// on; 0 with it off.
    pub mv_reclaimed: u64,
    /// Peak arena bytes backing one block's version index (entries +
    /// segments).
    pub arena_bytes: u64,
    pub elapsed: Duration,
    /// Winning execution-attempt latency per transaction (only
    /// populated when `obs::timing_enabled()`).
    pub txn_lat: LatencyHist,
    /// Admit→promote latency per block (only populated when
    /// `obs::timing_enabled()`).
    pub block_lat: LatencyHist,
}

impl BatchReport {
    /// Accumulate another run (e.g. the next block of a long stream).
    pub fn merge(&mut self, other: &BatchReport) {
        self.txns += other.txns;
        self.executions += other.executions;
        self.validations += other.validations;
        self.validation_aborts += other.validation_aborts;
        self.dependencies += other.dependencies;
        self.steals += other.steals;
        self.local_steals += other.local_steals;
        self.overlapped_txns += other.overlapped_txns;
        self.pinned_workers = self.pinned_workers.max(other.pinned_workers);
        self.window_admissions += other.window_admissions;
        self.window_depth_sum += other.window_depth_sum;
        self.quarantines += other.quarantines;
        self.watchdog_kicks += other.watchdog_kicks;
        self.degradations += other.degradations;
        self.faults_injected += other.faults_injected;
        // Peaks are session properties: max, not sum.
        self.mv_live_cells = self.mv_live_cells.max(other.mv_live_cells);
        self.mv_retired += other.mv_retired;
        self.mv_reclaimed += other.mv_reclaimed;
        self.arena_bytes = self.arena_bytes.max(other.arena_bytes);
        self.elapsed += other.elapsed;
        self.txn_lat.merge(&other.txn_lat);
        self.block_lat.merge(&other.block_lat);
    }

    /// Fraction of steals served by a same-locality-group victim.
    /// Vacuously 1.0 when nothing was stolen (or on flat topologies,
    /// where every steal is local by definition).
    pub fn locality_steal_ratio(&self) -> f64 {
        if self.steals == 0 {
            1.0
        } else {
            self.local_steals as f64 / self.steals as f64
        }
    }

    /// Mean blocks in flight at admission time (1.0 = pure barrier
    /// stream, up to W for a saturated W-deep window; 0.0 when nothing
    /// was admitted through a pipelined session).
    pub fn window_occupancy(&self) -> f64 {
        if self.window_admissions == 0 {
            0.0
        } else {
            self.window_depth_sum as f64 / self.window_admissions as f64
        }
    }

    /// Fold into the stats-plane shape: batch commits are software
    /// commits (speculation in software, like an STM), re-executions
    /// count as software aborts; the worker-runtime counters ride
    /// along.
    pub fn to_stats(&self) -> TxStats {
        let mut s = TxStats::new();
        s.sw_commits = self.txns as u64;
        s.sw_aborts = self.validation_aborts + self.dependencies;
        s.steals = self.steals;
        s.local_steals = self.local_steals;
        s.overlapped_txns = self.overlapped_txns;
        s.pinned_workers = self.pinned_workers;
        s.quarantines = self.quarantines;
        s.watchdog_kicks = self.watchdog_kicks;
        s.degradations = self.degradations;
        s.faults_injected = self.faults_injected;
        s.mv_live_cells = self.mv_live_cells;
        s.mv_retired = self.mv_retired;
        s.mv_reclaimed = self.mv_reclaimed;
        s.arena_bytes = self.arena_bytes;
        s.time_ns = self.elapsed.as_nanos() as u64;
        s.txn_lat = self.txn_lat;
        s.block_lat = self.block_lat;
        s
    }
}

/// One admitted block of a pipelined run: its transactions plus the
/// per-block scheduler, store, and counters.
struct BlockRun<'b, M: MvStore> {
    txns: Vec<BatchTxn<'b>>,
    scheduler: Scheduler,
    mv: M,
    counters: BatchCounters,
    /// The predecessor block has completed (written back). The first
    /// block of a stream starts true.
    prev_done: AtomicBool,
    /// This block's winning versions have been flushed to the heap —
    /// the flag chained base-peeks short-circuit on (blocks complete
    /// in admission order, so a set flag covers every older block too).
    written_back: AtomicBool,
    /// Transactions parked on the predecessor (see
    /// [`executor::CrossBlockPark`]).
    parked: Mutex<Vec<TxnIdx>>,
    /// Write-back claimed (exactly one worker completes a block).
    completed: AtomicBool,
    admitted: Instant,
    /// Stream-wide admission index (set at admission; the trace plane's
    /// block id).
    seq: AtomicU64,
}

impl<'b, M: MvStore> BlockRun<'b, M> {
    fn new(txns: Vec<BatchTxn<'b>>, workers: usize, groups: &[usize]) -> Self {
        let n = txns.len();
        Self {
            txns,
            scheduler: Scheduler::with_groups(n, workers, groups),
            mv: M::new(n),
            counters: BatchCounters::default(),
            prev_done: AtomicBool::new(false),
            written_back: AtomicBool::new(false),
            parked: Mutex::new(Vec::new()),
            completed: AtomicBool::new(false),
            admitted: Instant::now(),
            seq: AtomicU64::new(0),
        }
    }

    /// This block's contribution to the stream report (elapsed, pin
    /// counts, and window occupancy are session-level and filled in by
    /// the caller).
    fn report(&self) -> BatchReport {
        BatchReport {
            txns: self.txns.len(),
            executions: self.counters.executions.load(Ordering::Relaxed),
            validations: self.counters.validations.load(Ordering::Relaxed),
            validation_aborts: self.counters.validation_aborts.load(Ordering::Relaxed),
            dependencies: self.counters.dependencies.load(Ordering::Relaxed),
            steals: self.scheduler.steals(),
            local_steals: self.scheduler.local_steals(),
            overlapped_txns: self.counters.overlapped.load(Ordering::Relaxed),
            pinned_workers: 0,
            window_admissions: 0,
            window_depth_sum: 0,
            quarantines: self.counters.quarantines.load(Ordering::Relaxed),
            watchdog_kicks: self.counters.watchdog_kicks.load(Ordering::Relaxed),
            degradations: self.counters.degradations.load(Ordering::Relaxed),
            faults_injected: 0,
            // Memory counters are session-level (the gc outlives every
            // block); filled in by the session finale.
            mv_live_cells: 0,
            mv_retired: 0,
            mv_reclaimed: 0,
            arena_bytes: 0,
            elapsed: Duration::ZERO,
            txn_lat: self.counters.txn_lat.fold(),
            block_lat: LatencyHist::default(),
        }
    }
}

/// The batch backend entry point.
pub struct BatchSystem;

impl BatchSystem {
    /// Execute `txns` as ONE block with `concurrency` workers over the
    /// lock-free multi-version store, to a full barrier. Blocks until
    /// every transaction has committed, then flushes the winning
    /// versions to `heap`. The final heap state is bit-identical to
    /// running the batch sequentially in index order. (The streamed,
    /// cross-block-overlapping variant is [`BatchSystem::run_pipelined`];
    /// this barrier form is the benchmark baseline and the single-block
    /// primitive.)
    pub fn run(heap: &TxHeap, txns: &[BatchTxn<'_>], concurrency: usize) -> BatchReport {
        Self::run_with::<MvMemory>(heap, txns, concurrency)
    }

    /// Same contract as [`BatchSystem::run`], but over the PR-1
    /// sharded-mutex store — the baseline `benches/batch_throughput`
    /// measures the lock-free hot path against. Not used by any
    /// shipped path.
    pub fn run_baseline_mutex(
        heap: &TxHeap,
        txns: &[BatchTxn<'_>],
        concurrency: usize,
    ) -> BatchReport {
        Self::run_with::<MutexMvMemory>(heap, txns, concurrency)
    }

    fn run_with<M: MvStore>(
        heap: &TxHeap,
        txns: &[BatchTxn<'_>],
        concurrency: usize,
    ) -> BatchReport {
        let t0 = Instant::now();
        if txns.is_empty() {
            return BatchReport {
                elapsed: t0.elapsed(),
                ..BatchReport::default()
            };
        }
        let workers = concurrency.max(1).min(txns.len());
        let plan = PinPlan::detect();
        let scheduler =
            Scheduler::with_groups(txns.len(), workers, &plan.worker_groups(workers));
        let mv = M::new(txns.len());
        let counters = BatchCounters::default();
        let wd = watchdog();
        let faults_before = crate::fault::injected_total();
        // If a worker panics (a body violating the infallibility
        // contract, or a bug in a user closure), it unwinds with
        // `num_active` still elevated and the done-check could never
        // fire — stranding its peers in the polling loop and hanging
        // the join below. This guard halts the scheduler on the way
        // out of a panicking worker; the pool then joins everyone and
        // re-raises the original panic.
        struct HaltOnPanic<'a>(&'a Scheduler);
        impl Drop for HaltOnPanic<'_> {
            fn drop(&mut self) {
                if std::thread::panicking() {
                    self.0.halt();
                }
            }
        }
        let (pins, _) = run_pool_plan_with(
            &plan,
            workers,
            |w, pinned| {
                let _guard = HaltOnPanic(&scheduler);
                let worker = Worker {
                    heap,
                    txns,
                    mv: &mv,
                    scheduler: &scheduler,
                    counters: &counters,
                    base: BaseSource::Heap,
                    park: None,
                    wd: wd.as_ref(),
                };
                worker.run(w);
                pinned
            },
            || (),
        );
        mv.write_back(heap);
        let elapsed = t0.elapsed();
        let mut block_lat = LatencyHist::default();
        if crate::obs::timing_enabled() {
            // A barrier run is one block: admit→promote is the run.
            block_lat.record_duration(elapsed);
        }
        BatchReport {
            txns: txns.len(),
            executions: counters.executions.load(Ordering::Relaxed),
            validations: counters.validations.load(Ordering::Relaxed),
            validation_aborts: counters.validation_aborts.load(Ordering::Relaxed),
            dependencies: counters.dependencies.load(Ordering::Relaxed),
            steals: scheduler.steals(),
            local_steals: scheduler.local_steals(),
            overlapped_txns: 0,
            pinned_workers: pins.iter().filter(|&&p| p).count() as u64,
            window_admissions: 0,
            window_depth_sum: 0,
            quarantines: counters.quarantines.load(Ordering::Relaxed),
            watchdog_kicks: counters.watchdog_kicks.load(Ordering::Relaxed),
            degradations: counters.degradations.load(Ordering::Relaxed),
            faults_injected: crate::fault::injected_total().saturating_sub(faults_before),
            // Barrier runs keep the store's prev-chains until the block
            // drops — no reclamation domain, nothing to report.
            mv_live_cells: 0,
            mv_retired: 0,
            mv_reclaimed: 0,
            arena_bytes: 0,
            elapsed,
            txn_lat: counters.txn_lat.fold(),
            block_lat,
        }
    }

    /// Stream blocks through one persistent pinned worker pool with
    /// W-deep cross-block pipelining (see the module docs). `source` is
    /// called with the controller's current block size and returns the
    /// next block of transactions — `None` (or an empty block) ends the
    /// stream. The controller also sets the window depth
    /// ([`BlockSizeController::current_window`]); each completed block
    /// feeds it conflict rate *and* wall time. The final heap state is
    /// bit-identical to sequential execution of the concatenated
    /// stream, for every window depth.
    pub fn run_pipelined<'b, M, S>(
        heap: &TxHeap,
        source: S,
        concurrency: usize,
        ctl: &mut BlockSizeController,
    ) -> BatchReport
    where
        M: MvStore,
        S: FnMut(usize) -> Option<Vec<BatchTxn<'b>>> + Send,
    {
        Self::run_pipelined_pool_with::<M, S, (), _>(
            heap,
            source,
            &PoolConfig::pinned(concurrency),
            ctl,
            || (),
        )
        .0
    }

    /// [`BatchSystem::run_pipelined`] plus a `main` job that runs on
    /// the *calling thread* while the pool works — the streaming
    /// pipeline's producer side (which may be thread-pinned, e.g. the
    /// PJRT client) runs there.
    pub fn run_pipelined_with<'b, M, S, R, F>(
        heap: &TxHeap,
        source: S,
        concurrency: usize,
        ctl: &mut BlockSizeController,
        main: F,
    ) -> (BatchReport, R)
    where
        M: MvStore,
        S: FnMut(usize) -> Option<Vec<BatchTxn<'b>>> + Send,
        F: FnOnce() -> R,
    {
        Self::run_pipelined_pool_with::<M, S, R, F>(
            heap,
            source,
            &PoolConfig::pinned(concurrency),
            ctl,
            main,
        )
    }

    /// [`BatchSystem::run_pipelined`] with an explicit [`PoolConfig`] —
    /// how the determinism suite exercises the topology-fallback path
    /// (`pin: false` → flat `PinPlan::none()` groups).
    pub fn run_pipelined_pool<'b, M, S>(
        heap: &TxHeap,
        source: S,
        pool: &PoolConfig,
        ctl: &mut BlockSizeController,
    ) -> BatchReport
    where
        M: MvStore,
        S: FnMut(usize) -> Option<Vec<BatchTxn<'b>>> + Send,
    {
        Self::run_pipelined_pool_with::<M, S, (), _>(heap, source, pool, ctl, || ()).0
    }

    /// The full pipelined session: explicit pool shape plus a
    /// main-thread job. Everything above delegates here (with a no-op
    /// promotion hook).
    pub fn run_pipelined_pool_with<'b, M, S, R, F>(
        heap: &TxHeap,
        source: S,
        pool: &PoolConfig,
        ctl: &mut BlockSizeController,
        main: F,
    ) -> (BatchReport, R)
    where
        M: MvStore,
        S: FnMut(usize) -> Option<Vec<BatchTxn<'b>>> + Send,
        F: FnOnce() -> R,
    {
        Self::run_pipelined_session::<M, S, R, F, _>(
            heap,
            source,
            pool,
            ctl,
            main,
            |_: u64, _: &M, _: &BatchReport| (),
        )
    }

    /// [`run_pipelined_pool_with`](Self::run_pipelined_pool_with) plus
    /// an `on_promote` hook — the continuous-serving plane's tap into
    /// the promotion boundary. The hook runs on the completing worker
    /// once the head block's scheduler is done and its completion is
    /// claimed, but **before** its winning versions are written back
    /// to the heap (and before its sets retire and the epoch
    /// advances): the one point where the block's final `(addr,
    /// value)` pairs are knowable (`MvStore::for_each_winning`) while
    /// the heap still holds the pre-promotion state — exactly what an
    /// abort-free snapshot log needs under concurrent promotions. The
    /// hook receives the block's stream-wide admission sequence, its
    /// store, and its (already-folded) per-block report. Called under
    /// the window lock, so promotions — and hook invocations — are
    /// strictly ordered by sequence; keep it short.
    pub fn run_pipelined_session<'b, M, S, R, F, P>(
        heap: &TxHeap,
        source: S,
        pool: &PoolConfig,
        ctl: &mut BlockSizeController,
        main: F,
        on_promote: P,
    ) -> (BatchReport, R)
    where
        M: MvStore,
        S: FnMut(usize) -> Option<Vec<BatchTxn<'b>>> + Send,
        F: FnOnce() -> R,
        P: Fn(u64, &M, &BatchReport) + Sync,
    {
        let t0 = Instant::now();
        let workers = pool.workers.max(1);
        let plan = PinPlan::for_config(pool);
        let groups = plan.worker_groups(workers);
        let source = Mutex::new(source);
        let ctl = Mutex::new(ctl);
        let report = Mutex::new(BatchReport::default());
        let window: Mutex<VecDeque<Arc<BlockRun<'b, M>>>> = Mutex::new(VecDeque::new());
        let exhausted = AtomicBool::new(false);
        let halted = AtomicBool::new(false);
        let pinned = AtomicU64::new(0);
        let admissions = AtomicU64::new(0);
        let depth_sum = AtomicU64::new(0);
        let wd = watchdog();
        let faults_before = crate::fault::injected_total();
        // Progress already contributed by completed (popped) blocks, so
        // the watchdog's progress counter stays monotone across block
        // promotions (a completing block's live counters leave the
        // window sum and re-enter here, under the same window lock).
        let completed_progress = AtomicU64::new(0);
        // The session's epoch-reclamation domain: workers pin an epoch
        // per drain iteration, promotion advances it, and superseded
        // recorded sets retire through its limbo (`mem::epoch`). One
        // domain for the whole stream — the blocks' stores attach at
        // admission.
        let gc = Arc::new(EpochGc::with_reclaim(workers, reclaim_enabled()));

        // Pull the next block from the source and admit it. Single
        // puller at a time (try_lock); the source may block (e.g. a
        // channel recv) without holding up head completion, which only
        // needs the window lock.
        let admit = |_w: usize| {
            let Ok(mut src) = source.try_lock() else {
                std::thread::yield_now();
                return;
            };
            if exhausted.load(Ordering::SeqCst) {
                return;
            }
            let (size, depth) = {
                let c = ctl.lock().unwrap();
                (c.current().max(1), c.current_window().max(1))
            };
            {
                let win = window.lock().unwrap();
                if win.len() >= depth {
                    return;
                }
                // Chained admission gate: a new block only enters once
                // the youngest admitted block's execution stream has
                // drained — every level of the window overlaps a
                // predecessor's validation tail, never raw execution
                // backlog.
                if let Some(last) = win.back() {
                    if !last.scheduler.execution_drained() {
                        return;
                    }
                }
            }
            match (*src)(size) {
                Some(txns) if !txns.is_empty() => {
                    let n = txns.len() as u64;
                    let run = Arc::new(BlockRun::new(txns, workers, &groups));
                    run.mv.attach_gc(&gc);
                    let mut win = window.lock().unwrap();
                    if win.is_empty() {
                        run.prev_done.store(true, Ordering::SeqCst);
                    }
                    let seq = admissions.fetch_add(1, Ordering::SeqCst);
                    run.seq.store(seq, Ordering::SeqCst);
                    depth_sum.fetch_add(win.len() as u64 + 1, Ordering::SeqCst);
                    win.push_back(run);
                    crate::obs::trace::block_admitted(seq, n);
                }
                _ => exhausted.store(true, Ordering::SeqCst),
            }
        };

        // Complete the head block: exactly one worker claims the
        // write-back (under the window lock, so admission and the next
        // completion are ordered after it), feeds the controller, and
        // promotes the *next* block — and only it, admission order is
        // promotion order — to head: resume its parked transactions
        // and force a full revalidation pass against the now-final
        // heap. Deeper blocks keep speculating; their chains shorten
        // through the `written_back` flag.
        let complete_head = |head: &Arc<BlockRun<'b, M>>| {
            let mut win = window.lock().unwrap();
            match win.front() {
                Some(front) if Arc::ptr_eq(front, head) => {}
                _ => return, // someone else already completed it
            }
            if !head.scheduler.done() || head.completed.swap(true, Ordering::SeqCst) {
                return;
            }
            // Fold the block's report once; the promotion hook sees
            // the same numbers the session report merges below.
            let block_report = head.report();
            on_promote(head.seq.load(Ordering::SeqCst), &head.mv, &block_report);
            head.mv.write_back(heap);
            // Publish the flush: stale chain snapshots that still link
            // this block fall through to the heap from here on.
            head.written_back.store(true, Ordering::SeqCst);
            let block_lat = head.admitted.elapsed();
            ctl.lock().unwrap().observe_block(
                head.counters.executions.load(Ordering::Relaxed),
                head.txns.len() as u64,
                block_lat,
            );
            crate::obs::trace::block_promoted(
                head.seq.load(Ordering::SeqCst),
                block_lat.as_nanos() as u64,
            );
            {
                let mut rep = report.lock().unwrap();
                rep.merge(&block_report);
                if crate::obs::timing_enabled() {
                    rep.block_lat.record_duration(block_lat);
                }
            }
            completed_progress.fetch_add(
                head.counters.executions.load(Ordering::Relaxed)
                    + head.counters.validations.load(Ordering::Relaxed),
                Ordering::Relaxed,
            );
            // Promotion is the reclamation epoch boundary: detach the
            // promoted block's recorded sets into limbo, sample its
            // arena footprint, advance the global epoch, and free
            // every limbo bin all live workers have passed. (The
            // completing worker's own pin keeps the bins it may still
            // reference; they free on a later promotion.)
            head.mv.retire_sets();
            gc.note_arena_bytes(head.mv.mem_bytes());
            gc.advance();
            let (freed_cells, freed_bytes) = gc.try_reclaim();
            if freed_cells != 0 || freed_bytes != 0 {
                crate::obs::trace::reclaim(freed_cells, freed_bytes);
            }
            win.pop_front();
            if let Some(next) = win.front() {
                let mut parked = next.parked.lock().unwrap();
                next.prev_done.store(true, Ordering::SeqCst);
                let resume = std::mem::take(&mut *parked);
                drop(parked);
                next.scheduler.resume_external(&resume);
                next.scheduler.reopen_validation();
            }
        };

        let (_, r) = run_pool_plan_with(
            &plan,
            workers,
            |w, is_pinned| {
                if is_pinned {
                    pinned.fetch_add(1, Ordering::SeqCst);
                }
                // A panicking worker must not strand its peers: flag the
                // session halted and halt every admitted scheduler.
                struct Guard<'a, 'b, M: MvStore> {
                    halted: &'a AtomicBool,
                    window: &'a Mutex<VecDeque<Arc<BlockRun<'b, M>>>>,
                }
                impl<M: MvStore> Drop for Guard<'_, '_, M> {
                    fn drop(&mut self) {
                        if std::thread::panicking() {
                            self.halted.store(true, Ordering::SeqCst);
                            if let Ok(win) = self.window.lock() {
                                for b in win.iter() {
                                    b.scheduler.halt();
                                }
                            }
                        }
                    }
                }
                let _guard = Guard {
                    halted: &halted,
                    window: &window,
                };
                // Reusable snapshot buffer: the idle tail-wait regime
                // re-enters this loop at spin frequency, so the
                // per-iteration window copy must not allocate once the
                // buffer has grown to the window depth.
                let mut snap: Vec<Arc<BlockRun<'b, M>>> = Vec::new();
                loop {
                    if halted.load(Ordering::SeqCst) {
                        return;
                    }
                    // Pin a reclamation epoch for this whole drain
                    // iteration: every raw recorded-sets pointer a
                    // validation below may hold stays covered until
                    // the guard drops at the loop bottom. Fresh pin
                    // per iteration, so promotions made by peers can
                    // keep reclaiming between our task runs.
                    let _epoch = gc.pin(w);
                    // One window-lock snapshot amortizes over a whole
                    // run of tasks, keeping the mutex off the per-task
                    // hot path. (A snapshot can go stale while we
                    // drain; that's fine: a completed-elsewhere block's
                    // scheduler hands out no more tasks, and its
                    // `written_back` flag redirects stale chains to the
                    // heap.)
                    snap.clear();
                    snap.extend(window.lock().unwrap().iter().cloned());
                    if snap.is_empty() {
                        if exhausted.load(Ordering::SeqCst) {
                            return;
                        }
                        // Empty window with the stream still open: a
                        // *paused* serving stream never promotes, so
                        // nothing would ever advance the epoch past
                        // the last promotion's limbo bins — the drain
                        // bug `flush()` papers over only because a
                        // batch run's pool always joins. Quiescent
                        // flush reclaims up to the live horizon (our
                        // own per-iteration pin re-publishes above, so
                        // an idle pool converges on an empty limbo
                        // within two laps) and is a cheap no-op once
                        // limbo is empty.
                        let (qc, qb) = gc.quiescent_flush();
                        if qc != 0 || qb != 0 {
                            crate::obs::trace::reclaim(qc, qb);
                        }
                        // An empty window is idleness, not a stall:
                        // heartbeat the watchdog so the first
                        // flat-progress poll after a long serving
                        // pause cannot spuriously kick or escalate.
                        if let Some(wd) = &wd {
                            wd.note_idle();
                        }
                        admit(w);
                        continue;
                    }
                    // Walk the window front to back: head work first
                    // (it gates everything behind it), then each
                    // successively deeper block against the chain of
                    // its draining predecessors, nearest first.
                    let mut did_work = false;
                    for i in 0..snap.len() {
                        let blk = &snap[i];
                        // Pull a first task before building the base
                        // chain: a drained block costs no allocation.
                        let Some(first) = blk.scheduler.next_task(w) else {
                            if i == 0 && blk.scheduler.done() {
                                complete_head(blk);
                                did_work = true;
                                break;
                            }
                            continue;
                        };
                        let base = if i == 0 {
                            BaseSource::Heap
                        } else {
                            BaseSource::Chain {
                                links: snap[..i]
                                    .iter()
                                    .rev()
                                    .map(|p| PrevLink {
                                        mv: &p.mv,
                                        done: &p.written_back,
                                    })
                                    .collect(),
                            }
                        };
                        let park = if i == 0 {
                            None
                        } else {
                            Some(CrossBlockPark {
                                prev_done: &blk.prev_done,
                                parked: &blk.parked,
                            })
                        };
                        let worker = Worker {
                            heap,
                            txns: blk.txns.as_slice(),
                            mv: &blk.mv,
                            scheduler: &blk.scheduler,
                            counters: &blk.counters,
                            base,
                            park,
                            // The pipelined loop polls the watchdog
                            // itself (below), with the whole window in
                            // scope.
                            wd: None,
                        };
                        worker.step(first);
                        while let Some(task) = blk.scheduler.next_task(w) {
                            worker.step(task);
                        }
                        // Re-snapshot: the head may have become
                        // completable, and our chain view may have
                        // gone stale.
                        did_work = true;
                        break;
                    }
                    if did_work {
                        continue;
                    }
                    // Idle with the window non-empty: the only regime a
                    // genuine stall is visible from — poll the fault
                    // plane's watchdog (no-op without `--faults`). The
                    // kicker is a live pool worker, so whatever the
                    // kick reopens, this thread is around to drain it.
                    if let Some(wd) = &wd {
                        Self::watchdog_poll_window(wd, &snap, &completed_progress);
                    }
                    // Whole window drained of claimable work: deepen it
                    // (the admit gate re-checks depth and the youngest
                    // block's execution stream under its own locks).
                    if !exhausted.load(Ordering::SeqCst)
                        && snap
                            .last()
                            .is_some_and(|b| b.scheduler.execution_drained())
                    {
                        admit(w);
                    }
                    std::hint::spin_loop();
                }
            },
            main,
        );

        // Pool joined — nothing is pinned: drain the limbo (a no-op
        // when reclamation is off, so the leaky baseline's counters
        // show the leak) and publish the session's memory counters.
        gc.flush();
        let gcc = gc.counters();
        let mut rep = { report.lock().unwrap().clone() };
        rep.elapsed = t0.elapsed();
        rep.pinned_workers = pinned.load(Ordering::SeqCst);
        rep.window_admissions = admissions.load(Ordering::SeqCst);
        rep.window_depth_sum = depth_sum.load(Ordering::SeqCst);
        rep.faults_injected = crate::fault::injected_total().saturating_sub(faults_before);
        rep.mv_live_cells = gcc.live_peak_cells;
        rep.mv_retired = gcc.retired_cells;
        rep.mv_reclaimed = gcc.reclaimed_cells;
        rep.arena_bytes = gcc.arena_peak_bytes;
        (rep, r)
    }

    /// One watchdog poll from an idle pipelined worker: progress is the
    /// session-wide execution+validation count (completed blocks plus
    /// the live window), and a kick runs the recovery pass over the
    /// whole window — re-ready every block's lost wakeups, force a
    /// revalidation pass on the head (the block gating everything
    /// behind it), and escalate to the degraded serial backend after
    /// repeated fruitless kicks. Only called with the fault plane
    /// installed.
    #[cold]
    fn watchdog_poll_window<M: MvStore>(
        wd: &crate::fault::watchdog::Watchdog,
        snap: &[Arc<BlockRun<'_, M>>],
        completed_progress: &AtomicU64,
    ) {
        use crate::fault::watchdog::Diagnosis;
        let Some(head) = snap.first() else {
            return;
        };
        let lat = head.counters.txn_lat.fold();
        if lat.count() > 0 {
            wd.observe_commit_latency(lat.p50().max(1));
        }
        let live: u64 = snap
            .iter()
            .map(|b| {
                b.counters.executions.load(Ordering::Relaxed)
                    + b.counters.validations.load(Ordering::Relaxed)
            })
            .sum();
        if !wd.poll(completed_progress.load(Ordering::Relaxed) + live) {
            if crate::engine::degraded::is_degraded() && wd.ready_to_recover() {
                crate::engine::degraded::recover(wd.kicks());
            }
            return;
        }
        let mut recovered = 0usize;
        for b in snap {
            recovered += b.scheduler.recover_lost();
        }
        head.scheduler.reopen_validation();
        let parked = snap.iter().any(|b| !b.parked.lock().unwrap().is_empty());
        // Zero backlog on both task streams of every block means all
        // remaining work is claimed by workers whose counters are
        // flat — a dead/stalled worker holding tickets, not a retry
        // storm.
        let all_claimed = snap.iter().all(|b| {
            b.scheduler.execution_backlog() == 0 && b.scheduler.validation_backlog() == 0
        });
        let diag = if recovered > 0 {
            Diagnosis::LostWakeup
        } else if parked {
            Diagnosis::ParkedChain
        } else if all_claimed {
            Diagnosis::WorkerStall
        } else {
            Diagnosis::Livelock
        };
        crate::obs::trace::watchdog_kick(diag as u64, recovered as u64);
        head.counters.watchdog_kicks.fetch_add(1, Ordering::Relaxed);
        if wd.should_escalate() && !crate::engine::degraded::is_degraded() {
            crate::engine::degraded::escalate(wd.kicks());
            head.counters.degradations.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::access::DirectAccess;

    fn counter_txns<'h>(addr: usize, n: usize) -> Vec<BatchTxn<'h>> {
        (0..n)
            .map(|_| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(addr)?;
                    t.write(addr, v + 1)
                })
            })
            .collect()
    }

    /// Drain `txns` into `block`-sized chunks through the pipelined
    /// session (the same shipped source the workloads use).
    fn run_pipelined_chunks(
        heap: &TxHeap,
        txns: Vec<BatchTxn<'_>>,
        block: usize,
        workers: usize,
    ) -> BatchReport {
        let mut ctl = BlockSizeController::fixed(block);
        workload::run_txns_pipelined(heap, txns, workers, &mut ctl)
    }

    /// Like [`run_pipelined_chunks`], at an explicit window depth.
    fn run_windowed_chunks(
        heap: &TxHeap,
        txns: Vec<BatchTxn<'_>>,
        block: usize,
        workers: usize,
        window: usize,
    ) -> BatchReport {
        let mut ctl = BlockSizeController::fixed(block).with_window(window);
        workload::run_txns_pipelined(heap, txns, workers, &mut ctl)
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let heap = TxHeap::new(64);
        let r = BatchSystem::run(&heap, &[], 4);
        assert_eq!(r.txns, 0);
        assert_eq!(r.executions, 0);
    }

    #[test]
    fn single_worker_matches_sequential() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(1);
        let r = BatchSystem::run(&heap, &counter_txns(a, 50), 1);
        assert_eq!(r.txns, 50);
        assert_eq!(heap.load(a), 50);
    }

    #[test]
    fn high_conflict_counter_is_exact_under_concurrency() {
        // Every transaction RMWs the same word: worst case for
        // speculation, but the result must still be exact — on both
        // stores.
        for workers in [2usize, 4, 8] {
            let heap = TxHeap::new(64);
            let a = heap.alloc(1);
            heap.store(a, 1000);
            let r = BatchSystem::run(&heap, &counter_txns(a, 200), workers);
            assert_eq!(heap.load(a), 1200, "workers={workers}");
            assert!(r.executions >= 200, "every txn executes at least once");
            assert_eq!(r.txns, 200);

            let heap_m = TxHeap::new(64);
            let a_m = heap_m.alloc(1);
            heap_m.store(a_m, 1000);
            let rm = BatchSystem::run_baseline_mutex(&heap_m, &counter_txns(a_m, 200), workers);
            assert_eq!(heap_m.load(a_m), 1200, "mutex baseline, workers={workers}");
            assert_eq!(rm.txns, 200);
        }
    }

    #[test]
    fn disjoint_txns_commit_without_aborts() {
        let heap = TxHeap::new(1 << 12);
        let base = heap.alloc(256);
        let txns: Vec<BatchTxn> = (0..64)
            .map(|i| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(base + i)?;
                    t.write(base + i, v + 10 + i as u64)
                })
            })
            .collect();
        let r = BatchSystem::run(&heap, &txns, 4);
        assert_eq!(r.validation_aborts, 0, "disjoint batch must not abort");
        for i in 0..64usize {
            assert_eq!(heap.load(base + i), 10 + i as u64);
        }
    }

    #[test]
    fn read_chain_respects_index_order() {
        // txn i reads slot[i-1] and writes slot[i] = slot[i-1] + 1: the
        // only correct outcome is the fully propagated chain, which
        // forces the executor through dependencies/re-incarnations.
        const N: usize = 32;
        let heap = TxHeap::new(1 << 10);
        let base = heap.alloc(N + 1);
        heap.store(base, 7);
        let txns: Vec<BatchTxn> = (0..N)
            .map(|i| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(base + i)?;
                    t.write(base + i + 1, v + 1)
                })
            })
            .collect();
        for workers in [1usize, 3, 8] {
            let heap2 = TxHeap::new(1 << 10);
            let b2 = heap2.alloc(N + 1);
            assert_eq!(b2, base);
            heap2.store(b2, 7);
            BatchSystem::run(&heap2, &txns, workers);
            for i in 0..=N {
                assert_eq!(heap2.load(b2 + i), 7 + i as u64, "slot {i}, workers {workers}");
            }
        }
    }

    #[test]
    fn data_dependent_write_sets_match_sequential() {
        // Append-to-log shape (the computation kernel's collect phase):
        // the write address depends on a value read — write sets change
        // across incarnations.
        const N: usize = 40;
        let run_seq = |heap: &TxHeap, txns: &[BatchTxn]| {
            let mut acc = DirectAccess { heap };
            for t in txns {
                (t.body)(&mut acc).unwrap();
            }
        };
        let mk_txns = |count_addr: usize, log_base: usize| -> Vec<BatchTxn<'static>> {
            (0..N)
                .map(|i| {
                    BatchTxn::new(move |t: &mut dyn TxAccess| {
                        let n = t.read(count_addr)?;
                        t.write(log_base + n as usize, 1000 + i as u64)?;
                        t.write(count_addr, n + 1)
                    })
                })
                .collect()
        };
        let heap_a = TxHeap::new(1 << 10);
        let count_a = heap_a.alloc_lines(1);
        let log_a = heap_a.alloc(N);
        run_seq(&heap_a, &mk_txns(count_a, log_a));

        let heap_b = TxHeap::new(1 << 10);
        let count_b = heap_b.alloc_lines(1);
        let log_b = heap_b.alloc(N);
        assert_eq!((count_a, log_a), (count_b, log_b));
        BatchSystem::run(&heap_b, &mk_txns(count_b, log_b), 4);

        assert_eq!(heap_a.load(count_a), heap_b.load(count_b));
        for i in 0..N {
            assert_eq!(heap_a.load(log_a + i), heap_b.load(log_b + i), "log slot {i}");
        }
    }

    #[test]
    fn pipelined_counter_chain_is_exact_across_blocks() {
        // The worst case for cross-block speculation: every transaction
        // RMWs the same word, so every block-N+1 base read guesses a
        // value the block-N tail is still changing. The forced
        // revalidation at promotion must repair all of it.
        for (workers, block) in [(1usize, 8usize), (2, 16), (4, 8), (3, 64)] {
            let heap = TxHeap::new(64);
            let a = heap.alloc(1);
            heap.store(a, 500);
            let r = run_pipelined_chunks(&heap, counter_txns(a, 200), block, workers);
            assert_eq!(r.txns, 200, "workers={workers} block={block}");
            assert_eq!(
                heap.load(a),
                700,
                "workers={workers} block={block}: pipelined chain must be exact"
            );
        }
    }

    #[test]
    fn pipelined_read_chain_matches_sequential_across_blocks() {
        const N: usize = 48;
        let mk = |base: usize| -> Vec<BatchTxn<'static>> {
            (0..N)
                .map(|i| {
                    BatchTxn::new(move |t: &mut dyn TxAccess| {
                        let v = t.read(base + i)?;
                        t.write(base + i + 1, v + 1)
                    })
                })
                .collect()
        };
        for workers in [1usize, 2, 4] {
            let heap = TxHeap::new(1 << 10);
            let base = heap.alloc(N + 1);
            heap.store(base, 3);
            run_pipelined_chunks(&heap, mk(base), 8, workers);
            for i in 0..=N {
                assert_eq!(heap.load(base + i), 3 + i as u64, "slot {i}, workers {workers}");
            }
        }
    }

    #[test]
    fn pipelined_disjoint_stream_reports_no_aborts() {
        let heap = TxHeap::new(1 << 12);
        let base = heap.alloc(256);
        let txns: Vec<BatchTxn> = (0..128)
            .map(|i| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(base + i)?;
                    t.write(base + i, v + 1 + i as u64)
                })
            })
            .collect();
        let r = run_pipelined_chunks(&heap, txns, 16, 3);
        assert_eq!(r.txns, 128);
        assert_eq!(r.validation_aborts, 0, "disjoint stream must not abort");
        for i in 0..128usize {
            assert_eq!(heap.load(base + i), 1 + i as u64);
        }
    }

    #[test]
    fn pipelined_empty_source_is_a_noop() {
        let heap = TxHeap::new(64);
        let mut ctl = BlockSizeController::fixed(8);
        let r = BatchSystem::run_pipelined::<MvMemory, _>(&heap, |_| None, 3, &mut ctl);
        assert_eq!(r.txns, 0);
        assert_eq!(r.executions, 0);
    }

    #[test]
    fn pipelined_session_feeds_the_controller_per_block() {
        let heap = TxHeap::new(1 << 10);
        let base = heap.alloc(64);
        let txns: Vec<BatchTxn> = (0..64)
            .map(|i| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(base + i)?;
                    t.write(base + i, v + 1)
                })
            })
            .collect();
        let mut ctl = BlockSizeController::with_bounds(8, 4, 64, 8);
        let r = workload::run_txns_pipelined(&heap, txns, 2, &mut ctl);
        assert_eq!(r.txns, 64);
        assert!(ctl.samples >= 2, "every completed block must be observed");
        assert!(
            ctl.current() > 8,
            "a clean disjoint stream must grow the block"
        );
    }

    #[test]
    fn report_merge_accumulates() {
        let mut a = BatchReport {
            txns: 10,
            executions: 12,
            validations: 11,
            validation_aborts: 2,
            dependencies: 1,
            steals: 3,
            local_steals: 2,
            overlapped_txns: 4,
            pinned_workers: 2,
            window_admissions: 5,
            window_depth_sum: 9,
            mv_live_cells: 7,
            mv_retired: 40,
            mv_reclaimed: 35,
            arena_bytes: 4096,
            elapsed: Duration::from_millis(5),
            ..BatchReport::default()
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.txns, 20);
        assert_eq!(a.executions, 24);
        assert_eq!(a.steals, 6);
        assert_eq!(a.local_steals, 4);
        assert_eq!(a.overlapped_txns, 8);
        assert_eq!(a.pinned_workers, 2, "pin count is a run property: max, not sum");
        assert_eq!(a.window_admissions, 10);
        assert_eq!(a.window_depth_sum, 18);
        assert_eq!(a.mv_live_cells, 7, "live peak is a session property: max, not sum");
        assert_eq!(a.mv_retired, 80);
        assert_eq!(a.mv_reclaimed, 70);
        assert_eq!(a.arena_bytes, 4096, "arena peak is a session property: max, not sum");
        assert_eq!(a.elapsed, Duration::from_millis(10));
        let s = a.to_stats();
        assert_eq!(s.sw_commits, 20);
        assert_eq!(s.sw_aborts, 6);
        assert_eq!(s.steals, 6);
        assert_eq!(s.local_steals, 4);
        assert_eq!(s.overlapped_txns, 8);
        assert_eq!(s.mv_live_cells, 7);
        assert_eq!(s.mv_retired, 80);
        assert_eq!(s.mv_reclaimed, 70);
        assert_eq!(s.arena_bytes, 4096);
        assert_eq!(s.total_commits(), 20);
    }

    #[test]
    fn report_derived_metrics() {
        let mut r = BatchReport::default();
        assert_eq!(r.locality_steal_ratio(), 1.0, "no steals: vacuously local");
        assert_eq!(r.window_occupancy(), 0.0, "no admissions: no occupancy");
        r.steals = 8;
        r.local_steals = 6;
        r.window_admissions = 4;
        r.window_depth_sum = 10;
        assert!((r.locality_steal_ratio() - 0.75).abs() < 1e-12);
        assert!((r.window_occupancy() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn windowed_counter_chain_is_exact_across_depths() {
        // The W-deep tentpole at the worst case (every txn RMWs one
        // word): whatever the window depth — including the degenerate
        // barrier stream W=1 — the chained base-peeks plus the forced
        // promotion revalidation must keep the result exact.
        for window in [1usize, 2, 3, 4] {
            for (workers, block) in [(2usize, 8usize), (4, 8), (3, 16)] {
                let heap = TxHeap::new(64);
                let a = heap.alloc(1);
                heap.store(a, 500);
                let r = run_windowed_chunks(&heap, counter_txns(a, 200), block, workers, window);
                assert_eq!(r.txns, 200, "window={window} workers={workers}");
                assert_eq!(
                    heap.load(a),
                    700,
                    "window={window} workers={workers} block={block}"
                );
            }
        }
    }

    #[test]
    fn window_one_never_overlaps() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(1);
        let r = run_windowed_chunks(&heap, counter_txns(a, 100), 8, 4, 1);
        assert_eq!(r.txns, 100);
        assert_eq!(r.overlapped_txns, 0, "W=1 is a pure barrier stream");
        assert!(
            r.window_occupancy() <= 1.0 + 1e-12,
            "occupancy {} must be 1 at W=1",
            r.window_occupancy()
        );
    }

    #[test]
    fn deep_window_occupancy_stays_within_invariants() {
        // A long disjoint stream in tiny blocks at W=4. How deep the
        // window actually gets is scheduling-dependent (a fast head can
        // complete before the next admission), so this test asserts
        // only the counter invariants; the by-construction deepening
        // proof is `deep_window_actually_overlaps_by_construction`.
        let heap = TxHeap::new(1 << 12);
        let base = heap.alloc(512);
        let txns: Vec<BatchTxn> = (0..512)
            .map(|i| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(base + i)?;
                    t.write(base + i, v + 1 + i as u64)
                })
            })
            .collect();
        let r = run_windowed_chunks(&heap, txns, 8, 4, 4);
        assert_eq!(r.txns, 512);
        assert!(r.window_admissions >= 64, "512 txns / block 8");
        let occ = r.window_occupancy();
        assert!((1.0..=4.0).contains(&occ), "occupancy {occ} outside [1, W]");
        assert!(
            r.window_depth_sum >= r.window_admissions,
            "every admission counts at least depth 1"
        );
        for i in 0..512usize {
            assert_eq!(heap.load(base + i), 1 + i as u64);
        }
    }

    #[test]
    fn deep_window_actually_overlaps_by_construction() {
        // Forces the W=3 window to provably deepen, so a regression
        // that silently degrades the live session to a barrier stream
        // (e.g. an inverted admission gate) fails loudly. The head
        // block's only transaction holds its execution open until the
        // *last* block's transaction has started executing — which can
        // only happen if blocks 1 and 2 were admitted and executed
        // while block 0 was still live. The admission depths are then
        // fully determined: 1, then 2, then 3.
        use std::sync::atomic::AtomicUsize;
        let heap = TxHeap::new(256);
        let base = heap.alloc(8);
        // Set by block 2's transaction the moment it starts executing;
        // block 0's transaction spins on it. Idempotent across
        // re-executions.
        let tail_started = AtomicBool::new(false);
        let calls = AtomicUsize::new(0);
        let mut ctl = BlockSizeController::fixed(1).with_window(3);
        let r = BatchSystem::run_pipelined::<MvMemory, _>(
            &heap,
            |_size| {
                let k = calls.fetch_add(1, Ordering::SeqCst);
                if k >= 3 {
                    return None;
                }
                let addr = base + k;
                let tail_started = &tail_started;
                Some(vec![BatchTxn::new(move |t: &mut dyn TxAccess| {
                    if k == 0 {
                        // Head: stay live until the window's tail runs.
                        // yield, not spin: on a single-core host the
                        // other pinned worker needs the CPU to admit
                        // and execute the tail.
                        while !tail_started.load(Ordering::SeqCst) {
                            std::thread::yield_now();
                        }
                    } else if k == 2 {
                        tail_started.store(true, Ordering::SeqCst);
                    }
                    let v = t.read(addr)?;
                    t.write(addr, v + 7)
                })])
            },
            2,
            &mut ctl,
        );
        assert_eq!(r.txns, 3);
        assert_eq!(r.window_admissions, 3);
        assert_eq!(
            r.window_depth_sum, 6,
            "the three admissions must observe depths 1 + 2 + 3"
        );
        assert!((r.window_occupancy() - 2.0).abs() < 1e-12);
        assert!(
            r.overlapped_txns >= 2,
            "blocks 1 and 2 must execute while block 0 holds the head open \
             (overlapped: {})",
            r.overlapped_txns
        );
        for kk in 0..3usize {
            assert_eq!(heap.load(base + kk), 7, "slot {kk}");
        }
    }
}
