//! `batch` — a Block-STM-style speculative batch executor: the fifth
//! synchronization backend.
//!
//! The paper's executors ([`crate::hytm`]) admit transactions one at a
//! time per thread and synchronize each against all concurrent peers.
//! This subsystem instead admits a whole *batch* (a block) of
//! transactions with a fixed serialization order — their index in the
//! batch — and executes them optimistically in parallel:
//!
//! * [`mvmemory`] — the multi-version store. The production
//!   implementation is **lock-free on the hot path**: the address
//!   index is CAS-published chains off an atomic shard array, each
//!   address owns a grow-only version vector whose `(txn, incarnation,
//!   value)` cells publish through a two-word seqlock, and each
//!   transaction's read/write sets are immutable nodes handed off
//!   through one `AtomicPtr` — reads of committed versions take zero
//!   locks, writes CAS-publish. The PR-1 sharded-mutex layout survives
//!   as `MutexMvMemory` behind the same `MvStore` trait, purely so the
//!   benchmark can price what the locks cost;
//! * [`scheduler`] — execution/validation task streams over atomic
//!   index counters, with each transaction's lifecycle packed into a
//!   single `incarnation << 2 | state` atomic status word (CAS
//!   transitions; the only mutex left guards the rare
//!   ESTIMATE-dependency lists);
//! * [`executor`] — the worker loop: execute against a recording
//!   [`crate::tm::access::TxAccess`] view → record read/write sets →
//!   validate → abort/re-incarnate;
//! * [`adaptive`] — the [`adaptive::BlockSizeController`]: AIMD block
//!   sizing from each block's observed re-incarnation rate
//!   (multiplicative decrease on conflict spikes, additive increase
//!   when clean — DyAdHyTM's adapt-at-runtime loop applied to the
//!   batch knob). `--policy batch=adaptive` runs it live and in the
//!   simulator; `--policy batch=N` pins the block through the same
//!   controller;
//! * [`workload`] — adapters feeding the SSCA-2 kernels (generation,
//!   computation, and kernel-3 subgraph extraction as a
//!   level-synchronous batch BFS whose per-level candidate stream is
//!   consumed lazily, never materialized whole) and the simulator's
//!   [`crate::sim::workload::TxnDesc`] shapes through the batch API.
//!
//! **Determinism guarantee.** Whatever interleaving the workers take —
//! and whatever block sizes the controller picks — the final heap
//! state equals executing the batch *sequentially in index order* —
//! bit for bit. That is what makes the backend measurable head-to-head
//! against the paper's policies: same inputs, same outputs, different
//! concurrency control. The guarantee is enforced by tests in this
//! module and the `batch_determinism` property suite (including a
//! fixed-vs-adaptive sizing property).
//!
//! **Full routing.** Select it end-to-end with `--policy batch[=N]` or
//! `--policy batch=adaptive` ([`crate::hytm::PolicySpec::Batch`] /
//! `PolicySpec::BatchAdaptive`): all three SSCA-2 kernels and the
//! streaming pipeline ([`crate::runtime::pipeline`]) run through
//! [`BatchSystem`]. No path silently degrades to per-transaction
//! NOrec: a batch spec reaching `ThreadExecutor::execute` is loudly
//! warned, accounted under the `norec_fallback` stats counter, and
//! reported as `batch(fallback:norec)`. The simulator prices the
//! backend with its own multi-version cost mode (`sim::engine`'s
//! `Mode::MultiVersion`): estimate-wait, validation, re-incarnation
//! charges and per-block admission barriers driven by the *same*
//! `BlockSizeController` as the live runs.

pub mod adaptive;
pub mod executor;
pub mod mvmemory;
pub mod scheduler;
pub mod workload;

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use crate::mem::TxHeap;
use crate::stats::TxStats;
use crate::tm::access::{TxAccess, TxResult};

use executor::{BatchCounters, Worker};
use mvmemory::{MutexMvMemory, MvMemory, MvStore};
use scheduler::Scheduler;

/// Default number of transactions admitted per speculative block
/// (`--policy batch=N` overrides it; `--policy batch=adaptive` lets
/// the controller pick).
pub const DEFAULT_BLOCK: usize = 2048;

/// A batch transaction body. Must be a pure function of the values it
/// reads through the access handle (it may be re-executed any number of
/// times, concurrently with other transactions), and must not return
/// `Err` of its own — only the speculative view aborts an attempt.
pub type BatchBody<'b> = Box<dyn Fn(&mut dyn TxAccess) -> TxResult<()> + Send + Sync + 'b>;

/// One transaction of a batch.
pub struct BatchTxn<'b> {
    pub body: BatchBody<'b>,
}

impl<'b> BatchTxn<'b> {
    pub fn new(body: impl Fn(&mut dyn TxAccess) -> TxResult<()> + Send + Sync + 'b) -> Self {
        Self {
            body: Box::new(body),
        }
    }
}

/// Outcome counters of one (or several, merged) batch runs.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchReport {
    /// Transactions committed (= batch size; every txn commits).
    pub txns: usize,
    /// Incarnation executions started.
    pub executions: u64,
    /// Validation tasks performed.
    pub validations: u64,
    /// Validation aborts (re-incarnations forced by a read-set change).
    pub validation_aborts: u64,
    /// Executions suspended on a lower transaction's ESTIMATE.
    pub dependencies: u64,
    pub elapsed: Duration,
}

impl BatchReport {
    /// Accumulate another run (e.g. the next block of a long stream).
    pub fn merge(&mut self, other: &BatchReport) {
        self.txns += other.txns;
        self.executions += other.executions;
        self.validations += other.validations;
        self.validation_aborts += other.validation_aborts;
        self.dependencies += other.dependencies;
        self.elapsed += other.elapsed;
    }

    /// Fold into the stats-plane shape: batch commits are software
    /// commits (speculation in software, like an STM), re-executions
    /// count as software aborts.
    pub fn to_stats(&self) -> TxStats {
        let mut s = TxStats::new();
        s.sw_commits = self.txns as u64;
        s.sw_aborts = self.validation_aborts + self.dependencies;
        s.time_ns = self.elapsed.as_nanos() as u64;
        s
    }
}

/// The batch backend entry point.
pub struct BatchSystem;

impl BatchSystem {
    /// Execute `txns` with `concurrency` workers over the lock-free
    /// multi-version store. Blocks until every transaction has
    /// committed, then flushes the winning versions to `heap`. The
    /// final heap state is bit-identical to running the batch
    /// sequentially in index order.
    pub fn run(heap: &TxHeap, txns: &[BatchTxn<'_>], concurrency: usize) -> BatchReport {
        Self::run_with::<MvMemory>(heap, txns, concurrency)
    }

    /// Same contract as [`BatchSystem::run`], but over the PR-1
    /// sharded-mutex store — the baseline `benches/batch_throughput`
    /// measures the lock-free hot path against. Not used by any
    /// shipped path.
    pub fn run_baseline_mutex(
        heap: &TxHeap,
        txns: &[BatchTxn<'_>],
        concurrency: usize,
    ) -> BatchReport {
        Self::run_with::<MutexMvMemory>(heap, txns, concurrency)
    }

    fn run_with<M: MvStore>(
        heap: &TxHeap,
        txns: &[BatchTxn<'_>],
        concurrency: usize,
    ) -> BatchReport {
        let t0 = Instant::now();
        if txns.is_empty() {
            return BatchReport {
                elapsed: t0.elapsed(),
                ..BatchReport::default()
            };
        }
        let workers = concurrency.max(1).min(txns.len());
        let scheduler = Scheduler::new(txns.len());
        let mv = M::new(txns.len());
        let counters = BatchCounters::default();
        // If a worker panics (a body violating the infallibility
        // contract, or a bug in a user closure), it unwinds with
        // `num_active` still elevated and the done-check could never
        // fire — stranding its peers in the polling loop and hanging
        // the join below. This guard halts the scheduler on the way
        // out of a panicking worker; scope then joins everyone and
        // re-raises the original panic.
        struct HaltOnPanic<'a>(&'a Scheduler);
        impl Drop for HaltOnPanic<'_> {
            fn drop(&mut self) {
                if std::thread::panicking() {
                    self.0.halt();
                }
            }
        }
        std::thread::scope(|s| {
            for _ in 0..workers {
                let w = Worker {
                    heap,
                    txns,
                    mv: &mv,
                    scheduler: &scheduler,
                    counters: &counters,
                };
                s.spawn(move || {
                    let _guard = HaltOnPanic(w.scheduler);
                    w.run()
                });
            }
        });
        mv.write_back(heap);
        BatchReport {
            txns: txns.len(),
            executions: counters.executions.load(Ordering::Relaxed),
            validations: counters.validations.load(Ordering::Relaxed),
            validation_aborts: counters.validation_aborts.load(Ordering::Relaxed),
            dependencies: counters.dependencies.load(Ordering::Relaxed),
            elapsed: t0.elapsed(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::access::DirectAccess;

    fn counter_txns<'h>(addr: usize, n: usize) -> Vec<BatchTxn<'h>> {
        (0..n)
            .map(|_| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(addr)?;
                    t.write(addr, v + 1)
                })
            })
            .collect()
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let heap = TxHeap::new(64);
        let r = BatchSystem::run(&heap, &[], 4);
        assert_eq!(r.txns, 0);
        assert_eq!(r.executions, 0);
    }

    #[test]
    fn single_worker_matches_sequential() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(1);
        let r = BatchSystem::run(&heap, &counter_txns(a, 50), 1);
        assert_eq!(r.txns, 50);
        assert_eq!(heap.load(a), 50);
    }

    #[test]
    fn high_conflict_counter_is_exact_under_concurrency() {
        // Every transaction RMWs the same word: worst case for
        // speculation, but the result must still be exact — on both
        // stores.
        for workers in [2usize, 4, 8] {
            let heap = TxHeap::new(64);
            let a = heap.alloc(1);
            heap.store(a, 1000);
            let r = BatchSystem::run(&heap, &counter_txns(a, 200), workers);
            assert_eq!(heap.load(a), 1200, "workers={workers}");
            assert!(r.executions >= 200, "every txn executes at least once");
            assert_eq!(r.txns, 200);

            let heap_m = TxHeap::new(64);
            let a_m = heap_m.alloc(1);
            heap_m.store(a_m, 1000);
            let rm = BatchSystem::run_baseline_mutex(&heap_m, &counter_txns(a_m, 200), workers);
            assert_eq!(heap_m.load(a_m), 1200, "mutex baseline, workers={workers}");
            assert_eq!(rm.txns, 200);
        }
    }

    #[test]
    fn disjoint_txns_commit_without_aborts() {
        let heap = TxHeap::new(1 << 12);
        let base = heap.alloc(256);
        let txns: Vec<BatchTxn> = (0..64)
            .map(|i| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(base + i)?;
                    t.write(base + i, v + 10 + i as u64)
                })
            })
            .collect();
        let r = BatchSystem::run(&heap, &txns, 4);
        assert_eq!(r.validation_aborts, 0, "disjoint batch must not abort");
        for i in 0..64usize {
            assert_eq!(heap.load(base + i), 10 + i as u64);
        }
    }

    #[test]
    fn read_chain_respects_index_order() {
        // txn i reads slot[i-1] and writes slot[i] = slot[i-1] + 1: the
        // only correct outcome is the fully propagated chain, which
        // forces the executor through dependencies/re-incarnations.
        const N: usize = 32;
        let heap = TxHeap::new(1 << 10);
        let base = heap.alloc(N + 1);
        heap.store(base, 7);
        let txns: Vec<BatchTxn> = (0..N)
            .map(|i| {
                BatchTxn::new(move |t: &mut dyn TxAccess| {
                    let v = t.read(base + i)?;
                    t.write(base + i + 1, v + 1)
                })
            })
            .collect();
        for workers in [1usize, 3, 8] {
            let heap2 = TxHeap::new(1 << 10);
            let b2 = heap2.alloc(N + 1);
            assert_eq!(b2, base);
            heap2.store(b2, 7);
            BatchSystem::run(&heap2, &txns, workers);
            for i in 0..=N {
                assert_eq!(heap2.load(b2 + i), 7 + i as u64, "slot {i}, workers {workers}");
            }
        }
    }

    #[test]
    fn data_dependent_write_sets_match_sequential() {
        // Append-to-log shape (the computation kernel's collect phase):
        // the write address depends on a value read — write sets change
        // across incarnations.
        const N: usize = 40;
        let run_seq = |heap: &TxHeap, txns: &[BatchTxn]| {
            let mut acc = DirectAccess { heap };
            for t in txns {
                (t.body)(&mut acc).unwrap();
            }
        };
        let mk_txns = |count_addr: usize, log_base: usize| -> Vec<BatchTxn<'static>> {
            (0..N)
                .map(|i| {
                    BatchTxn::new(move |t: &mut dyn TxAccess| {
                        let n = t.read(count_addr)?;
                        t.write(log_base + n as usize, 1000 + i as u64)?;
                        t.write(count_addr, n + 1)
                    })
                })
                .collect()
        };
        let heap_a = TxHeap::new(1 << 10);
        let count_a = heap_a.alloc_lines(1);
        let log_a = heap_a.alloc(N);
        run_seq(&heap_a, &mk_txns(count_a, log_a));

        let heap_b = TxHeap::new(1 << 10);
        let count_b = heap_b.alloc_lines(1);
        let log_b = heap_b.alloc(N);
        assert_eq!((count_a, log_a), (count_b, log_b));
        BatchSystem::run(&heap_b, &mk_txns(count_b, log_b), 4);

        assert_eq!(heap_a.load(count_a), heap_b.load(count_b));
        for i in 0..N {
            assert_eq!(heap_a.load(log_a + i), heap_b.load(log_b + i), "log slot {i}");
        }
    }

    #[test]
    fn report_merge_accumulates() {
        let mut a = BatchReport {
            txns: 10,
            executions: 12,
            validations: 11,
            validation_aborts: 2,
            dependencies: 1,
            elapsed: Duration::from_millis(5),
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.txns, 20);
        assert_eq!(a.executions, 24);
        assert_eq!(a.elapsed, Duration::from_millis(10));
        let s = a.to_stats();
        assert_eq!(s.sw_commits, 20);
        assert_eq!(s.sw_aborts, 6);
        assert_eq!(s.total_commits(), 20);
    }
}
