//! Feeding real workloads through the batch executor.
//!
//! Four adapters:
//!
//! * SSCA-2 **generation kernel**: the tuple list becomes one insert
//!   transaction per `cfg.batch` edges, with the *same* cell-assignment
//!   order as the sequential path — so the built graph is bit-identical
//!   to a serial build, whatever the workers do.
//! * SSCA-2 **computation kernel**: chunked gmax probes (phase 1) and
//!   in-cell-order band appends (phase 2).
//! * SSCA-2 **subgraph kernel (kernel 3)**: level-synchronous
//!   multi-source BFS where each level's vertex claims (`read mark; if
//!   unmarked, write level`) are admitted as deterministic blocks — the
//!   claimed ball and every per-vertex level are bit-identical to the
//!   serial oracle in [`crate::graph::subgraph::verify_subgraph`]. The
//!   per-level candidate list is *streamed* from the frontier's
//!   adjacency (two lazy passes), never materialized whole, so peak
//!   memory stays O(block × chunk) even on hub-dense levels.
//! * **Descriptor bodies**: turn the simulator's
//!   [`TxnDesc`](crate::sim::workload::TxnDesc) cache-line footprints
//!   into executable read/modify/write bodies on a scratch heap — the
//!   substrate of the `batch_determinism` property tests.
//!
//! Every adapter streams its blocks through the cross-block-pipelined
//! session ([`BatchSystem::run_pipelined`]) sized by a
//! [`BlockSizeController`] — pinned for `--policy batch=N`, the AIMD
//! law (plus the optional latency deadline) for `--policy
//! batch=adaptive[...]` — and folds the controller's decisions into
//! the run's [`crate::stats::TxStats`]
//! (`block_grows`/`block_shrinks`/`final_block`). The streaming
//! pipeline (`crate::runtime::pipeline`) drains its bounded channel in
//! controller-sized blocks built by [`edge_insert_block_owned`]: each
//! transaction owns its tuple chunk, because under cross-block
//! pipelining a block outlives the drain buffer it was cut from.

use std::time::{Duration, Instant};

use crate::graph::computation::{append_results, ComputationResult, COLLECT_FLUSH};
use crate::graph::generation::insert_edge;
use crate::graph::layout::Graph;
use crate::graph::rmat::EdgeTuple;
use crate::graph::subgraph::SubgraphResult;
use crate::mem::{TxHeap, WORDS_PER_LINE};
use crate::runtime::workers::PoolConfig;
use crate::sim::workload::TxnDesc;
use crate::stats::StatsTable;
use crate::tm::access::{DirectAccess, TxAccess, TxResult};

use super::adaptive::BlockSizeController;
use super::mvmemory::MvMemory;
use super::{BatchReport, BatchSystem, BatchTxn};

/// Scanned edges folded into one gmax-probe transaction (phase 1 of
/// the computation kernel under the batch backend).
pub const PROBE_CHUNK: usize = 16;

/// Transaction `j` of the edge-insertion batch: inserts
/// `tuples[j*chunk..][..chunk]` into cells `j*chunk ..`, matching the
/// cell order a sequential insert produces.
pub fn edge_insert_txn<'g>(
    g: &'g Graph,
    tuples: &'g [EdgeTuple],
    chunk: usize,
    j: usize,
) -> BatchTxn<'g> {
    let chunk = chunk.max(1);
    let lo = j * chunk;
    let hi = (lo + chunk).min(tuples.len());
    let slice = &tuples[lo..hi];
    BatchTxn::new(move |t: &mut dyn TxAccess| -> TxResult<()> {
        for (k, e) in slice.iter().enumerate() {
            // The same critical section every other backend runs —
            // shared so all builds stay bit-identical.
            insert_edge(t, g, lo + k, e)?;
        }
        Ok(())
    })
}

/// Insert-transactions for `tuples`, `chunk` edges per transaction,
/// with cells assigned sequentially from `first_cell` — the building
/// block of the streaming pipeline's batch drain, where `first_cell`
/// is the number of edges already inserted by previous blocks. The
/// cell order equals a sequential insert of the whole stream.
pub fn edge_insert_block<'g>(
    g: &'g Graph,
    tuples: &'g [EdgeTuple],
    first_cell: usize,
    chunk: usize,
) -> Vec<BatchTxn<'g>> {
    let chunk = chunk.max(1);
    (0..tuples.len().div_ceil(chunk))
        .map(move |j| {
            let lo = j * chunk;
            let hi = (lo + chunk).min(tuples.len());
            let slice = &tuples[lo..hi];
            let cell0 = first_cell + lo;
            BatchTxn::new(move |t: &mut dyn TxAccess| -> TxResult<()> {
                for (k, e) in slice.iter().enumerate() {
                    insert_edge(t, g, cell0 + k, e)?;
                }
                Ok(())
            })
        })
        .collect()
}

/// Like [`edge_insert_block`], but each transaction *owns* its tuple
/// chunk (copied out of `tuples`), so the block only borrows the
/// graph. This is what the streaming pipeline's drain source needs:
/// under cross-block pipelining a block stays live while the next one
/// is built from freshly received tuples, so blocks cannot borrow the
/// drain buffer.
pub fn edge_insert_block_owned<'g>(
    g: &'g Graph,
    tuples: &[EdgeTuple],
    first_cell: usize,
    chunk: usize,
) -> Vec<BatchTxn<'g>> {
    let chunk = chunk.max(1);
    (0..tuples.len().div_ceil(chunk))
        .map(|j| {
            let lo = j * chunk;
            let hi = (lo + chunk).min(tuples.len());
            let slice: Vec<EdgeTuple> = tuples[lo..hi].to_vec();
            let cell0 = first_cell + lo;
            BatchTxn::new(move |t: &mut dyn TxAccess| -> TxResult<()> {
                for (k, e) in slice.iter().enumerate() {
                    insert_edge(t, g, cell0 + k, e)?;
                }
                Ok(())
            })
        })
        .collect()
}

/// All edge-insertion transactions for `tuples`, `chunk` edges per
/// transaction. Convenience for tests/examples; the streaming
/// [`run_generation`] below builds one block at a time instead.
pub fn edge_insert_txns<'g>(
    g: &'g Graph,
    tuples: &'g [EdgeTuple],
    chunk: usize,
) -> Vec<BatchTxn<'g>> {
    edge_insert_block(g, tuples, 0, chunk)
}

/// Run an already-materialized transaction list through
/// [`BatchSystem::run`] in controller-sized blocks **to a barrier per
/// block** — the admission-barrier baseline the bench A/Bs the
/// pipelined session against. The final state is bit-identical to
/// sequential execution for *every* controller trajectory (blocks
/// preserve index order).
pub fn run_blocks(
    heap: &TxHeap,
    txns: &[BatchTxn<'_>],
    concurrency: usize,
    ctl: &mut BlockSizeController,
) -> BatchReport {
    let mut report = BatchReport::default();
    let mut j0 = 0;
    while j0 < txns.len() {
        let j1 = (j0 + ctl.current().max(1)).min(txns.len());
        let t0 = Instant::now();
        let r = BatchSystem::run(heap, &txns[j0..j1], concurrency);
        ctl.observe_block(r.executions, r.txns as u64, t0.elapsed());
        report.merge(&r);
        j0 = j1;
    }
    report
}

/// The same contract as [`run_blocks`], but streamed through the
/// cross-block-pipelined session ([`BatchSystem::run_pipelined`]):
/// block N+1 executes while block N's validation tail drains. Output
/// is still bit-identical to sequential index order — the
/// `batch_determinism` suite proves barrier, pipelined, and the serial
/// oracle agree word for word.
pub fn run_txns_pipelined(
    heap: &TxHeap,
    txns: Vec<BatchTxn<'_>>,
    concurrency: usize,
    ctl: &mut BlockSizeController,
) -> BatchReport {
    run_txns_pipelined_with_pool(heap, txns, &PoolConfig::pinned(concurrency), ctl)
}

/// [`run_txns_pipelined`] with an explicit pool shape — `pin: false`
/// exercises the topology-fallback path (flat groups, no affinity),
/// which is what the determinism suite's pinning-unavailable case and
/// hosted-CI runners hit.
pub fn run_txns_pipelined_with_pool(
    heap: &TxHeap,
    txns: Vec<BatchTxn<'_>>,
    pool: &PoolConfig,
    ctl: &mut BlockSizeController,
) -> BatchReport {
    let mut iter = txns.into_iter();
    BatchSystem::run_pipelined_pool::<MvMemory, _>(
        heap,
        move |block| {
            let blk: Vec<BatchTxn> = iter.by_ref().take(block.max(1)).collect();
            if blk.is_empty() {
                None
            } else {
                Some(blk)
            }
        },
        pool,
        ctl,
    )
}

/// Generation kernel through the pipelined batch session:
/// controller-sized blocks, `concurrency` pinned workers, block N+1
/// executing while block N's validation tail drains. Mirrors the
/// signature of [`crate::graph::generation::run`]. Blocks are
/// constructed lazily so peak memory is O(block), not O(edges).
pub fn run_generation(
    g: &Graph,
    tuples: &[EdgeTuple],
    concurrency: usize,
    mut ctl: BlockSizeController,
) -> (Duration, StatsTable) {
    let t0 = Instant::now();
    let chunk = g.cfg.batch.max(1);
    let n_txns = tuples.len().div_ceil(chunk);
    let mut j0 = 0usize;
    let report = BatchSystem::run_pipelined::<MvMemory, _>(
        &g.heap,
        move |block| {
            if j0 >= n_txns {
                return None;
            }
            let j1 = (j0 + block.max(1)).min(n_txns);
            let blk: Vec<BatchTxn> = (j0..j1)
                .map(|j| edge_insert_txn(g, tuples, chunk, j))
                .collect();
            j0 = j1;
            Some(blk)
        },
        concurrency,
        &mut ctl,
    );
    // The transactional paths advance the pool cursor as they reserve
    // cells; the batch path assigns cells by index, so it settles the
    // cursor once at the end — same final value.
    g.heap.store(g.pool_cursor, tuples.len() as u64);
    let elapsed = t0.elapsed();
    let mut table = StatsTable::new();
    let mut stats = report.to_stats();
    ctl.apply_to(&mut stats);
    stats.time_ns = elapsed.as_nanos() as u64;
    table.push(0, stats);
    (elapsed, table)
}

fn append_txn(g: &Graph, cells: Vec<u64>) -> BatchTxn<'_> {
    BatchTxn::new(move |t: &mut dyn TxAccess| -> TxResult<()> {
        append_results(t, g, &cells)
    })
}

/// Computation kernel through the pipelined batch session. Mirrors
/// [`crate::graph::computation::run`]: phase 1 finds the max weight
/// (chunked probes), phase 2 appends the top band in cell order. One
/// controller spans both phases, so what phase 1 learns about the
/// conflict regime carries into phase 2's sizing. The phase boundary
/// is a real barrier (the cutoff depends on every probe), so each
/// phase is its own pipelined stream.
pub fn run_computation(
    g: &Graph,
    concurrency: usize,
    mut ctl: BlockSizeController,
) -> ComputationResult {
    let t0 = Instant::now();
    let total_cells = g.cells_allocated();

    // Phase 1: gmax probes. Weights are immutable after generation, so
    // each body scans its cell range non-transactionally (exactly as
    // the sequential kernel does) — the transaction is the paper's
    // `read gmax; maybe write` critical section, PROBE_CHUNK scanned
    // edges per txn. Closures capture only their (lo, hi) range, so
    // nothing is materialized up front.
    let gmax_addr = g.gmax;
    let mut report = BatchReport::default();
    let n_probes = total_cells.div_ceil(PROBE_CHUNK);
    let mut j0 = 0usize;
    let r1 = BatchSystem::run_pipelined::<MvMemory, _>(
        &g.heap,
        move |block| {
            if j0 >= n_probes {
                return None;
            }
            let j1 = (j0 + block.max(1)).min(n_probes);
            let blk: Vec<BatchTxn> = (j0..j1)
                .map(|j| {
                    let lo = j * PROBE_CHUNK;
                    let hi = (lo + PROBE_CHUNK).min(total_cells);
                    BatchTxn::new(move |t: &mut dyn TxAccess| -> TxResult<()> {
                        let mut cur = t.read(gmax_addr)?;
                        for i in lo..hi {
                            let w = g.heap.load(g.cell(i) + Graph::CELL_WEIGHT);
                            if w > cur {
                                t.write(gmax_addr, w)?;
                                cur = w;
                            }
                        }
                        Ok(())
                    })
                })
                .collect();
            j0 = j1;
            Some(blk)
        },
        concurrency,
        &mut ctl,
    );
    report.merge(&r1);

    let max_weight = g.heap.load(g.gmax) as u32;
    let cutoff = g.weight_cutoff() as u64;

    // Phase 2: collect the band, `flush` hits per append transaction,
    // in cell order — the deterministic sequential order. The source
    // streams the cell scan, so memory stays O(block).
    let flush = g.cfg.batch.max(COLLECT_FLUSH);
    let mut i = 0usize;
    let mut pending: Vec<u64> = Vec::new();
    let mut drained = false;
    let r2 = BatchSystem::run_pipelined::<MvMemory, _>(
        &g.heap,
        move |block| {
            if drained {
                return None;
            }
            let want = block.max(1);
            let mut blk: Vec<BatchTxn> = Vec::new();
            while blk.len() < want {
                if i >= total_cells {
                    if !pending.is_empty() {
                        blk.push(append_txn(g, std::mem::take(&mut pending)));
                    }
                    drained = true;
                    break;
                }
                let cell = g.cell(i);
                if g.heap.load(cell + Graph::CELL_WEIGHT) > cutoff {
                    pending.push(cell as u64);
                    if pending.len() == flush {
                        blk.push(append_txn(g, std::mem::take(&mut pending)));
                    }
                }
                i += 1;
            }
            if blk.is_empty() {
                None
            } else {
                Some(blk)
            }
        },
        concurrency,
        &mut ctl,
    );
    report.merge(&r2);

    let selected = g.heap.load(g.result_count) as usize;
    let elapsed = t0.elapsed();
    let mut table = StatsTable::new();
    let mut stats = report.to_stats();
    ctl.apply_to(&mut stats);
    stats.time_ns = elapsed.as_nanos() as u64;
    table.push(0, stats);
    ComputationResult {
        max_weight,
        cutoff: cutoff as u32,
        selected,
        elapsed,
        stats: table,
    }
}

/// Claim every vertex of the `candidates` stream at `mark_val` through
/// the pipelined batch session — `chunk` claims per transaction,
/// controller-sized blocks with cross-block overlap — then return the
/// newly claimed vertices in first-candidate order, which is exactly
/// the order the serial BFS oracle discovers them in. The stream is
/// consumed twice (claims, then the next-frontier scan), so peak
/// memory is O(block × chunk) instead of the whole level's candidate
/// list. `seen` dedups within the level (a vertex reachable through
/// two frontier members is claimed once).
#[allow(clippy::too_many_arguments)]
fn claim_level<I>(
    g: &Graph,
    marks_base: crate::mem::Addr,
    candidates: I,
    mark_val: u64,
    concurrency: usize,
    ctl: &mut BlockSizeController,
    chunk: usize,
    report: &mut BatchReport,
    seen: &mut [bool],
) -> Vec<u32>
where
    I: Iterator<Item = u32> + Clone + Send,
{
    let mk_txn = |slice: Vec<u32>| {
        BatchTxn::new(move |t: &mut dyn TxAccess| -> TxResult<()> {
            for &v in &slice {
                // The same `read mark; if unmarked, write level`
                // critical section the policy executors run.
                let addr = marks_base + v as usize;
                if t.read(addr)? == 0 {
                    t.write(addr, mark_val)?;
                }
            }
            Ok(())
        })
    };

    // Pass 1: stream the candidates into claim transactions; the
    // session overlaps each block's execution with the previous
    // block's validation tail. The level boundary itself stays a real
    // barrier (run_pipelined returns only when every claim committed).
    {
        let mut cand = candidates.clone();
        let mut drained = false;
        let r = BatchSystem::run_pipelined::<MvMemory, _>(
            &g.heap,
            move |block| {
                if drained {
                    return None;
                }
                let want = block.max(1);
                let mut blk: Vec<BatchTxn> = Vec::new();
                while blk.len() < want && !drained {
                    let mut buf: Vec<u32> = Vec::with_capacity(chunk);
                    while buf.len() < chunk {
                        match cand.next() {
                            Some(v) => buf.push(v),
                            None => {
                                drained = true;
                                break;
                            }
                        }
                    }
                    if buf.is_empty() {
                        break;
                    }
                    blk.push(mk_txn(buf));
                }
                if blk.is_empty() {
                    None
                } else {
                    Some(blk)
                }
            },
            concurrency,
            ctl,
        );
        report.merge(&r);
    }

    // Pass 2: the committed marks decide the next frontier. A
    // candidate whose mark equals `mark_val` was claimed this level;
    // first occurrence wins, matching the serial discovery order.
    let mut next = Vec::new();
    for v in candidates {
        if !seen[v as usize] && g.heap.load(marks_base + v as usize) == mark_val {
            seen[v as usize] = true;
            next.push(v);
        }
    }
    next
}

/// Subgraph kernel (kernel 3) through [`BatchSystem`]: mirrors
/// [`crate::graph::subgraph::run`]. Each BFS level's claims are
/// admitted as deterministic blocks (`g.cfg.batch` claims per
/// transaction, the same task-size knob as the other kernels), so the
/// claimed ball and every per-vertex level are bit-identical to the
/// serial oracle regardless of `concurrency`. Power-law hubs make the
/// early levels conflict-dense — the multi-version store absorbs the
/// races the per-transaction executors fight over, and the adaptive
/// controller shrinks blocks exactly there.
pub fn run_subgraph(
    g: &Graph,
    roots: &[u32],
    depth: usize,
    concurrency: usize,
    mut ctl: BlockSizeController,
) -> SubgraphResult {
    let t0 = Instant::now();
    let n = g.cfg.vertices();
    // Mark region: one word per vertex, level+1 when claimed (the same
    // layout the threaded kernel allocates).
    let marks_base = g.heap.alloc_lines(n.div_ceil(WORDS_PER_LINE));
    let chunk = g.cfg.batch.max(1);
    let mut report = BatchReport::default();
    let mut seen = vec![false; n];

    // Level 0: claim the roots.
    let mut frontier = claim_level(
        g,
        marks_base,
        roots.iter().copied(),
        1,
        concurrency,
        &mut ctl,
        chunk,
        &mut report,
        &mut seen,
    );
    let mut level_sizes = vec![frontier.len()];

    for level in 1..=depth {
        if frontier.is_empty() {
            break;
        }
        // Candidate order = (frontier order, adjacency order): the
        // serial oracle's discovery order, streamed lazily — the
        // adjacency walk is non-transactional (the graph is frozen
        // after kernel 1) and cheap enough to run twice.
        let candidates = frontier
            .iter()
            .flat_map(|&v| g.adjacency(v).into_iter().map(|(dst, _, _)| dst));
        let next = claim_level(
            g,
            marks_base,
            candidates,
            (level + 1) as u64,
            concurrency,
            &mut ctl,
            chunk,
            &mut report,
            &mut seen,
        );
        level_sizes.push(next.len());
        frontier = next;
    }

    let total_marked = level_sizes.iter().sum();
    let elapsed = t0.elapsed();
    let mut table = StatsTable::new();
    let mut stats = report.to_stats();
    ctl.apply_to(&mut stats);
    stats.time_ns = elapsed.as_nanos() as u64;
    table.push(0, stats);
    SubgraphResult {
        level_sizes,
        total_marked,
        elapsed,
        stats: table,
        marks_base,
    }
}

/// Turn a simulator descriptor into an executable body on a scratch
/// heap: reads fold into an accumulator, each written line is
/// read-modify-written with a mix of the accumulator. The result is a
/// deterministic function of the memory the body observes, so batch
/// and sequential execution must agree bit-for-bit. Lines map to
/// addresses as `line * WORDS_PER_LINE`; callers bound `wlines` /
/// `rlines` by `heap.capacity() / WORDS_PER_LINE`.
pub fn desc_txn(desc: TxnDesc, salt: u64) -> BatchTxn<'static> {
    BatchTxn::new(move |t: &mut dyn TxAccess| -> TxResult<()> {
        let mut acc = salt;
        for &line in desc.rlines() {
            acc ^= t.read(line as usize * WORDS_PER_LINE)?;
        }
        for &line in desc.wlines() {
            let addr = line as usize * WORDS_PER_LINE;
            let v = t.read(addr)?;
            acc = acc
                .rotate_left(13)
                .wrapping_add(v ^ 0x9E37_79B9_7F4A_7C15);
            t.write(addr, acc)?;
        }
        Ok(())
    })
}

/// Sequential oracle: run the batch in index order, directly against
/// the heap. Defines the state every concurrent execution must match.
pub fn run_sequential(heap: &TxHeap, txns: &[BatchTxn<'_>]) {
    let mut acc = DirectAccess { heap };
    for txn in txns {
        (txn.body)(&mut acc).expect("direct execution cannot abort");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layout::Ssca2Config;
    use crate::graph::{rmat, verify};

    #[test]
    fn batched_generation_matches_serial_build_bitwise() {
        let cfg = Ssca2Config::new(7);
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);

        // Serial oracle.
        let ga = Graph::alloc(cfg);
        run_sequential(&ga.heap, &edge_insert_txns(&ga, &tuples, 1));
        ga.heap.store(ga.pool_cursor, tuples.len() as u64);

        // Batch backend, several worker counts.
        for workers in [1usize, 2, 4] {
            let gb = Graph::alloc(cfg);
            let (_, table) =
                run_generation(&gb, &tuples, workers, BlockSizeController::fixed(256));
            verify::check_graph(&gb, &tuples).unwrap();
            assert_eq!(
                table.total().total_commits(),
                tuples.len() as u64,
                "one commit per edge at chunk=1"
            );
            assert_eq!(ga.heap.allocated(), gb.heap.allocated());
            for addr in 0..ga.heap.allocated() {
                assert_eq!(
                    ga.heap.load(addr),
                    gb.heap.load(addr),
                    "heap divergence at word {addr} with {workers} workers"
                );
            }
        }
    }

    #[test]
    fn chunked_generation_matches_too() {
        let mut cfg = Ssca2Config::new(6);
        cfg.batch = 8;
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        let g = Graph::alloc(cfg);
        let (_, table) = run_generation(&g, &tuples, 3, BlockSizeController::fixed(64));
        verify::check_graph(&g, &tuples).unwrap();
        assert_eq!(
            table.total().total_commits(),
            (tuples.len() as u64).div_ceil(8)
        );
    }

    #[test]
    fn adaptive_generation_matches_fixed_bitwise() {
        // The controller's trajectory must not leak into the output.
        let cfg = Ssca2Config::new(7);
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        let ga = Graph::alloc(cfg);
        let (_, ta) = run_generation(&ga, &tuples, 3, BlockSizeController::fixed(128));
        let gb = Graph::alloc(cfg);
        let (_, tb) = run_generation(
            &gb,
            &tuples,
            3,
            BlockSizeController::with_bounds(32, 8, 512, 32),
        );
        verify::check_graph(&gb, &tuples).unwrap();
        assert_eq!(ta.total().total_commits(), tb.total().total_commits());
        assert!(
            tb.total().final_block > 0,
            "adaptive run must report its converged block"
        );
        for addr in 0..ga.heap.allocated() {
            assert_eq!(ga.heap.load(addr), gb.heap.load(addr), "word {addr}");
        }
    }

    #[test]
    fn batch_computation_finds_true_max_and_band() {
        let cfg = Ssca2Config::new(6);
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        let g = Graph::alloc(cfg);
        run_sequential(&g.heap, &edge_insert_txns(&g, &tuples, 1));
        g.heap.store(g.pool_cursor, tuples.len() as u64);

        let r = run_computation(&g, 4, BlockSizeController::fixed(128));
        let true_max = tuples.iter().map(|e| e.weight).max().unwrap();
        assert_eq!(r.max_weight, true_max);
        verify::check_results(&g, &tuples).unwrap();
        assert!(r.selected > 0);
    }

    #[test]
    fn batch_subgraph_matches_serial_oracle_across_workers() {
        use crate::graph::subgraph;

        let mut totals = Vec::new();
        for workers in [1usize, 2, 4] {
            let cfg = Ssca2Config::new(7);
            let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
            let g = Graph::alloc(cfg);
            run_sequential(&g.heap, &edge_insert_txns(&g, &tuples, 1));
            g.heap.store(g.pool_cursor, tuples.len() as u64);
            let _ = run_computation(&g, 2, BlockSizeController::fixed(64));
            let roots = subgraph::roots_from_results(&g);
            assert!(!roots.is_empty());
            let r = run_subgraph(&g, &roots, 3, workers, BlockSizeController::fixed(32));
            subgraph::verify_subgraph(&g, &roots, 3, &r)
                .unwrap_or_else(|e| panic!("workers={workers}: {e}"));
            assert!(
                r.stats.total().sw_commits >= roots.len() as u64,
                "at chunk=1 every root claim is one committed transaction"
            );
            totals.push(r.total_marked);
        }
        assert!(
            totals.windows(2).all(|w| w[0] == w[1]),
            "visited set must be worker-count-independent: {totals:?}"
        );
    }

    #[test]
    fn batch_subgraph_adaptive_sizing_matches_fixed() {
        use crate::graph::subgraph;

        let cfg = Ssca2Config::new(7);
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        let g = Graph::alloc(cfg);
        run_sequential(&g.heap, &edge_insert_txns(&g, &tuples, 1));
        g.heap.store(g.pool_cursor, tuples.len() as u64);
        let _ = run_computation(&g, 2, BlockSizeController::fixed(64));
        let roots = subgraph::roots_from_results(&g);

        let fixed = run_subgraph(&g, &roots, 3, 3, BlockSizeController::fixed(32));
        subgraph::verify_subgraph(&g, &roots, 3, &fixed).unwrap();

        // Fresh graph for the adaptive run (marks regions allocate).
        let g2 = Graph::alloc(cfg);
        run_sequential(&g2.heap, &edge_insert_txns(&g2, &tuples, 1));
        g2.heap.store(g2.pool_cursor, tuples.len() as u64);
        let _ = run_computation(&g2, 2, BlockSizeController::fixed(64));
        let adaptive = run_subgraph(
            &g2,
            &roots,
            3,
            3,
            BlockSizeController::with_bounds(8, 2, 128, 8),
        );
        subgraph::verify_subgraph(&g2, &roots, 3, &adaptive).unwrap();
        assert_eq!(fixed.level_sizes, adaptive.level_sizes);
        assert_eq!(fixed.total_marked, adaptive.total_marked);
    }

    #[test]
    fn batch_subgraph_depth_zero_claims_only_roots() {
        let cfg = Ssca2Config::new(6);
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        let g = Graph::alloc(cfg);
        run_sequential(&g.heap, &edge_insert_txns(&g, &tuples, 1));
        g.heap.store(g.pool_cursor, tuples.len() as u64);
        let _ = run_computation(&g, 2, BlockSizeController::fixed(64));
        let roots = crate::graph::subgraph::roots_from_results(&g);
        let r = run_subgraph(&g, &roots, 0, 3, BlockSizeController::fixed(16));
        assert_eq!(r.total_marked, roots.len());
        crate::graph::subgraph::verify_subgraph(&g, &roots, 0, &r).unwrap();
    }

    #[test]
    fn desc_txn_is_deterministic() {
        let heap_a = TxHeap::new(32 * WORDS_PER_LINE);
        let heap_b = TxHeap::new(32 * WORDS_PER_LINE);
        let mut d = TxnDesc {
            work: 0,
            wlines: [0; crate::sim::workload::MAX_WLINES],
            n_wlines: 2,
            rlines: [0; 2],
            n_rlines: 1,
            n_reads: 0,
            n_writes: 0,
            footprint_lines: 0,
        };
        d.wlines[0] = 3;
        d.wlines[1] = 5;
        d.rlines[0] = 7;
        let txns = vec![desc_txn(d, 42), desc_txn(d, 43)];
        run_sequential(&heap_a, &txns);
        BatchSystem::run(&heap_b, &txns, 2);
        for line in [3usize, 5, 7] {
            assert_eq!(
                heap_a.load(line * WORDS_PER_LINE),
                heap_b.load(line * WORDS_PER_LINE)
            );
        }
    }
}
