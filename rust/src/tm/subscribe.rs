//! Lock subscription: how a hardware transaction couples to a software
//! lock (the HyTM gbllock, or the fallback lock of an HTM+lock scheme).
//!
//! On real RTM the hardware transaction *reads the lock word inside the
//! transaction*; any writer to that word then causes a data conflict.
//! Our software HTM reproduces that with an explicit sample/validate
//! protocol. Implementors expose a monotone component in the sampled
//! word so that even a lock episode that begins *and ends* within the
//! hardware transaction's window is detected (see
//! [`crate::hytm::GblLock`] for why that matters).

/// A lock word a hardware transaction can subscribe to.
pub trait Subscription: Sync {
    /// Snapshot of the lock word (taken at `HW_BEGIN`).
    fn sample(&self) -> u64;
    /// True iff the word has not changed since `sample` — no acquire or
    /// release happened.
    fn unchanged_since(&self, sample: u64) -> bool;
    /// Is the lock currently held? (`HW_BEGIN` aborts Explicit if so.)
    fn is_held(&self) -> bool;
}
