//! The transactional access interface.
//!
//! Workload code (the SSCA-2 kernels) is written once against
//! [`TxAccess`]; each policy executor supplies its own implementation —
//! speculative (software HTM), logged (NOrec/TL2 STM), or direct
//! (coarse lock). A body returns `Err(Abort)` when the underlying
//! speculation failed mid-flight and the executor must retry.

use super::cause::AbortCause;
use crate::mem::Addr;

/// Marker error: the enclosing transaction attempt must abort.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Abort(pub AbortCause);

pub type TxResult<T> = Result<T, Abort>;

/// What a transaction body may do to shared memory.
pub trait TxAccess {
    /// Transactionally read the word at `addr`.
    fn read(&mut self, addr: Addr) -> TxResult<u64>;
    /// Transactionally write `val` to `addr`.
    fn write(&mut self, addr: Addr, val: u64) -> TxResult<()>;

    /// Read-modify-write helper.
    fn update(&mut self, addr: Addr, f: impl FnOnce(u64) -> u64) -> TxResult<u64>
    where
        Self: Sized,
    {
        let v = f(self.read(addr)?);
        self.write(addr, v)?;
        Ok(v)
    }
}

/// A transaction body: runs against any access implementation, returns a
/// value on success. `FnMut` because the executor re-runs it on retry.
pub trait TxBody<R>: FnMut(&mut dyn TxAccess) -> TxResult<R> {}
impl<R, F: FnMut(&mut dyn TxAccess) -> TxResult<R>> TxBody<R> for F {}

/// Direct (non-speculative) access: used under the coarse lock, by the
/// HLE/HTM lock fallback paths, and for single-threaded trace capture.
pub struct DirectAccess<'h> {
    pub heap: &'h crate::mem::TxHeap,
}

impl TxAccess for DirectAccess<'_> {
    #[inline]
    fn read(&mut self, addr: Addr) -> TxResult<u64> {
        Ok(self.heap.load_acquire(addr))
    }

    #[inline]
    fn write(&mut self, addr: Addr, val: u64) -> TxResult<()> {
        self.heap.store_release(addr, val);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::TxHeap;

    #[test]
    fn direct_access_reads_writes_heap() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(1);
        let mut acc = DirectAccess { heap: &heap };
        acc.write(a, 99).unwrap();
        assert_eq!(acc.read(a).unwrap(), 99);
        assert_eq!(heap.load(a), 99);
    }

    #[test]
    fn update_applies_function() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(1);
        heap.store(a, 10);
        let mut acc = DirectAccess { heap: &heap };
        let v = acc.update(a, |x| x * 3).unwrap();
        assert_eq!(v, 30);
        assert_eq!(heap.load(a), 30);
    }

    #[test]
    fn body_trait_object_compatible() {
        let heap = TxHeap::new(64);
        let a = heap.alloc(1);
        let body = |acc: &mut dyn TxAccess| -> TxResult<u64> {
            acc.write(a, 5)?;
            acc.read(a)
        };
        let mut acc = DirectAccess { heap: &heap };
        assert_eq!(body(&mut acc).unwrap(), 5);
    }
}
