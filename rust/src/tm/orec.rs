//! Ownership records: a global version clock plus a table of per-line
//! versioned write-locks (TL2-style). Shared by the software HTM
//! (`htm/`) and the TL2 STM (`stm/tl2.rs`); NOrec deliberately does not
//! use it (that is its design point).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::mem::Line;

/// Global version clock. Even/odd is irrelevant here — versions are
/// plain integers; lock words distinguish locked/unlocked by their LSB.
pub struct GlobalClock(AtomicU64);

impl GlobalClock {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    /// Current timestamp — a transaction's read version.
    #[inline]
    pub fn now(&self) -> u64 {
        self.0.load(Ordering::Acquire)
    }

    /// Advance and return the new (unique) write version.
    #[inline]
    pub fn tick(&self) -> u64 {
        self.0.fetch_add(1, Ordering::AcqRel) + 1
    }
}

impl Default for GlobalClock {
    fn default() -> Self {
        Self::new()
    }
}

/// Decoded state of one ownership record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrecValue {
    /// Unlocked; last committed write carried this version.
    Version(u64),
    /// Write-locked by transaction/thread `owner`.
    Locked { owner: u32 },
}

impl OrecValue {
    #[inline]
    fn decode(raw: u64) -> Self {
        if raw & 1 == 1 {
            OrecValue::Locked {
                owner: (raw >> 1) as u32,
            }
        } else {
            OrecValue::Version(raw >> 1)
        }
    }

    #[inline]
    fn encode(self) -> u64 {
        match self {
            OrecValue::Version(v) => v << 1,
            OrecValue::Locked { owner } => ((owner as u64) << 1) | 1,
        }
    }
}

/// Striped per-line versioned-lock table.
///
/// `size` is a power of two; lines hash into it with a Fibonacci mix so
/// that the regular stride patterns of the heap allocator don't alias
/// into the same stripe. Striping can manufacture false conflicts
/// (two distinct lines sharing an orec) exactly as physical caches
/// manufacture false sharing; the table is sized so this is rare.
pub struct LockTable {
    orecs: Box<[AtomicU64]>,
    mask: u64,
}

pub const DEFAULT_LOCK_TABLE_BITS: u32 = 18; // 256 Ki orecs = 2 MiB

impl LockTable {
    pub fn new(bits: u32) -> Self {
        let size = 1usize << bits;
        let mut v = Vec::with_capacity(size);
        v.resize_with(size, || AtomicU64::new(0));
        Self {
            orecs: v.into_boxed_slice(),
            mask: (size as u64) - 1,
        }
    }

    #[inline]
    fn slot(&self, line: Line) -> &AtomicU64 {
        let h = line.0.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32;
        &self.orecs[(h & self.mask) as usize]
    }

    /// Read the orec for `line`.
    #[inline]
    pub fn read(&self, line: Line) -> OrecValue {
        OrecValue::decode(self.slot(line).load(Ordering::Acquire))
    }

    /// Try to acquire the write lock for `line`, expecting it unlocked at
    /// `expect_version`. Returns false if the orec changed (locked by
    /// someone, or version moved).
    #[inline]
    pub fn try_lock(&self, line: Line, expect_version: u64, owner: u32) -> bool {
        self.slot(line)
            .compare_exchange(
                OrecValue::Version(expect_version).encode(),
                OrecValue::Locked { owner }.encode(),
                Ordering::AcqRel,
                Ordering::Relaxed,
            )
            .is_ok()
    }

    /// Release a lock held by `owner`, stamping `new_version`.
    /// Panics if the orec is not locked by `owner` (protocol bug).
    #[inline]
    pub fn unlock(&self, line: Line, owner: u32, new_version: u64) {
        let prev = self.slot(line).swap(
            OrecValue::Version(new_version).encode(),
            Ordering::AcqRel,
        );
        debug_assert_eq!(
            OrecValue::decode(prev),
            OrecValue::Locked { owner },
            "orec released by non-owner"
        );
        let _ = prev;
    }

    /// Release a lock *without* bumping the version (abort path: memory
    /// was never written, so readers need not be invalidated).
    #[inline]
    pub fn unlock_restore(&self, line: Line, owner: u32, old_version: u64) {
        let prev = self
            .slot(line)
            .swap(OrecValue::Version(old_version).encode(), Ordering::AcqRel);
        debug_assert_eq!(OrecValue::decode(prev), OrecValue::Locked { owner });
        let _ = prev;
    }

    /// Two lines share a stripe (useful for tests and the false-conflict
    /// diagnostics).
    pub fn aliases(&self, a: Line, b: Line) -> bool {
        std::ptr::eq(self.slot(a), self.slot(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::qcheck::qcheck;

    #[test]
    fn clock_monotonic() {
        let c = GlobalClock::new();
        let a = c.now();
        let b = c.tick();
        let d = c.tick();
        assert!(a < b && b < d);
        assert_eq!(c.now(), d);
    }

    #[test]
    fn orec_encode_decode_roundtrip() {
        qcheck(
            "orec roundtrip",
            500,
            |r| {
                if r.next_u64() & 1 == 0 {
                    OrecValue::Version(r.below(1 << 62))
                } else {
                    OrecValue::Locked {
                        owner: r.next_u32(),
                    }
                }
            },
            |&v| OrecValue::decode(v.encode()) == v,
        );
    }

    #[test]
    fn lock_unlock_cycle() {
        let t = LockTable::new(8);
        let line = Line(42);
        assert_eq!(t.read(line), OrecValue::Version(0));
        assert!(t.try_lock(line, 0, 7));
        assert_eq!(t.read(line), OrecValue::Locked { owner: 7 });
        // Second lock attempt fails while held.
        assert!(!t.try_lock(line, 0, 8));
        t.unlock(line, 7, 5);
        assert_eq!(t.read(line), OrecValue::Version(5));
    }

    #[test]
    fn try_lock_fails_on_stale_version() {
        let t = LockTable::new(8);
        let line = Line(1);
        assert!(t.try_lock(line, 0, 1));
        t.unlock(line, 1, 10);
        assert!(!t.try_lock(line, 0, 2), "stale expected version");
        assert!(t.try_lock(line, 10, 2));
        t.unlock_restore(line, 2, 10);
        assert_eq!(t.read(line), OrecValue::Version(10));
    }

    #[test]
    fn distinct_lines_mostly_distinct_slots() {
        let t = LockTable::new(DEFAULT_LOCK_TABLE_BITS);
        let mut collisions = 0;
        for i in 0..1000u64 {
            if t.aliases(Line(i), Line(i + 1)) {
                collisions += 1;
            }
        }
        assert!(collisions < 10, "{collisions} adjacent-line collisions");
    }

    #[test]
    fn concurrent_lockers_mutually_exclude() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;
        let t = Arc::new(LockTable::new(4));
        let line = Line(3);
        let counter = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for owner in 0..4u32 {
            let t = Arc::clone(&t);
            let counter = Arc::clone(&counter);
            handles.push(std::thread::spawn(move || {
                let mut acquired = 0;
                for _ in 0..1000 {
                    let v = match t.read(line) {
                        OrecValue::Version(v) => v,
                        OrecValue::Locked { .. } => continue,
                    };
                    if t.try_lock(line, v, owner) {
                        // Critical section: non-atomic RMW through an
                        // atomic cell must never be racy under mutual
                        // exclusion.
                        let x = counter.load(Ordering::Relaxed);
                        counter.store(x + 1, Ordering::Relaxed);
                        t.unlock(line, owner, v + 1);
                        acquired += 1;
                    }
                }
                acquired
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(counter.load(Ordering::Relaxed), total);
    }
}
