//! Abort causes, mirroring Intel RTM's `_xabort` status bits.
//!
//! The whole point of DyAdHyTM (paper §3.6) is that the HTM *tells you
//! why* it aborted: `_XABORT_CAPACITY` means the transaction can never
//! succeed in hardware, so retrying is wasted work — fall back to STM
//! immediately. Our software HTM reports the same taxonomy so the policy
//! layer consumes exactly the bits `_xbegin()` would deliver.

/// Why a (hardware) transaction aborted.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AbortCause {
    /// Data conflict with a concurrent transaction (`_XABORT_CONFLICT`).
    /// The RTM "may succeed on retry" hint is set for this cause.
    Conflict,
    /// Read/write set exceeded the transactional buffers
    /// (`_XABORT_CAPACITY`): L1d write-set or L2 read-set bound, or a
    /// set-associativity eviction. Retrying in hardware cannot succeed.
    Capacity,
    /// The transaction explicitly aborted itself (`_XABORT_EXPLICIT`).
    /// In every HyTM here the only explicit abort is the gbllock
    /// subscription: an STM transaction holds the global lock.
    Explicit,
    /// Asynchronous event — interrupt, context switch, page fault
    /// (status bits all zero on real RTM). Rare; injected
    /// probabilistically by the fault model and by the DES simulator.
    Interrupt,
    /// Software transaction aborted on validation failure (STM-side
    /// cause; never produced by the HTM path).
    SwConflict,
}

impl AbortCause {
    /// Intel's "retry may succeed" hint (`_XABORT_RETRY`): set for
    /// conflicts and transient events, clear for capacity/explicit.
    #[inline]
    pub fn may_succeed_on_retry(self) -> bool {
        matches!(self, AbortCause::Conflict | AbortCause::Interrupt)
    }

    /// Stable index for per-cause counters.
    #[inline]
    pub fn index(self) -> usize {
        match self {
            AbortCause::Conflict => 0,
            AbortCause::Capacity => 1,
            AbortCause::Explicit => 2,
            AbortCause::Interrupt => 3,
            AbortCause::SwConflict => 4,
        }
    }

    pub const COUNT: usize = 5;

    pub const ALL: [AbortCause; 5] = [
        AbortCause::Conflict,
        AbortCause::Capacity,
        AbortCause::Explicit,
        AbortCause::Interrupt,
        AbortCause::SwConflict,
    ];

    pub fn name(self) -> &'static str {
        match self {
            AbortCause::Conflict => "conflict",
            AbortCause::Capacity => "capacity",
            AbortCause::Explicit => "explicit",
            AbortCause::Interrupt => "interrupt",
            AbortCause::SwConflict => "sw-conflict",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_hint_matches_rtm_semantics() {
        assert!(AbortCause::Conflict.may_succeed_on_retry());
        assert!(AbortCause::Interrupt.may_succeed_on_retry());
        assert!(!AbortCause::Capacity.may_succeed_on_retry());
        assert!(!AbortCause::Explicit.may_succeed_on_retry());
    }

    #[test]
    fn indices_are_dense_and_unique() {
        let mut seen = [false; AbortCause::COUNT];
        for c in AbortCause::ALL {
            assert!(!seen[c.index()]);
            seen[c.index()] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
