//! Transactional-memory core: the access interface every synchronization
//! policy implements, Intel-RTM-style abort causes, and the shared
//! versioned-lock machinery (global version clock + per-line lock table)
//! used by both the software HTM and the TL2 STM.

pub mod access;
pub mod cause;
pub mod orec;
pub mod subscribe;

pub use access::{TxAccess, TxBody, TxResult};
pub use cause::AbortCause;
pub use orec::{GlobalClock, LockTable, OrecValue};
pub use subscribe::Subscription;
