//! The statistics plane (DESIGN.md S14): per-thread commit/abort/retry
//! counters feeding Figures 4(a–c) and the hardware-insight discussion
//! in the paper's §4.

mod table;

pub use table::{StatsTable, ThreadStats};

use crate::obs::hist::LatencyHist;
use crate::tm::AbortCause;

/// Counters for one thread under one policy. Plain u64 fields — each
/// thread owns its own instance, aggregation happens after join.
#[derive(Clone, Debug, Default)]
pub struct TxStats {
    /// Transactions that committed in hardware (`HW_COMMIT`).
    pub hw_commits: u64,
    /// Hardware transaction attempts that started (Fig 4a counts HTM
    /// transactions = attempts).
    pub hw_attempts: u64,
    /// Hardware retries: re-attempts after an abort (Fig 4b).
    pub hw_retries: u64,
    /// Hardware aborts by cause.
    pub hw_aborts: [u64; AbortCause::COUNT],
    /// Transactions that fell back to and committed in software (Fig 4c
    /// counts STM transactions).
    pub sw_commits: u64,
    /// Software validation aborts (internal STM retries).
    pub sw_aborts: u64,
    /// Transactions executed under a non-speculative lock fallback
    /// (HTMALock / HTMSpin / HLE second attempt).
    pub lock_commits: u64,
    /// Transactions a `PolicySpec::Batch` executor ran on the
    /// per-transaction NOrec fallback instead of `BatchSystem`. Zero on
    /// every routed path (generation, computation, subgraph, pipeline);
    /// non-zero means a caller is degrading batch speculation to plain
    /// NOrec, and the run is reported as `batch(fallback:norec)` (see
    /// `PolicySpec::label`).
    pub norec_fallback: u64,
    /// Adaptive batch sizing (`--policy batch=adaptive`):
    /// additive-increase decisions the `BlockSizeController` took.
    pub block_grows: u64,
    /// Adaptive batch sizing: multiplicative-decrease decisions.
    pub block_shrinks: u64,
    /// Block size the batch run finished on (0 when no batch
    /// controller ran). `PolicySpec::label` reports this for
    /// `batch=adaptive` runs.
    pub final_block: u64,
    /// Worker-runtime counter (`runtime::workers`): tasks taken from a
    /// peer worker's deque.
    pub steals: u64,
    /// The subset of `steals` whose victim shared the thief's
    /// socket/L3 locality group (topology-aware `PinPlan`; equals
    /// `steals` on flat/fallback topologies).
    pub local_steals: u64,
    /// Worker-runtime counter: pool workers whose core pin applied
    /// (a property of the run — merges take the max, not the sum).
    pub pinned_workers: u64,
    /// Cross-block pipelining: execution attempts started while the
    /// previous block's validation tail was still draining.
    pub overlapped_txns: u64,
    /// Pipelining window depth the batch controller finished on (0 when
    /// no batch controller ran; 2 is the default head+overlap window,
    /// `--policy batch=adaptive:window=W` raises the ceiling).
    pub final_window: u64,
    /// Backend switches the `--policy auto` meta-controller committed
    /// (`engine::auto`). Zero under every fixed spec; `PolicySpec::label`
    /// reports it for auto runs and the snapshot schema exports it.
    pub backend_switches: u64,
    /// Fault-plane injections fired during this interval (`crate::fault`;
    /// always 0 without `--faults`).
    pub faults_injected: u64,
    /// Panicking transaction bodies caught and re-dispatched by the
    /// batch executor's quarantine (`catch_unwind`) path.
    pub quarantines: u64,
    /// Progress-watchdog kicks: stall deadlines that fired and ran
    /// recovery (`fault::watchdog`).
    pub watchdog_kicks: u64,
    /// Watchdog escalations to the serial lock backend
    /// (`engine::degraded`).
    pub degradations: u64,
    /// Peak live recorded-set cells across the batch pipeline's
    /// reclamation domain (`mem::epoch`) — retired minus reclaimed,
    /// sampled at every retire. A session property: merges take the
    /// max, not the sum. Bounded (plateaus) when reclamation is on;
    /// grows with the stream when it is off.
    pub mv_live_cells: u64,
    /// Recorded-set cells retired into epoch limbo (superseded
    /// incarnations plus each promoted block's final sets).
    pub mv_retired: u64,
    /// Recorded-set cells actually freed once every live worker
    /// passed their epoch. Stays 0 with reclamation disabled.
    pub mv_reclaimed: u64,
    /// Peak bump-arena footprint (bytes) of the lock-free store's
    /// version segments and address entries, sampled at promotion.
    /// A session property: merges take the max, not the sum.
    pub arena_bytes: u64,
    /// Wall-clock or virtual nanoseconds attributed to this thread.
    pub time_ns: u64,
    /// Per-transaction attempt→commit latency (only populated when
    /// `obs::timing_enabled()`; merged element-wise across threads).
    pub txn_lat: LatencyHist,
    /// Per-block admit→promote latency of the batch pipeline (only
    /// populated when `obs::timing_enabled()`).
    pub block_lat: LatencyHist,
}

impl TxStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Count a hardware abort by cause. This is the single accounting
    /// site for every HTM backend (live and simulated), so it doubles
    /// as the `hw-abort` trace event site — one branch when tracing is
    /// off.
    #[inline]
    pub fn note_hw_abort(&mut self, cause: AbortCause) {
        self.hw_aborts[cause.index()] += 1;
        crate::obs::trace::hw_abort(cause);
    }

    pub fn hw_aborts_total(&self) -> u64 {
        self.hw_aborts.iter().sum()
    }

    pub fn aborts_of(&self, cause: AbortCause) -> u64 {
        self.hw_aborts[cause.index()]
    }

    /// Total critical-section executions that completed, on any path.
    pub fn total_commits(&self) -> u64 {
        self.hw_commits + self.sw_commits + self.lock_commits
    }

    pub fn merge(&mut self, other: &TxStats) {
        self.hw_commits += other.hw_commits;
        self.hw_attempts += other.hw_attempts;
        self.hw_retries += other.hw_retries;
        for i in 0..AbortCause::COUNT {
            self.hw_aborts[i] += other.hw_aborts[i];
        }
        self.sw_commits += other.sw_commits;
        self.sw_aborts += other.sw_aborts;
        self.lock_commits += other.lock_commits;
        self.norec_fallback += other.norec_fallback;
        self.block_grows += other.block_grows;
        self.block_shrinks += other.block_shrinks;
        if other.final_block != 0 {
            // Later merges carry the most recent controller state.
            self.final_block = other.final_block;
        }
        self.steals += other.steals;
        self.local_steals += other.local_steals;
        self.pinned_workers = self.pinned_workers.max(other.pinned_workers);
        self.overlapped_txns += other.overlapped_txns;
        if other.final_window != 0 {
            // Later merges carry the most recent controller state.
            self.final_window = other.final_window;
        }
        self.backend_switches += other.backend_switches;
        self.faults_injected += other.faults_injected;
        self.quarantines += other.quarantines;
        self.watchdog_kicks += other.watchdog_kicks;
        self.degradations += other.degradations;
        self.mv_live_cells = self.mv_live_cells.max(other.mv_live_cells);
        self.mv_retired += other.mv_retired;
        self.mv_reclaimed += other.mv_reclaimed;
        self.arena_bytes = self.arena_bytes.max(other.arena_bytes);
        self.time_ns = self.time_ns.max(other.time_ns);
        self.txn_lat.merge(&other.txn_lat);
        self.block_lat.merge(&other.block_lat);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sums_counters_and_maxes_time() {
        let mut a = TxStats::new();
        a.hw_commits = 10;
        a.time_ns = 100;
        a.note_hw_abort(AbortCause::Capacity);
        let mut b = TxStats::new();
        b.hw_commits = 5;
        b.sw_commits = 3;
        b.time_ns = 250;
        b.note_hw_abort(AbortCause::Capacity);
        b.note_hw_abort(AbortCause::Conflict);
        a.merge(&b);
        assert_eq!(a.hw_commits, 15);
        assert_eq!(a.sw_commits, 3);
        assert_eq!(a.aborts_of(AbortCause::Capacity), 2);
        assert_eq!(a.aborts_of(AbortCause::Conflict), 1);
        assert_eq!(a.time_ns, 250, "parallel time = max, not sum");
        assert_eq!(a.total_commits(), 18);
    }

    #[test]
    fn merge_folds_per_worker_histograms() {
        // Two workers with disjoint latency profiles: the merged
        // histogram keeps every sample and its percentiles stay
        // monotone — the cross-worker aggregation StatsTable::total
        // relies on.
        let mut a = TxStats::new();
        for _ in 0..99 {
            a.txn_lat.record(200); // bucket 8, upper 255
        }
        a.block_lat.record(1_000_000);
        let mut b = TxStats::new();
        b.txn_lat.record(50_000); // bucket 16, upper 65535
        b.block_lat.record(2_000_000);
        a.merge(&b);
        assert_eq!(a.txn_lat.count(), 100, "merge preserves total count");
        assert_eq!(a.block_lat.count(), 2);
        assert_eq!(a.txn_lat.p50(), 255);
        assert_eq!(a.txn_lat.p99(), 255);
        assert_eq!(a.txn_lat.percentile(1.0), 65535);
        assert!(a.txn_lat.p50() <= a.txn_lat.p90());
        assert!(a.txn_lat.p90() <= a.txn_lat.p99());
    }
}
