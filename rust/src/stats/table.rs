//! Aggregation and rendering of per-thread stats into the paper's
//! reporting shapes (per-thread series for Fig 4, totals for the text).

use super::TxStats;
use crate::tm::AbortCause;

/// One thread's stats, labeled.
#[derive(Clone, Debug)]
pub struct ThreadStats {
    pub thread: usize,
    pub stats: TxStats,
}

/// A collection of per-thread stats for one (policy, workload) run.
#[derive(Clone, Debug, Default)]
pub struct StatsTable {
    pub rows: Vec<ThreadStats>,
}

impl StatsTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, thread: usize, stats: TxStats) {
        self.rows.push(ThreadStats { thread, stats });
    }

    /// Fold all threads into one TxStats (commit counts summed,
    /// time = max across threads).
    pub fn total(&self) -> TxStats {
        let mut t = TxStats::new();
        for r in &self.rows {
            t.merge(&r.stats);
        }
        t
    }

    /// Fig 4(a): mean HTM transactions (attempts) per thread.
    pub fn hw_attempts_per_thread(&self) -> f64 {
        self.mean(|s| s.hw_attempts)
    }

    /// Fig 4(b): mean HTM retries per thread.
    pub fn hw_retries_per_thread(&self) -> f64 {
        self.mean(|s| s.hw_retries)
    }

    /// Fig 4(c): mean STM transactions per thread.
    pub fn sw_commits_per_thread(&self) -> f64 {
        self.mean(|s| s.sw_commits)
    }

    fn mean(&self, f: impl Fn(&TxStats) -> u64) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        self.rows.iter().map(|r| f(&r.stats)).sum::<u64>() as f64 / self.rows.len() as f64
    }

    /// Markdown rendering for reports and EXPERIMENTS.md.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "| thread | hw_attempts | hw_commits | hw_retries | conflict | capacity | explicit | sw_commits | sw_aborts | lock |\n",
        );
        out.push_str("|---|---|---|---|---|---|---|---|---|---|\n");
        for r in &self.rows {
            let s = &r.stats;
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |\n",
                r.thread,
                s.hw_attempts,
                s.hw_commits,
                s.hw_retries,
                s.aborts_of(AbortCause::Conflict),
                s.aborts_of(AbortCause::Capacity),
                s.aborts_of(AbortCause::Explicit),
                s.sw_commits,
                s.sw_aborts,
                s.lock_commits,
            ));
        }
        let t = self.total();
        out.push_str(&format!(
            "| **total** | {} | {} | {} | {} | {} | {} | {} | {} | {} |\n",
            t.hw_attempts,
            t.hw_commits,
            t.hw_retries,
            t.aborts_of(AbortCause::Conflict),
            t.aborts_of(AbortCause::Capacity),
            t.aborts_of(AbortCause::Explicit),
            t.sw_commits,
            t.sw_aborts,
            t.lock_commits,
        ));
        out
    }

    /// CSV rendering (one row per thread) for plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "thread,hw_attempts,hw_commits,hw_retries,conflict,capacity,explicit,interrupt,sw_commits,sw_aborts,lock_commits,time_ns\n",
        );
        for r in &self.rows {
            let s = &r.stats;
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{}\n",
                r.thread,
                s.hw_attempts,
                s.hw_commits,
                s.hw_retries,
                s.aborts_of(AbortCause::Conflict),
                s.aborts_of(AbortCause::Capacity),
                s.aborts_of(AbortCause::Explicit),
                s.aborts_of(AbortCause::Interrupt),
                s.sw_commits,
                s.sw_aborts,
                s.lock_commits,
                s.time_ns,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StatsTable {
        let mut t = StatsTable::new();
        for i in 0..4 {
            let mut s = TxStats::new();
            s.hw_attempts = 100 * (i as u64 + 1);
            s.hw_commits = 90;
            s.hw_retries = 10 * (i as u64);
            s.sw_commits = i as u64;
            s.time_ns = 1000 + i as u64;
            t.push(i, s);
        }
        t
    }

    #[test]
    fn per_thread_means() {
        let t = sample();
        assert!((t.hw_attempts_per_thread() - 250.0).abs() < 1e-9);
        assert!((t.hw_retries_per_thread() - 15.0).abs() < 1e-9);
        assert!((t.sw_commits_per_thread() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn total_time_is_max() {
        let t = sample();
        assert_eq!(t.total().time_ns, 1003);
        assert_eq!(t.total().hw_commits, 360);
    }

    #[test]
    fn renders_markdown_and_csv() {
        let t = sample();
        let md = t.to_markdown();
        assert!(md.contains("| 0 | 100 | 90 |"));
        assert!(md.contains("**total**"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.starts_with("thread,"));
    }
}
