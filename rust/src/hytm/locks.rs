//! Non-speculative locks: the coarse-grain baseline and the fallback
//! locks of the HTM+lock schemes (paper §3.7).
//!
//! The paper distinguishes two HTM fallback flavours:
//! * **atomic lock** — the waiter retries the atomic acquisition itself
//!   in a loop (test-and-set: every probe is an atomic RMW);
//! * **spinlock** — the waiter spins on a plain load until the lock
//!   looks free, then attempts the atomic acquisition (test-and-test-
//!   and-set), which is cheaper under contention on real cache-coherent
//!   hardware.
//!
//! Both are the same `RawLock` word: bit 0 = held, bits 63..1 = a
//! monotone acquisition count so hardware transactions can subscribe to
//! the word ([`crate::tm::Subscription`]) and detect even a complete
//! acquire/release episode inside their window.

use std::hint;
use std::sync::atomic::Ordering;

use crate::mem::layout::PaddedAtomicU64;
use crate::tm::Subscription;

/// Acquisition flavour (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LockFlavor {
    /// Test-and-set loop: atomic RMW per probe.
    Atomic,
    /// Test-and-test-and-set: spin on loads, RMW only when free.
    Spin,
}

/// Word layout: bit 0 = held; bits 63..1 = acquisition counter.
pub struct RawLock(PaddedAtomicU64);

impl RawLock {
    pub fn new() -> Self {
        Self(PaddedAtomicU64::new(0))
    }

    /// Try to acquire once. Returns true on success.
    #[inline]
    pub fn try_acquire(&self) -> bool {
        let cur = self.0.load(Ordering::Relaxed);
        if cur & 1 == 1 {
            return false;
        }
        // Acquire: set held bit, bump the episode counter.
        self.0
            .compare_exchange(cur, cur + 3, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
    }

    /// Blocking acquire with the given flavour.
    pub fn acquire(&self, flavor: LockFlavor) {
        match flavor {
            LockFlavor::Atomic => loop {
                // Test-and-set: probe with an RMW every time.
                let cur = self.0.fetch_or(1, Ordering::AcqRel);
                if cur & 1 == 0 {
                    // We took it; account the episode.
                    self.0.fetch_add(2, Ordering::AcqRel);
                    return;
                }
                hint::spin_loop();
            },
            LockFlavor::Spin => loop {
                // Spin on plain loads until it looks free.
                while self.0.load(Ordering::Relaxed) & 1 == 1 {
                    hint::spin_loop();
                }
                if self.try_acquire() {
                    return;
                }
            },
        }
    }

    #[inline]
    pub fn release(&self) {
        self.0.fetch_and(!1, Ordering::Release);
    }
}

impl Default for RawLock {
    fn default() -> Self {
        Self::new()
    }
}

impl Subscription for RawLock {
    #[inline]
    fn sample(&self) -> u64 {
        self.0.load(Ordering::Acquire)
    }

    #[inline]
    fn unchanged_since(&self, sample: u64) -> bool {
        self.0.load(Ordering::Acquire) == sample
    }

    #[inline]
    fn is_held(&self) -> bool {
        self.0.load(Ordering::Acquire) & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn acquire_release_cycle() {
        let l = RawLock::new();
        assert!(!l.is_held());
        l.acquire(LockFlavor::Spin);
        assert!(l.is_held());
        assert!(!l.try_acquire());
        l.release();
        assert!(!l.is_held());
    }

    #[test]
    fn episode_counter_detects_complete_cycles() {
        let l = RawLock::new();
        let s = l.sample();
        l.acquire(LockFlavor::Atomic);
        l.release();
        assert!(!l.is_held());
        assert!(!l.unchanged_since(s), "acquire/release must move the word");
    }

    #[test]
    fn mutual_exclusion_under_contention() {
        for flavor in [LockFlavor::Atomic, LockFlavor::Spin] {
            let l = Arc::new(RawLock::new());
            let counter = Arc::new(std::sync::atomic::AtomicU64::new(0));
            let mut hs = Vec::new();
            for _ in 0..4 {
                let l = Arc::clone(&l);
                let c = Arc::clone(&counter);
                hs.push(std::thread::spawn(move || {
                    for _ in 0..5000 {
                        l.acquire(flavor);
                        // Non-atomic RMW through the atomic: safe only
                        // under mutual exclusion.
                        let v = c.load(Ordering::Relaxed);
                        c.store(v + 1, Ordering::Relaxed);
                        l.release();
                    }
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(counter.load(Ordering::Relaxed), 20_000, "{flavor:?}");
        }
    }
}
