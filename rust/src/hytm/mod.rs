//! The synchronization-policy layer (DESIGN.md S5–S7): everything the
//! paper benchmarks, behind one executor interface.
//!
//! * [`gbllock`] — the counting global lock coupling HTM and STM (§3.6)
//! * [`locks`]   — coarse-grain / atomic / spin locks (§3.7 baselines)
//! * [`policies`]— the Figure-1 retry state machines (RND/Fx/StAd/DyAd)
//! * [`system`]  — [`TmSystem`] + [`ThreadExecutor`]: drives a
//!   transaction body through whichever policy a run is configured for

pub mod gbllock;
pub mod locks;
pub mod phtm;
pub mod policies;
pub mod system;

pub use gbllock::GblLock;
pub use phtm::{Phase, PhaseWord};
pub use locks::{LockFlavor, RawLock};
pub use policies::{Decision, DyAdPolicy, FxPolicy, RetryPolicy, RndPolicy, StAdPolicy};
pub use system::{PolicySpec, ThreadExecutor, TmSystem};
