//! PhTM — *Phased* Transactional Memory (Lev, Moir, Nussbaum,
//! TRANSACT'07): the second HyTM class in the paper's taxonomy (§2.1,
//! "HTM and STM in phases").
//!
//! Instead of coupling concurrent HTM and STM transactions through a
//! lock (the paper's DyAdHyTM design), PhTM keeps the *whole system* in
//! one mode at a time:
//!
//! * **HW phase** — every transaction runs on the best-effort HTM; a
//!   transaction that cannot make progress (capacity, or quota
//!   exhausted) flips the global mode to SW.
//! * **SW phase** — every transaction runs on the STM, no
//!   instrumentation interplay needed; after `sw_quantum` software
//!   commits the system flips back to HW and tries again.
//!
//! The mode word carries a monotone epoch so hardware transactions
//! subscribe to it exactly like a fallback lock: any phase change inside
//! a hardware window is a conflict.
//!
//! Implemented as an ablation baseline (DESIGN.md A5): the paper argues
//! adaptive *per-transaction* fallback beats phase-global switching on
//! graph workloads, because one capacity-doomed transaction need not
//! drag every thread into the slow phase.

use std::sync::atomic::Ordering;

use crate::mem::layout::PaddedAtomicU64;
use crate::tm::Subscription;

/// Global phase word: bit 0 = mode (0 = HW, 1 = SW); bits 63..1 = epoch
/// (increments on every switch). `sw_left` counts the SW-phase budget;
/// `sw_inflight` counts STM transactions currently executing — the flip
/// back to HW waits for them to drain (an STM write-back must never
/// overlap a hardware phase).
pub struct PhaseWord {
    word: PaddedAtomicU64,
    sw_left: PaddedAtomicU64,
    sw_inflight: PaddedAtomicU64,
}

/// Which phase the system is in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Hw,
    Sw,
}

impl PhaseWord {
    pub fn new() -> Self {
        Self {
            word: PaddedAtomicU64::new(0),
            sw_left: PaddedAtomicU64::new(0),
            sw_inflight: PaddedAtomicU64::new(0),
        }
    }

    #[inline]
    pub fn phase(&self) -> Phase {
        if self.word.load(Ordering::Acquire) & 1 == 0 {
            Phase::Hw
        } else {
            Phase::Sw
        }
    }

    /// Flip HW -> SW (idempotent if already SW): grants `sw_quantum`
    /// software commits before the system tries hardware again.
    pub fn enter_sw(&self, sw_quantum: u64) {
        let cur = self.word.load(Ordering::Acquire);
        if cur & 1 == 1 {
            return; // already SW
        }
        if self
            .word
            .compare_exchange(cur, cur + 3, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
        {
            self.sw_left.store(sw_quantum, Ordering::Release);
        }
    }

    /// An STM transaction is about to start (SW phase).
    pub fn begin_sw_txn(&self) {
        self.sw_inflight.fetch_add(1, Ordering::AcqRel);
    }

    /// Account one SW commit and leave the STM path. The thread that
    /// both exhausts the quantum and drains the in-flight count flips
    /// back to HW.
    pub fn note_sw_commit(&self) {
        // Saturating decrement of the quantum.
        let mut left = self.sw_left.load(Ordering::Acquire);
        while left > 0 {
            match self.sw_left.compare_exchange_weak(
                left,
                left - 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(cur) => left = cur,
            }
        }
        let inflight = self.sw_inflight.fetch_sub(1, Ordering::AcqRel) - 1;
        if inflight == 0 && self.sw_left.load(Ordering::Acquire) == 0 {
            let cur = self.word.load(Ordering::Acquire);
            if cur & 1 == 1 {
                let _ = self.word.compare_exchange(
                    cur,
                    cur + 1,
                    Ordering::AcqRel,
                    Ordering::Relaxed,
                );
            }
        }
    }

    /// Epoch+mode snapshot (diagnostics).
    pub fn raw(&self) -> u64 {
        self.word.load(Ordering::Acquire)
    }
}

impl Default for PhaseWord {
    fn default() -> Self {
        Self::new()
    }
}

impl Subscription for PhaseWord {
    #[inline]
    fn sample(&self) -> u64 {
        self.word.load(Ordering::Acquire)
    }

    #[inline]
    fn unchanged_since(&self, sample: u64) -> bool {
        self.word.load(Ordering::Acquire) == sample
    }

    /// "Held" = the system is in the SW phase: hardware must not begin.
    #[inline]
    fn is_held(&self) -> bool {
        self.word.load(Ordering::Acquire) & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn starts_in_hw_phase() {
        let p = PhaseWord::new();
        assert_eq!(p.phase(), Phase::Hw);
        assert!(!p.is_held());
    }

    #[test]
    fn enter_sw_flips_and_is_idempotent() {
        let p = PhaseWord::new();
        p.enter_sw(3);
        assert_eq!(p.phase(), Phase::Sw);
        let raw = p.raw();
        p.enter_sw(3); // no double-flip
        assert_eq!(p.raw(), raw);
    }

    #[test]
    fn sw_quantum_counts_back_to_hw() {
        let p = PhaseWord::new();
        p.enter_sw(3);
        for _ in 0..2 {
            p.begin_sw_txn();
            p.note_sw_commit();
        }
        assert_eq!(p.phase(), Phase::Sw);
        p.begin_sw_txn();
        p.note_sw_commit();
        assert_eq!(p.phase(), Phase::Hw);
    }

    #[test]
    fn flip_back_waits_for_inflight_drain() {
        let p = PhaseWord::new();
        p.enter_sw(1);
        p.begin_sw_txn(); // A
        p.begin_sw_txn(); // B
        p.note_sw_commit(); // A commits, quantum 0 but B in flight
        assert_eq!(p.phase(), Phase::Sw, "B still running");
        p.note_sw_commit(); // B commits
        assert_eq!(p.phase(), Phase::Hw);
    }

    #[test]
    fn epoch_is_monotone_across_phases() {
        let p = PhaseWord::new();
        let s0 = p.sample();
        p.enter_sw(1);
        p.begin_sw_txn();
        p.note_sw_commit();
        assert_eq!(p.phase(), Phase::Hw);
        assert!(
            !p.unchanged_since(s0),
            "a full SW episode must invalidate HW subscriptions"
        );
    }

    #[test]
    fn concurrent_switching_settles() {
        let p = Arc::new(PhaseWord::new());
        let mut hs = Vec::new();
        for _ in 0..4 {
            let p = Arc::clone(&p);
            hs.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    p.enter_sw(2);
                    p.begin_sw_txn();
                    p.note_sw_commit();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        // No assertion on final phase (racy by design); the word must
        // still be structurally sane: epoch far advanced, no stuck
        // in-flight count.
        assert!(p.raw() >> 1 > 100);
        assert_eq!(p.sw_inflight.load(Ordering::Acquire), 0);
    }
}
