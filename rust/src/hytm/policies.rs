//! The paper's contribution: the retry/fallback policies of Figure 1.
//!
//! Each policy is a pure state machine consuming RTM-style abort causes
//! and emitting retry/fallback decisions. Both the live executor
//! ([`super::system::TmSystem`]) and the discrete-event simulator
//! (`crate::sim`) drive these same machines, so the paper's contribution
//! is implemented once and measured in both worlds.

use crate::tm::AbortCause;
use crate::util::rng::Rng;

/// What to do after a hardware abort.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Retry the transaction in hardware.
    RetryHw,
    /// Take the global lock and execute in software.
    FallbackSw,
}

/// A Figure-1 retry policy. `begin_txn` is called once per logical
/// transaction (not per attempt); `on_abort` after every failed hardware
/// attempt.
pub trait RetryPolicy: Send {
    fn begin_txn(&mut self, rng: &mut Rng);
    fn on_abort(&mut self, cause: AbortCause, rng: &mut Rng) -> Decision;
    fn name(&self) -> &'static str;

    /// Per-transaction bookkeeping cost in "policy overhead units" —
    /// consumed by the simulator's cost model: RNG draws are expensive
    /// (the paper calls RNDHyTM's RNG overhead "quite significant"),
    /// flag checks are nearly free.
    fn begin_cost_rng_draws(&self) -> u32 {
        0
    }
}

/// RNDHyTM (§3.3): a fresh *random* retry quota per transaction.
/// The paper's experiments draw from 1–50.
#[derive(Clone, Debug)]
pub struct RndPolicy {
    pub lo: u32,
    pub hi: u32,
    tries: i64,
}

impl RndPolicy {
    pub fn new(lo: u32, hi: u32) -> Self {
        assert!(lo >= 1 && lo <= hi);
        Self { lo, hi, tries: 0 }
    }
}

impl RetryPolicy for RndPolicy {
    fn begin_txn(&mut self, rng: &mut Rng) {
        // The RNG draw itself is the overhead the paper charges RND with.
        self.tries = rng.range(self.lo as u64, self.hi as u64) as i64;
    }

    fn on_abort(&mut self, _cause: AbortCause, _rng: &mut Rng) -> Decision {
        if self.tries > 0 {
            self.tries -= 1;
            Decision::RetryHw
        } else {
            Decision::FallbackSw
        }
    }

    fn name(&self) -> &'static str {
        "RNDHyTM"
    }

    fn begin_cost_rng_draws(&self) -> u32 {
        1
    }
}

/// FxHyTM (§3.4): a fixed, *untuned* retry quota ("a fixed random
/// number such as 43, 23 or 76 without any design space exploration").
#[derive(Clone, Debug)]
pub struct FxPolicy {
    pub n: u32,
    tries: i64,
}

impl FxPolicy {
    /// The paper's example untuned constant.
    pub const DEFAULT_N: u32 = 43;

    pub fn new(n: u32) -> Self {
        Self { n, tries: 0 }
    }
}

impl RetryPolicy for FxPolicy {
    fn begin_txn(&mut self, _rng: &mut Rng) {
        self.tries = self.n as i64;
    }

    fn on_abort(&mut self, _cause: AbortCause, _rng: &mut Rng) -> Decision {
        if self.tries > 0 {
            self.tries -= 1;
            Decision::RetryHw
        } else {
            Decision::FallbackSw
        }
    }

    fn name(&self) -> &'static str {
        "FxHyTM"
    }
}

/// StAdHyTM (§3.5): same machine as FxHyTM, but `n` comes from an
/// *offline* design-space exploration (our `policy_explorer` example /
/// `dyadhytm tune`). The paper charges this policy with the unreported
/// profiling cost of that DSE.
#[derive(Clone, Debug)]
pub struct StAdPolicy {
    pub tuned_n: u32,
    tries: i64,
}

impl StAdPolicy {
    /// Default produced by our DSE at scale 16 / 28 threads
    /// (EXPERIMENTS.md §Tuning).
    pub const DEFAULT_TUNED_N: u32 = 6;

    pub fn new(tuned_n: u32) -> Self {
        Self { tuned_n, tries: 0 }
    }
}

impl RetryPolicy for StAdPolicy {
    fn begin_txn(&mut self, _rng: &mut Rng) {
        self.tries = self.tuned_n as i64;
    }

    fn on_abort(&mut self, _cause: AbortCause, _rng: &mut Rng) -> Decision {
        if self.tries > 0 {
            self.tries -= 1;
            Decision::RetryHw
        } else {
            Decision::FallbackSw
        }
    }

    fn name(&self) -> &'static str {
        "StAdHyTM"
    }
}

/// DyAdHyTM (§3.6, Figure 1b): the dynamically adaptive policy.
///
/// Starts with a fixed quota like FxHyTM, but consumes the abort-cause
/// flags at runtime: a CAPACITY abort zeroes the quota (hardware can
/// never fit this transaction), grants one last hardware attempt (the
/// pseudocode's `tries = 0; retry in HW`), and then falls back. The
/// only overhead over FxHyTM is reading the abort status — no RNG, no
/// offline profiling.
#[derive(Clone, Debug)]
pub struct DyAdPolicy {
    pub n: u32,
    tries: i64,
    /// Set when a capacity abort zeroed the quota: the next abort (of
    /// any cause) goes straight to software.
    exhausted_by_capacity: bool,
}

impl DyAdPolicy {
    /// NUM_RETRIES is "set to a fixed random" like FxHyTM — the paper's
    /// point is that the capacity short-circuit makes its exact value
    /// barely matter. We use the same untuned constant as FxHyTM.
    pub const DEFAULT_N: u32 = FxPolicy::DEFAULT_N;

    pub fn new(n: u32) -> Self {
        Self {
            n,
            tries: 0,
            exhausted_by_capacity: false,
        }
    }
}

impl RetryPolicy for DyAdPolicy {
    fn begin_txn(&mut self, _rng: &mut Rng) {
        self.tries = self.n as i64;
        self.exhausted_by_capacity = false;
    }

    fn on_abort(&mut self, cause: AbortCause, _rng: &mut Rng) -> Decision {
        if self.exhausted_by_capacity {
            // The one post-capacity hardware attempt failed too.
            return Decision::FallbackSw;
        }
        match cause {
            AbortCause::Capacity => {
                // Figure 1b: `if (capacity limit reached) tries = 0` —
                // one last hardware try, then software.
                self.tries = 0;
                self.exhausted_by_capacity = true;
                Decision::RetryHw
            }
            _ => {
                if self.tries > 0 {
                    self.tries -= 1;
                    Decision::RetryHw
                } else {
                    Decision::FallbackSw
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "DyAdHyTM"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(p: &mut dyn RetryPolicy, cause: AbortCause) -> u32 {
        // Count RetryHw decisions until fallback.
        let mut rng = Rng::new(1);
        let mut retries = 0;
        loop {
            match p.on_abort(cause, &mut rng) {
                Decision::RetryHw => retries += 1,
                Decision::FallbackSw => return retries,
            }
        }
    }

    #[test]
    fn fx_retries_exactly_n() {
        let mut p = FxPolicy::new(5);
        let mut rng = Rng::new(0);
        p.begin_txn(&mut rng);
        assert_eq!(drain(&mut p, AbortCause::Conflict), 5);
    }

    #[test]
    fn fx_quota_resets_each_txn() {
        let mut p = FxPolicy::new(3);
        let mut rng = Rng::new(0);
        p.begin_txn(&mut rng);
        assert_eq!(drain(&mut p, AbortCause::Conflict), 3);
        p.begin_txn(&mut rng);
        assert_eq!(drain(&mut p, AbortCause::Conflict), 3);
    }

    #[test]
    fn rnd_draws_within_range_and_varies() {
        let mut p = RndPolicy::new(1, 50);
        let mut rng = Rng::new(7);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..40 {
            p.begin_txn(&mut rng);
            let r = drain(&mut p, AbortCause::Conflict);
            assert!((1..=50).contains(&r), "quota {r} outside 1-50");
            seen.insert(r);
        }
        assert!(seen.len() > 5, "quotas should vary: {seen:?}");
        assert_eq!(p.begin_cost_rng_draws(), 1, "RND pays an RNG draw");
    }

    #[test]
    fn stad_is_fx_with_tuned_constant() {
        let mut p = StAdPolicy::new(StAdPolicy::DEFAULT_TUNED_N);
        let mut rng = Rng::new(0);
        p.begin_txn(&mut rng);
        assert_eq!(
            drain(&mut p, AbortCause::Conflict),
            StAdPolicy::DEFAULT_TUNED_N
        );
    }

    #[test]
    fn dyad_conflicts_behave_like_fx() {
        let mut p = DyAdPolicy::new(4);
        let mut rng = Rng::new(0);
        p.begin_txn(&mut rng);
        assert_eq!(drain(&mut p, AbortCause::Conflict), 4);
    }

    #[test]
    fn dyad_capacity_short_circuits_to_one_last_try() {
        let mut p = DyAdPolicy::new(40);
        let mut rng = Rng::new(0);
        p.begin_txn(&mut rng);
        // Capacity: one more hardware attempt granted...
        assert_eq!(p.on_abort(AbortCause::Capacity, &mut rng), Decision::RetryHw);
        // ...and any further abort goes to software immediately.
        assert_eq!(
            p.on_abort(AbortCause::Conflict, &mut rng),
            Decision::FallbackSw
        );
    }

    #[test]
    fn dyad_capacity_after_conflicts_still_short_circuits() {
        let mut p = DyAdPolicy::new(40);
        let mut rng = Rng::new(0);
        p.begin_txn(&mut rng);
        for _ in 0..10 {
            assert_eq!(
                p.on_abort(AbortCause::Conflict, &mut rng),
                Decision::RetryHw
            );
        }
        assert_eq!(p.on_abort(AbortCause::Capacity, &mut rng), Decision::RetryHw);
        assert_eq!(
            p.on_abort(AbortCause::Capacity, &mut rng),
            Decision::FallbackSw
        );
    }

    #[test]
    fn dyad_resets_capacity_state_per_txn() {
        let mut p = DyAdPolicy::new(2);
        let mut rng = Rng::new(0);
        p.begin_txn(&mut rng);
        p.on_abort(AbortCause::Capacity, &mut rng);
        assert_eq!(
            p.on_abort(AbortCause::Conflict, &mut rng),
            Decision::FallbackSw
        );
        // New transaction: full quota again.
        p.begin_txn(&mut rng);
        assert_eq!(drain(&mut p, AbortCause::Conflict), 2);
    }

    #[test]
    fn dyad_saves_retries_vs_fx_under_capacity() {
        // The paper's Fig 4(b) mechanism: under capacity aborts DyAd
        // burns ~1 retry where Fx burns its whole quota.
        let mut rng = Rng::new(0);
        let mut fx = FxPolicy::new(43);
        fx.begin_txn(&mut rng);
        let fx_retries = drain(&mut fx, AbortCause::Capacity);
        let mut dy = DyAdPolicy::new(43);
        dy.begin_txn(&mut rng);
        let dy_retries = drain(&mut dy, AbortCause::Capacity);
        assert_eq!(fx_retries, 43);
        assert_eq!(dy_retries, 1);
    }
}
