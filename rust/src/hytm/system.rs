//! [`TmSystem`]: one shared synchronization fabric per address space,
//! and [`ThreadExecutor`]: the per-thread driver that runs transaction
//! bodies under a configured [`PolicySpec`].

use std::sync::Arc;

use crate::htm::{HtmConfig, HtmEngine, HtmScratch};
use crate::mem::TxHeap;
use crate::stats::TxStats;
use crate::stm::{NorecEngine, Tl2Engine};
use crate::tm::access::{DirectAccess, TxAccess, TxResult};
use crate::tm::{AbortCause, Subscription};
use crate::util::rng::Rng;

use super::gbllock::GblLock;
use super::locks::{LockFlavor, RawLock};
use super::policies::{
    Decision, DyAdPolicy, FxPolicy, RetryPolicy, RndPolicy, StAdPolicy,
};

/// Which synchronization policy a run uses (CLI: `--policy <name>`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicySpec {
    /// Coarse-grain lock (the OpenMP-style baseline).
    CoarseLock,
    /// Pure NOrec STM ("low overhead STM", GCC-TM-like).
    StmNorec,
    /// Pure TL2 STM (ablation A2's "more complex STM").
    StmTl2,
    /// Best-effort HTM, fixed retries, atomic-lock (TAS) fallback.
    HtmALock { retries: u32 },
    /// Best-effort HTM, fixed retries, spinlock (TTAS) fallback.
    HtmSpin { retries: u32 },
    /// Hardware Lock Elision: one speculative attempt, then the lock.
    Hle,
    /// RNDHyTM: random retry quota per transaction (paper draws 1-50).
    Rnd { lo: u32, hi: u32 },
    /// FxHyTM: fixed untuned quota.
    Fx { n: u32 },
    /// StAdHyTM: offline-tuned quota.
    StAd { n: u32 },
    /// DyAdHyTM: fixed quota + capacity-flag short-circuit.
    DyAd { n: u32 },
    /// Ablation A2: DyAdHyTM falling back to TL2 instead of NOrec.
    DyAdTl2 { n: u32 },
    /// PhTM (Lev et al.): phase-global HW/SW switching — the paper's
    /// taxonomy class 2, as an ablation baseline (A5).
    PhTm { retries: u32, sw_quantum: u32 },
    /// Block-STM-style speculative batch execution (`crate::batch`):
    /// transactions are admitted in blocks of `block` with a fixed
    /// serialization order and run against multi-version memory. Every
    /// shipped path (generation, computation, subgraph extraction, the
    /// streaming pipeline) dispatches this spec to `batch::BatchSystem`.
    /// A single transaction fed through `ThreadExecutor` degenerates to
    /// one optimistic NOrec attempt — loudly warned and accounted as
    /// `norec_fallback`, and reported as `batch(fallback:norec)`.
    Batch { block: usize },
    /// The batch backend with runtime-adaptive block sizing
    /// (`--policy batch=adaptive`): a
    /// [`crate::batch::adaptive::BlockSizeController`] resizes every
    /// admitted block from the observed re-incarnation rate (AIMD —
    /// the DyAdHyTM adapt-at-runtime loop applied to the batch knob).
    /// `latency_ms > 0` (`--policy batch=adaptive:latency=MS`) adds a
    /// block deadline: a block whose wall time overruns it halves even
    /// at a clean conflict rate — the streaming pipeline's
    /// blocks-sized-by-deadline mode. `window > 0`
    /// (`--policy batch=adaptive:window=W`) sets the cross-block
    /// pipelining window ceiling: up to W blocks in flight at once,
    /// co-tuned downward with block size under conflict pressure
    /// (0 = the default 2-deep head+overlap window). Routed exactly
    /// like [`PolicySpec::Batch`]; `label` reports the converged block
    /// size (and the deadline/window, when set).
    BatchAdaptive {
        /// Block wall-time deadline in milliseconds; 0 = none.
        latency_ms: u32,
        /// Pipelining window ceiling in blocks; 0 = default (2).
        window: u32,
    },
    /// `--policy auto[=hysteresis=N]`: the runtime meta-controller
    /// ([`crate::engine::auto::AutoController`]). The run starts on the
    /// adaptive batch backend and switches backends at kernel/phase
    /// boundaries from the observed snapshot counters — batch under
    /// capacity/high-conflict regimes, DyAdHyTM under sparse ones —
    /// after `hysteresis` consecutive votes plus a minimum dwell.
    /// Dispatch goes through [`crate::engine::Engine`]; a bare
    /// `ThreadExecutor` handed this spec degrades to the DyAd default,
    /// which is the controller's own sparse-regime choice.
    Auto {
        /// Consecutive intervals the same regime must win before a
        /// switch commits (≥ 1).
        hysteresis: u32,
    },
}

impl PolicySpec {
    /// The adaptive batch backend without a latency deadline — the
    /// `--policy batch=adaptive` default.
    pub const fn batch_adaptive() -> PolicySpec {
        PolicySpec::BatchAdaptive {
            latency_ms: 0,
            window: 0,
        }
    }

    /// The six Figure-2 policies with the paper's defaults.
    pub fn fig2_set() -> Vec<PolicySpec> {
        vec![
            PolicySpec::CoarseLock,
            PolicySpec::StmNorec,
            PolicySpec::Hle,
            PolicySpec::HtmALock { retries: 8 },
            PolicySpec::HtmSpin { retries: 8 },
            PolicySpec::DyAd {
                n: DyAdPolicy::DEFAULT_N,
            },
        ]
    }

    /// The four Figure-3/4 HyTM variants with the paper's defaults.
    pub fn fig3_set() -> Vec<PolicySpec> {
        vec![
            PolicySpec::Rnd { lo: 1, hi: 50 },
            PolicySpec::Fx {
                n: FxPolicy::DEFAULT_N,
            },
            PolicySpec::StAd {
                n: StAdPolicy::DEFAULT_TUNED_N,
            },
            PolicySpec::DyAd {
                n: DyAdPolicy::DEFAULT_N,
            },
        ]
    }

    /// The policy's *family* name. Parameters are not part of it —
    /// `Fx { n: 20 }` and `Fx { n: 43 }` are both `"fx-hytm"`, and
    /// `BatchAdaptive { latency_ms: 40, window: 4 }` is
    /// `"batch-adaptive"` — so
    /// `parse(name())` reconstructs the family with its *default*
    /// parameters. Use the original CLI spelling (or
    /// [`PolicySpec::label`]) when a round-trip must preserve them.
    pub fn name(&self) -> &'static str {
        match self {
            PolicySpec::CoarseLock => "lock",
            PolicySpec::StmNorec => "stm",
            PolicySpec::StmTl2 => "stm-tl2",
            PolicySpec::HtmALock { .. } => "htm-alock",
            PolicySpec::HtmSpin { .. } => "htm-spin",
            PolicySpec::Hle => "hle",
            PolicySpec::Rnd { .. } => "rnd-hytm",
            PolicySpec::Fx { .. } => "fx-hytm",
            PolicySpec::StAd { .. } => "stad-hytm",
            PolicySpec::DyAd { .. } => "dyad-hytm",
            PolicySpec::DyAdTl2 { .. } => "dyad-tl2",
            PolicySpec::PhTm { .. } => "phtm",
            PolicySpec::Batch { .. } => "batch",
            PolicySpec::BatchAdaptive { .. } => "batch-adaptive",
            PolicySpec::Auto { .. } => "auto",
        }
    }

    /// Parse a CLI name, optionally with `=N` / `=LO-HI` parameters,
    /// e.g. `fx=20`, `rnd=1-50`, `dyad`, `htm-spin=8`.
    pub fn parse(s: &str) -> Option<PolicySpec> {
        let (name, arg) = match s.split_once('=') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        let n_or = |d: u32| arg.and_then(|a| a.parse().ok()).unwrap_or(d);
        Some(match name {
            "lock" => PolicySpec::CoarseLock,
            "stm" => PolicySpec::StmNorec,
            "stm-tl2" => PolicySpec::StmTl2,
            "htm-alock" => PolicySpec::HtmALock { retries: n_or(8) },
            "htm-spin" => PolicySpec::HtmSpin { retries: n_or(8) },
            "hle" => PolicySpec::Hle,
            "rnd" | "rnd-hytm" => {
                let (lo, hi) = match arg.and_then(|a| a.split_once('-')) {
                    Some((l, h)) => (l.parse().ok()?, h.parse().ok()?),
                    None => (1, 50),
                };
                PolicySpec::Rnd { lo, hi }
            }
            "fx" | "fx-hytm" => PolicySpec::Fx {
                n: n_or(FxPolicy::DEFAULT_N),
            },
            "stad" | "stad-hytm" => PolicySpec::StAd {
                n: n_or(StAdPolicy::DEFAULT_TUNED_N),
            },
            "dyad" | "dyad-hytm" => PolicySpec::DyAd {
                n: n_or(DyAdPolicy::DEFAULT_N),
            },
            "dyad-tl2" => PolicySpec::DyAdTl2 {
                n: n_or(DyAdPolicy::DEFAULT_N),
            },
            "phtm" => PolicySpec::PhTm {
                retries: n_or(8),
                sw_quantum: 64,
            },
            "batch" => match arg {
                // `batch=adaptive[:latency=MS][:window=W]`: adaptive
                // sizing with optional colon-separated knobs — a block
                // wall-time deadline and/or a pipelining window
                // ceiling. Unknown keys and malformed values are
                // rejected, not silently defaulted.
                Some(a) if a == "adaptive" || a.starts_with("adaptive:") => {
                    let mut latency_ms = 0u32;
                    let mut window = 0u32;
                    if let Some(opts) =
                        a.strip_prefix("adaptive").and_then(|r| r.strip_prefix(':'))
                    {
                        for kv in opts.split(':') {
                            match kv.split_once('=') {
                                Some(("latency", v)) => latency_ms = v.parse().ok()?,
                                Some(("window", v)) => {
                                    window = v.parse().ok().filter(|&w| w > 0)?;
                                }
                                _ => return None,
                            }
                        }
                    }
                    PolicySpec::BatchAdaptive { latency_ms, window }
                }
                _ => PolicySpec::Batch {
                    block: arg
                        .and_then(|a| a.parse().ok())
                        .unwrap_or(crate::batch::DEFAULT_BLOCK),
                },
            },
            // `batch=adaptive` is the CLI spelling; the round-trip name
            // is accepted too.
            "batch-adaptive" => PolicySpec::batch_adaptive(),
            // `auto[=hysteresis=N]`: the split on the *first* `=` left
            // `hysteresis=N` intact in `arg`. Unknown keys and
            // malformed or zero values are rejected, not defaulted.
            "auto" => match arg {
                None => PolicySpec::Auto {
                    hysteresis: crate::engine::auto::DEFAULT_HYSTERESIS,
                },
                Some(a) => match a.split_once('=') {
                    Some(("hysteresis", v)) => PolicySpec::Auto {
                        hysteresis: v.parse().ok().filter(|&h| h > 0)?,
                    },
                    _ => return None,
                },
            },
            _ => return None,
        })
    }

    /// Reporting label for a finished run: stats produced under a
    /// batch spec that contain NOrec-fallback transactions are labeled
    /// `batch(fallback:norec)` so a degraded run can't masquerade as
    /// batch speculation; an adaptive run reports the block size its
    /// controller converged to (plus the latency deadline, when set);
    /// and batch runs surface the worker-runtime counters — cross-block
    /// overlap and deque steals — when they occurred. Every other
    /// (spec, stats) pair is just [`PolicySpec::name`].
    pub fn label(&self, stats: &TxStats) -> String {
        // Worker-runtime annotations shared by the batch labels.
        let runtime_parts = |parts: &mut Vec<String>| {
            if stats.overlapped_txns > 0 {
                parts.push(format!("overlap={}", stats.overlapped_txns));
            }
            if stats.steals > 0 {
                parts.push(format!("steals={}", stats.steals));
            }
        };
        match *self {
            PolicySpec::Batch { .. } | PolicySpec::BatchAdaptive { .. }
                if stats.norec_fallback > 0 =>
            {
                "batch(fallback:norec)".into()
            }
            PolicySpec::BatchAdaptive { latency_ms, window } if stats.final_block > 0 => {
                let mut parts = vec![format!("block={}", stats.final_block)];
                if latency_ms > 0 {
                    parts.push(format!("latency={latency_ms}ms"));
                }
                if window > 0 {
                    // The depth the controller converged to, out of the
                    // configured ceiling.
                    let converged = if stats.final_window > 0 {
                        stats.final_window
                    } else {
                        window as u64
                    };
                    parts.push(format!("window={converged}/{window}"));
                }
                runtime_parts(&mut parts);
                format!("batch(adaptive:{})", parts.join(","))
            }
            PolicySpec::Batch { .. } => {
                let mut parts = Vec::new();
                runtime_parts(&mut parts);
                if parts.is_empty() {
                    "batch".into()
                } else {
                    format!("batch({})", parts.join(","))
                }
            }
            // An auto run that actually switched reports how often; a
            // run the controller never moved is just "auto".
            PolicySpec::Auto { hysteresis } if stats.backend_switches > 0 => {
                format!(
                    "auto(hysteresis={hysteresis},switches={})",
                    stats.backend_switches
                )
            }
            _ => self.name().into(),
        }
    }

    /// The block-size controller a batch dispatch runs with, or `None`
    /// for the per-transaction policies. This is the single seam the
    /// kernels, the pipeline, and the simulator all go through, so
    /// `--policy batch=N` and `--policy batch=adaptive` are priced and
    /// executed by the same state machine everywhere.
    pub fn batch_sizing(&self) -> Option<crate::batch::adaptive::BlockSizeController> {
        use crate::batch::adaptive::BlockSizeController;
        match *self {
            PolicySpec::Batch { block } => Some(BlockSizeController::fixed(block)),
            PolicySpec::BatchAdaptive { latency_ms, window } => {
                let mut ctl = BlockSizeController::adaptive();
                if latency_ms > 0 {
                    ctl = ctl.with_latency_target(std::time::Duration::from_millis(
                        latency_ms as u64,
                    ));
                }
                if window > 0 {
                    ctl = ctl.with_window(window as usize);
                }
                Some(ctl)
            }
            _ => None,
        }
    }

    fn make_retry_policy(&self) -> Option<Box<dyn RetryPolicy>> {
        match *self {
            PolicySpec::Rnd { lo, hi } => Some(Box::new(RndPolicy::new(lo, hi))),
            PolicySpec::Fx { n } => Some(Box::new(FxPolicy::new(n))),
            PolicySpec::StAd { n } => Some(Box::new(StAdPolicy::new(n))),
            PolicySpec::DyAd { n } | PolicySpec::DyAdTl2 { n } => {
                Some(Box::new(DyAdPolicy::new(n)))
            }
            // A bare executor handed the meta-controller spec runs the
            // controller's sparse-regime choice: DyAd at the paper
            // default. (Engine-routed runs resolve Auto before an
            // executor is built.)
            PolicySpec::Auto { .. } => {
                Some(Box::new(DyAdPolicy::new(DyAdPolicy::DEFAULT_N)))
            }
            _ => None,
        }
    }
}

/// The shared synchronization fabric: heap + every engine and lock, so
/// any policy can run against the same memory.
pub struct TmSystem {
    pub heap: Arc<TxHeap>,
    pub htm: HtmEngine,
    pub norec: NorecEngine,
    pub tl2: Tl2Engine,
    pub gbllock: GblLock,
    /// Fallback lock of the HTM+lock schemes and HLE.
    pub fallback: RawLock,
    /// The coarse-grain baseline lock.
    pub coarse: RawLock,
    /// PhTM's global phase word.
    pub phase: super::phtm::PhaseWord,
}

impl TmSystem {
    pub fn new(heap: Arc<TxHeap>, htm_cfg: HtmConfig) -> Self {
        Self {
            htm: HtmEngine::new(Arc::clone(&heap), htm_cfg),
            norec: NorecEngine::new(Arc::clone(&heap)),
            tl2: Tl2Engine::new(Arc::clone(&heap)),
            gbllock: GblLock::new(),
            fallback: RawLock::new(),
            coarse: RawLock::new(),
            phase: super::phtm::PhaseWord::new(),
            heap,
        }
    }
}

/// Once-per-process warning for the NOrec fallback under
/// `PolicySpec::Batch`: a single transaction pushed through
/// [`ThreadExecutor::execute`] cannot be block-speculated, so it runs
/// as one optimistic NOrec attempt — correct, but it is *not* the batch
/// backend, and quiet degradation is exactly the bug class ISSUE 2
/// closes. (A `debug_assert!` here would outlaw the documented
/// batch-of-one degenerate case, so the contract is a loud log plus the
/// `norec_fallback` stats counter instead.)
fn warn_batch_fallback_once() {
    static WARNED: std::sync::Once = std::sync::Once::new();
    WARNED.call_once(|| {
        // Routed through the `[obs]` diag logger (level 1: on unless
        // `--obs-verbosity 0`) so the warning obeys the same verbosity
        // gate as every other diagnostic.
        crate::obs::diag(
            1,
            "warning: PolicySpec::Batch executed through ThreadExecutor — \
             running per-transaction NOrec, not BatchSystem; stats for this \
             run are labeled batch(fallback:norec). Route the workload \
             through crate::batch (generation/computation/subgraph/pipeline \
             all do this) to get block speculation.",
        );
    });
}

/// Per-thread executor: owns the thread's RNG, stats, and policy state.
pub struct ThreadExecutor<'s> {
    pub sys: &'s TmSystem,
    pub spec: PolicySpec,
    pub tid: u32,
    pub rng: Rng,
    pub stats: TxStats,
    policy: Option<Box<dyn RetryPolicy>>,
    /// Reusable speculation buffers: the hot path is allocation-free.
    scratch: HtmScratch,
}

impl<'s> ThreadExecutor<'s> {
    pub fn new(sys: &'s TmSystem, spec: PolicySpec, tid: u32, seed: u64) -> Self {
        Self {
            sys,
            spec,
            tid,
            rng: Rng::new(seed ^ (tid as u64).wrapping_mul(0xA24B_AED4_963E_E407)),
            stats: TxStats::new(),
            policy: spec.make_retry_policy(),
            scratch: HtmScratch::new(sys.htm.config()),
        }
    }

    /// Run one transaction body to completion under the configured
    /// policy. Never returns until the body has committed on some path.
    ///
    /// When the telemetry plane is live (`obs::timing_enabled`), the
    /// whole attempt→commit span — hardware retries, fallback, and all
    /// — lands in `TxStats::txn_lat`; otherwise the guard is one
    /// relaxed load and no clock is read.
    pub fn execute<R>(
        &mut self,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
    ) -> R {
        if crate::obs::timing_enabled() {
            let t0 = std::time::Instant::now();
            let r = self.execute_untimed(body);
            self.stats.txn_lat.record_duration(t0.elapsed());
            return r;
        }
        self.execute_untimed(body)
    }

    fn execute_untimed<R>(
        &mut self,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
    ) -> R {
        match self.spec {
            PolicySpec::CoarseLock => self.run_locked(body),
            PolicySpec::StmNorec => self.run_stm_norec(body),
            PolicySpec::StmTl2 => self.run_stm_tl2(body),
            PolicySpec::HtmALock { retries } => {
                self.run_htm_lock(retries, LockFlavor::Atomic, body)
            }
            PolicySpec::HtmSpin { retries } => {
                self.run_htm_lock(retries, LockFlavor::Spin, body)
            }
            PolicySpec::Hle => self.run_htm_lock(0, LockFlavor::Spin, body),
            PolicySpec::Rnd { .. }
            | PolicySpec::Fx { .. }
            | PolicySpec::StAd { .. }
            | PolicySpec::DyAd { .. }
            | PolicySpec::Auto { .. } => self.run_hybrid(body, false),
            PolicySpec::DyAdTl2 { .. } => self.run_hybrid(body, true),
            PolicySpec::PhTm {
                retries,
                sw_quantum,
            } => self.run_phtm(retries, sw_quantum as u64, body),
            // Unreachable from any shipped path: generation,
            // computation, subgraph, and the streaming pipeline all
            // dispatch `Batch` to `crate::batch::BatchSystem` before a
            // ThreadExecutor sees it. A caller landing here is silently
            // degrading block speculation to per-transaction NOrec —
            // make it loud and account it separately so the stats can't
            // masquerade as batch commits (`PolicySpec::label` reports
            // the run as `batch(fallback:norec)`).
            PolicySpec::Batch { .. } | PolicySpec::BatchAdaptive { .. } => {
                warn_batch_fallback_once();
                self.stats.norec_fallback += 1;
                self.run_stm_norec(body)
            }
        }
    }

    /// PhTM executor: phase-global switching (see [`super::phtm`]).
    fn run_phtm<R>(
        &mut self,
        retries: u32,
        sw_quantum: u64,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
    ) -> R {
        use super::phtm::Phase;
        let mut tries = retries as i64;
        loop {
            match self.sys.phase.phase() {
                Phase::Hw => {
                    self.stats.hw_attempts += 1;
                    match self.sys.htm.attempt_with(
                        &mut self.scratch,
                        self.tid,
                        &mut self.rng,
                        Some(&self.sys.phase as &dyn Subscription),
                        body,
                    ) {
                        Ok(r) => {
                            self.stats.hw_commits += 1;
                            return r;
                        }
                        Err(cause) => {
                            self.stats.note_hw_abort(cause);
                            if cause == AbortCause::Capacity || tries <= 0 {
                                // This transaction cannot make progress
                                // in hardware: drag the whole system
                                // into the SW phase.
                                self.sys.phase.enter_sw(sw_quantum);
                            } else {
                                tries -= 1;
                                self.stats.hw_retries += 1;
                            }
                        }
                    }
                }
                Phase::Sw => {
                    self.sys.phase.begin_sw_txn();
                    // Drain hardware write-backs racing the flip.
                    self.sys.htm.quiesce_commits();
                    let r = loop {
                        match self.sys.norec.attempt(body) {
                            Ok(r) => break r,
                            Err(_) => self.stats.sw_aborts += 1,
                        }
                    };
                    self.stats.sw_commits += 1;
                    self.sys.phase.note_sw_commit();
                    return r;
                }
            }
        }
    }

    /// Coarse lock: acquire, run directly, release.
    fn run_locked<R>(
        &mut self,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
    ) -> R {
        let sys = self.sys; // copy the &'s reference out of self
        let lock = &sys.coarse;
        lock.acquire(LockFlavor::Spin);
        let mut acc = DirectAccess { heap: &sys.heap };
        let r = body(&mut acc).expect("direct execution cannot abort");
        lock.release();
        self.stats.lock_commits += 1;
        r
    }

    fn run_stm_norec<R>(
        &mut self,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
    ) -> R {
        loop {
            match self.sys.norec.attempt(body) {
                Ok(r) => {
                    self.stats.sw_commits += 1;
                    return r;
                }
                Err(_) => self.stats.sw_aborts += 1,
            }
        }
    }

    fn run_stm_tl2<R>(
        &mut self,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
    ) -> R {
        loop {
            match self.sys.tl2.attempt(self.tid, body) {
                Ok(r) => {
                    self.stats.sw_commits += 1;
                    return r;
                }
                Err(_) => self.stats.sw_aborts += 1,
            }
        }
    }

    /// HTM with a non-speculative lock fallback (HTMALock / HTMSpin /
    /// HLE, which is the retries=0 case).
    fn run_htm_lock<R>(
        &mut self,
        retries: u32,
        flavor: LockFlavor,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
    ) -> R {
        let mut tries = retries as i64;
        loop {
            self.stats.hw_attempts += 1;
            match self.sys.htm.attempt_with(
                &mut self.scratch,
                self.tid,
                &mut self.rng,
                Some(&self.sys.fallback as &dyn Subscription),
                body,
            ) {
                Ok(r) => {
                    self.stats.hw_commits += 1;
                    return r;
                }
                Err(cause) => {
                    self.stats.note_hw_abort(cause);
                    if tries > 0 && cause != AbortCause::Capacity {
                        tries -= 1;
                        self.stats.hw_retries += 1;
                        continue;
                    }
                    break;
                }
            }
        }
        // Non-speculative path: take the lock, drain in-flight hardware
        // write-backs, then run directly. Concurrent speculators abort
        // through the subscription.
        self.sys.fallback.acquire(flavor);
        self.sys.htm.quiesce_commits();
        let mut acc = DirectAccess {
            heap: &self.sys.heap,
        };
        let r = body(&mut acc).expect("direct execution cannot abort");
        self.sys.fallback.release();
        self.stats.lock_commits += 1;
        r
    }

    /// The HyTM executor of Figure 1: hardware attempts under the retry
    /// policy, then the counting-gbllock STM path.
    fn run_hybrid<R>(
        &mut self,
        body: &mut dyn FnMut(&mut dyn TxAccess) -> TxResult<R>,
        tl2_fallback: bool,
    ) -> R {
        let mut policy = self.policy.take().expect("hybrid spec has a policy");
        policy.begin_txn(&mut self.rng);
        loop {
            self.stats.hw_attempts += 1;
            match self.sys.htm.attempt_with(
                &mut self.scratch,
                self.tid,
                &mut self.rng,
                Some(&self.sys.gbllock as &dyn Subscription),
                body,
            ) {
                Ok(r) => {
                    self.stats.hw_commits += 1;
                    self.policy = Some(policy);
                    return r;
                }
                Err(cause) => {
                    self.stats.note_hw_abort(cause);
                    match policy.on_abort(cause, &mut self.rng) {
                        Decision::RetryHw => {
                            self.stats.hw_retries += 1;
                            continue;
                        }
                        Decision::FallbackSw => break,
                    }
                }
            }
        }
        self.policy = Some(policy);

        // SW_BEGIN .. SW_COMMIT under the counting global lock. Entering
        // flips the subscribed word; draining the commit fence then
        // guarantees no hardware write-back overlaps the STM execution.
        self.sys.gbllock.enter_sw();
        self.sys.htm.quiesce_commits();
        let r = loop {
            let attempt = if tl2_fallback {
                self.sys.tl2.attempt(self.tid, body)
            } else {
                self.sys.norec.attempt(body)
            };
            match attempt {
                Ok(r) => break r,
                Err(_) => self.stats.sw_aborts += 1,
            }
        };
        self.sys.gbllock.exit_sw();
        self.stats.sw_commits += 1;
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_specs() -> Vec<PolicySpec> {
        vec![
            PolicySpec::CoarseLock,
            PolicySpec::StmNorec,
            PolicySpec::StmTl2,
            PolicySpec::HtmALock { retries: 4 },
            PolicySpec::HtmSpin { retries: 4 },
            PolicySpec::Hle,
            PolicySpec::Rnd { lo: 1, hi: 50 },
            PolicySpec::Fx { n: 43 },
            PolicySpec::StAd { n: 6 },
            PolicySpec::DyAd { n: 43 },
            PolicySpec::DyAdTl2 { n: 43 },
            PolicySpec::PhTm { retries: 4, sw_quantum: 16 },
            PolicySpec::Batch {
                block: crate::batch::DEFAULT_BLOCK,
            },
            PolicySpec::batch_adaptive(),
            // A bare executor degrades Auto to the DyAd default, so it
            // belongs in the exhaustive correctness sweeps too.
            PolicySpec::Auto {
                hysteresis: crate::engine::auto::DEFAULT_HYSTERESIS,
            },
        ]
    }

    #[test]
    fn parse_roundtrips_names() {
        for spec in all_specs() {
            let parsed = PolicySpec::parse(spec.name()).unwrap();
            assert_eq!(parsed.name(), spec.name());
        }
        assert_eq!(
            PolicySpec::parse("fx=20"),
            Some(PolicySpec::Fx { n: 20 })
        );
        assert_eq!(
            PolicySpec::parse("rnd=5-10"),
            Some(PolicySpec::Rnd { lo: 5, hi: 10 })
        );
        assert_eq!(
            PolicySpec::parse("htm-spin=3"),
            Some(PolicySpec::HtmSpin { retries: 3 })
        );
        assert_eq!(PolicySpec::parse("nonsense"), None);
    }

    #[test]
    fn parse_roundtrips_fig_sets_and_batch_exactly() {
        // Satellite guarantee: `parse(name()) == Some(spec)` — not just
        // name equality — for every figure-set variant and the batch
        // backend, so the CLI defaults match the paper defaults.
        let mut specs = PolicySpec::fig2_set();
        specs.extend(PolicySpec::fig3_set());
        specs.push(PolicySpec::Batch {
            block: crate::batch::DEFAULT_BLOCK,
        });
        specs.push(PolicySpec::batch_adaptive());
        for spec in specs {
            assert_eq!(
                PolicySpec::parse(spec.name()),
                Some(spec),
                "default-parameter round-trip for {}",
                spec.name()
            );
        }
        assert_eq!(
            PolicySpec::parse("batch=512"),
            Some(PolicySpec::Batch { block: 512 })
        );
        assert_eq!(
            PolicySpec::parse("batch"),
            Some(PolicySpec::Batch {
                block: crate::batch::DEFAULT_BLOCK
            })
        );
        // The adaptive variant round-trips through both spellings.
        assert_eq!(
            PolicySpec::parse("batch=adaptive"),
            Some(PolicySpec::batch_adaptive())
        );
        assert_eq!(
            PolicySpec::parse("batch-adaptive"),
            Some(PolicySpec::batch_adaptive())
        );
        // The latency-target spelling parses the deadline; garbage
        // after the `=` is rejected, not silently defaulted.
        assert_eq!(
            PolicySpec::parse("batch=adaptive:latency=40"),
            Some(PolicySpec::BatchAdaptive {
                latency_ms: 40,
                window: 0
            })
        );
        assert_eq!(PolicySpec::parse("batch=adaptive:latency=oops"), None);
        // The window spelling, alone and combined (either order).
        assert_eq!(
            PolicySpec::parse("batch=adaptive:window=3"),
            Some(PolicySpec::BatchAdaptive {
                latency_ms: 0,
                window: 3
            })
        );
        assert_eq!(
            PolicySpec::parse("batch=adaptive:latency=40:window=4"),
            Some(PolicySpec::BatchAdaptive {
                latency_ms: 40,
                window: 4
            })
        );
        assert_eq!(
            PolicySpec::parse("batch=adaptive:window=4:latency=40"),
            Some(PolicySpec::BatchAdaptive {
                latency_ms: 40,
                window: 4
            })
        );
        // window=0, malformed values, and unknown keys are rejected.
        assert_eq!(PolicySpec::parse("batch=adaptive:window=0"), None);
        assert_eq!(PolicySpec::parse("batch=adaptive:window=x"), None);
        assert_eq!(PolicySpec::parse("batch=adaptive:depth=3"), None);
    }

    #[test]
    fn parse_roundtrips_auto() {
        // Bare spelling: controller defaults.
        assert_eq!(
            PolicySpec::parse("auto"),
            Some(PolicySpec::Auto {
                hysteresis: crate::engine::auto::DEFAULT_HYSTERESIS,
            })
        );
        // `parse(name())` reconstructs the defaults, like every family.
        let auto = PolicySpec::Auto { hysteresis: 7 };
        assert_eq!(auto.name(), "auto");
        assert_eq!(
            PolicySpec::parse(auto.name()),
            Some(PolicySpec::Auto {
                hysteresis: crate::engine::auto::DEFAULT_HYSTERESIS,
            })
        );
        // The parameterized spelling survives the first-`=` split.
        assert_eq!(
            PolicySpec::parse("auto=hysteresis=3"),
            Some(PolicySpec::Auto { hysteresis: 3 })
        );
        // Zero, malformed values, and unknown keys are rejected, not
        // silently defaulted.
        assert_eq!(PolicySpec::parse("auto=hysteresis=0"), None);
        assert_eq!(PolicySpec::parse("auto=hysteresis=x"), None);
        assert_eq!(PolicySpec::parse("auto=dwell=3"), None);
        assert_eq!(PolicySpec::parse("auto=3"), None);
    }

    #[test]
    fn parse_rejects_malformed_specs_without_panicking() {
        // CLI-audit satellite: every malformed spec the user can type
        // must come back as a rejection the CLI maps to a usage error —
        // never an unwrap panic inside the parser.
        for bad in [
            "", "=", "lock=", "rnd=5-", "rnd=-10", "rnd=a-b", "batch=",
            "batch=adaptive:", "batch=adaptive:latency=", "auto=",
            "auto=hysteresis=", "dyad=-1", "htm-spin=4294967296",
        ] {
            // Rejection may surface as None or as the family default —
            // what it must never do is panic. Pin the ones with a
            // single correct answer.
            let _ = PolicySpec::parse(bad);
        }
        assert_eq!(PolicySpec::parse(""), None);
        assert_eq!(PolicySpec::parse("="), None);
        assert_eq!(PolicySpec::parse("rnd=5-"), None);
        assert_eq!(PolicySpec::parse("batch=adaptive:"), None);
        assert_eq!(PolicySpec::parse("batch=adaptive:latency="), None);
        assert_eq!(PolicySpec::parse("auto="), None);
        assert_eq!(PolicySpec::parse("auto=hysteresis="), None);

        // The fault plane's spec parser holds the same line: malformed
        // input is an Err with a reason, never a panic, and good input
        // round-trips every field.
        use crate::fault::FaultSpec;
        for bad in [
            "", "seed", "seed=", "seed=x", "panic=1.5", "panic=-0.1",
            "panic=oops", "worker_stall=0.1:2", "worker_stall=0.1:2days",
            "gamma_ray=0.5", "htm_abort", ",",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
        let spec = FaultSpec::parse(
            "seed=7,htm_abort=0.05,validation_fail=0.02,wakeup_drop=0.01,\
             worker_stall=0.005:2ms,panic=0.001",
        )
        .unwrap();
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.htm_abort, 0.05);
        assert_eq!(spec.validation_fail, 0.02);
        assert_eq!(spec.wakeup_drop, 0.01);
        assert_eq!(spec.worker_stall, 0.005);
        assert_eq!(spec.stall, std::time::Duration::from_millis(2));
        assert_eq!(spec.panic, 0.001);
    }

    #[test]
    fn auto_label_reports_switches() {
        let auto = PolicySpec::Auto { hysteresis: 2 };
        let mut stats = TxStats::new();
        // A run the controller never moved is just the family name —
        // label and parse stay symmetric.
        assert_eq!(auto.label(&stats), "auto");
        assert_eq!(
            PolicySpec::parse(&auto.label(&stats)).map(|p| p.name()),
            Some("auto")
        );
        stats.backend_switches = 3;
        assert_eq!(auto.label(&stats), "auto(hysteresis=2,switches=3)");
        // Other specs never surface the counter.
        assert_eq!(PolicySpec::StmNorec.label(&stats), "stm");
    }

    #[test]
    fn every_policy_executes_a_counter_txn() {
        for spec in all_specs() {
            let heap = Arc::new(TxHeap::new(1 << 12));
            let a = heap.alloc(1);
            let sys = TmSystem::new(heap, HtmConfig::broadwell());
            let mut ex = ThreadExecutor::new(&sys, spec, 0, 42);
            for _ in 0..100 {
                ex.execute(&mut |t: &mut dyn TxAccess| {
                    let v = t.read(a)?;
                    t.write(a, v + 1)
                });
            }
            assert_eq!(sys.heap.load(a), 100, "{}", spec.name());
            assert_eq!(ex.stats.total_commits(), 100, "{}", spec.name());
        }
    }

    #[test]
    fn every_policy_correct_under_contention() {
        const THREADS: u32 = 4;
        const PER: u64 = 1500;
        for spec in all_specs() {
            let heap = Arc::new(TxHeap::new(1 << 12));
            let a = heap.alloc(1);
            let sys = Arc::new(TmSystem::new(heap, HtmConfig::broadwell()));
            std::thread::scope(|s| {
                for tid in 0..THREADS {
                    let sys = Arc::clone(&sys);
                    s.spawn(move || {
                        let mut ex = ThreadExecutor::new(&sys, spec, tid, 7);
                        for _ in 0..PER {
                            ex.execute(&mut |t: &mut dyn TxAccess| {
                                let v = t.read(a)?;
                                t.write(a, v + 1)
                            });
                        }
                    });
                }
            });
            assert_eq!(
                sys.heap.load(a),
                THREADS as u64 * PER,
                "lost updates under {}",
                spec.name()
            );
        }
    }

    #[test]
    fn batch_through_executor_is_loudly_accounted_as_fallback() {
        // The graph kernels and the pipeline never take this path; a
        // caller that does must see every transaction counted under
        // `norec_fallback` and the run relabeled.
        let heap = Arc::new(TxHeap::new(1 << 12));
        let a = heap.alloc(1);
        let sys = TmSystem::new(heap, HtmConfig::broadwell());
        let spec = PolicySpec::Batch { block: 4 };
        let mut ex = ThreadExecutor::new(&sys, spec, 0, 1);
        for _ in 0..5 {
            ex.execute(&mut |t: &mut dyn TxAccess| {
                let v = t.read(a)?;
                t.write(a, v + 1)
            });
        }
        assert_eq!(ex.stats.norec_fallback, 5);
        assert_eq!(ex.stats.sw_commits, 5);
        assert_eq!(spec.label(&ex.stats), "batch(fallback:norec)");
        assert_eq!(
            PolicySpec::batch_adaptive().label(&ex.stats),
            "batch(fallback:norec)"
        );
        // Other specs and clean batch stats keep their plain names.
        assert_eq!(PolicySpec::StmNorec.label(&ex.stats), "stm");
        assert_eq!(spec.label(&TxStats::new()), "batch");
    }

    #[test]
    fn adaptive_label_reports_converged_block() {
        let mut stats = TxStats::new();
        assert_eq!(
            PolicySpec::batch_adaptive().label(&stats),
            "batch-adaptive"
        );
        stats.final_block = 1536;
        assert_eq!(
            PolicySpec::batch_adaptive().label(&stats),
            "batch(adaptive:block=1536)"
        );
        // A latency deadline is part of the label.
        assert_eq!(
            PolicySpec::BatchAdaptive {
                latency_ms: 25,
                window: 0
            }
            .label(&stats),
            "batch(adaptive:block=1536,latency=25ms)"
        );
        // A configured window reports converged/ceiling depth — the
        // spec's ceiling when the controller state never reached the
        // stats, the co-tuned depth when it did.
        assert_eq!(
            PolicySpec::BatchAdaptive {
                latency_ms: 0,
                window: 4
            }
            .label(&stats),
            "batch(adaptive:block=1536,window=4/4)"
        );
        stats.final_window = 2;
        assert_eq!(
            PolicySpec::BatchAdaptive {
                latency_ms: 0,
                window: 4
            }
            .label(&stats),
            "batch(adaptive:block=1536,window=2/4)"
        );
        stats.final_window = 0;
        // A fixed batch run never claims adaptivity.
        assert_eq!(PolicySpec::Batch { block: 64 }.label(&stats), "batch");
    }

    #[test]
    fn labels_surface_worker_runtime_counters() {
        let mut stats = TxStats::new();
        stats.overlapped_txns = 7;
        stats.steals = 3;
        assert_eq!(
            PolicySpec::Batch { block: 64 }.label(&stats),
            "batch(overlap=7,steals=3)"
        );
        stats.final_block = 512;
        assert_eq!(
            PolicySpec::batch_adaptive().label(&stats),
            "batch(adaptive:block=512,overlap=7,steals=3)"
        );
        // Non-batch specs never grow annotations.
        assert_eq!(PolicySpec::StmNorec.label(&stats), "stm");
    }

    #[test]
    fn batch_sizing_matches_the_spec() {
        let fixed = PolicySpec::Batch { block: 96 }.batch_sizing().unwrap();
        assert_eq!(fixed.current(), 96);
        assert!(!fixed.is_adaptive());
        let adaptive = PolicySpec::batch_adaptive().batch_sizing().unwrap();
        assert!(adaptive.is_adaptive());
        assert_eq!(adaptive.latency_target(), None);
        assert_eq!(
            adaptive.current_window(),
            crate::batch::adaptive::BlockSizeController::DEFAULT_WINDOW
        );
        let deadline = PolicySpec::BatchAdaptive {
            latency_ms: 15,
            window: 0,
        }
        .batch_sizing()
        .unwrap();
        assert_eq!(
            deadline.latency_target(),
            Some(std::time::Duration::from_millis(15))
        );
        let windowed = PolicySpec::BatchAdaptive {
            latency_ms: 0,
            window: 4,
        }
        .batch_sizing()
        .unwrap();
        assert_eq!(windowed.current_window(), 4);
        assert_eq!(windowed.window_max(), 4);
        assert!(PolicySpec::StmNorec.batch_sizing().is_none());
    }

    #[test]
    fn hybrid_falls_back_to_stm_on_capacity() {
        // Tiny HTM: a wide transaction must end up committing in SW.
        let heap = Arc::new(TxHeap::new(1 << 14));
        let base = heap.alloc(64 * 8);
        let sys = TmSystem::new(heap, HtmConfig::tiny());
        let mut ex = ThreadExecutor::new(&sys, PolicySpec::DyAd { n: 43 }, 0, 1);
        ex.execute(&mut |t: &mut dyn TxAccess| {
            for i in 0..64 {
                t.write(base + i * 8, i as u64)?;
            }
            Ok(())
        });
        assert_eq!(ex.stats.sw_commits, 1);
        assert_eq!(ex.stats.hw_commits, 0);
        assert!(ex.stats.aborts_of(AbortCause::Capacity) >= 1);
        // DyAd's short-circuit: exactly one post-capacity retry.
        assert_eq!(ex.stats.hw_retries, 1);
        // And the data is there.
        assert_eq!(sys.heap.load(base + 63 * 8), 63);
    }

    #[test]
    fn fx_burns_quota_on_capacity_dyad_does_not() {
        let mk = |spec| {
            let heap = Arc::new(TxHeap::new(1 << 14));
            let base = heap.alloc(64 * 8);
            let sys = TmSystem::new(heap, HtmConfig::tiny());
            let mut ex = ThreadExecutor::new(&sys, spec, 0, 1);
            ex.execute(&mut |t: &mut dyn TxAccess| {
                for i in 0..64 {
                    t.write(base + i * 8, 1)?;
                }
                Ok(())
            });
            ex.stats.hw_retries
        };
        assert_eq!(mk(PolicySpec::Fx { n: 43 }), 43);
        assert_eq!(mk(PolicySpec::DyAd { n: 43 }), 1);
    }

    #[test]
    fn phtm_switches_phases_under_capacity_pressure() {
        // Wide transactions on a tiny HTM: the system must visit the SW
        // phase and come back, and still lose no updates.
        let heap = Arc::new(TxHeap::new(1 << 14));
        let base = heap.alloc(64 * 8);
        let a = heap.alloc_lines(1);
        let sys = TmSystem::new(heap, HtmConfig::tiny());
        let spec = PolicySpec::PhTm { retries: 4, sw_quantum: 2 };
        let mut ex = ThreadExecutor::new(&sys, spec, 0, 1);
        for round in 0..10u64 {
            // Narrow txn first: at round start the quantum has drained
            // back to HW, so this commits in hardware.
            ex.execute(&mut |t: &mut dyn TxAccess| {
                let v = t.read(a)?;
                t.write(a, v + 1)
            });
            // Wide txn: capacity-aborts and drags the system into the
            // SW phase, where it commits; the quantum then drains.
            ex.execute(&mut |t: &mut dyn TxAccess| {
                for i in 0..64 {
                    t.write(base + i * 8, round)?;
                }
                Ok(())
            });
        }
        assert_eq!(sys.heap.load(a), 10);
        assert!(ex.stats.sw_commits > 0, "never entered SW phase");
        assert!(ex.stats.hw_commits > 0, "never committed in HW phase");
        // Drain the residual quantum: a few more narrow txns must bring
        // the system back to the HW phase.
        for _ in 0..20 {
            if sys.phase.phase() == super::super::phtm::Phase::Hw {
                break;
            }
            ex.execute(&mut |t: &mut dyn TxAccess| {
                let v = t.read(a)?;
                t.write(a, v + 1)
            });
        }
        assert_eq!(
            sys.phase.phase(),
            super::super::phtm::Phase::Hw,
            "quantum must drain back to HW"
        );
    }

    #[test]
    fn hle_takes_lock_after_one_speculative_attempt() {
        let heap = Arc::new(TxHeap::new(1 << 14));
        let base = heap.alloc(64 * 8);
        let sys = TmSystem::new(heap, HtmConfig::tiny());
        let mut ex = ThreadExecutor::new(&sys, PolicySpec::Hle, 0, 1);
        ex.execute(&mut |t: &mut dyn TxAccess| {
            for i in 0..64 {
                t.write(base + i * 8, 1)?;
            }
            Ok(())
        });
        assert_eq!(ex.stats.hw_attempts, 1);
        assert_eq!(ex.stats.lock_commits, 1);
    }
}
