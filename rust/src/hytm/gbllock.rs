//! The counting global lock coupling HTM and STM (paper §3.6, DESIGN S5).
//!
//! The paper's `gbllock` is a counter: every STM transaction atomically
//! increments it on entry (`atomic add(gblloc,1)`) and decrements on
//! exit; several STM transactions may hold it simultaneously (their
//! mutual conflicts are the STM's problem). Hardware transactions read
//! it *transactionally* at begin — so on real RTM any STM increment is a
//! data conflict that aborts the hardware transaction.
//!
//! Our software HTM cannot get that conflict for free, so the lock word
//! carries a second field: the *total entry count* in the high 32 bits,
//! which never decreases. A hardware transaction samples the whole word
//! at begin and validates it unchanged at commit (and on every read —
//! giving the speculation opacity against STM write-backs). This is
//! exactly the published Hybrid-NOrec subscription, realized on the
//! paper's counting-lock semantics:
//!
//!   low 32 bits  = STMs in flight  (inc on enter, dec on exit)
//!   high 32 bits = total STM entries ever (inc on enter, monotone)

use std::sync::atomic::Ordering;

use crate::mem::layout::PaddedAtomicU64;

const ENTER: u64 = (1 << 32) | 1;

/// The counting global lock + publication counter.
pub struct GblLock(PaddedAtomicU64);

impl GblLock {
    pub fn new() -> Self {
        Self(PaddedAtomicU64::new(0))
    }

    /// STM entry: `atomic add(gblloc, 1)` of the paper, plus the
    /// monotone entry count.
    #[inline]
    pub fn enter_sw(&self) {
        self.0.fetch_add(ENTER, Ordering::AcqRel);
    }

    /// STM exit: `atomic sub(gblloc, 1)`.
    #[inline]
    pub fn exit_sw(&self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }

    /// Is any STM transaction in flight?
    #[inline]
    pub fn is_held(&self) -> bool {
        self.0.load(Ordering::Acquire) & 0xFFFF_FFFF != 0
    }

    /// In-flight STM count (diagnostics).
    #[inline]
    pub fn holders(&self) -> u32 {
        (self.0.load(Ordering::Acquire) & 0xFFFF_FFFF) as u32
    }

    /// Sample the full word for hardware-transaction subscription.
    #[inline]
    pub fn sample(&self) -> u64 {
        self.0.load(Ordering::Acquire)
    }

    /// True iff no STM entered or exited since `sample` — i.e. the
    /// hardware transaction's read of the lock word is still valid.
    #[inline]
    pub fn unchanged_since(&self, sample: u64) -> bool {
        self.0.load(Ordering::Acquire) == sample
    }
}

impl Default for GblLock {
    fn default() -> Self {
        Self::new()
    }
}

impl crate::tm::Subscription for GblLock {
    #[inline]
    fn sample(&self) -> u64 {
        GblLock::sample(self)
    }

    #[inline]
    fn unchanged_since(&self, sample: u64) -> bool {
        GblLock::unchanged_since(self, sample)
    }

    #[inline]
    fn is_held(&self) -> bool {
        GblLock::is_held(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counting_semantics() {
        let gl = GblLock::new();
        assert!(!gl.is_held());
        gl.enter_sw();
        gl.enter_sw();
        assert!(gl.is_held());
        assert_eq!(gl.holders(), 2);
        gl.exit_sw();
        assert!(gl.is_held());
        gl.exit_sw();
        assert!(!gl.is_held());
    }

    #[test]
    fn entry_count_is_monotone_through_enter_exit() {
        let gl = GblLock::new();
        let s0 = gl.sample();
        gl.enter_sw();
        gl.exit_sw();
        assert!(!gl.is_held());
        assert!(
            !gl.unchanged_since(s0),
            "a completed STM episode must still invalidate HW subscriptions"
        );
    }

    #[test]
    fn unchanged_when_nothing_happened() {
        let gl = GblLock::new();
        let s = gl.sample();
        assert!(gl.unchanged_since(s));
    }

    #[test]
    fn concurrent_enter_exit_balances() {
        let gl = Arc::new(GblLock::new());
        let mut hs = Vec::new();
        for _ in 0..8 {
            let gl = Arc::clone(&gl);
            hs.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    gl.enter_sw();
                    gl.exit_sw();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert!(!gl.is_held());
        assert_eq!(gl.holders(), 0);
    }
}
