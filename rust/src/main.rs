//! `dyadhytm` — CLI for the DyAdHyTM reproduction.
//!
//! Subcommands (no external arg-parsing crates in the offline registry;
//! parsing is hand-rolled):
//!
//! ```text
//! dyadhytm run    [--policy P] [--scale S] [--threads T] [--batch B]
//!                 [--seed N] [--artifacts] [--tiny-htm] [--no-verify]
//!                 one live SSCA-2 experiment (real threads, verified).
//!                 `--policy batch[=BLOCK|adaptive]` selects the
//!                 Block-STM-style speculative batch backend (threads =
//!                 workers; `adaptive` resizes blocks at runtime from
//!                 the observed conflict rate;
//!                 `adaptive:window=W` deepens the cross-block
//!                 pipelining window to W blocks, co-tuned with size)
//! dyadhytm sim    --fig <t0|2a..2f|3a..3c|4a..4c|all> [--seed N]
//!                 regenerate a paper figure on the simulated 28-HT node
//! dyadhytm sim    --policy P --scale S --threads T [--kernel g|c|b]
//!                 one simulated cell
//! dyadhytm headline        paper's headline speedup table
//! dyadhytm tune   [--scale S] [--threads T]   StAdHyTM offline DSE
//! dyadhytm calibrate       measure live per-primitive costs
//! dyadhytm check-artifacts smoke-test the PJRT artifact path
//! dyadhytm pipeline [--policy P] [--scale S] [--workers W] [--artifacts]
//!                          streaming generation pipeline (L1/L2 producer,
//!                          L3 transactional consumers, bounded queue)
//! dyadhytm k3     [--policy P] [--scale S] [--threads T] [--depth D]
//!                          SSCA-2 kernel 3: multi-source BFS extraction
//! dyadhytm serve  [--producers N] [--tenants T] [--read-mix F]
//!                 [--duration SECS] [--workers W] [--window W]
//!                 [--block B] [--verts V] [--cap C] [--queue-cap Q]
//!                 [--policy auto|batch[=B]] [--seed N]
//!                          continuous-serving session: N producers
//!                          stream tenant-partitioned graph mutations
//!                          while abort-free snapshot reads serve
//!                          degree/neighborhood/reachability queries
//!                          (`--read-mix` = probability a reader pass
//!                          queries instead of idling)
//! dyadhytm policies        list policy names
//! ```
//!
//! Global telemetry flags (any subcommand, see `dyadhytm::obs`):
//!
//! ```text
//! --trace[=PATH]       event tracing -> JSON-lines (default trace.jsonl)
//! --metrics-json PATH  phase-scoped metric snapshots -> JSON-lines
//! --obs-verbosity N    [obs] diagnostics: 0 silent, 1 default, 2 chatty
//! --faults SPEC        deterministic fault injection (see `dyadhytm::fault`),
//!                      e.g. seed=7,htm_abort=0.05,validation_fail=0.02,
//!                      wakeup_drop=0.01,worker_stall=0.005:2ms,panic=0.001
//! ```

use std::process::ExitCode;

use dyadhytm::coordinator::figures::{self, Kernel};
use dyadhytm::coordinator::{calibrate, live, tune};
use dyadhytm::htm::HtmConfig;
use dyadhytm::hytm::PolicySpec;
use dyadhytm::runtime::ArtifactRuntime;

/// Minimal flag parser: `--key value` and boolean `--flag`.
struct Args {
    rest: Vec<String>,
}

impl Args {
    fn new(args: Vec<String>) -> Self {
        Self { rest: args }
    }

    fn flag(&mut self, name: &str) -> bool {
        if let Some(i) = self.rest.iter().position(|a| a == name) {
            self.rest.remove(i);
            true
        } else {
            false
        }
    }

    /// `--name` / `--name=VALUE` (the value never consumes the next
    /// token, so the flag can precede a subcommand argument safely).
    /// Returns `Some(None)` for the bare form, `Some(Some(v))` for
    /// `--name=v`.
    fn opt_eq(&mut self, name: &str) -> Option<Option<String>> {
        let prefix = format!("{name}=");
        let i = self
            .rest
            .iter()
            .position(|a| a == name || a.starts_with(&prefix))?;
        let arg = self.rest.remove(i);
        if arg == name {
            Some(None)
        } else {
            Some(Some(arg[prefix.len()..].to_string()))
        }
    }

    fn opt(&mut self, name: &str) -> Option<String> {
        let i = self.rest.iter().position(|a| a == name)?;
        if i + 1 >= self.rest.len() {
            eprintln!("missing value for {name}");
            std::process::exit(2);
        }
        let v = self.rest.remove(i + 1);
        self.rest.remove(i);
        Some(v)
    }

    fn opt_parse<T: std::str::FromStr>(&mut self, name: &str, default: T) -> T {
        match self.opt(name) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("bad value for {name}: {v}");
                std::process::exit(2);
            }),
            None => default,
        }
    }

    fn finish(self) {
        if !self.rest.is_empty() {
            eprintln!("unrecognized arguments: {:?}", self.rest);
            std::process::exit(2);
        }
    }
}

fn parse_policy(s: &str) -> PolicySpec {
    PolicySpec::parse(s).unwrap_or_else(|| {
        eprintln!("unknown policy '{s}'; see `dyadhytm policies`");
        std::process::exit(2);
    })
}

fn cmd_run(mut a: Args) -> anyhow::Result<()> {
    let policy = parse_policy(&a.opt("--policy").unwrap_or_else(|| "dyad".into()));
    let mut cfg = live::RunConfig::new(
        a.opt_parse("--scale", 12u32),
        policy,
        a.opt_parse("--threads", 4usize),
    );
    cfg.batch = a.opt_parse("--batch", 1usize);
    cfg.seed = a.opt_parse("--seed", 0x55CA_2017u64);
    cfg.use_artifacts = a.flag("--artifacts");
    if a.flag("--tiny-htm") {
        cfg.htm = HtmConfig::tiny();
    }
    if a.flag("--no-verify") {
        cfg.verify = false;
    }
    a.finish();
    let report = live::run_live(&cfg)?;
    println!("{}", report.to_markdown());
    println!(
        "per-thread stats (generation kernel):\n{}",
        report.gen_stats.to_markdown()
    );
    println!(
        "per-thread stats (computation kernel):\n{}",
        report.comp_stats.to_markdown()
    );
    Ok(())
}

fn cmd_sim(mut a: Args) -> anyhow::Result<()> {
    let seed = a.opt_parse("--seed", 7u64);
    if let Some(fig) = a.opt("--fig") {
        a.finish();
        let ids: Vec<&str> = if fig == "all" {
            figures::all_figures()
        } else {
            vec![fig.as_str()]
        };
        for id in ids {
            let spec = figures::fig_by_name(id)
                .ok_or_else(|| anyhow::anyhow!("unknown figure '{id}'"))?;
            println!("{}", figures::render_figure(&spec, seed));
        }
        return Ok(());
    }
    // Single cell.
    let policy = parse_policy(&a.opt("--policy").unwrap_or_else(|| "dyad".into()));
    let scale = a.opt_parse("--scale", 16u32);
    let threads = a.opt_parse("--threads", 14usize);
    let batch = a.opt_parse("--batch", 1usize);
    let kernel = match a.opt("--kernel").as_deref() {
        Some("g") | Some("gen") | Some("generation") => Kernel::Generation,
        Some("c") | Some("comp") | Some("computation") => Kernel::Computation,
        _ => Kernel::Both,
    };
    a.finish();
    let (secs, stats) = figures::sim_cell(policy, threads, scale, kernel, batch, seed);
    println!(
        "policy={} scale={scale} threads={threads} kernel={kernel:?}",
        policy.name()
    );
    println!("{}", stats.to_markdown());
    println!("total virtual time: {secs:.3} s");
    Ok(())
}

fn cmd_check_artifacts() -> anyhow::Result<()> {
    let dir = ArtifactRuntime::default_dir();
    anyhow::ensure!(
        ArtifactRuntime::available(&dir),
        "artifacts missing in {} — run `make artifacts`",
        dir.display()
    );
    let rt = ArtifactRuntime::load(&dir)?;
    println!(
        "manifest: batch={} levels={}",
        rt.manifest.batch, rt.manifest.levels
    );
    let tuples = rt.edge_batch((1, 2), 14, 1 << 14)?;
    println!(
        "edge_batch OK: {} tuples, first = {:?}",
        tuples.len(),
        tuples[0]
    );
    anyhow::ensure!(tuples.iter().all(|t| t.src < (1 << 14) && t.dst < (1 << 14)));
    let weights: Vec<u32> = tuples.iter().map(|t| t.weight).collect();
    let gmax = rt.max_weight(&weights)?;
    let (_, mask) = rt.classify(&weights, gmax)?;
    let hits = mask.iter().sum::<u32>();
    let expect = weights.iter().filter(|&&w| w == gmax).count() as u32;
    anyhow::ensure!(hits == expect, "mask hits {hits} != expected {expect}");
    println!("classify OK: gmax={gmax}, {hits} max-weight edges");
    println!("artifact path healthy");
    Ok(())
}

fn cmd_pipeline(mut a: Args) -> anyhow::Result<()> {
    use dyadhytm::graph::{Graph, Ssca2Config};
    use dyadhytm::hytm::TmSystem;
    use dyadhytm::runtime::{pipeline, TupleSource};
    use std::sync::Arc;

    let policy = parse_policy(&a.opt("--policy").unwrap_or_else(|| "dyad".into()));
    let scale = a.opt_parse("--scale", 13u32);
    let workers = a.opt_parse("--workers", 4usize);
    let use_artifacts = a.flag("--artifacts");
    let seed = a.opt_parse("--seed", 0x55CA_2017u64);
    a.finish();

    let mut cfg = pipeline::PipelineConfig::new(scale, policy, workers);
    cfg.seed = seed;
    let source = if use_artifacts {
        let dir = ArtifactRuntime::default_dir();
        anyhow::ensure!(
            ArtifactRuntime::available(&dir),
            "artifacts missing — run `make artifacts`"
        );
        TupleSource::Artifacts(ArtifactRuntime::load(&dir)?)
    } else {
        TupleSource::Native { seed }
    };

    let gcfg = Ssca2Config::new(scale).with_seed(seed);
    let g = Graph::alloc(gcfg);
    let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
    let report = pipeline::run(&sys, &g, source, &cfg)?;
    println!(
        "pipeline: {} edges in {:?} ({:.0} edges/s), producer blocked {:?}, \
         consumers blocked {:?}",
        report.edges,
        report.elapsed,
        report.edges_per_sec,
        report.producer_blocked,
        report.consumer_blocked
    );
    println!("{}", report.stats.to_markdown());
    Ok(())
}

fn cmd_k3(mut a: Args) -> anyhow::Result<()> {
    use dyadhytm::graph::{computation, generation, rmat, subgraph, Graph, Ssca2Config};
    use dyadhytm::hytm::TmSystem;
    use std::sync::Arc;

    let policy = parse_policy(&a.opt("--policy").unwrap_or_else(|| "dyad".into()));
    let scale = a.opt_parse("--scale", 12u32);
    let threads = a.opt_parse("--threads", 4usize);
    let depth = a.opt_parse("--depth", 3usize);
    let seed = a.opt_parse("--seed", 0x55CA_2017u64);
    a.finish();

    let cfg = Ssca2Config::new(scale).with_seed(seed);
    let g = Graph::alloc(cfg);
    let sys = TmSystem::new(Arc::clone(&g.heap), HtmConfig::broadwell());
    let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
    generation::build_serial(&sys, &g, &tuples);
    // One engine handle across both kernels: under `--policy auto` the
    // meta-controller's votes and decision log carry from the
    // computation intervals into the extraction levels.
    let mut engine = dyadhytm::engine::Engine::new(policy);
    let _ = computation::run_with(&sys, &g, &mut engine, threads, seed);
    let roots = subgraph::roots_from_results(&g);
    let r = subgraph::run_with(&sys, &g, &roots, depth, &mut engine, threads, seed);
    subgraph::verify_subgraph(&g, &roots, depth, &r)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "kernel 3: {} roots, depth {depth} -> {} vertices in {:?} (levels: {:?})",
        roots.len(),
        r.total_marked,
        r.elapsed,
        r.level_sizes
    );
    println!("{}", r.stats.to_markdown());
    println!("verified OK");
    Ok(())
}

fn cmd_serve(mut a: Args) -> anyhow::Result<()> {
    use dyadhytm::serve::{Op, ServeConfig, ServeSession, TenantLayout};
    use dyadhytm::util::rng::Rng;
    use std::time::{Duration, Instant};

    let producers = a.opt_parse("--producers", 2usize).max(1);
    let tenants = a.opt_parse("--tenants", 2usize).max(1);
    let verts = a.opt_parse("--verts", 64usize);
    let cap = a.opt_parse("--cap", 8usize);
    let read_mix = a.opt_parse("--read-mix", 0.5f64).clamp(0.0, 1.0);
    let duration = Duration::from_secs_f64(a.opt_parse("--duration", 1.0f64).max(0.0));
    let workers = a.opt_parse("--workers", 2usize);
    let window = a.opt_parse("--window", 2usize);
    let block = a.opt_parse("--block", 64usize);
    let queue_cap = a.opt_parse("--queue-cap", 256usize);
    let seed = a.opt_parse("--seed", 0x55CA_2017u64);
    let policy = a.opt("--policy");
    a.finish();

    let mut cfg = ServeConfig {
        producers,
        workers,
        window,
        block,
        queue_cap,
        ..ServeConfig::default()
    };
    if let Some(p) = &policy {
        match parse_policy(p) {
            PolicySpec::Auto { .. } => cfg.auto_policy = true,
            PolicySpec::Batch { block } => cfg.block = block,
            PolicySpec::BatchAdaptive { .. } => {}
            other => {
                eprintln!(
                    "serve only takes --policy auto|batch[=B] (got {})",
                    other.name()
                );
                std::process::exit(2);
            }
        }
    }
    let lay = TenantLayout::new(tenants, verts, cap);
    let heap = lay.make_heap();

    let (rep, final_degrees) = ServeSession::run(&heap, lay, &cfg, |h| {
        std::thread::scope(|s| {
            for p in 0..producers {
                s.spawn(move || {
                    let mut rng = Rng::new(seed ^ (0xA5E1 + 0x1000 * p as u64));
                    let t0 = Instant::now();
                    while t0.elapsed() < duration {
                        let t = rng.below(tenants as u64) as usize;
                        let u = rng.below(verts as u64) as usize;
                        let v = rng.below(verts as u64) as usize;
                        // One op in eight crosses tenants (when it can).
                        let op = if tenants > 1 && rng.below(8) == 0 {
                            Op::Bridge { from: t, to: (t + 1) % tenants, u, v }
                        } else {
                            Op::Edge { tenant: t, u, v }
                        };
                        if h.submit(p, op).is_err() {
                            break;
                        }
                    }
                    h.close_producer(p);
                });
            }
            // Reader loop on the session thread, concurrent with the
            // producers: each pass either queries every tenant from
            // one pinned snapshot (probability `read_mix`) or idles.
            let mut rng = Rng::new(seed ^ 0x5EAD);
            let t0 = Instant::now();
            while t0.elapsed() < duration {
                if rng.next_f64() < read_mix {
                    let snap = h.snapshot();
                    for t in 0..tenants {
                        let v = rng.below(verts as u64) as usize;
                        let _ = snap.degree(t, v);
                        let _ = snap.neighbors(t, v);
                        if rng.below(4) == 0 {
                            let dst = rng.below(verts as u64) as usize;
                            let _ = snap.reachable(t, v, dst, 4);
                        }
                    }
                } else {
                    std::thread::sleep(Duration::from_micros(500));
                }
            }
        });
        // Producers closed and joined: drain the window, then one
        // guaranteed probe per tenant off the final snapshot (so a
        // smoke run always serves >= 1 read per tenant).
        h.quiesce();
        let snap = h.snapshot();
        (0..tenants)
            .map(|t| snap.degree(t, 0))
            .collect::<Vec<u64>>()
    });

    println!(
        "serve: {} ops from {} producers in {:?} ({:.0} ops/s), {} blocks promoted",
        rep.promoted_txns, producers, rep.batch.elapsed, rep.ingest_rate, rep.promoted_blocks
    );
    anyhow::ensure!(
        rep.promoted_txns == rep.submitted,
        "exactly-once violated: {} submitted vs {} promoted",
        rep.submitted,
        rep.promoted_txns
    );
    for (t, reads) in rep.reads_by_tenant.iter().enumerate() {
        println!(
            "serve: tenant {t} reads={reads} degree(v0)={}",
            final_degrees[t]
        );
    }
    println!(
        "serve: reads={} p50={}ns p99={}ns snapshot_age={}ns",
        rep.served_reads,
        rep.read_lat.p50(),
        rep.read_lat.p99(),
        rep.snapshot_age_ns
    );
    println!(
        "serve: queue_peak={} policy_switches={} mv_live_cells={} mv_retired={} mv_reclaimed={}",
        rep.queue_depth_peak,
        rep.policy_switches,
        rep.batch.mv_live_cells,
        rep.batch.mv_retired,
        rep.batch.mv_reclaimed
    );
    println!(
        "serve: log_live_peak={} log_retired={} log_reclaimed={} aborts={}",
        rep.log_live_peak_cells,
        rep.log_retired_cells,
        rep.log_reclaimed_cells,
        rep.batch.validation_aborts
    );
    Ok(())
}

fn usage() -> ExitCode {
    eprintln!(
        "usage: dyadhytm <run|sim|headline|tune|calibrate|check-artifacts|pipeline|k3|serve|policies> [flags]\n\
         see README for flags"
    );
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut a = Args::new(argv);

    // Telemetry-plane flags are global: they work before or after any
    // subcommand. `--trace[=PATH]` turns the event rings on,
    // `--metrics-json PATH` turns the snapshot registry on, and both
    // flush after the subcommand returns.
    let trace_path = a
        .opt_eq("--trace")
        .map(|v| v.unwrap_or_else(|| "trace.jsonl".into()));
    let metrics_path = a.opt("--metrics-json");
    dyadhytm::obs::set_verbosity(a.opt_parse("--obs-verbosity", 1u8));
    if trace_path.is_some() {
        dyadhytm::obs::trace::enable();
    }
    if metrics_path.is_some() {
        dyadhytm::obs::snapshot::enable();
    }
    // `--faults SPEC` (or `--faults=SPEC`) installs the deterministic
    // fault-injection plane for the whole process before any subcommand
    // runs. A malformed spec is a usage error, never a panic.
    let faults = a.opt("--faults").or_else(|| a.opt_eq("--faults").flatten());
    if let Some(spec) = &faults {
        match dyadhytm::fault::FaultSpec::parse(spec) {
            Ok(s) => dyadhytm::fault::install(s),
            Err(e) => {
                eprintln!("bad --faults spec: {e}");
                std::process::exit(2);
            }
        }
    }

    // Abnormal-exit flush: a genuine panic anywhere still lands the
    // telemetry buffers on disk before the process dies. Injected fault
    // panics are expected — the batch executor quarantines them — so
    // the hook stays silent for those and leaves flushing to the normal
    // exit path below.
    {
        let default_hook = std::panic::take_hook();
        let trace_path = trace_path.clone();
        let metrics_path = metrics_path.clone();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.contains("injected fault"))
                .or_else(|| {
                    info.payload()
                        .downcast_ref::<String>()
                        .map(|s| s.contains("injected fault"))
                })
                .unwrap_or(false);
            if injected {
                return;
            }
            default_hook(info);
            if let Some(path) = &trace_path {
                match dyadhytm::obs::trace::write_jsonl(path) {
                    Ok(n) => eprintln!("panic: flushed {n} trace events -> {path}"),
                    Err(e) => eprintln!("panic: error writing {path}: {e}"),
                }
            }
            if let Some(path) = &metrics_path {
                match dyadhytm::obs::snapshot::write_jsonl(path) {
                    Ok(n) => eprintln!("panic: flushed {n} snapshots -> {path}"),
                    Err(e) => eprintln!("panic: error writing {path}: {e}"),
                }
            }
        }));
    }

    if a.rest.is_empty() {
        return usage();
    }
    let cmd = a.rest.remove(0);

    let result = match cmd.as_str() {
        "run" => cmd_run(a),
        "sim" => cmd_sim(a),
        "headline" => {
            let mut a = a;
            let seed = a.opt_parse("--seed", 7u64);
            a.finish();
            println!("{}", figures::render_headline(seed));
            Ok(())
        }
        "tune" => {
            let mut a = a;
            let scale = a.opt_parse("--scale", 16u32);
            let threads = a.opt_parse("--threads", 28usize);
            let seed = a.opt_parse("--seed", 7u64);
            a.finish();
            println!("{}", tune::render_tuning(scale, threads, seed));
            Ok(())
        }
        "calibrate" => {
            a.finish();
            println!("{}", calibrate::run_calibration().to_markdown());
            Ok(())
        }
        "check-artifacts" => {
            a.finish();
            cmd_check_artifacts()
        }
        "pipeline" => cmd_pipeline(a),
        "k3" => cmd_k3(a),
        "serve" => cmd_serve(a),
        "policies" => {
            a.finish();
            for s in [
                "lock", "stm", "stm-tl2", "htm-alock[=R]", "htm-spin[=R]", "hle",
                "rnd[=LO-HI]", "fx[=N]", "stad[=N]", "dyad[=N]", "dyad-tl2[=N]",
                "phtm[=R]", "batch[=BLOCK]", "batch=adaptive",
                "batch=adaptive:latency=MS", "batch=adaptive:window=W",
                "auto", "auto=hysteresis=N",
            ] {
                println!("{s}");
            }
            Ok(())
        }
        _ => return usage(),
    };
    if let Some(path) = &trace_path {
        // Capture the overwrite count before the drain resets cursors.
        let lost = dyadhytm::obs::trace::dropped();
        match dyadhytm::obs::trace::write_jsonl(path) {
            Ok(n) => dyadhytm::obs::diag(
                1,
                &format!("trace: {n} events -> {path} ({lost} overwritten)"),
            ),
            Err(e) => eprintln!("error writing {path}: {e}"),
        }
    }
    if let Some(path) = &metrics_path {
        match dyadhytm::obs::snapshot::write_jsonl(path) {
            Ok(n) => dyadhytm::obs::diag(1, &format!("metrics: {n} snapshots -> {path}")),
            Err(e) => eprintln!("error writing {path}: {e}"),
        }
    }
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
