//! Discrete-event simulator of the paper's testbed (DESIGN.md S11/S12).
//!
//! The paper's scaling results come from a 14-core / 28-hyperthread
//! Broadwell Xeon. This build machine has one core, so wall-clock
//! thread sweeps cannot show parallel speedup. Following the system
//! substitution rule, `sim` models that machine in *virtual time*:
//!
//! * per-thread virtual clocks advanced by a calibrated cycle cost
//!   model ([`cost`]);
//! * the *same* SSCA-2 workload (same R-MAT tuples, same heap layout,
//!   same cache-line addresses) expressed as transaction descriptors
//!   ([`workload`]);
//! * the *same* Figure-1 policy state machines
//!   ([`crate::hytm::policies`]) deciding retry/fallback;
//! * an event-driven conflict engine ([`engine`]): a transaction
//!   windows `[start, commit)`; it aborts if any line it touched was
//!   committed to inside its window, if a subscribed lock moved, or if
//!   its footprint trips the capacity model — except under
//!   `PolicySpec::Batch` / `PolicySpec::BatchAdaptive`, which run as a
//!   multi-version mode: only lower-serialization-index commits
//!   invalidate a window, failed validations charge
//!   re-incarnation/ESTIMATE-wait costs instead of NOrec's serial
//!   write-back, and admission models the pipelined session's
//!   overlapped drain (a W-deep window of admission lookahead —
//!   `batch=adaptive:window=W` — completion in admission order) sized
//!   by the same `BlockSizeController` the live executors drive;
//! * hyperthread derating beyond 14 threads (shared execution ports →
//!   per-thread IPC drops; [`cost::CostModel::derate`]).
//!
//! Virtual seconds out of this engine reproduce the *shape* of the
//! paper's Figures 2–4: who wins, by roughly what factor, where the
//! 14-thread knee falls. They are not (and cannot be) the authors'
//! absolute seconds.

pub mod cost;
pub mod engine;
pub mod trace;
pub mod workload;

pub use cost::CostModel;
pub use engine::{SimOutcome, Simulator};
pub use trace::{Trace, TraceRecorder};
pub use workload::{SimWorkload, TxnDesc};
