//! Transaction-descriptor streams: the SSCA-2 kernels expressed as what
//! the conflict engine needs — cache-line footprints and work cycles —
//! using the *same* heap-layout arithmetic as the live workload
//! (`crate::graph::layout`), so hub hotness and counter contention are
//! identical in both worlds.

use crate::graph::rmat::{rmat_edge, EdgeTuple};
use crate::mem::WORDS_PER_LINE;
use crate::util::rng::Rng;

use super::cost::CostModel;

/// Max distinct shared write lines a descriptor carries (generation
/// batches beyond this are truncated — footprint-accurate up to 8 hub
/// lines, which covers every configuration the figures use).
pub const MAX_WLINES: usize = 8;

/// One critical section, as the engine sees it.
#[derive(Clone, Copy, Debug)]
pub struct TxnDesc {
    /// Non-critical cycles before this transaction (tuple generation /
    /// cell scanning), pre-derating.
    pub work: u64,
    /// Distinct *shared* cache lines read-modify-written (hub heads,
    /// degrees, counters). Thread-private cell lines are excluded from
    /// conflict tracking but counted in the access totals below.
    pub wlines: [u64; MAX_WLINES],
    pub n_wlines: u8,
    /// Distinct shared lines read but not written (the computation
    /// kernel's read-mostly gmax probe). Conflict-checked, never
    /// recorded.
    pub rlines: [u64; 2],
    pub n_rlines: u8,
    /// Word reads/writes inside the transaction (cost accounting).
    pub n_reads: u32,
    pub n_writes: u32,
    /// Total distinct lines written incl. private cells (capacity).
    pub footprint_lines: u16,
}

impl TxnDesc {
    pub fn wlines(&self) -> &[u64] {
        &self.wlines[..self.n_wlines as usize]
    }

    pub fn rlines(&self) -> &[u64] {
        &self.rlines[..self.n_rlines as usize]
    }

    fn empty(work: u64) -> TxnDesc {
        TxnDesc {
            work,
            wlines: [0; MAX_WLINES],
            n_wlines: 0,
            rlines: [0; 2],
            n_rlines: 0,
            n_reads: 0,
            n_writes: 0,
            footprint_lines: 0,
        }
    }

    fn push_wline(&mut self, line: u64) {
        let ws = &mut self.wlines[..self.n_wlines as usize];
        if ws.contains(&line) {
            return;
        }
        if (self.n_wlines as usize) < MAX_WLINES {
            self.wlines[self.n_wlines as usize] = line;
            self.n_wlines += 1;
        }
    }
}

/// Virtual heap layout in line units — mirrors `graph::layout::Graph`
/// region order without allocating a heap.
#[derive(Clone, Copy, Debug)]
struct VLayout {
    head_line0: u64,
    degree_line0: u64,
    cells_line0: u64,
    result_count_line: u64,
    gmax_line: u64,
}

impl VLayout {
    fn new(scale: u32, edge_factor: u32) -> Self {
        let n = 1u64 << scale;
        let m = n * edge_factor as u64;
        let head_lines = n.div_ceil(WORDS_PER_LINE as u64);
        let cell_lines = (m * 4).div_ceil(WORDS_PER_LINE as u64);
        let result_lines = m.div_ceil(WORDS_PER_LINE as u64);
        let head_line0 = 1;
        let degree_line0 = head_line0 + head_lines;
        let cells_line0 = degree_line0 + head_lines;
        let results_line0 = cells_line0 + cell_lines;
        Self {
            head_line0,
            degree_line0,
            cells_line0,
            result_count_line: results_line0 + result_lines,
            gmax_line: results_line0 + result_lines + 2,
        }
    }

    #[inline]
    fn head_line(&self, v: u32) -> u64 {
        self.head_line0 + v as u64 / WORDS_PER_LINE as u64
    }

    #[inline]
    fn degree_line(&self, v: u32) -> u64 {
        self.degree_line0 + v as u64 / WORDS_PER_LINE as u64
    }

    #[inline]
    fn cell_line(&self, cell_index: u64) -> u64 {
        self.cells_line0 + cell_index * 4 / WORDS_PER_LINE as u64
    }
}

/// SSCA-2 workload parameters for the simulator.
#[derive(Clone, Copy, Debug)]
pub struct SimWorkload {
    pub scale: u32,
    pub edge_factor: u32,
    pub batch: usize,
    pub seed: u64,
    pub selectivity_shift: u32,
}

impl SimWorkload {
    pub fn new(scale: u32) -> Self {
        Self {
            scale,
            edge_factor: 8,
            batch: 1,
            seed: 0x55CA_2017,
            selectivity_shift: 3,
        }
    }

    pub fn edges(&self) -> u64 {
        (1u64 << self.scale) * self.edge_factor as u64
    }

    /// This thread's tuple count under block partitioning.
    fn share(&self, threads: usize, tid: usize) -> u64 {
        let m = self.edges();
        let per = m.div_ceil(threads as u64);
        let lo = (tid as u64 * per).min(m);
        let hi = ((tid as u64 + 1) * per).min(m);
        hi - lo
    }

    /// Generation-kernel stream for one thread.
    pub fn generation_stream(
        &self,
        cost: &CostModel,
        threads: usize,
        tid: usize,
    ) -> GenStream {
        let layout = VLayout::new(self.scale, self.edge_factor);
        let m = self.edges();
        let per = m.div_ceil(threads as u64);
        GenStream {
            layout,
            rng: Rng::new(self.seed ^ (tid as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93)),
            scale: self.scale,
            max_weight: 1u32 << self.scale,
            batch: self.batch.max(1),
            remaining: self.share(threads, tid),
            next_cell: tid as u64 * per, // disjoint per-thread cell ranges
            edge_work: cost.edge_gen_work,
        }
    }

    /// Computation-kernel phase-1 stream: the per-edge transactional
    /// max probe — SSCA-2's "extract edges by weight" critical section.
    /// Every scanned edge checks the shared maximum (`read gmax; if w >
    /// gmax write gmax`): read-only in the overwhelmingly common case,
    /// which is exactly why TM crushes the coarse lock here (the lock
    /// serializes every probe; paper Fig 2(c/f)).
    pub fn max_stream(
        &self,
        cost: &CostModel,
        threads: usize,
        tid: usize,
    ) -> MaxStream {
        let layout = VLayout::new(self.scale, self.edge_factor);
        MaxStream {
            gmax_line: layout.gmax_line,
            rng: Rng::new(self.seed ^ 0xA5 ^ (tid as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)),
            remaining_cells: self.share(threads, tid),
            running_max: 0.0,
            scan_work: cost.scan_work,
        }
    }

    /// Computation-kernel phase-2 stream: top-band appends.
    pub fn collect_stream(
        &self,
        cost: &CostModel,
        threads: usize,
        tid: usize,
    ) -> CollectStream {
        let layout = VLayout::new(self.scale, self.edge_factor);
        CollectStream {
            layout,
            rng: Rng::new(self.seed ^ 0xC0 ^ (tid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            remaining_cells: self.share(threads, tid),
            // Band = top 1/2^shift of the weight range.
            hit_prob: 1.0 / (1u64 << self.selectivity_shift) as f64,
            // Appends are buffered locally and flushed in groups (the
            // live kernel's flush batch): without this every append
            // would serialize on the result counter and phase 2 would
            // drown phase 1's read-mostly win.
            batch: self.batch.max(COLLECT_FLUSH),
            scan_work: cost.scan_work,
        }
    }
}

/// Flush granularity of the collect phase's shared-list appends.
pub const COLLECT_FLUSH: usize = 8;

/// Iterator of the computation kernel's per-edge max probes.
pub struct MaxStream {
    gmax_line: u64,
    rng: Rng,
    remaining_cells: u64,
    /// Thread-local running max as a quantile in [0,1): the probe
    /// writes gmax only when this cell beats everything the thread has
    /// seen — a slight overestimate of global-max updates (harmonic,
    /// ~ln(share) writes per thread), conservative for contention.
    running_max: f64,
    scan_work: u64,
}

impl Iterator for MaxStream {
    type Item = TxnDesc;

    fn next(&mut self) -> Option<TxnDesc> {
        if self.remaining_cells == 0 {
            return None;
        }
        self.remaining_cells -= 1;
        let w = self.rng.next_f64();
        let mut d = TxnDesc::empty(self.scan_work);
        d.n_reads = 1;
        d.footprint_lines = 1;
        if w > self.running_max {
            self.running_max = w;
            d.n_writes = 1;
            d.push_wline(self.gmax_line);
        } else {
            d.rlines[0] = self.gmax_line;
            d.n_rlines = 1;
        }
        Some(d)
    }
}

/// Iterator of generation-kernel insert transactions.
pub struct GenStream {
    layout: VLayout,
    rng: Rng,
    scale: u32,
    max_weight: u32,
    batch: usize,
    remaining: u64,
    next_cell: u64,
    edge_work: u64,
}

impl Iterator for GenStream {
    type Item = TxnDesc;

    fn next(&mut self) -> Option<TxnDesc> {
        if self.remaining == 0 {
            return None;
        }
        let k = (self.batch as u64).min(self.remaining) as usize;
        self.remaining -= k as u64;

        let mut d = TxnDesc::empty(self.edge_work * k as u64);
        d.n_reads = 2 * k as u32; // head + degree per edge
        d.n_writes = 6 * k as u32; // 4 cell words + head + degree

        let mut cell_lines = 0u16;
        let mut last_cell_line = u64::MAX;
        for _ in 0..k {
            let e: EdgeTuple = rmat_edge(&mut self.rng, self.scale, self.max_weight);
            d.push_wline(self.layout.head_line(e.src));
            d.push_wline(self.layout.degree_line(e.src));
            let cl = self.layout.cell_line(self.next_cell);
            if cl != last_cell_line {
                cell_lines += 1;
                last_cell_line = cl;
            }
            self.next_cell += 1;
        }
        d.footprint_lines = d.n_wlines as u16 + cell_lines;
        Some(d)
    }
}

/// Iterator of computation-kernel append transactions.
pub struct CollectStream {
    layout: VLayout,
    rng: Rng,
    remaining_cells: u64,
    hit_prob: f64,
    batch: usize,
    scan_work: u64,
}

impl Iterator for CollectStream {
    type Item = TxnDesc;

    fn next(&mut self) -> Option<TxnDesc> {
        let mut scanned = 0u64;
        let mut hits = 0usize;
        while self.remaining_cells > 0 && hits < self.batch {
            self.remaining_cells -= 1;
            scanned += 1;
            if self.rng.next_f64() < self.hit_prob {
                hits += 1;
            }
        }
        if hits == 0 {
            // Tail of the scan with no hit: pure work, no transaction —
            // fold it into nothing (the engine only advances clocks on
            // transactions; a zero-txn tail is negligible by
            // construction since hit_prob * share >> 1).
            return None;
        }
        let mut d = TxnDesc::empty(scanned * self.scan_work);
        d.n_reads = 1;
        d.n_writes = 1 + hits as u32;
        d.footprint_lines = 2;
        d.push_wline(self.layout.result_count_line);
        Some(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> CostModel {
        CostModel::broadwell()
    }

    #[test]
    fn generation_stream_covers_all_edges() {
        let w = SimWorkload::new(10);
        let total: u64 = (0..4)
            .map(|tid| {
                w.generation_stream(&cost(), 4, tid)
                    .map(|d| (d.n_reads / 2) as u64)
                    .sum::<u64>()
            })
            .sum();
        assert_eq!(total, w.edges());
    }

    #[test]
    fn generation_descriptors_have_hub_lines() {
        let w = SimWorkload::new(10);
        let descs: Vec<TxnDesc> = w.generation_stream(&cost(), 1, 0).collect();
        // Every insert touches exactly 2 shared lines (head + degree)
        // at batch=1.
        for d in &descs {
            assert_eq!(d.n_wlines, 2);
            assert!(d.work == cost().edge_gen_work);
            assert!(d.footprint_lines >= 3);
        }
        // Power-law: some head line must appear far more often than the
        // mean.
        let mut counts = std::collections::HashMap::new();
        for d in &descs {
            *counts.entry(d.wlines[0]).or_insert(0u64) += 1;
        }
        let max = *counts.values().max().unwrap();
        let mean = descs.len() as f64 / counts.len() as f64;
        assert!(max as f64 > 4.0 * mean, "no hub concentration");
    }

    #[test]
    fn batched_generation_aggregates_footprint() {
        let mut w = SimWorkload::new(10);
        w.batch = 16;
        let d = w.generation_stream(&cost(), 1, 0).next().unwrap();
        assert_eq!(d.n_reads, 32);
        assert_eq!(d.n_writes, 96);
        assert!(d.footprint_lines > 8, "16 edges span many cell lines");
    }

    #[test]
    fn max_stream_is_read_mostly() {
        let w = SimWorkload::new(10);
        let descs: Vec<TxnDesc> = w.max_stream(&cost(), 4, 2).collect();
        // One probe per cell in the thread's share.
        assert_eq!(descs.len() as u64, w.edges() / 4);
        let writes = descs.iter().filter(|d| d.n_wlines > 0).count();
        let reads = descs.iter().filter(|d| d.n_rlines > 0).count();
        assert_eq!(writes + reads, descs.len());
        // Harmonic number of writes: ~ln(2048) ~= 7.6; allow slack.
        assert!(writes >= 3 && writes <= 40, "writes {writes}");
        // Every probe touches the same gmax line.
        for d in &descs {
            let l = if d.n_wlines > 0 { d.wlines[0] } else { d.rlines[0] };
            assert_eq!(l, descs.last().map(|x| if x.n_wlines>0 {x.wlines[0]} else {x.rlines[0]}).unwrap());
        }
    }

    #[test]
    fn collect_stream_hits_about_an_eighth() {
        let w = SimWorkload::new(12);
        let txns: Vec<TxnDesc> = w.collect_stream(&cost(), 1, 0).collect();
        let appends: u32 = txns.iter().map(|d| d.n_writes - 1).sum();
        let frac = appends as f64 / w.edges() as f64;
        assert!((0.10..0.15).contains(&frac), "selectivity {frac}");
        // All appends hit the same counter line.
        let line = txns[0].wlines[0];
        assert!(txns.iter().all(|d| d.wlines[0] == line));
    }

    #[test]
    fn streams_are_deterministic() {
        let w = SimWorkload::new(9);
        let a: Vec<u64> = w.generation_stream(&cost(), 2, 1).map(|d| d.wlines[0]).collect();
        let b: Vec<u64> = w.generation_stream(&cost(), 2, 1).map(|d| d.wlines[0]).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn vlayout_regions_disjoint() {
        let l = VLayout::new(12, 8);
        assert!(l.head_line0 < l.degree_line0);
        assert!(l.degree_line0 < l.cells_line0);
        assert!(l.cells_line0 < l.result_count_line);
        assert_ne!(l.result_count_line, l.gmax_line);
        // Head line of last vertex stays inside the head region.
        assert!(l.head_line((1 << 12) - 1) < l.degree_line0);
    }
}
