//! The simulator's cycle cost model.
//!
//! Defaults are drawn from published RTM/STM microbenchmarks (Goel et
//! al. IPDPS'14 for RTM begin/commit/abort; Dalessandro et al. PPoPP'10
//! for NOrec per-access overheads) and sanity-checked against this
//! repo's own live single-core measurements (`dyadhytm calibrate`,
//! EXPERIMENTS.md §Calibration). All values are cycles on the modeled
//! 2.4 GHz Broadwell.

/// Cycle costs of every primitive the engine charges.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Physical cores before hyperthreading kicks in.
    pub cores: usize,
    /// Throughput gain of running 2 threads per core (Broadwell SMT on
    /// this integer-heavy workload: ~24%).
    pub smt_gain: f64,
    /// Clock, Hz — converts cycles to (virtual) seconds.
    pub clock_hz: f64,

    // -- hardware transactions ------------------------------------------
    /// `_xbegin` entry.
    pub hw_begin: u64,
    /// Successful `_xend`.
    pub hw_commit: u64,
    /// Abort: pipeline flush + register restore.
    pub hw_abort: u64,
    /// Per transactional read/write (cache-resident).
    pub hw_access: u64,

    // -- software transactions (NOrec-shaped) ---------------------------
    pub sw_begin: u64,
    /// Per logged read (value log append + seq check).
    pub sw_read: u64,
    /// Per buffered write.
    pub sw_write: u64,
    /// Commit: seq-lock CAS + write-back per entry charged via sw_write.
    pub sw_commit: u64,
    /// Validation on abort/retry: per read-log entry re-read.
    pub sw_validate_per_read: u64,

    // -- multi-version (batch backend) execution -------------------------
    /// Per versioned read: shard lock + version-map lookup in the
    /// multi-version store (`batch::mvmemory`), vs `sw_read`'s value-log
    /// append.
    pub mv_read: u64,
    /// Per buffered write (local write-set append; publication is paid
    /// in the commit/validation term).
    pub mv_write: u64,
    /// Validation re-read per read-set entry. Every transaction
    /// validates at least once before its block commits.
    pub mv_validate_per_read: u64,
    /// Re-incarnation after a failed validation: convert the write set
    /// to ESTIMATEs + rescheduling (the PR-1 `validation_aborts`
    /// counter).
    pub mv_abort: u64,
    /// Suspension on a lower transaction's ESTIMATE: parked until the
    /// blocking transaction finishes and the scheduler re-readies us
    /// (the PR-1 `dependencies` counter).
    pub mv_estimate_wait: u64,
    /// Epoch-reclamation work charged per promoted block
    /// (`mem::epoch`): retiring the block's recorded sets into limbo,
    /// advancing the epoch, and freeing the bins every worker has
    /// passed. Amortized — the real cost is a handful of frees plus
    /// two atomics per promotion, independent of block size.
    pub mv_reclaim_per_block: u64,

    // -- locks -----------------------------------------------------------
    /// Uncontended acquire+release round trip (atomic RMW pair).
    pub lock_cycle: u64,
    /// Per access under the lock (plain, but uncacheable-shared).
    pub direct_access: u64,

    // -- policy bookkeeping ----------------------------------------------
    /// One PRNG draw (RNDHyTM's per-transaction cost; the paper calls it
    /// "quite significant").
    pub rng_draw: u64,
    /// Reading the abort-status flags (DyAdHyTM's only overhead).
    pub flag_check: u64,

    /// Committed `--policy auto` backend switch: drain the old backend,
    /// quiesce its workers, and warm the new one's structures (batch
    /// promotion queues or per-thread executors). Charged once per
    /// switch by the simulator's auto controller — the explicit
    /// switch-cost term that keeps a flappy controller from looking
    /// free in virtual time.
    pub backend_switch: u64,

    // -- fault plane (robustness pricing) ---------------------------------
    /// Catching, quarantining, and re-dispatching a panicking
    /// transaction body (`--faults panic=P`): unwind teardown plus the
    /// scheduler requeue, on top of the wasted attempt.
    pub quarantine: u64,
    /// One watchdog recovery pass after a dropped dependency wakeup
    /// (`--faults wakeup_drop=P`): the missed-deadline stall share plus
    /// the re-ready and forced revalidation, amortized to cycles.
    pub watchdog_recovery: u64,

    // -- workload work ----------------------------------------------------
    /// Non-critical work to produce one edge tuple and bring its insert
    /// footprint into the cache (R-MAT descent + DRAM stalls at
    /// LLC-exceeding graph scales; calibrated against the paper's T0
    /// triple: lock speedup 6.3x at 14 threads requires the critical
    /// section to be ~10% of serial execution).
    pub edge_gen_work: u64,
    /// Non-critical work to scan one edge cell (computation kernel).
    pub scan_work: u64,

    // -- large-graph fault model ------------------------------------------
    /// Per-transaction probability of a capacity-class abort (TSX's
    /// footprint/TLB/page-walk fatality on graphs far larger than the
    /// caches). Persistent per transaction: retrying in hardware cannot
    /// help — exactly the signal DyAdHyTM keys on. Scales with graph
    /// size; see [`CostModel::for_scale`].
    pub capacity_prob: f64,
}

impl CostModel {
    /// Broadwell-flavoured defaults (see module docs for sources).
    pub fn broadwell() -> Self {
        Self {
            cores: 14,
            smt_gain: 0.24,
            clock_hz: 2.4e9,
            hw_begin: 45,
            hw_commit: 40,
            hw_abort: 160,
            hw_access: 6,
            sw_begin: 30,
            sw_read: 22,
            sw_write: 16,
            sw_commit: 60,
            sw_validate_per_read: 14,
            mv_read: 34,
            mv_write: 12,
            // Batched sorted-walk validation with per-shard watermark
            // skips (PR 9) re-probes only marked shards: cheaper per
            // read-set entry than the NOrec full re-read (14).
            mv_validate_per_read: 9,
            mv_abort: 120,
            mv_estimate_wait: 400,
            mv_reclaim_per_block: 700,
            lock_cycle: 70,
            direct_access: 8,
            rng_draw: 20,
            flag_check: 3,
            backend_switch: 25_000,
            quarantine: 2_000,
            watchdog_recovery: 80_000,
            edge_gen_work: 1200,
            scan_work: 65,
            capacity_prob: 0.0,
        }
    }

    /// Defaults with the capacity fault model sized for a graph scale:
    /// the resident fraction of head/degree/cell lines shrinks as the
    /// graph outgrows the LLC, and with it grows the chance an insert
    /// trips a footprint/page-walk abort. Calibrated so the paper's
    /// scale-27 retry counts (Fig 4b: ~0.4% of 1.07 G transactions) and
    /// our laptop scales line up on the same curve.
    pub fn for_scale(scale: u32) -> Self {
        let mut m = Self::broadwell();
        m.capacity_prob = (2f64.powi(scale as i32 - 24)).min(0.05);
        m
    }

    /// Per-thread slowdown factor at `threads` live threads.
    ///
    /// <= cores: full speed (1.0). Beyond: two threads share a core's
    /// execution ports; aggregate throughput grows only by `smt_gain`,
    /// so each thread runs at `cores * (1 + smt_gain * over) / threads`
    /// of full speed, `over` = fraction of cores doubled.
    pub fn derate(&self, threads: usize) -> f64 {
        if threads <= self.cores {
            return 1.0;
        }
        let t = threads.min(2 * self.cores) as f64;
        let over = (t - self.cores as f64) / self.cores as f64;
        let equivalent = self.cores as f64 * (1.0 + self.smt_gain * over);
        t / equivalent
    }

    /// Convert cycles to virtual seconds.
    pub fn to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_hz
    }

    /// Duration of one hardware attempt of a txn with `r` reads and `w`
    /// writes (body work excluded — charged separately).
    pub fn hw_txn_cycles(&self, r: u64, w: u64) -> u64 {
        self.hw_begin + self.hw_access * (r + w) + self.hw_commit
    }

    /// Duration of one software (NOrec) attempt.
    pub fn sw_txn_cycles(&self, r: u64, w: u64) -> u64 {
        self.sw_begin + self.sw_read * r + self.sw_write * w + self.sw_commit
    }

    /// Duration of a lock-held direct execution.
    pub fn locked_txn_cycles(&self, r: u64, w: u64) -> u64 {
        self.lock_cycle + self.direct_access * (r + w)
    }

    /// Duration of one multi-version (batch backend) execution attempt:
    /// optimistic execution through the version store, the mandatory
    /// validation pass, and the transaction's share of the block
    /// write-back (amortized into the commit term).
    pub fn mv_txn_cycles(&self, r: u64, w: u64) -> u64 {
        self.sw_begin
            + self.mv_read * r
            + self.mv_write * w
            + self.mv_validate_per_read * r
            + self.sw_commit
    }
}

impl Default for CostModel {
    fn default() -> Self {
        Self::broadwell()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derate_is_one_up_to_cores() {
        let m = CostModel::broadwell();
        for t in 1..=14 {
            assert_eq!(m.derate(t), 1.0, "{t}");
        }
    }

    #[test]
    fn derate_grows_beyond_cores() {
        let m = CostModel::broadwell();
        let d20 = m.derate(20);
        let d28 = m.derate(28);
        assert!(d20 > 1.0 && d28 > d20);
        // At 28 threads: 28 / (14 * 1.24) ~= 1.61.
        assert!((d28 - 1.61).abs() < 0.02, "d28={d28}");
    }

    #[test]
    fn capacity_prob_grows_with_scale_and_saturates() {
        let p15 = CostModel::for_scale(15).capacity_prob;
        let p20 = CostModel::for_scale(20).capacity_prob;
        let p27 = CostModel::for_scale(27).capacity_prob;
        assert!(p15 < p20);
        assert!(p20 <= p27, "saturated band");
        assert!(p27 <= 0.05);
        // Paper-scale anchor: ~0.4% at scale 16 in our laptop band.
        assert!((CostModel::for_scale(16).capacity_prob - 0.0039).abs() < 0.001);
    }

    #[test]
    fn stm_is_slower_than_htm_per_txn() {
        let m = CostModel::broadwell();
        assert!(m.sw_txn_cycles(2, 6) > m.hw_txn_cycles(2, 6));
    }

    #[test]
    fn mv_attempt_costs_more_than_plain_stm_attempt() {
        // The multi-version store's per-read lookup + mandatory
        // validation make a conflict-free MV attempt dearer than a
        // conflict-free NOrec attempt — the batch backend buys its
        // no-serial-write-back commit with per-access overhead.
        let m = CostModel::broadwell();
        assert!(m.mv_txn_cycles(2, 6) > m.sw_txn_cycles(2, 6));
        assert!(m.mv_read > m.sw_read);
    }

    #[test]
    fn seconds_conversion() {
        let m = CostModel::broadwell();
        assert!((m.to_seconds(2_400_000_000) - 1.0).abs() < 1e-9);
    }
}
