//! The discrete-event conflict engine.
//!
//! Single-threaded, deterministic, chronological: a binary heap of
//! (virtual time, thread) events. Each simulated thread walks its
//! transaction stream; a transaction's attempt occupies a window
//! `[start, commit)` and commits iff no tracked line it touches was
//! committed-to inside the window, no subscribed lock word moved, and
//! its footprint clears the capacity model. Policy decisions come from
//! the *same* [`RetryPolicy`] state machines the live executor drives.
//!
//! Documented approximations (DESIGN.md §6.4):
//! * conflicts are detected at commit-check time against commits with
//!   earlier timestamps (committer-wins ordering);
//! * NOrec's serial write-back is modeled by serializing STM commit
//!   times through `seq_free_at`;
//! * lock-path and STM writes recorded with their completion timestamps
//!   invalidate overlapping speculators exactly as the live
//!   subscription + commit fence do;
//! * the batch backend runs as [`Mode::MultiVersion`]: admission order
//!   is the serialization order, only lower-index commits invalidate an
//!   execution, failed validations charge re-incarnation (and, for
//!   repeat offenders, ESTIMATE-wait) costs, and commits skip NOrec's
//!   serial write-back — the block write-back is amortized per
//!   transaction in [`CostModel::mv_txn_cycles`].

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::batch::adaptive::BlockSizeController;
use crate::hytm::policies::{Decision, DyAdPolicy, FxPolicy, RetryPolicy, RndPolicy, StAdPolicy};
use crate::hytm::PolicySpec;
use crate::stats::{StatsTable, TxStats};
use crate::tm::AbortCause;
use crate::util::rng::Rng;

use super::cost::CostModel;
use super::workload::TxnDesc;

/// Result of one simulated (policy, threads, workload) run.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    /// Makespan in cycles (max thread completion time).
    pub cycles: u64,
    /// Makespan in virtual seconds.
    pub seconds: f64,
    pub stats: StatsTable,
}

/// How a thread executes its transactions (derived from PolicySpec).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mode {
    Lock,
    Stm,
    /// HTM with `retries` then the fallback lock.
    HtmLock { retries: u32 },
    /// HyTM: policy-driven retries then gbllock STM.
    Hybrid,
    /// PhTM: phase-global HW/SW switching (ablation A5).
    Phased { sw_quantum: u32 },
    /// Block-STM-style multi-version batch execution
    /// (`PolicySpec::Batch` / `PolicySpec::BatchAdaptive`):
    /// transactions take a global serialization index; only
    /// *lower-index* writers can invalidate an execution, and commits
    /// never serialize through NOrec's sequence lock. Failed
    /// validations charge re-incarnation (and, for repeat offenders,
    /// ESTIMATE-wait) costs — the virtual-time analogue of the live
    /// `BatchReport` counters. Admission models the live
    /// `BatchSystem::run_pipelined` session's **W-deep overlapped
    /// drain**: up to `BlockSizeController::current_window()` blocks
    /// are open at once — lookahead blocks' transactions admit while
    /// the head's tail drains (counted as `overlapped_txns`), and a
    /// thread parks only when admission would need a block *beyond*
    /// the window. Blocks complete in order; each completion feeds the
    /// *same* `BlockSizeController` the live executors run (pinned for
    /// `Batch`, AIMD with window co-tuning for `BatchAdaptive`, with
    /// the block's virtual wall time driving the optional latency
    /// target) — so `--policy batch=adaptive:window=W` is priced by
    /// `sim --fig combined` exactly as the live session runs it.
    MultiVersion,
}

/// Per-thread simulation state.
struct ThreadSim {
    stream: Box<dyn Iterator<Item = TxnDesc>>,
    policy: Option<Box<dyn RetryPolicy>>,
    rng: Rng,
    stats: TxStats,
    clock: u64,
    cur: Option<TxnDesc>,
    /// Persistent capacity verdict for the current transaction.
    cur_capacity: bool,
    /// Global serialization index of the current transaction
    /// (Mode::MultiVersion only).
    mv_idx: u64,
    /// Re-incarnations of the current transaction (Mode::MultiVersion).
    mv_retries: u32,
    state: TState,
    done: bool,
}

#[derive(Clone, Copy, Debug)]
enum TState {
    /// Pull the next transaction at the event time.
    Ready,
    /// A hardware attempt commits/aborts at the event time;
    /// `start` is the attempt's begin time.
    HwCheck { start: u64 },
    /// A software (STM) attempt finishes at the event time.
    SwCheck { start: u64 },
}

/// Shared lock word state: free time + last-change time (the
/// subscription signal).
#[derive(Clone, Copy, Debug, Default)]
struct LockSim {
    free_at: u64,
    acquired_at: u64,
    last_change: u64,
    held: bool,
}

impl LockSim {
    /// Serialize: acquire at max(now, free_at), hold for `dur`.
    fn acquire(&mut self, now: u64, dur: u64) -> (u64, u64) {
        let acq = now.max(self.free_at);
        let rel = acq + dur;
        self.acquired_at = acq;
        self.free_at = rel;
        self.last_change = rel;
        self.held = true; // released lazily: held_at() compares times
        (acq, rel)
    }

    /// Was the lock held at time `t` (by the most recent episode)?
    fn held_at(&self, t: u64) -> bool {
        self.acquired_at <= t && t < self.free_at
    }

    /// Did the word change inside `(s, c]`?
    fn changed_in(&self, s: u64, c: u64) -> bool {
        (self.acquired_at > s && self.acquired_at <= c)
            || (self.last_change > s && self.last_change <= c)
    }
}

/// The simulator: cost model + capacity threshold.
pub struct Simulator {
    pub cost: CostModel,
    /// Deterministic capacity bound: distinct written lines above this
    /// abort (mirrors HtmConfig::broadwell()'s 512-line L1d write set
    /// with set-conflict slack).
    pub wr_line_capacity: u16,
    /// The fault spec installed at construction time (`--faults`), if
    /// any: the engine prices its regimes in virtual time — forced HTM
    /// aborts, forced validation failures, stall/quarantine/watchdog
    /// charges — with its own deterministic ticket streams (the live
    /// plane's tickets and trace events are never consumed).
    faults: Option<crate::fault::FaultSpec>,
}

/// Deterministic per-run fault draws: same `SplitMix64(seed ^ salt ^
/// ticket)` decision function as the live plane, with run-local ticket
/// counters so virtual runs replay bit-for-bit.
struct FaultDice {
    spec: crate::fault::FaultSpec,
    tickets: [u64; crate::fault::SITES],
}

impl FaultDice {
    /// Draw the site's next ticket; `Some(ticket)` when it injects.
    fn fire(&mut self, site: crate::fault::Site) -> Option<u64> {
        let t = self.tickets[site as usize];
        self.tickets[site as usize] += 1;
        self.spec.draw(site, t).then_some(t)
    }
}

impl Simulator {
    pub fn new(cost: CostModel) -> Self {
        Self {
            cost,
            wr_line_capacity: 448,
            faults: crate::fault::current(),
        }
    }

    /// Run `threads` streams under `spec`. Deterministic per seed.
    pub fn run(
        &self,
        spec: PolicySpec,
        threads: usize,
        streams: Vec<Box<dyn Iterator<Item = TxnDesc>>>,
        seed: u64,
    ) -> SimOutcome {
        assert_eq!(streams.len(), threads);
        if let PolicySpec::Auto { hysteresis } = spec {
            // The meta-controller runs *above* the conflict engine:
            // round-robin intervals of the stream are priced under the
            // controller's current backend, interval stats feed the
            // same `engine::auto` law the live kernels use, and every
            // committed switch charges `CostModel::backend_switch`.
            return self.run_auto(hysteresis, threads, streams, seed);
        }
        let derate = self.cost.derate(threads);
        let scale = |cycles: u64| -> u64 { (cycles as f64 * derate) as u64 };

        let mode = match spec {
            PolicySpec::CoarseLock => Mode::Lock,
            PolicySpec::StmNorec | PolicySpec::StmTl2 => Mode::Stm,
            PolicySpec::HtmALock { retries } | PolicySpec::HtmSpin { retries } => {
                Mode::HtmLock { retries }
            }
            PolicySpec::Hle => Mode::HtmLock { retries: 0 },
            PolicySpec::PhTm { sw_quantum, .. } => Mode::Phased { sw_quantum },
            // The batch backend is priced as what it is: multi-version
            // speculative execution with a fixed serialization order,
            // block-bounded admission, and the live controller sizing
            // each block (the cost model amortizes the block
            // write-back per transaction).
            PolicySpec::Batch { .. } | PolicySpec::BatchAdaptive { .. } => Mode::MultiVersion,
            _ => Mode::Hybrid,
        };
        // The block-size controller shared with the live executors
        // (Mode::MultiVersion only; a non-batch spec never consults it).
        let mut mv_ctl = spec
            .batch_sizing()
            .unwrap_or_else(|| BlockSizeController::fixed(usize::MAX));
        // Test-and-set fallback (HTMALock) pays an extra RMW storm per
        // acquisition vs the test-and-test-and-set spinlock.
        let lock_extra: u64 = match spec {
            PolicySpec::HtmALock { .. } => 45,
            _ => 0,
        };
        // Fault-regime pricing: run-local deterministic dice mirroring
        // the live plane's decision function, plus the injected stall
        // length converted to cycles once.
        let mut dice = self.faults.clone().map(|spec| FaultDice {
            spec,
            tickets: [0; crate::fault::SITES],
        });
        let stall_cycles: u64 = self
            .faults
            .as_ref()
            .map_or(0, |f| (f.stall.as_secs_f64() * self.cost.clock_hz) as u64);

        let mut threads_sim: Vec<ThreadSim> = streams
            .into_iter()
            .enumerate()
            .map(|(tid, stream)| ThreadSim {
                stream,
                policy: make_policy(&spec),
                rng: Rng::new(seed ^ (tid as u64).wrapping_mul(0xA24B_AED4_963E_E407)),
                stats: TxStats::new(),
                clock: 0,
                cur: None,
                cur_capacity: false,
                mv_idx: 0,
                mv_retries: 0,
                state: TState::Ready,
                done: false,
            })
            .collect();

        // Global state.
        let mut last_write: HashMap<u64, u64> = HashMap::new();
        let mut coarse = LockSim::default(); // CoarseLock / HTM fallback lock
        let mut gbl = LockSim::default(); // gbllock episodes (interval view)
        let mut gbl_count: u32 = 0; // STMs in flight
        let mut seq_free_at: u64 = 0; // NOrec serial write-back
        // PhTM phase-global state (Mode::Phased only).
        let mut ph = LockSim::default(); // subscription view of the phase word
        let mut ph_sw: bool = false;
        let mut ph_sw_left: i64 = 0;
        let mut ph_inflight: u32 = 0;
        // Multi-version state (Mode::MultiVersion only): the global
        // serialization order and, per line, the recent commit history
        // as (time, writer index) pairs. A history — not just the last
        // writer — because a higher-index commit must not hide a
        // lower-index commit that also landed inside an open window.
        // Entries older than the longest attempt window seen so far can
        // never fall inside any future window (event times are
        // processed in nondecreasing order), so they are pruned lazily.
        let mut mv_next_idx: u64 = 0;
        let mut mv_commits: HashMap<u64, std::collections::VecDeque<(u64, u64)>> =
            HashMap::new();
        let mut mv_max_window: u64 = 0;
        // Overlapped block admission — the virtual-time analogue of
        // `BatchSystem::run_pipelined`: at most `current_window()`
        // blocks are open at once (the draining head plus W-1
        // lookahead blocks; the controller co-tunes the depth at
        // runtime). A transaction admitted into a lookahead block
        // while the head is still draining counts as overlapped; a
        // thread whose admission would need a block beyond the window
        // parks until the head's last commit, which feeds the
        // controller (waste + virtual wall time) and pops the queue in
        // admission order.
        struct SimBlock {
            lo: u64,
            hi: u64,
            execs: u64,
            commits: u64,
            admitted_at: u64,
        }
        let mut mv_blocks: std::collections::VecDeque<SimBlock> =
            std::collections::VecDeque::new();
        let mut mv_parked: Vec<usize> = Vec::new();
        // RNDHyTM's per-transaction rand() goes through libc's internal
        // lock: draws from all threads serialize (the paper: "overhead
        // due to random number generation which is quite significant").
        let mut rng_free_at: u64 = 0;

        let mut queue: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        for tid in 0..threads {
            queue.push(Reverse((0, tid)));
        }

        // Conflict check helper: any line touched (written OR read)
        // committed-to inside (s, c]?
        let lines_conflict =
            |last_write: &HashMap<u64, u64>, desc: &TxnDesc, s: u64, c: u64| -> bool {
                let hit = |l: &u64| matches!(last_write.get(l), Some(&t) if t > s && t <= c);
                desc.wlines().iter().any(hit) || desc.rlines().iter().any(hit)
            };

        while let Some(Reverse((now, tid))) = queue.pop() {
            let th = &mut threads_sim[tid];
            if th.done {
                continue;
            }
            match th.state {
                // ---------------------------------------------- Ready
                TState::Ready => {
                    if mode == Mode::MultiVersion {
                        // With no open block (start of run, or all open
                        // blocks just completed) the next block anchors
                        // at the admission cursor, not 0 — re-covering
                        // committed index space would leave a block
                        // that can never fill.
                        let frontier = mv_blocks.back().map_or(mv_next_idx, |b| b.hi);
                        if mv_next_idx >= frontier {
                            if mv_blocks.len() >= mv_ctl.current_window().max(1) {
                                // The whole W-deep window is fully
                                // admitted but not fully committed:
                                // park; a completing head re-queues
                                // us. (All in-flight txns are owned by
                                // non-parked threads, so the closing
                                // commit always arrives.)
                                mv_parked.push(tid);
                                continue;
                            }
                            let quota = mv_ctl.current().max(1) as u64;
                            mv_blocks.push_back(SimBlock {
                                lo: frontier,
                                hi: frontier + quota,
                                execs: 0,
                                commits: 0,
                                admitted_at: now,
                            });
                        }
                    }
                    let Some(desc) = th.stream.next() else {
                        th.done = true;
                        th.clock = now;
                        continue;
                    };
                    // Capacity verdict is persistent for this txn:
                    // deterministic footprint bound + the large-graph
                    // fault model, scaled by the transaction's own
                    // footprint (every extra line is another chance to
                    // trip a TLB/page-walk fatality on a graph that
                    // dwarfs the caches).
                    let p_eff = self.cost.capacity_prob
                        * (desc.footprint_lines.max(1) as f64 / 4.0);
                    th.cur_capacity = desc.footprint_lines > self.wr_line_capacity
                        || (p_eff > 0.0 && th.rng.next_f64() < p_eff);
                    let mut start = now + scale(desc.work);
                    if let Some(d) = dice.as_mut() {
                        // `--faults worker_stall=P:DUR`: the worker
                        // sleeps before its next task; virtual time
                        // just pays the nap.
                        if d.fire(crate::fault::Site::WorkerStall).is_some() {
                            th.stats.faults_injected += 1;
                            start += scale(stall_cycles);
                        }
                        // `--faults panic=P` (multi-version executor
                        // site): the body panics, is caught before
                        // publishing, quarantined, and re-dispatched —
                        // one wasted attempt plus the quarantine charge.
                        if mode == Mode::MultiVersion
                            && d.fire(crate::fault::Site::Panic).is_some()
                        {
                            th.stats.faults_injected += 1;
                            th.stats.quarantines += 1;
                            start += scale(
                                self.cost.mv_txn_cycles(
                                    desc.n_reads as u64,
                                    desc.n_writes as u64,
                                ) + self.cost.quarantine,
                            );
                        }
                    }
                    th.cur = Some(desc);
                    if let Some(p) = th.policy.as_mut() {
                        p.begin_txn(&mut th.rng);
                    }
                    match mode {
                        Mode::Lock => {
                            // Coarse lock: serialize and complete.
                            let d = scale(self.cost.locked_txn_cycles(
                                desc.n_reads as u64,
                                desc.n_writes as u64,
                            ));
                            let (_, rel) = coarse.acquire(start, d);
                            for &l in desc.wlines() {
                                last_write.insert(l, rel);
                            }
                            th.stats.lock_commits += 1;
                            th.state = TState::Ready;
                            queue.push(Reverse((rel, tid)));
                        }
                        Mode::Stm => {
                            let d = scale(self.cost.sw_txn_cycles(
                                desc.n_reads as u64,
                                desc.n_writes as u64,
                            ));
                            th.state = TState::SwCheck { start };
                            queue.push(Reverse((start + d, tid)));
                        }
                        Mode::MultiVersion => {
                            // Admission order is the serialization
                            // order: take the next global index.
                            th.mv_idx = mv_next_idx;
                            mv_next_idx += 1;
                            th.mv_retries = 0;
                            if let Some(b) = mv_blocks
                                .iter_mut()
                                .find(|b| b.lo <= th.mv_idx && th.mv_idx < b.hi)
                            {
                                b.execs += 1;
                            }
                            if mv_blocks.len() >= 2 && th.mv_idx >= mv_blocks[1].lo {
                                // Executing the lookahead block while
                                // the head still drains.
                                th.stats.overlapped_txns += 1;
                            }
                            let d = scale(self.cost.mv_txn_cycles(
                                desc.n_reads as u64,
                                desc.n_writes as u64,
                            ));
                            mv_max_window = mv_max_window.max(d);
                            th.state = TState::SwCheck { start };
                            queue.push(Reverse((start + d, tid)));
                        }
                        Mode::Phased { .. } if ph_sw => {
                            // SW phase: run on the STM directly.
                            ph_inflight += 1;
                            let d = scale(self.cost.sw_txn_cycles(
                                desc.n_reads as u64,
                                desc.n_writes as u64,
                            ));
                            th.state = TState::SwCheck { start };
                            queue.push(Reverse((start + d, tid)));
                        }
                        Mode::HtmLock { .. } | Mode::Hybrid | Mode::Phased { .. } => {
                            // Policy-level RNG cost (RNDHyTM's draw):
                            // serialized through libc rand()'s lock.
                            let draws = th
                                .policy
                                .as_ref()
                                .map(|p| p.begin_cost_rng_draws() as u64)
                                .unwrap_or(0);
                            let start = if draws > 0 {
                                let s2 = start.max(rng_free_at);
                                let done = s2 + scale(draws * self.cost.rng_draw);
                                rng_free_at = done;
                                done
                            } else {
                                start
                            };
                            let d = scale(self.cost.hw_txn_cycles(
                                desc.n_reads as u64,
                                desc.n_writes as u64,
                            ));
                            th.stats.hw_attempts += 1;
                            th.state = TState::HwCheck { start };
                            queue.push(Reverse((start + d, tid)));
                        }
                    }
                }

                // -------------------------------------------- HwCheck
                TState::HwCheck { start } => {
                    let desc = th.cur.expect("HwCheck without txn");
                    let lock: &LockSim = match mode {
                        Mode::HtmLock { .. } => &coarse,
                        Mode::Phased { .. } => &ph,
                        _ => &gbl,
                    };
                    // `--faults htm_abort=P`: a forced abort ahead of
                    // the genuine causes, ticket parity picking
                    // conflict vs capacity exactly like the live site
                    // in `htm::engine::attempt_with`.
                    let forced = dice
                        .as_mut()
                        .and_then(|d| d.fire(crate::fault::Site::HtmAbort))
                        .map(|t| {
                            th.stats.faults_injected += 1;
                            if t & 1 == 0 {
                                AbortCause::Conflict
                            } else {
                                AbortCause::Capacity
                            }
                        });
                    // Abort cause resolution, in RTM's priority order.
                    let cause = if let Some(c) = forced {
                        Some(c)
                    } else if th.cur_capacity {
                        Some(AbortCause::Capacity)
                    } else if lock.held_at(start) {
                        Some(AbortCause::Explicit)
                    } else if lock.changed_in(start, now) {
                        Some(AbortCause::Conflict)
                    } else if lines_conflict(&last_write, &desc, start, now) {
                        Some(AbortCause::Conflict)
                    } else {
                        None
                    };

                    match cause {
                        None => {
                            // HW_COMMIT: publish.
                            for &l in desc.wlines() {
                                last_write.insert(l, now);
                            }
                            th.stats.hw_commits += 1;
                            th.cur = None;
                            th.state = TState::Ready;
                            queue.push(Reverse((now, tid)));
                        }
                        Some(cause) => {
                            th.stats.note_hw_abort(cause);
                            let decision = th
                                .policy
                                .as_mut()
                                .map(|p| p.on_abort(cause, &mut th.rng))
                                .unwrap_or(Decision::FallbackSw);
                            // HtmLock/Phased modes: capacity is
                            // terminal regardless of remaining quota
                            // (matches the live executors).
                            let decision = match (mode, cause) {
                                (Mode::HtmLock { .. }, AbortCause::Capacity)
                                | (Mode::Phased { .. }, AbortCause::Capacity) => {
                                    Decision::FallbackSw
                                }
                                _ => decision,
                            };
                            let retry_at = now + scale(self.cost.hw_abort);
                            match decision {
                                Decision::RetryHw => {
                                    th.stats.hw_retries += 1;
                                    th.stats.hw_attempts += 1;
                                    let d = scale(self.cost.hw_txn_cycles(
                                        desc.n_reads as u64,
                                        desc.n_writes as u64,
                                    ));
                                    th.state = TState::HwCheck { start: retry_at };
                                    queue.push(Reverse((retry_at + d, tid)));
                                }
                                Decision::FallbackSw => match mode {
                                    Mode::Phased { sw_quantum } => {
                                        // Flip the whole system to SW.
                                        if !ph_sw {
                                            ph_sw = true;
                                            ph_sw_left = sw_quantum as i64;
                                            ph.acquired_at = retry_at;
                                            ph.last_change = retry_at;
                                            ph.free_at = u64::MAX;
                                        }
                                        ph_inflight += 1;
                                        let d = scale(self.cost.sw_txn_cycles(
                                            desc.n_reads as u64,
                                            desc.n_writes as u64,
                                        ));
                                        th.state = TState::SwCheck { start: retry_at };
                                        queue.push(Reverse((retry_at + d, tid)));
                                    }
                                    Mode::HtmLock { .. } => {
                                        // Take the fallback lock,
                                        // execute directly.
                                        let d = scale(self.cost.locked_txn_cycles(
                                            desc.n_reads as u64,
                                            desc.n_writes as u64,
                                        ) + lock_extra);
                                        let (_, rel) = coarse.acquire(retry_at, d);
                                        for &l in desc.wlines() {
                                            last_write.insert(l, rel);
                                        }
                                        th.stats.lock_commits += 1;
                                        th.cur = None;
                                        th.state = TState::Ready;
                                        queue.push(Reverse((rel, tid)));
                                    }
                                    _ => {
                                        // gbllock enter + STM attempt.
                                        if gbl_count == 0 {
                                            gbl.acquired_at = retry_at;
                                        }
                                        gbl_count += 1;
                                        gbl.last_change = retry_at;
                                        gbl.free_at = u64::MAX; // held until count drains
                                        let d = scale(self.cost.sw_txn_cycles(
                                            desc.n_reads as u64,
                                            desc.n_writes as u64,
                                        ));
                                        th.state = TState::SwCheck { start: retry_at };
                                        queue.push(Reverse((retry_at + d, tid)));
                                    }
                                },
                            }
                        }
                    }
                }

                // -------------------------------------------- SwCheck
                TState::SwCheck { start } => {
                    let desc = th.cur.expect("SwCheck without txn");
                    if mode == Mode::MultiVersion {
                        // Multi-version validation: only a *lower*
                        // transaction in the serialization order
                        // committing to a touched line inside the
                        // window invalidates this execution — higher
                        // writers are invisible to its versioned reads.
                        // The per-line history is scanned (not just the
                        // last writer) so a later higher-index commit
                        // cannot mask a lower-index one.
                        let my_idx = th.mv_idx;
                        let horizon = now.saturating_sub(mv_max_window);
                        let mut hit = |l: &u64| {
                            let Some(commits) = mv_commits.get_mut(l) else {
                                return false;
                            };
                            while matches!(commits.front(), Some(&(t, _)) if t < horizon)
                            {
                                commits.pop_front();
                            }
                            commits
                                .iter()
                                .any(|&(t, i)| t > start && t <= now && i < my_idx)
                        };
                        let mut lower_conflict = desc.wlines().iter().any(&mut hit)
                            || desc.rlines().iter().any(&mut hit);
                        // `--faults validation_fail=P`: force a passing
                        // validation to fail — the re-incarnation below
                        // is the genuine recovery path, priced as such.
                        if !lower_conflict {
                            if let Some(d) = dice.as_mut() {
                                if d.fire(crate::fault::Site::ValidationFail).is_some() {
                                    th.stats.faults_injected += 1;
                                    lower_conflict = true;
                                }
                            }
                        }
                        if lower_conflict {
                            // Re-incarnate: failed validation + ESTIMATE
                            // conversion; repeat offenders model the
                            // dependency path (suspend on the lower
                            // writer's ESTIMATE) with the parked wait on
                            // top. Mirrors the live `validation_aborts`
                            // / `dependencies` counters, folded into
                            // sw_aborts exactly as BatchReport::to_stats
                            // does.
                            th.stats.sw_aborts += 1;
                            if let Some(b) = mv_blocks
                                .iter_mut()
                                .find(|b| b.lo <= my_idx && my_idx < b.hi)
                            {
                                b.execs += 1;
                            }
                            let mut penalty = self.cost.mv_validate_per_read
                                * desc.n_reads as u64
                                + self.cost.mv_abort;
                            if th.mv_retries > 0 {
                                penalty += self.cost.mv_estimate_wait;
                                // `--faults wakeup_drop=P`: the resume
                                // wakeup for this dependency is dropped
                                // and the watchdog's recovery pass
                                // (deadline stall + re-ready + forced
                                // revalidation) brings it back.
                                if let Some(d) = dice.as_mut() {
                                    if d.fire(crate::fault::Site::WakeupDrop).is_some() {
                                        th.stats.faults_injected += 1;
                                        th.stats.watchdog_kicks += 1;
                                        penalty += self.cost.watchdog_recovery;
                                    }
                                }
                            }
                            th.mv_retries += 1;
                            let s2 = now + scale(penalty);
                            let d = scale(self.cost.mv_txn_cycles(
                                desc.n_reads as u64,
                                desc.n_writes as u64,
                            ));
                            th.state = TState::SwCheck { start: s2 };
                            queue.push(Reverse((s2 + d, tid)));
                        } else {
                            // Commit: versions publish without NOrec's
                            // serial write-back (the block write-back is
                            // amortized into mv_txn_cycles).
                            for &l in desc.wlines() {
                                mv_commits.entry(l).or_default().push_back((now, my_idx));
                            }
                            th.stats.sw_commits += 1;
                            if let Some(b) = mv_blocks
                                .iter_mut()
                                .find(|b| b.lo <= my_idx && my_idx < b.hi)
                            {
                                b.commits += 1;
                            }
                            // Complete finished blocks from the head —
                            // in admission order, exactly as the live
                            // pipelined session does — feeding the
                            // controller the block's waste AND its
                            // virtual wall time (the latency-target
                            // signal), then unparking admission.
                            let mut promoted = 0u64;
                            while let Some(front) = mv_blocks.front() {
                                if front.commits < front.hi - front.lo {
                                    break;
                                }
                                let b = mv_blocks.pop_front().unwrap();
                                promoted += 1;
                                let wall = std::time::Duration::from_secs_f64(
                                    self.cost
                                        .to_seconds(now.saturating_sub(b.admitted_at))
                                        .max(0.0),
                                );
                                mv_ctl.observe_block(b.execs, b.commits, wall);
                                for p in mv_parked.drain(..) {
                                    queue.push(Reverse((now, p)));
                                }
                            }
                            th.cur = None;
                            th.state = TState::Ready;
                            // The promoting thread pays the reclamation
                            // pass (retire + epoch advance + limbo
                            // frees) for each block it promoted before
                            // picking up new work — mirrors the live
                            // complete_head path.
                            queue.push(Reverse((
                                now + scale(self.cost.mv_reclaim_per_block) * promoted,
                                tid,
                            )));
                        }
                        continue;
                    }
                    if lines_conflict(&last_write, &desc, start, now) {
                        // Validation failure: revalidate + retry in SW.
                        th.stats.sw_aborts += 1;
                        let revalidate =
                            scale(self.cost.sw_validate_per_read * desc.n_reads as u64);
                        let d = scale(self.cost.sw_txn_cycles(
                            desc.n_reads as u64,
                            desc.n_writes as u64,
                        ));
                        let s2 = now + revalidate;
                        th.state = TState::SwCheck { start: s2 };
                        queue.push(Reverse((s2 + d, tid)));
                    } else {
                        // NOrec write-back is serial: writer commits
                        // serialize through the sequence lock;
                        // read-only commits are free.
                        let commit = if desc.n_wlines > 0 {
                            let c = now.max(seq_free_at + 1);
                            seq_free_at = c + scale(self.cost.sw_commit);
                            c
                        } else {
                            now
                        };
                        for &l in desc.wlines() {
                            last_write.insert(l, commit);
                        }
                        th.stats.sw_commits += 1;
                        match mode {
                            Mode::Hybrid => {
                                gbl_count -= 1;
                                gbl.last_change = commit;
                                if gbl_count == 0 {
                                    gbl.free_at = commit;
                                }
                            }
                            Mode::Phased { .. } => {
                                ph_sw_left -= 1;
                                ph_inflight -= 1;
                                if ph_sw && ph_sw_left <= 0 && ph_inflight == 0 {
                                    // Flip back to HW.
                                    ph_sw = false;
                                    ph.free_at = commit;
                                    ph.last_change = commit;
                                }
                            }
                            _ => {}
                        }
                        th.cur = None;
                        th.state = TState::Ready;
                        queue.push(Reverse((commit, tid)));
                    }
                }
            }
        }

        if mode == Mode::MultiVersion {
            // The stream usually ends mid-block: the live session's
            // complete_head still observes that final partial block, so
            // the model does too (controller parity — same samples,
            // same converged size).
            let end = threads_sim.iter().map(|t| t.clock).max().unwrap_or(0);
            for b in mv_blocks.drain(..) {
                if b.commits > 0 {
                    let wall = std::time::Duration::from_secs_f64(
                        self.cost
                            .to_seconds(end.saturating_sub(b.admitted_at))
                            .max(0.0),
                    );
                    mv_ctl.observe_block(b.execs, b.commits, wall);
                }
            }
            if let Some(th0) = threads_sim.first_mut() {
                // Controller outcome on the report row (thread 0):
                // what `PolicySpec::label` and the figure tables read.
                mv_ctl.apply_to(&mut th0.stats);
            }
        }
        let mut table = StatsTable::new();
        let mut makespan = 0u64;
        for (tid, th) in threads_sim.into_iter().enumerate() {
            makespan = makespan.max(th.clock);
            let mut s = th.stats;
            s.time_ns = (self.cost.to_seconds(th.clock) * 1e9) as u64;
            table.push(tid, s);
        }
        // Same snapshot schema as the live kernels, with *virtual* time
        // in `time_ns` — so simulator sweeps and live runs land in one
        // metrics stream.
        if crate::obs::snapshot::is_enabled() {
            let mut interval = table.total();
            interval.time_ns = (self.cost.to_seconds(makespan) * 1e9) as u64;
            crate::obs::snapshot::record(
                "sim",
                spec.name(),
                &interval,
                &[
                    ("threads", threads.to_string()),
                    ("cycles", makespan.to_string()),
                ],
            );
        }
        SimOutcome {
            cycles: makespan,
            seconds: self.cost.to_seconds(makespan),
            stats: table,
        }
    }

    /// `--policy auto` in virtual time: drain the streams in
    /// round-robin intervals, price each interval under the
    /// controller's current backend through a nested [`Simulator::run`],
    /// and feed interval stats to the *same* `engine::auto` law the
    /// live kernels use — plus two sim-only terms the live controller
    /// cannot afford to measure:
    ///
    /// * every committed switch (and every revert) charges
    ///   [`CostModel::backend_switch`] cycles, so a flappy controller
    ///   pays for its drains in the figure tables;
    /// * a measured-cost revert guard: the first interval after a
    ///   switch re-prices the new backend, and if its cycles-per-commit
    ///   EWMA runs >10% worse than the old backend's, the controller is
    ///   forced back and that target is vetoed until the conflict
    ///   regime changes.
    ///
    /// Interval length starts at a short probe and doubles while the
    /// controller is stable (capped), resetting after any switch — the
    /// same AIMD shape as `batch/adaptive.rs`.
    fn run_auto(
        &self,
        hysteresis: u32,
        threads: usize,
        streams: Vec<Box<dyn Iterator<Item = TxnDesc>>>,
        seed: u64,
    ) -> SimOutcome {
        use crate::engine::auto::{AutoController, Sample};
        use std::collections::VecDeque;

        const PROBE: usize = 256;
        const MAX_INTERVAL: usize = 8192;

        let derate = self.cost.derate(threads);
        let scale = |cycles: u64| -> u64 { (cycles as f64 * derate) as u64 };

        let mut queues: Vec<VecDeque<TxnDesc>> =
            streams.into_iter().map(|s| s.collect()).collect();

        let mut ctl = AutoController::new(hysteresis);
        let mut acc: Vec<TxStats> = vec![TxStats::new(); threads];
        let mut total_cycles: u64 = 0;
        // Cycles-per-commit EWMA per backend name. Keyed lookups only —
        // the map is never iterated, so it cannot perturb determinism.
        let mut cpc: HashMap<&'static str, f64> = HashMap::new();
        // Revert-guard state: a just-committed switch awaiting its
        // first priced interval, and a vetoed (backend, regime) pair.
        let mut judging: Option<(PolicySpec, PolicySpec)> = None;
        let mut veto: Option<(&'static str, u8)> = None;
        let mut interval = PROBE;
        let mut round: u64 = 0;

        while queues.iter().any(|q| !q.is_empty()) {
            let backend = ctl.current();
            let chunk_streams: Vec<Box<dyn Iterator<Item = TxnDesc>>> = queues
                .iter_mut()
                .map(|q| {
                    let n = interval.min(q.len());
                    let chunk: Vec<TxnDesc> = q.drain(..n).collect();
                    Box::new(chunk.into_iter()) as Box<dyn Iterator<Item = TxnDesc>>
                })
                .collect();
            let out = self.run(
                backend,
                threads,
                chunk_streams,
                seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            );
            total_cycles += out.cycles;
            for r in &out.stats.rows {
                if let Some(a) = acc.get_mut(r.thread) {
                    // merge() keeps the max time_ns (parallel workers);
                    // rounds run back to back, so re-sum it.
                    let t = a.time_ns + r.stats.time_ns;
                    a.merge(&r.stats);
                    a.time_ns = t;
                }
            }
            round += 1;

            let itotal = out.stats.total();
            let commits = itotal.total_commits().max(1);
            let this_cpc = out.cycles as f64 / commits as f64;
            let e = cpc.entry(backend.name()).or_insert(this_cpc);
            *e = 0.5 * *e + 0.5 * this_cpc;

            let sample = Sample::from_stats(&itotal);

            // Revert guard: this interval was the new backend's
            // audition — did it actually price better?
            if let Some((old, new)) = judging.take() {
                let new_cpc = cpc.get(new.name()).copied().unwrap_or(0.0);
                let old_cpc = cpc.get(old.name()).copied().unwrap_or(f64::INFINITY);
                if new_cpc > old_cpc * 1.10 {
                    ctl.force_switch(old);
                    total_cycles += scale(self.cost.backend_switch);
                    crate::obs::trace::backend_switch(
                        crate::engine::ordinal(new),
                        crate::engine::ordinal(old),
                    );
                    veto = Some((new.name(), sample.regime()));
                    interval = PROBE;
                    continue;
                }
            }

            // A veto expires when the conflict regime moves on.
            if let Some((_, regime)) = veto {
                if regime != sample.regime() {
                    veto = None;
                }
            }
            let target = AutoController::target_for(&sample);
            let vetoed = matches!(
                (target, veto),
                (Some(t), Some((name, _))) if t.name() == name
            );
            if vetoed {
                interval = (interval * 2).min(MAX_INTERVAL);
                continue;
            }
            if let Some((from, to)) = ctl.observe(&sample) {
                total_cycles += scale(self.cost.backend_switch);
                crate::obs::trace::backend_switch(
                    crate::engine::ordinal(from),
                    crate::engine::ordinal(to),
                );
                judging = Some((from, to));
                interval = PROBE;
            } else {
                interval = (interval * 2).min(MAX_INTERVAL);
            }
        }

        if let Some(a0) = acc.first_mut() {
            // Controller outcome on the report row (thread 0), same
            // slot the batch controller uses for its converged block.
            a0.backend_switches = ctl.switch_count();
        }
        let mut table = StatsTable::new();
        for (tid, s) in acc.into_iter().enumerate() {
            table.push(tid, s);
        }
        if crate::obs::snapshot::is_enabled() {
            let mut total = table.total();
            total.time_ns = (self.cost.to_seconds(total_cycles) * 1e9) as u64;
            crate::obs::snapshot::record(
                "sim",
                "auto",
                &total,
                &[
                    ("threads", threads.to_string()),
                    ("cycles", total_cycles.to_string()),
                ],
            );
        }
        SimOutcome {
            cycles: total_cycles,
            seconds: self.cost.to_seconds(total_cycles),
            stats: table,
        }
    }
}

/// Policy factory: HyTMs use their Figure-1 machines; HTM+lock modes use
/// a fixed quota (the live executor's behaviour); lock/STM need none.
fn make_policy(spec: &PolicySpec) -> Option<Box<dyn RetryPolicy>> {
    match *spec {
        PolicySpec::Rnd { lo, hi } => Some(Box::new(RndPolicy::new(lo, hi))),
        PolicySpec::Fx { n } => Some(Box::new(FxPolicy::new(n))),
        PolicySpec::StAd { n } => Some(Box::new(StAdPolicy::new(n))),
        PolicySpec::DyAd { n } | PolicySpec::DyAdTl2 { n } => {
            Some(Box::new(DyAdPolicy::new(n)))
        }
        PolicySpec::HtmALock { retries } | PolicySpec::HtmSpin { retries } => {
            Some(Box::new(FxPolicy::new(retries)))
        }
        PolicySpec::Hle => Some(Box::new(FxPolicy::new(0))),
        PolicySpec::PhTm { retries, .. } => Some(Box::new(FxPolicy::new(retries))),
        // Unreachable through Simulator::run (Auto is intercepted into
        // run_auto), but keep the factory total: the controller's
        // hybrid regime resolves to DyAd.
        PolicySpec::Auto { .. } => Some(Box::new(DyAdPolicy::new(DyAdPolicy::DEFAULT_N))),
        PolicySpec::CoarseLock
        | PolicySpec::StmNorec
        | PolicySpec::StmTl2
        | PolicySpec::Batch { .. }
        | PolicySpec::BatchAdaptive { .. } => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workload::SimWorkload;

    fn run_gen(spec: PolicySpec, threads: usize, scale: u32) -> SimOutcome {
        let cost = CostModel::broadwell();
        let w = SimWorkload::new(scale);
        let sim = Simulator::new(cost.clone());
        let streams: Vec<Box<dyn Iterator<Item = TxnDesc>>> = (0..threads)
            .map(|tid| {
                Box::new(w.generation_stream(&cost, threads, tid))
                    as Box<dyn Iterator<Item = TxnDesc>>
            })
            .collect();
        sim.run(spec, threads, streams, 7)
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_gen(PolicySpec::DyAd { n: 43 }, 4, 10);
        let b = run_gen(PolicySpec::DyAd { n: 43 }, 4, 10);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(
            a.stats.total().hw_commits,
            b.stats.total().hw_commits
        );
    }

    #[test]
    fn auto_is_deterministic_and_commits_everything() {
        let a = run_gen(PolicySpec::Auto { hysteresis: 2 }, 4, 10);
        let b = run_gen(PolicySpec::Auto { hysteresis: 2 }, 4, 10);
        assert_eq!(a.cycles, b.cycles, "same seed, same switch trajectory");
        let t = a.stats.total();
        assert_eq!(t.total_commits(), SimWorkload::new(10).edges());
        assert_eq!(
            t.backend_switches,
            b.stats.total().backend_switches,
            "decision log must replay identically"
        );
    }

    #[test]
    fn all_transactions_commit_somewhere() {
        for spec in [
            PolicySpec::CoarseLock,
            PolicySpec::StmNorec,
            PolicySpec::HtmSpin { retries: 8 },
            PolicySpec::Hle,
            PolicySpec::DyAd { n: 43 },
            PolicySpec::Rnd { lo: 1, hi: 50 },
            PolicySpec::Batch { block: 2048 },
            PolicySpec::batch_adaptive(),
            PolicySpec::Auto { hysteresis: 2 },
        ] {
            let out = run_gen(spec, 4, 10);
            let m = SimWorkload::new(10).edges();
            assert_eq!(
                out.stats.total().total_commits(),
                m,
                "{}",
                spec.name()
            );
        }
    }

    #[test]
    fn parallelism_speeds_up_tm_policies() {
        let t1 = run_gen(PolicySpec::DyAd { n: 43 }, 1, 12).seconds;
        let t8 = run_gen(PolicySpec::DyAd { n: 43 }, 8, 12).seconds;
        assert!(
            t8 < t1 / 4.0,
            "8 threads should be >4x faster: {t1} vs {t8}"
        );
    }

    #[test]
    fn lock_scales_worse_than_dyad() {
        let lock = run_gen(PolicySpec::CoarseLock, 14, 12).seconds;
        let dyad = run_gen(PolicySpec::DyAd { n: 43 }, 14, 12).seconds;
        assert!(dyad < lock, "DyAd {dyad} must beat lock {lock} at 14 thr");
    }

    #[test]
    fn hyperthread_derating_bends_the_curve() {
        let t14 = run_gen(PolicySpec::DyAd { n: 43 }, 14, 12).seconds;
        let t28 = run_gen(PolicySpec::DyAd { n: 43 }, 28, 12).seconds;
        // Speedup from 14 -> 28 threads must be well below 2x.
        assert!(t28 > t14 * 0.55, "14thr {t14}, 28thr {t28}");
    }

    #[test]
    fn stm_slower_than_htm_at_low_threads() {
        let stm = run_gen(PolicySpec::StmNorec, 4, 12).seconds;
        let dyad = run_gen(PolicySpec::DyAd { n: 43 }, 4, 12).seconds;
        assert!(dyad < stm);
    }

    #[test]
    fn batch_mode_is_multiversion_not_stm() {
        let batch = run_gen(PolicySpec::Batch { block: 2048 }, 4, 10);
        let stm = run_gen(PolicySpec::StmNorec, 4, 10);
        let m = SimWorkload::new(10).edges();
        let t = batch.stats.total();
        assert_eq!(t.total_commits(), m);
        assert_eq!(t.sw_commits, m, "MV commits are software commits");
        assert_eq!(t.hw_attempts, 0, "MV execution never touches the HTM");
        assert_ne!(
            batch.cycles, stm.cycles,
            "batch must not alias the plain-STM cost model"
        );
    }

    #[test]
    fn adaptive_batch_is_deterministic_and_reports_controller_state() {
        let a = run_gen(PolicySpec::batch_adaptive(), 4, 10);
        let b = run_gen(PolicySpec::batch_adaptive(), 4, 10);
        assert_eq!(a.cycles, b.cycles, "same seed, same trajectory");
        let t = a.stats.total();
        assert_eq!(t.total_commits(), SimWorkload::new(10).edges());
        assert!(t.final_block > 0, "controller state must reach the stats");
    }

    #[test]
    fn adaptive_grows_blocks_on_a_clean_single_thread() {
        // One thread = serial admission = zero conflict: every block is
        // clean, so the additive-increase law must raise the block size
        // above its starting point.
        let out = run_gen(PolicySpec::batch_adaptive(), 1, 12);
        let t = out.stats.total();
        assert_eq!(t.sw_aborts, 0, "serial admission cannot conflict");
        assert_eq!(t.total_commits(), SimWorkload::new(12).edges());
        assert!(
            t.final_block as usize > BlockSizeController::ADAPTIVE_INITIAL,
            "clean blocks must grow: final {}",
            t.final_block
        );
        assert!(t.block_grows > 0);
    }

    #[test]
    fn overlapped_drain_admits_lookahead_blocks() {
        // Small blocks at 4 threads: the model must overlap block N+1's
        // admissions with block N's drain (the run_pipelined analogue)
        // and report them, while committing every transaction exactly
        // once.
        let small = run_gen(PolicySpec::Batch { block: 8 }, 4, 10);
        let large = run_gen(PolicySpec::Batch { block: 2048 }, 4, 10);
        assert_eq!(
            small.stats.total().total_commits(),
            large.stats.total().total_commits()
        );
        assert!(
            small.stats.total().overlapped_txns > 0,
            "8-txn blocks at 4 threads must overlap adjacent blocks"
        );
        // One block of lookahead still bounds the in-flight window, so
        // tiny blocks cannot meaningfully OUTRUN large ones.
        assert!(
            small.cycles * 10 >= large.cycles * 9,
            "8-txn blocks ({}) should not materially outrun 2048-txn blocks ({})",
            small.cycles,
            large.cycles
        );
    }

    #[test]
    fn single_thread_never_overlaps_blocks() {
        // Serial admission commits each txn before the next admission:
        // the head block is always complete before the lookahead would
        // start, so no overlap is ever recorded.
        let out = run_gen(PolicySpec::Batch { block: 64 }, 1, 10);
        assert_eq!(out.stats.total().overlapped_txns, 0);
    }

    #[test]
    fn window_one_models_a_barrier_stream() {
        // W=1 structurally removes the lookahead: mv_blocks can never
        // hold a second block, so overlap is impossible — and every
        // transaction still commits exactly once.
        let spec = PolicySpec::BatchAdaptive {
            latency_ms: 0,
            window: 1,
        };
        let out = run_gen(spec, 4, 10);
        let t = out.stats.total();
        assert_eq!(t.total_commits(), SimWorkload::new(10).edges());
        assert_eq!(t.overlapped_txns, 0, "W=1 admits no lookahead block");
        assert_eq!(t.final_window, 1, "controller state reaches the stats");
    }

    #[test]
    fn deep_window_is_deterministic_and_commits_everything() {
        let spec = PolicySpec::BatchAdaptive {
            latency_ms: 0,
            window: 4,
        };
        let a = run_gen(spec, 4, 10);
        let b = run_gen(spec, 4, 10);
        assert_eq!(a.cycles, b.cycles, "same seed, same trajectory");
        let t = a.stats.total();
        assert_eq!(t.total_commits(), SimWorkload::new(10).edges());
        assert!(
            (1..=4).contains(&(t.final_window as usize)),
            "converged window {} outside [floor, W]",
            t.final_window
        );
    }

    #[test]
    fn multiversion_single_thread_never_aborts() {
        // Serial admission: every window closes before the next opens,
        // so no lower-index commit can land inside it.
        let out = run_gen(PolicySpec::Batch { block: 1024 }, 1, 10);
        let t = out.stats.total();
        assert_eq!(t.sw_commits, SimWorkload::new(10).edges());
        assert_eq!(t.sw_aborts, 0, "serial admission cannot conflict");
    }

    #[test]
    fn multiversion_beats_norec_when_writeback_serializes() {
        // Zero non-critical work: back-to-back critical sections, where
        // NOrec pays whole-window conflicts plus the serial write-back
        // for every writer commit. Multi-version execution only
        // re-incarnates against *lower*-index active transactions (a
        // bounded set), so it must finish first.
        let cost = CostModel {
            edge_gen_work: 0,
            ..CostModel::broadwell()
        };
        let run = |spec| {
            let w = SimWorkload::new(12);
            let sim = Simulator::new(cost.clone());
            let streams: Vec<Box<dyn Iterator<Item = TxnDesc>>> = (0..14)
                .map(|tid| {
                    Box::new(w.generation_stream(&cost, 14, tid))
                        as Box<dyn Iterator<Item = TxnDesc>>
                })
                .collect();
            sim.run(spec, 14, streams, 3)
        };
        let stm = run(PolicySpec::StmNorec);
        let mv = run(PolicySpec::Batch { block: 2048 });
        assert_eq!(
            mv.stats.total().sw_commits,
            SimWorkload::new(12).edges(),
            "every transaction commits under MV"
        );
        assert!(
            mv.stats.total().sw_aborts > 0,
            "hub conflicts must force re-incarnations"
        );
        assert!(
            mv.cycles < stm.cycles,
            "multi-version {} must beat serial-write-back NOrec {}",
            mv.cycles,
            stm.cycles
        );
    }

    #[test]
    fn capacity_fault_model_drives_fallbacks() {
        let cost = CostModel {
            capacity_prob: 0.05,
            ..CostModel::broadwell()
        };
        let w = SimWorkload::new(10);
        let sim = Simulator::new(cost.clone());
        let streams: Vec<Box<dyn Iterator<Item = TxnDesc>>> = (0..4)
            .map(|tid| {
                Box::new(w.generation_stream(&cost, 4, tid))
                    as Box<dyn Iterator<Item = TxnDesc>>
            })
            .collect();
        let out = sim.run(PolicySpec::DyAd { n: 43 }, 4, streams, 3);
        let t = out.stats.total();
        assert!(t.aborts_of(AbortCause::Capacity) > 0);
        assert!(t.sw_commits > 0);
        // DyAd: one retry per capacity abort, so retries stay close to
        // the capacity-abort count (conflicts add a few).
        assert!(t.hw_retries < 3 * t.aborts_of(AbortCause::Capacity) + 100);
    }

    #[test]
    fn fx_burns_far_more_retries_than_dyad_under_capacity() {
        let cost = CostModel {
            capacity_prob: 0.02,
            ..CostModel::broadwell()
        };
        let run = |spec| {
            let w = SimWorkload::new(11);
            let sim = Simulator::new(cost.clone());
            let streams: Vec<Box<dyn Iterator<Item = TxnDesc>>> = (0..8)
                .map(|tid| {
                    Box::new(w.generation_stream(&cost, 8, tid))
                        as Box<dyn Iterator<Item = TxnDesc>>
                })
                .collect();
            sim.run(spec, 8, streams, 3).stats.total().hw_retries
        };
        let fx = run(PolicySpec::Fx { n: 43 });
        let dyad = run(PolicySpec::DyAd { n: 43 });
        assert!(
            fx > 5 * dyad,
            "Fig 4b shape: Fx retries {fx} vs DyAd {dyad}"
        );
    }
}
