//! Live trace capture (DESIGN.md S12): record the cache-line footprint
//! of every transaction from a *real* run, and hand it to the simulator.
//!
//! This is the bridge that keeps the simulator honest: the DES normally
//! runs on synthetic descriptor streams (`sim::workload`) that recompute
//! the workload's addresses; `TraceRecorder` instead wraps the live
//! `DirectAccess` path and logs exactly which lines each critical
//! section touched. Tests cross-validate the two (same hot-line
//! concentration, same footprint histogram), and `trace_stream` lets a
//! captured trace drive the simulator directly.

use crate::graph::EdgeTuple;
use crate::graph::Graph;
use crate::mem::{Addr, TxHeap};
use crate::tm::access::{TxAccess, TxResult};

use super::cost::CostModel;
use super::workload::{TxnDesc, MAX_WLINES};

/// One recorded transaction: distinct lines read / written.
#[derive(Clone, Debug, Default)]
pub struct TraceTxn {
    pub rlines: Vec<u64>,
    pub wlines: Vec<u64>,
    pub n_reads: u32,
    pub n_writes: u32,
}

/// A captured trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub txns: Vec<TraceTxn>,
}

impl Trace {
    /// Distinct written lines across the whole trace, with counts,
    /// hottest first.
    pub fn write_line_histogram(&self) -> Vec<(u64, usize)> {
        let mut counts = std::collections::HashMap::new();
        for t in &self.txns {
            for &l in &t.wlines {
                *counts.entry(l).or_insert(0usize) += 1;
            }
        }
        let mut v: Vec<(u64, usize)> = counts.into_iter().collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v
    }

    /// Fraction of transactions whose hottest write line is among the
    /// top-`k` hottest lines overall (hub concentration).
    pub fn hub_concentration(&self, k: usize) -> f64 {
        let hist = self.write_line_histogram();
        let top: std::collections::HashSet<u64> =
            hist.iter().take(k).map(|&(l, _)| l).collect();
        if self.txns.is_empty() {
            return 0.0;
        }
        let hits = self
            .txns
            .iter()
            .filter(|t| t.wlines.iter().any(|l| top.contains(l)))
            .count();
        hits as f64 / self.txns.len() as f64
    }
}

/// A `TxAccess` that executes directly AND records the line footprint.
pub struct TraceRecorder<'h> {
    heap: &'h TxHeap,
    pub current: TraceTxn,
}

impl<'h> TraceRecorder<'h> {
    pub fn new(heap: &'h TxHeap) -> Self {
        Self {
            heap,
            current: TraceTxn::default(),
        }
    }

    /// Finish the current transaction, returning its record.
    pub fn take(&mut self) -> TraceTxn {
        // Reads that were also written count as writes only.
        let w = &self.current.wlines;
        self.current.rlines.retain(|l| !w.contains(l));
        std::mem::take(&mut self.current)
    }
}

impl TxAccess for TraceRecorder<'_> {
    fn read(&mut self, addr: Addr) -> TxResult<u64> {
        let line = TxHeap::line_of(addr).0;
        if !self.current.rlines.contains(&line) {
            self.current.rlines.push(line);
        }
        self.current.n_reads += 1;
        Ok(self.heap.load_acquire(addr))
    }

    fn write(&mut self, addr: Addr, val: u64) -> TxResult<()> {
        let line = TxHeap::line_of(addr).0;
        if !self.current.wlines.contains(&line) {
            self.current.wlines.push(line);
        }
        self.current.n_writes += 1;
        self.heap.store_release(addr, val);
        Ok(())
    }
}

/// Capture the generation kernel's transaction trace, single-threaded.
/// The graph is really built (the recorder executes as it records).
pub fn capture_generation(g: &Graph, tuples: &[EdgeTuple]) -> Trace {
    let mut rec = TraceRecorder::new(&g.heap);
    let mut trace = Trace::default();
    let batch = g.cfg.batch.max(1);
    // Mirror generation::insert_slice's structure with direct recording.
    let mut pool_next = 0usize;
    let mut pool_left = 0usize;
    let mut consumed = 0usize;
    for chunk in tuples.chunks(batch) {
        if pool_left < chunk.len() {
            let remaining = tuples.len() - consumed;
            let aligned =
                (super::super::graph::layout::POOL_CHUNK_CELLS / batch).max(1) * batch;
            let take = aligned.min(remaining).max(chunk.len());
            pool_next = g.reserve_cells(take);
            pool_left = take;
        }
        let first_cell = pool_next;
        pool_next += chunk.len();
        pool_left -= chunk.len();

        for (k, e) in chunk.iter().enumerate() {
            let cell = g.cell(first_cell + k);
            let head = g.head(e.src);
            let old = rec.read(head).unwrap();
            rec.write(cell + Graph::CELL_DST, e.dst as u64).unwrap();
            rec.write(cell + Graph::CELL_WEIGHT, e.weight as u64).unwrap();
            rec.write(cell + Graph::CELL_NEXT, old).unwrap();
            rec.write(cell + Graph::CELL_ID, (first_cell + k) as u64 + 1)
                .unwrap();
            rec.write(head, cell as u64).unwrap();
            let deg = rec.read(g.degree(e.src)).unwrap();
            rec.write(g.degree(e.src), deg + 1).unwrap();
        }
        consumed += chunk.len();
        trace.txns.push(rec.take());
    }
    trace
}

/// Drive the simulator from a captured trace: each recorded transaction
/// becomes a descriptor (cell lines — thread-private in the live run —
/// are excluded from conflict tracking exactly as the synthetic streams
/// exclude them, by keeping only head/degree-region lines).
pub fn trace_stream<'a>(
    trace: &'a Trace,
    g: &Graph,
    cost: &CostModel,
) -> impl Iterator<Item = TxnDesc> + 'a {
    let shared_end = TxHeap::line_of(g.cells_base).0; // heads+degrees
    let edge_work = cost.edge_gen_work;
    trace.txns.iter().map(move |t| {
        let mut d = TxnDesc {
            work: edge_work * (t.n_reads as u64 / 2).max(1),
            wlines: [0; MAX_WLINES],
            n_wlines: 0,
            rlines: [0; 2],
            n_rlines: 0,
            n_reads: t.n_reads,
            n_writes: t.n_writes,
            footprint_lines: (t.wlines.len() + t.rlines.len()) as u16,
        };
        for &l in t.wlines.iter().filter(|&&l| l < shared_end) {
            if (d.n_wlines as usize) < MAX_WLINES {
                d.wlines[d.n_wlines as usize] = l;
                d.n_wlines += 1;
            }
        }
        d
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layout::Ssca2Config;
    use crate::graph::{rmat, verify};
    use crate::hytm::PolicySpec;
    use crate::sim::{SimWorkload, Simulator};

    fn capture(scale: u32) -> (Graph, Vec<EdgeTuple>, Trace) {
        let cfg = Ssca2Config::new(scale);
        let g = Graph::alloc(cfg);
        let tuples = rmat::generate(cfg.seed, cfg.scale, cfg.edge_factor);
        let trace = capture_generation(&g, &tuples);
        (g, tuples, trace)
    }

    #[test]
    fn recorder_builds_a_correct_graph() {
        let (g, tuples, trace) = capture(7);
        // The recorder executes for real: the graph must verify.
        verify::check_graph(&g, &tuples).unwrap();
        assert_eq!(trace.txns.len(), tuples.len());
    }

    #[test]
    fn per_txn_footprint_matches_the_kernel_shape() {
        let (_, _, trace) = capture(7);
        for t in &trace.txns {
            assert_eq!(t.n_reads, 2);
            assert_eq!(t.n_writes, 6);
            // head + degree + 1-2 cell lines.
            assert!(t.wlines.len() >= 3 && t.wlines.len() <= 4, "{t:?}");
        }
    }

    #[test]
    fn live_trace_and_synthetic_stream_agree_on_hub_concentration() {
        // The validation that keeps the DES honest: the fraction of
        // transactions touching the top-8 hottest lines must match
        // between the real executed trace and the synthetic stream the
        // figure sweeps use.
        let scale = 10;
        let (g, _, trace) = capture(scale);
        // Restrict the live trace to shared (head/degree) lines so both
        // sides measure the same contention surface.
        let shared_end = TxHeap::line_of(g.cells_base).0;
        let live_trace = Trace {
            txns: trace
                .txns
                .iter()
                .map(|t| TraceTxn {
                    wlines: t
                        .wlines
                        .iter()
                        .copied()
                        .filter(|&l| l < shared_end)
                        .collect(),
                    ..TraceTxn::default()
                })
                .collect(),
        };
        let live = live_trace.hub_concentration(8);

        // Build a like-for-like Trace from the synthetic stream (shared
        // write lines only, as the descriptors track) and reuse the
        // same concentration metric. The live side must be filtered to
        // shared lines too (cells are thread-private).
        let cost = CostModel::broadwell();
        let w = SimWorkload::new(scale);
        let synth_trace = Trace {
            txns: w
                .generation_stream(&cost, 1, 0)
                .map(|d| TraceTxn {
                    wlines: d.wlines().to_vec(),
                    ..TraceTxn::default()
                })
                .collect(),
        };
        let synth = synth_trace.hub_concentration(8);

        assert!(
            (live - synth).abs() < 0.1,
            "hub concentration diverges: live {live:.3} vs synthetic {synth:.3}"
        );
        // And both are heavily hub-concentrated (far above the uniform
        // baseline of 8 / (n/8) lines).
        assert!(live > 0.1 && synth > 0.1);
    }

    #[test]
    fn captured_trace_drives_the_simulator() {
        let (g, _, trace) = capture(8);
        let cost = CostModel::broadwell();
        let sim = Simulator::new(cost.clone());
        let stream = trace_stream(&trace, &g, &cost);
        let out = sim.run(
            PolicySpec::DyAd { n: 43 },
            1,
            vec![Box::new(stream.collect::<Vec<_>>().into_iter())],
            7,
        );
        assert_eq!(
            out.stats.total().total_commits(),
            trace.txns.len() as u64
        );
        assert!(out.seconds > 0.0);
    }
}
