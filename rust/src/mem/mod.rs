//! Shared transactional memory substrate (DESIGN.md S1).
//!
//! All transactional state — the SSCA-2 graph, its allocator cursors,
//! result lists — lives in a single word-addressable [`TxHeap`], so that
//! every synchronization policy (coarse lock, STM, software HTM, the
//! HyTMs) sees the *same* memory and conflicts through the *same*
//! addresses. Cache-line mapping (8 words = 64 B per line) gives the
//! software HTM its conflict/capacity granularity, mirroring Intel TSX
//! tracking read/write sets in L1 at line granularity.

pub mod epoch;
pub mod heap;
pub mod layout;

pub use heap::{Addr, TxHeap, WORDS_PER_LINE};
pub use layout::Line;
