//! Cache-line identity and padded atomics.

/// A cache-line id within the [`super::TxHeap`] (line = addr / 8).
/// This is the granularity at which the software HTM tracks read/write
/// sets and detects conflicts — mirroring Intel TSX, whose transactional
/// buffers live in the L1 data cache at 64-byte granularity.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Line(pub u64);

impl std::fmt::Debug for Line {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}", self.0)
    }
}

impl Line {
    /// The L1 set this line maps to under a `sets`-set cache (power of 2).
    #[inline]
    pub fn set_index(self, sets: usize) -> usize {
        (self.0 as usize) & (sets - 1)
    }
}

/// A cache-line padded atomic u64, to keep the global lock and the
/// sequence lock off each other's lines.
#[repr(align(64))]
pub struct PaddedAtomicU64(pub std::sync::atomic::AtomicU64);

impl PaddedAtomicU64 {
    pub const fn new(v: u64) -> Self {
        Self(std::sync::atomic::AtomicU64::new(v))
    }
}

impl std::ops::Deref for PaddedAtomicU64 {
    type Target = std::sync::atomic::AtomicU64;
    fn deref(&self) -> &Self::Target {
        &self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_index_masks_low_bits() {
        assert_eq!(Line(0).set_index(64), 0);
        assert_eq!(Line(63).set_index(64), 63);
        assert_eq!(Line(64).set_index(64), 0);
        assert_eq!(Line(65).set_index(64), 1);
    }

    #[test]
    fn padded_is_64_aligned() {
        assert_eq!(std::mem::align_of::<PaddedAtomicU64>(), 64);
        assert_eq!(std::mem::size_of::<PaddedAtomicU64>(), 64);
    }
}
