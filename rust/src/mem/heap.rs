//! The word-addressable shared heap.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::layout::Line;

/// A heap address: an index into the word array. Word 0 is reserved as a
/// null sentinel (valid allocations start at address 8 — one full line —
/// so `0` can mean "no cell" in linked structures).
pub type Addr = usize;

/// Words per 64-byte cache line.
pub const WORDS_PER_LINE: usize = 8;

/// Word-addressable shared heap with a bump allocator.
///
/// Plain (non-transactional) accessors use `Relaxed` atomics: they are
/// for single-threaded setup and post-run verification. All concurrent
/// access goes through the policy executors, which layer speculation or
/// locking on top.
pub struct TxHeap {
    words: Box<[AtomicU64]>,
    next: AtomicUsize,
}

impl TxHeap {
    /// Allocate a heap of `words` u64 cells (rounded up to a whole line).
    pub fn new(words: usize) -> Self {
        let words = words.next_multiple_of(WORDS_PER_LINE).max(WORDS_PER_LINE);
        let mut v = Vec::with_capacity(words);
        v.resize_with(words, || AtomicU64::new(0));
        Self {
            words: v.into_boxed_slice(),
            // Line 0 reserved: address 0 is the null sentinel.
            next: AtomicUsize::new(WORDS_PER_LINE),
        }
    }

    /// Total capacity in words.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.words.len()
    }

    /// Words allocated so far (including the reserved first line).
    #[inline]
    pub fn allocated(&self) -> usize {
        self.next.load(Ordering::Relaxed)
    }

    /// The cache line containing `addr`.
    #[inline]
    pub fn line_of(addr: Addr) -> Line {
        Line((addr / WORDS_PER_LINE) as u64)
    }

    /// Bump-allocate `n` words; returns the base address.
    /// Panics on exhaustion — capacity is sized by the workload up front.
    pub fn alloc(&self, n: usize) -> Addr {
        let base = self.next.fetch_add(n, Ordering::Relaxed);
        assert!(
            base + n <= self.words.len(),
            "TxHeap exhausted: {} + {} > {}",
            base,
            n,
            self.words.len()
        );
        base
    }

    /// Line-aligned allocation (for structures whose conflict footprint
    /// must not false-share with neighbours).
    pub fn alloc_lines(&self, lines: usize) -> Addr {
        loop {
            let cur = self.next.load(Ordering::Relaxed);
            let base = cur.next_multiple_of(WORDS_PER_LINE);
            let end = base + lines * WORDS_PER_LINE;
            assert!(end <= self.words.len(), "TxHeap exhausted (aligned)");
            if self
                .next
                .compare_exchange(cur, end, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return base;
            }
        }
    }

    /// Non-transactional read (setup/verification only).
    #[inline]
    pub fn load(&self, addr: Addr) -> u64 {
        self.words[addr].load(Ordering::Relaxed)
    }

    /// Non-transactional write (setup/verification only).
    #[inline]
    pub fn store(&self, addr: Addr, val: u64) {
        self.words[addr].store(val, Ordering::Relaxed);
    }

    /// Acquire-ordered read — used by speculation engines that pair it
    /// with version validation.
    #[inline]
    pub fn load_acquire(&self, addr: Addr) -> u64 {
        self.words[addr].load(Ordering::Acquire)
    }

    /// Release-ordered write — used by commit write-back.
    #[inline]
    pub fn store_release(&self, addr: Addr, val: u64) {
        self.words[addr].store(val, Ordering::Release);
    }

    /// Atomic fetch-add on a heap word (used by non-speculative paths,
    /// e.g. per-thread pool refills).
    #[inline]
    pub fn fetch_add(&self, addr: Addr, delta: u64) -> u64 {
        self.words[addr].fetch_add(delta, Ordering::AcqRel)
    }
}

impl std::fmt::Debug for TxHeap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TxHeap")
            .field("capacity", &self.capacity())
            .field("allocated", &self.allocated())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::qcheck::qcheck;
    use std::sync::Arc;

    #[test]
    fn rounds_capacity_to_lines() {
        let h = TxHeap::new(1);
        assert_eq!(h.capacity(), WORDS_PER_LINE);
    }

    #[test]
    fn alloc_reserves_null_line() {
        let h = TxHeap::new(64);
        let a = h.alloc(4);
        assert!(a >= WORDS_PER_LINE, "address 0 must stay null");
    }

    #[test]
    fn alloc_monotonic_disjoint() {
        let h = TxHeap::new(1024);
        let a = h.alloc(10);
        let b = h.alloc(10);
        assert!(b >= a + 10);
    }

    #[test]
    #[should_panic(expected = "TxHeap exhausted")]
    fn alloc_panics_on_exhaustion() {
        let h = TxHeap::new(16);
        h.alloc(1000);
    }

    #[test]
    fn aligned_alloc_is_line_aligned() {
        let h = TxHeap::new(1024);
        h.alloc(3); // misalign the cursor
        let a = h.alloc_lines(2);
        assert_eq!(a % WORDS_PER_LINE, 0);
    }

    #[test]
    fn line_mapping() {
        assert_eq!(TxHeap::line_of(0), Line(0));
        assert_eq!(TxHeap::line_of(7), Line(0));
        assert_eq!(TxHeap::line_of(8), Line(1));
        assert_eq!(TxHeap::line_of(17), Line(2));
    }

    #[test]
    fn load_store_roundtrip() {
        let h = TxHeap::new(64);
        let a = h.alloc(2);
        h.store(a, 0xDEAD_BEEF);
        h.store(a + 1, 42);
        assert_eq!(h.load(a), 0xDEAD_BEEF);
        assert_eq!(h.load(a + 1), 42);
    }

    #[test]
    fn concurrent_alloc_yields_disjoint_regions() {
        let h = Arc::new(TxHeap::new(64 * 1024));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let h = Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                (0..100).map(|_| h.alloc(16)).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<Addr> = handles
            .into_iter()
            .flat_map(|j| j.join().unwrap())
            .collect();
        all.sort_unstable();
        for pair in all.windows(2) {
            assert!(pair[1] - pair[0] >= 16, "overlapping allocations");
        }
    }

    #[test]
    fn prop_line_of_consistent_with_division() {
        qcheck(
            "line_of == addr/8",
            500,
            |r| r.below(1 << 40) as usize,
            |&a| TxHeap::line_of(a).0 == (a / WORDS_PER_LINE) as u64,
        );
    }
}
