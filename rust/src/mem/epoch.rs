//! Epoch-based reclamation for the batch backend's lock-free
//! multi-version store.
//!
//! The store publishes immutable `RecordedSets` nodes through raw
//! `AtomicPtr` handoffs: a validator may still be walking a node while
//! a re-executing incarnation swaps in its successor. Before this
//! module the superseded node simply stayed alive on a `prev` chain
//! until the whole store dropped — safe, but unbounded for a long
//! pipelined stream. [`EpochGc`] bounds it with the classic
//! epoch-based reclamation protocol:
//!
//! * a **global epoch** counter (starting at 1; slot value 0 means
//!   "unpinned") advanced at block promotion — under the W-deep
//!   window, promotion is a natural, strictly-ordered quiescence
//!   boundary: once block N is promoted and popped, no validator can
//!   acquire a fresh reference into its superseded sets;
//! * **per-worker pin slots**: a worker [`pin`](EpochGc::pin)s the
//!   current epoch before touching any store pointer and releases it
//!   when the guard drops. The pin loop re-checks the global after
//!   publishing the slot, so the reclamation horizon never misses a
//!   slot published against a stale epoch;
//! * **per-epoch limbo bins**: [`retire`](EpochGc::retire) moves an
//!   exclusively-owned garbage handle (its `Drop` frees the memory)
//!   into the bin tagged with the current epoch;
//!   [`try_reclaim`](EpochGc::try_reclaim) frees every bin whose epoch
//!   is strictly below the minimum pinned epoch — no live worker can
//!   still hold a pointer retired that long ago.
//!
//! The safety argument is the standard one: a reader pins epoch `E`
//! *before* loading a shared pointer; any retire of that pointer's
//! target happens after the swap that removed it, so its bin is tagged
//! `>= E`; a bin is only freed when every pinned slot is `> `its tag.
//! Hence no freed object is reachable from a pinned reader.
//!
//! Reclamation can be constructed disabled
//! ([`EpochGc::with_reclaim`]) — retires still count into the limbo
//! (so the bench A/B can price the leak) but nothing is freed before
//! the `EpochGc` itself drops.
//!
//! # Reader pins and quiescent sessions
//!
//! The continuous-serving plane (`crate::serve`) adds two demands the
//! original batch-run shape never made:
//!
//! * **Transient reader pins** ([`pin_reader`](EpochGc::pin_reader)):
//!   snapshot queries traverse store pointers from threads that are
//!   not pool workers and have no slot index. A separate fixed pool of
//!   CAS-acquired reader slots participates in the reclamation horizon
//!   exactly like worker slots. A reader pin is held only for the
//!   duration of one pointer traversal (microseconds) — the *snapshot
//!   horizon* itself is pinned by version-visibility bookkeeping in
//!   `serve::snapshot`, not by an epoch pin, so an hours-old snapshot
//!   never stalls reclamation of younger garbage.
//! * **Quiescent flush**
//!   ([`quiescent_flush`](EpochGc::quiescent_flush)): [`flush`]
//!   (EpochGc::flush) assumes the pool has joined (nothing pinned), so
//!   a session that idles without exiting would strand the final limbo
//!   bins forever — promotion, the normal epoch boundary, stops
//!   happening when the stream pauses. `quiescent_flush` is safe to
//!   call from a still-pinned worker: it advances and reclaims only up
//!   to the live horizon, and skips the advance entirely when limbo is
//!   already empty so an idle loop cannot spin the epoch counter.

use std::any::Any;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering::SeqCst};
use std::sync::Mutex;

/// One worker's pinned-epoch slot (0 = unpinned), padded to a cache
/// line so per-iteration pin/unpin stores never false-share.
struct Slot {
    epoch: AtomicU64,
    _pad: [u64; 7],
}

/// A batch of garbage retired under one epoch. Dropping the bin runs
/// the retired handles' destructors, which is what frees the memory.
struct Bin {
    epoch: u64,
    items: Vec<Box<dyn Any + Send>>,
    cells: u64,
    bytes: u64,
}

/// Counter snapshot of one reclamation domain.
#[derive(Clone, Copy, Debug, Default)]
pub struct GcCounters {
    /// Cells (recorded read/write entries) retired into limbo.
    pub retired_cells: u64,
    /// Approximate heap bytes retired into limbo.
    pub retired_bytes: u64,
    /// Retired cells actually freed.
    pub reclaimed_cells: u64,
    /// Retired bytes actually freed.
    pub reclaimed_bytes: u64,
    /// Peak of `retired - reclaimed` cells — the bounded-memory
    /// metric: a plateau under reclamation, the whole retired total
    /// with reclamation off.
    pub live_peak_cells: u64,
    /// Peak arena bytes observed via [`EpochGc::note_arena_bytes`].
    pub arena_peak_bytes: u64,
}

/// Size of the transient reader-pin slot pool. Reader pins are held
/// for one pointer traversal, so a small fixed pool suffices; an
/// acquirer finding all slots busy spins until one frees.
const READER_SLOTS: usize = 32;

/// One pipelined session's epoch-reclamation domain.
pub struct EpochGc {
    global: AtomicU64,
    slots: Box<[Slot]>,
    reader_slots: Box<[Slot]>,
    limbo: Mutex<VecDeque<Bin>>,
    enabled: bool,
    retired_cells: AtomicU64,
    retired_bytes: AtomicU64,
    reclaimed_cells: AtomicU64,
    reclaimed_bytes: AtomicU64,
    live_peak_cells: AtomicU64,
    arena_peak_bytes: AtomicU64,
}

/// RAII pin of one worker's epoch slot; dropping it unpins.
pub struct EpochGuard<'g> {
    slot: &'g Slot,
}

impl Drop for EpochGuard<'_> {
    fn drop(&mut self) {
        self.slot.epoch.store(0, SeqCst);
    }
}

/// RAII pin of one transient reader slot (see
/// [`EpochGc::pin_reader`]); dropping it releases the slot back to
/// the pool.
pub struct ReaderPin<'g> {
    slot: &'g Slot,
}

impl Drop for ReaderPin<'_> {
    fn drop(&mut self) {
        self.slot.epoch.store(0, SeqCst);
    }
}

impl EpochGc {
    /// Domain for `workers` pin slots, reclamation on.
    pub fn new(workers: usize) -> Self {
        Self::with_reclaim(workers, true)
    }

    /// Domain with reclamation optionally disabled: retires still
    /// accumulate (and count), nothing is freed before drop — the
    /// leaky A/B baseline.
    pub fn with_reclaim(workers: usize, enabled: bool) -> Self {
        Self {
            global: AtomicU64::new(1),
            slots: (0..workers.max(1))
                .map(|_| Slot {
                    epoch: AtomicU64::new(0),
                    _pad: [0; 7],
                })
                .collect(),
            reader_slots: (0..READER_SLOTS)
                .map(|_| Slot {
                    epoch: AtomicU64::new(0),
                    _pad: [0; 7],
                })
                .collect(),
            limbo: Mutex::new(VecDeque::new()),
            enabled,
            retired_cells: AtomicU64::new(0),
            retired_bytes: AtomicU64::new(0),
            reclaimed_cells: AtomicU64::new(0),
            reclaimed_bytes: AtomicU64::new(0),
            live_peak_cells: AtomicU64::new(0),
            arena_peak_bytes: AtomicU64::new(0),
        }
    }

    /// Is this domain actually freeing, or only counting?
    pub fn reclaim_enabled(&self) -> bool {
        self.enabled
    }

    /// The current global epoch.
    pub fn epoch(&self) -> u64 {
        self.global.load(SeqCst)
    }

    /// Pin `worker`'s slot to the current epoch. Must be held across
    /// any dereference of a pointer another thread may retire. The
    /// publish-then-recheck loop closes the classic race: if the
    /// global advances between our read and our slot store, the slot
    /// would under-report — so re-pin at the newer epoch.
    pub fn pin(&self, worker: usize) -> EpochGuard<'_> {
        let slot = &self.slots[worker % self.slots.len()];
        loop {
            let e = self.global.load(SeqCst);
            slot.epoch.store(e, SeqCst);
            if self.global.load(SeqCst) == e {
                return EpochGuard { slot };
            }
        }
    }

    /// Pin a transient reader slot to the current epoch. For threads
    /// outside the worker pool (snapshot queries) that need to
    /// traverse store pointers another thread may concurrently
    /// retire. CAS-scans the fixed reader pool for a free slot,
    /// spinning if all are briefly busy; once a slot is owned, the
    /// same publish-then-recheck loop as [`pin`](Self::pin) closes
    /// the stale-epoch race. Hold only across one traversal — a
    /// long-held reader pin stalls reclamation of everything retired
    /// after it.
    pub fn pin_reader(&self) -> ReaderPin<'_> {
        loop {
            for slot in self.reader_slots.iter() {
                if slot.epoch.load(SeqCst) != 0 {
                    continue;
                }
                let e = self.global.load(SeqCst);
                if slot.epoch.compare_exchange(0, e, SeqCst, SeqCst).is_err() {
                    continue;
                }
                // The slot is ours now; plain stores re-publish if the
                // global moved between our read and the CAS.
                let mut cur = e;
                loop {
                    let now = self.global.load(SeqCst);
                    if now == cur {
                        return ReaderPin { slot };
                    }
                    slot.epoch.store(now, SeqCst);
                    cur = now;
                }
            }
            std::hint::spin_loop();
        }
    }

    /// Move exclusively-owned garbage into the current epoch's limbo
    /// bin. `item`'s `Drop` frees the memory; `cells`/`bytes` feed the
    /// counters. The caller must hold the *only* path to the memory
    /// (e.g. the pointer just swapped out of a publication cell).
    pub fn retire(&self, item: Box<dyn Any + Send>, cells: u64, bytes: u64) {
        let retired = self.retired_cells.fetch_add(cells, SeqCst) + cells;
        self.retired_bytes.fetch_add(bytes, SeqCst);
        let live = retired.saturating_sub(self.reclaimed_cells.load(SeqCst));
        self.live_peak_cells.fetch_max(live, SeqCst);
        let mut limbo = self.limbo.lock().unwrap();
        // Tag under the lock, clamped to the youngest bin: an epoch
        // read racing an advance may only ever land *later* than the
        // retire really happened, which is the safe direction, and it
        // keeps the deque epoch-monotone for the pop loop below.
        let epoch = self
            .global
            .load(SeqCst)
            .max(limbo.back().map_or(0, |b| b.epoch));
        match limbo.back_mut() {
            Some(bin) if bin.epoch == epoch => {
                bin.items.push(item);
                bin.cells += cells;
                bin.bytes += bytes;
            }
            _ => limbo.push_back(Bin {
                epoch,
                items: vec![item],
                cells,
                bytes,
            }),
        }
    }

    /// Advance the global epoch (the promotion boundary). Returns the
    /// new epoch.
    pub fn advance(&self) -> u64 {
        self.global.fetch_add(1, SeqCst) + 1
    }

    /// Minimum epoch any worker is pinned at; the global epoch when
    /// nobody is pinned.
    fn min_pinned(&self) -> u64 {
        let mut min = u64::MAX;
        for s in self.slots.iter().chain(self.reader_slots.iter()) {
            let e = s.epoch.load(SeqCst);
            if e != 0 && e < min {
                min = e;
            }
        }
        if min == u64::MAX {
            self.global.load(SeqCst)
        } else {
            min
        }
    }

    /// Free every limbo bin whose epoch every live worker has passed.
    /// Returns `(cells, bytes)` freed; `(0, 0)` when reclamation is
    /// disabled. Destructors run outside the limbo lock.
    pub fn try_reclaim(&self) -> (u64, u64) {
        if !self.enabled {
            return (0, 0);
        }
        let horizon = self.min_pinned();
        let mut freed: Vec<Bin> = Vec::new();
        {
            let mut limbo = self.limbo.lock().unwrap();
            while limbo.front().is_some_and(|b| b.epoch < horizon) {
                freed.push(limbo.pop_front().unwrap());
            }
        }
        let (mut cells, mut bytes) = (0u64, 0u64);
        for b in &freed {
            cells += b.cells;
            bytes += b.bytes;
        }
        if cells != 0 || bytes != 0 {
            self.reclaimed_cells.fetch_add(cells, SeqCst);
            self.reclaimed_bytes.fetch_add(bytes, SeqCst);
        }
        drop(freed);
        (cells, bytes)
    }

    /// End-of-session drain: advance past every retired bin and — with
    /// the pool joined, so nothing is pinned — reclaim it all (when
    /// enabled).
    pub fn flush(&self) -> (u64, u64) {
        self.advance();
        self.try_reclaim()
    }

    /// Drain limbo from *inside* a still-running session. Unlike
    /// [`flush`](Self::flush) this is safe to call while workers (or
    /// readers) remain pinned: it reclaims only up to the live
    /// horizon, and it advances the epoch only when there is garbage
    /// to move past — so an idle loop calling it every poll neither
    /// frees anything a pin still protects nor spins the global epoch
    /// counter. A worker that re-pins each loop iteration drains a
    /// paused stream's tail within two idle iterations: the first
    /// call advances past the youngest bin, the re-pin publishes the
    /// new epoch, and the second call's horizon passes the bin.
    pub fn quiescent_flush(&self) -> (u64, u64) {
        if !self.enabled || self.limbo.lock().unwrap().is_empty() {
            return (0, 0);
        }
        self.advance();
        self.try_reclaim()
    }

    /// Feed the arena-bytes peak (sampled per block at promotion).
    pub fn note_arena_bytes(&self, bytes: u64) {
        self.arena_peak_bytes.fetch_max(bytes, SeqCst);
    }

    /// Cells currently sitting in limbo (`retired - reclaimed`).
    pub fn live_cells(&self) -> u64 {
        self.retired_cells
            .load(SeqCst)
            .saturating_sub(self.reclaimed_cells.load(SeqCst))
    }

    /// Counter snapshot.
    pub fn counters(&self) -> GcCounters {
        GcCounters {
            retired_cells: self.retired_cells.load(SeqCst),
            retired_bytes: self.retired_bytes.load(SeqCst),
            reclaimed_cells: self.reclaimed_cells.load(SeqCst),
            reclaimed_bytes: self.reclaimed_bytes.load(SeqCst),
            live_peak_cells: self.live_peak_cells.load(SeqCst),
            arena_peak_bytes: self.arena_peak_bytes.load(SeqCst),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Drop-counting sentinel standing in for retired store memory.
    struct Sentinel(Arc<AtomicU64>);
    impl Drop for Sentinel {
        fn drop(&mut self) {
            self.0.fetch_add(1, SeqCst);
        }
    }

    fn retire_sentinel(gc: &EpochGc, drops: &Arc<AtomicU64>, cells: u64) {
        gc.retire(Box::new(Sentinel(Arc::clone(drops))), cells, cells * 8);
    }

    #[test]
    fn late_pin_blocks_reclaim_until_release() {
        let gc = EpochGc::new(2);
        let drops = Arc::new(AtomicU64::new(0));
        // A validator pins the epoch the garbage is retired under.
        let guard = gc.pin(0);
        retire_sentinel(&gc, &drops, 3);
        gc.advance();
        let (c, _) = gc.try_reclaim();
        assert_eq!(c, 0, "pinned epoch must hold its limbo bin");
        assert_eq!(drops.load(SeqCst), 0);
        assert_eq!(gc.live_cells(), 3);
        // Release: the bin's epoch is now strictly below the horizon.
        drop(guard);
        let (c, b) = gc.try_reclaim();
        assert_eq!(c, 3);
        assert_eq!(b, 24);
        assert_eq!(drops.load(SeqCst), 1, "exactly the retired set freed");
        assert_eq!(gc.live_cells(), 0);
    }

    #[test]
    fn release_frees_exactly_the_passed_epochs() {
        let gc = EpochGc::new(2);
        let drops = Arc::new(AtomicU64::new(0));
        retire_sentinel(&gc, &drops, 1); // epoch 1
        gc.advance(); // -> 2
        let guard = gc.pin(1); // pinned at 2
        retire_sentinel(&gc, &drops, 1); // epoch 2
        gc.advance(); // -> 3
        let (c, _) = gc.try_reclaim();
        assert_eq!(c, 1, "only the bin below the pinned horizon frees");
        assert_eq!(drops.load(SeqCst), 1);
        drop(guard);
        gc.advance();
        let (c, _) = gc.try_reclaim();
        assert_eq!(c, 1, "the release frees exactly the held bin");
        assert_eq!(drops.load(SeqCst), 2);
        let k = gc.counters();
        assert_eq!(k.retired_cells, 2);
        assert_eq!(k.reclaimed_cells, 2);
        assert!(k.live_peak_cells >= 1);
    }

    #[test]
    fn disabled_domain_counts_but_never_frees_before_drop() {
        let drops = Arc::new(AtomicU64::new(0));
        {
            let gc = EpochGc::with_reclaim(1, false);
            assert!(!gc.reclaim_enabled());
            retire_sentinel(&gc, &drops, 5);
            gc.advance();
            assert_eq!(gc.try_reclaim(), (0, 0));
            assert_eq!(gc.flush(), (0, 0));
            assert_eq!(drops.load(SeqCst), 0, "leaky baseline holds garbage");
            assert_eq!(gc.live_cells(), 5);
            assert_eq!(gc.counters().reclaimed_cells, 0);
        }
        // Dropping the domain still frees (Rust ownership), it just
        // never counts as reclaimed.
        assert_eq!(drops.load(SeqCst), 1);
    }

    #[test]
    fn flush_drains_everything_once_unpinned() {
        let gc = EpochGc::new(4);
        let drops = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            retire_sentinel(&gc, &drops, 2);
            gc.advance();
        }
        gc.flush();
        assert_eq!(drops.load(SeqCst), 10);
        let k = gc.counters();
        assert_eq!(k.retired_cells, 20);
        assert_eq!(k.reclaimed_cells, 20);
        assert_eq!(gc.live_cells(), 0);
        assert!(k.live_peak_cells <= 20);
    }

    #[test]
    fn quiescent_flush_drains_a_session_that_never_joins() {
        // The latent drain bug: `flush()` assumes the pool joins, but
        // a serving session can idle forever with workers re-pinning
        // each loop iteration and no promotion advancing the epoch.
        let gc = EpochGc::new(2);
        let drops = Arc::new(AtomicU64::new(0));
        {
            // Iteration 1: worker pinned at the retire epoch — the
            // first quiescent flush advances but must hold the bin.
            let _g = gc.pin(0);
            retire_sentinel(&gc, &drops, 4);
            assert_eq!(gc.quiescent_flush(), (0, 0));
            assert_eq!(drops.load(SeqCst), 0, "own pin still guards the bin");
        }
        // Iteration 2: the worker re-pins at the advanced epoch; the
        // bin's tag is now strictly below the horizon and drains.
        let _g = gc.pin(0);
        let (c, b) = gc.quiescent_flush();
        assert_eq!((c, b), (4, 32));
        assert_eq!(drops.load(SeqCst), 1);
        assert_eq!(gc.live_cells(), 0);
        // Empty limbo: no advance, so an idle loop cannot spin the
        // epoch counter by polling.
        let e = gc.epoch();
        assert_eq!(gc.quiescent_flush(), (0, 0));
        assert_eq!(gc.epoch(), e, "empty-limbo flush must not advance");
    }

    #[test]
    fn quiescent_flush_disabled_domain_is_inert() {
        let gc = EpochGc::with_reclaim(1, false);
        let drops = Arc::new(AtomicU64::new(0));
        retire_sentinel(&gc, &drops, 2);
        let e = gc.epoch();
        assert_eq!(gc.quiescent_flush(), (0, 0));
        assert_eq!(gc.epoch(), e);
        assert_eq!(drops.load(SeqCst), 0);
    }

    #[test]
    fn reader_pin_holds_its_horizon_like_a_worker_pin() {
        let gc = EpochGc::new(1);
        let drops = Arc::new(AtomicU64::new(0));
        retire_sentinel(&gc, &drops, 1); // bin tagged epoch 1
        gc.advance(); // -> 2
        let pin = gc.pin_reader(); // reader pinned at 2
        retire_sentinel(&gc, &drops, 1); // bin tagged epoch 2
        gc.advance(); // -> 3
        let (c, _) = gc.try_reclaim();
        assert_eq!(c, 1, "pre-pin garbage reclaims under a live reader");
        assert_eq!(drops.load(SeqCst), 1);
        assert_eq!(gc.live_cells(), 1, "the reader's epoch is held");
        drop(pin);
        let (c, _) = gc.try_reclaim();
        assert_eq!(c, 1, "release frees exactly the held bin");
        assert_eq!(drops.load(SeqCst), 2);
    }

    #[test]
    fn reader_pins_acquire_distinct_slots_and_all_count() {
        let gc = EpochGc::new(1);
        let drops = Arc::new(AtomicU64::new(0));
        // Eight simultaneous readers must each own a distinct slot.
        let last = gc.pin_reader();
        let pins: Vec<_> = (0..7).map(|_| gc.pin_reader()).collect();
        retire_sentinel(&gc, &drops, 1);
        gc.advance();
        assert_eq!(gc.try_reclaim().0, 0, "any live reader holds the bin");
        // Dropping all but one keeps the horizon held.
        for p in pins {
            drop(p);
            assert_eq!(gc.try_reclaim().0, 0);
        }
        // Slot churn through the freed slots must not free anything
        // early while `last` still pins the retire epoch.
        drop(gc.pin_reader());
        assert_eq!(gc.try_reclaim().0, 0);
        assert_eq!(gc.counters().reclaimed_cells, 0);
        drop(last);
        assert_eq!(gc.try_reclaim().0, 1, "last reader out frees the bin");
        assert_eq!(drops.load(SeqCst), 1);
    }

    #[test]
    fn concurrent_reader_pins_never_lose_a_retire() {
        // Readers cycling through the CAS pool race retires+advances;
        // every sentinel must be freed exactly once by the end.
        let gc = Arc::new(EpochGc::new(2));
        let drops = Arc::new(AtomicU64::new(0));
        const N: u64 = 200;
        std::thread::scope(|s| {
            for _ in 0..3 {
                let gc = Arc::clone(&gc);
                s.spawn(move || {
                    for _ in 0..N {
                        let _p = gc.pin_reader();
                        std::hint::spin_loop();
                    }
                });
            }
            for _ in 0..N {
                retire_sentinel(&gc, &drops, 1);
                gc.advance();
                gc.try_reclaim();
            }
        });
        gc.flush();
        assert_eq!(drops.load(SeqCst), N, "every retire freed exactly once");
        assert_eq!(gc.counters().reclaimed_cells, N);
    }

    #[test]
    fn pin_republishes_when_the_global_moves() {
        // Concurrency smoke: retires + advances race pins; every
        // sentinel must be freed exactly once by the end.
        let gc = Arc::new(EpochGc::new(3));
        let drops = Arc::new(AtomicU64::new(0));
        const N: u64 = 200;
        std::thread::scope(|s| {
            for w in 0..2usize {
                let gc = Arc::clone(&gc);
                s.spawn(move || {
                    for _ in 0..N {
                        let _g = gc.pin(w);
                        std::hint::spin_loop();
                    }
                });
            }
            for _ in 0..N {
                retire_sentinel(&gc, &drops, 1);
                gc.advance();
                gc.try_reclaim();
            }
        });
        gc.flush();
        assert_eq!(drops.load(SeqCst), N, "every retire freed exactly once");
        assert_eq!(gc.counters().reclaimed_cells, N);
    }
}
