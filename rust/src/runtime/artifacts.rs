//! Loading and executing the `rmat` / `classify` HLO artifacts.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::graph::EdgeTuple;
use crate::util::json;

/// Static shapes the artifacts were lowered with (from manifest.json).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// Edges per `edge_batch` execution (u32[batch] outputs).
    pub batch: usize,
    /// R-MAT bit-planes compiled into the kernel (max graph scale).
    pub levels: usize,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let batch = json::scrape_u64(text, "batch")
            .ok_or_else(|| anyhow!("manifest missing 'batch'"))? as usize;
        let levels = json::scrape_u64(text, "levels")
            .ok_or_else(|| anyhow!("manifest missing 'levels'"))? as usize;
        Ok(Self { batch, levels })
    }
}

/// The compiled artifacts, ready to execute on the PJRT CPU client.
pub struct ArtifactRuntime {
    #[allow(dead_code)] // owns the device state the executables run on
    client: xla::PjRtClient,
    rmat: xla::PjRtLoadedExecutable,
    classify: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
}

impl ArtifactRuntime {
    /// Default artifact directory: `$REPO/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("DYADHYTM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Are artifacts present (cheap check before paying PJRT startup)?
    pub fn available(dir: &Path) -> bool {
        dir.join("manifest.json").exists()
            && dir.join("rmat.hlo.txt").exists()
            && dir.join("classify.hlo.txt").exists()
    }

    /// Load + compile both artifacts.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let manifest = Manifest::parse(&manifest_text)?;

        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let load = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(format!("{name}.hlo.txt"));
            // HLO TEXT is the interchange format: jax >= 0.5 emits
            // 64-bit-id protos this XLA rejects; the text parser
            // reassigns ids (see aot.py and /opt/xla-example/README.md).
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))
        };
        let rmat = load("rmat")?;
        let classify = load("classify")?;
        Ok(Self {
            client,
            rmat,
            classify,
            manifest,
        })
    }

    /// Execute one `edge_batch`: threefry key + scale + max weight →
    /// `manifest.batch` edge tuples.
    pub fn edge_batch(&self, key: (u32, u32), scale: u32, maxw: u32) -> Result<Vec<EdgeTuple>> {
        if scale as usize > self.manifest.levels {
            bail!(
                "scale {scale} exceeds compiled LEVELS {}",
                self.manifest.levels
            );
        }
        let key_lit = xla::Literal::vec1(&[key.0, key.1]);
        let scale_lit = xla::Literal::vec1(&[scale as f32]);
        let maxw_lit = xla::Literal::vec1(&[maxw as f32]);
        let result = self
            .rmat
            .execute::<xla::Literal>(&[key_lit, scale_lit, maxw_lit])?[0][0]
            .to_literal_sync()?;
        let (src, dst, w) = result.to_tuple3()?;
        let src = src.to_vec::<u32>()?;
        let dst = dst.to_vec::<u32>()?;
        let w = w.to_vec::<u32>()?;
        if src.len() != self.manifest.batch {
            bail!("batch mismatch: got {}, manifest {}", src.len(), self.manifest.batch);
        }
        Ok(src
            .into_iter()
            .zip(dst)
            .zip(w)
            .map(|((src, dst), weight)| EdgeTuple { src, dst, weight })
            .collect())
    }

    /// Execute `classify`: weights (padded to batch) + cutoff →
    /// (tile maxima, membership mask).
    pub fn classify(&self, weights: &[u32], cutoff: u32) -> Result<(Vec<u32>, Vec<u32>)> {
        let b = self.manifest.batch;
        if weights.len() != b {
            bail!("classify expects exactly {b} weights, got {}", weights.len());
        }
        let w_lit = xla::Literal::vec1(weights);
        let c_lit = xla::Literal::vec1(&[cutoff]);
        let result = self
            .classify
            .execute::<xla::Literal>(&[w_lit, c_lit])?[0][0]
            .to_literal_sync()?;
        let (tile_max, mask) = result.to_tuple2()?;
        Ok((tile_max.to_vec::<u32>()?, mask.to_vec::<u32>()?))
    }

    /// Produce a full SSCA-2 tuple list by repeated `edge_batch` calls
    /// (trailing surplus of the last batch is dropped).
    pub fn generate_tuples(
        &self,
        seed: u64,
        scale: u32,
        edge_factor: u32,
    ) -> Result<Vec<EdgeTuple>> {
        let m = (1usize << scale) * edge_factor as usize;
        let mut out = Vec::with_capacity(m);
        let maxw = 1u32 << scale;
        let mut batch_idx = 0u32;
        while out.len() < m {
            let key = (seed as u32 ^ batch_idx, (seed >> 32) as u32 ^ 0x9E37);
            let tuples = self.edge_batch(key, scale, maxw)?;
            let take = tuples.len().min(m - out.len());
            out.extend_from_slice(&tuples[..take]);
            batch_idx += 1;
        }
        Ok(out)
    }

    /// Global max weight over an arbitrary-length weight slice, chunked
    /// through the classify artifact (pass 1 of the computation kernel's
    /// runtime path). Short tails are padded with zeros.
    pub fn max_weight(&self, weights: &[u32]) -> Result<u32> {
        let b = self.manifest.batch;
        let mut gmax = 0u32;
        for chunk in weights.chunks(b) {
            let padded;
            let full = if chunk.len() == b {
                chunk
            } else {
                padded = {
                    let mut v = chunk.to_vec();
                    v.resize(b, 0);
                    v
                };
                &padded
            };
            let (tile_max, _) = self.classify(full, 0)?;
            gmax = gmax.max(tile_max.into_iter().max().unwrap_or(0));
        }
        Ok(gmax)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(r#"{"batch": 65536, "levels": 24}"#).unwrap();
        assert_eq!(m.batch, 65536);
        assert_eq!(m.levels, 24);
        assert!(Manifest::parse("{}").is_err());
    }

    // PJRT-backed tests live in rust/tests/runtime_integration.rs and
    // skip gracefully when artifacts are absent; unit scope here stays
    // PJRT-free so `cargo test --lib` works before `make artifacts`.
}
