//! Request-path runtime: the PJRT artifact executor, the streaming
//! pipeline, and the shared worker runtime.
//!
//! * [`artifacts`] — PJRT artifact runtime (DESIGN.md S13): `make
//!   artifacts` runs `python -m compile.aot` ONCE at build time; the
//!   HLO-text files it drops in `artifacts/` are compiled here with
//!   the PJRT CPU client and executed with concrete inputs. Python
//!   never runs at serve time — the binary is self-contained after
//!   artifacts exist.
//! * [`pipeline`] — the streaming generation pipeline (producer +
//!   bounded channel + consumers).
//! * [`workers`] — the shared worker runtime every execution loop in
//!   the crate spawns through: pinned pool, work-stealing deques,
//!   stealing parallel-for.

pub mod artifacts;
pub mod pipeline;
pub mod workers;

pub use artifacts::{ArtifactRuntime, Manifest};
pub use pipeline::{PipelineConfig, PipelineReport, TupleSource};
pub use workers::{PoolConfig, PoolStats};
