//! PJRT artifact runtime (DESIGN.md S13): load the AOT-compiled Layer-2
//! computations and execute them from the Rust request path.
//!
//! `make artifacts` runs `python -m compile.aot` ONCE at build time; the
//! HLO-text files it drops in `artifacts/` are compiled here with the
//! PJRT CPU client and executed with concrete inputs. Python never runs
//! at serve time — the binary is self-contained after artifacts exist.

pub mod artifacts;
pub mod pipeline;

pub use artifacts::{ArtifactRuntime, Manifest};
pub use pipeline::{PipelineConfig, PipelineReport, TupleSource};
